"""Runtime lock-order witness -- lockdep-lite (ISSUE 10 tentpole, part 3).

Each thread keeps a stack of held lock acquisitions. On every *blocking*
acquire the stack is checked against the declared ranks in
:mod:`.lock_order`:

  * an anti-edge hit or a held lock of rank >= the acquired rank raises
    :class:`~repro.analysis.lock_order.LockOrderViolation` (inversion);
  * same-class nesting of a ``multi`` class is allowed but recorded as
    an *instance* edge; closing a cycle in the global instance-edge
    graph (typically across threads: T1 took A then B, T2 takes B then
    A) raises at the acquire that would complete the cycle;
  * ``req.mp_mutex`` under ``req.mp_mutex`` is allowed iff the thread
    holds the target req's rwlock *write grant* (the PR 3 bailout gate);
  * trylock acquires are never flagged but join the held stack.

Every acquisition also records a class-level edge (held-top -> acquired,
tagged ok/gated/trylock) into a global graph; :func:`dump_graph` emits it
as JSON -- CI uploads this as the observed lock-edge artifact.

Violations both raise *and* latch into a global list: scheduler workers
may swallow task exceptions, so the lockdep CI lane asserts the latch is
empty after every test (see tests/conftest.py).
"""
from __future__ import annotations

import json
import sys
import threading
from typing import Dict, List, Optional, Set, Tuple

from .lock_order import ANTI_EDGES, LOCK_CLASSES, LockClass, LockOrderViolation

RWLOCK_CLASS = LOCK_CLASSES["req.rwlock"]

_tls = threading.local()

# global state below is guarded by _glock (a raw lock: the witness's own
# bookkeeping is outside the checked universe, by construction)
_glock = threading.Lock()
_class_edges: Dict[Tuple[str, str, str], int] = {}   # (src, dst, tag) -> count
_iedges: Dict[int, Set[int]] = {}                    # instance id -> successors
_ilabel: Dict[int, str] = {}                         # instance id -> label
violations: List[str] = []


class _Held:
    __slots__ = ("cls", "rank", "group", "trylock", "write", "iid", "site")

    def __init__(self, cls: str, rank: int, group: object, trylock: bool,
                 write: bool, iid: int, site: str) -> None:
        self.cls = cls
        self.rank = rank
        self.group = group
        self.trylock = trylock
        self.write = write
        self.iid = iid
        self.site = site


def _stack() -> List[_Held]:
    held = getattr(_tls, "held", None)
    if held is None:
        held = _tls.held = []
    return held


def _site(depth: int) -> str:
    try:
        f = sys._getframe(depth)
        return f"{f.f_code.co_filename}:{f.f_lineno}"
    except ValueError:  # pragma: no cover - shallow stack
        return "?"


def _violate(msg: str) -> None:
    with _glock:
        violations.append(msg)
    raise LockOrderViolation(msg)


def _holds_write_grant(held: List[_Held], group: object) -> bool:
    for h in held:
        if h.cls == "req.rwlock" and h.write and h.group == group:
            return True
    return False


def _record_edge(src: Optional[_Held], dst_cls: str, tag: str) -> None:
    key = (src.cls if src is not None else "<none>", dst_cls, tag)
    with _glock:
        _class_edges[key] = _class_edges.get(key, 0) + 1


def _record_instance_edge(src: _Held, dst_iid: int, dst_label: str,
                          site: str) -> None:
    """Add src -> dst to the instance graph; raise if it closes a cycle."""
    with _glock:
        _ilabel.setdefault(src.iid, f"{src.cls}@{src.site}")
        _ilabel.setdefault(dst_iid, dst_label)
        succ = _iedges.setdefault(src.iid, set())
        if dst_iid in succ:
            return
        # would dst -> ... -> src close a cycle?
        seen: Set[int] = set()
        frontier = [dst_iid]
        while frontier:
            n = frontier.pop()
            if n == src.iid:
                path = (f"{_ilabel.get(dst_iid, dst_iid)} ..-> "
                        f"{_ilabel.get(src.iid, src.iid)}")
                msg = (f"lock-order cycle: acquiring {dst_label} at {site} "
                       f"while holding {_ilabel[src.iid]} closes {path} "
                       f"(edge observed on another acquisition order)")
                violations.append(msg)
                raise LockOrderViolation(msg)
            if n in seen:
                continue
            seen.add(n)
            frontier.extend(_iedges.get(n, ()))
        succ.add(dst_iid)


def check_and_push(cls: LockClass, group: object, iid: int,
                   trylock: bool = False, write: bool = False,
                   depth: int = 3) -> None:
    """Order-check an acquisition against the thread's held stack, then
    push it. ``depth`` locates the caller's frame for the site label."""
    held = _stack()
    site = _site(depth)
    label = f"{cls.name}@{site}"
    tag = "trylock" if trylock else "ok"
    top = held[-1] if held else None
    if not trylock:
        for h in held:
            anti = ANTI_EDGES.get((h.cls, cls.name))
            if anti is not None:
                _violate(
                    f"anti-edge {h.cls} -> {cls.name}: acquiring "
                    f"{label} while holding {h.cls}@{h.site} -- {anti}")
            if h.rank > cls.rank:
                _violate(
                    f"rank inversion: acquiring {label} (rank {cls.rank}) "
                    f"while holding {h.cls}@{h.site} (rank {h.rank}); "
                    "blocking acquisitions must strictly ascend in rank")
            if h.rank == cls.rank:
                if (cls.name == "req.mp_mutex"
                        and _holds_write_grant(held, group)):
                    tag = "gated"  # PR 3 bailout: write grant held
                elif cls.multi:
                    _record_instance_edge(h, iid, label, site)
                else:
                    _violate(
                        f"same-rank nesting: acquiring {label} while "
                        f"holding {h.cls}@{h.site} (both rank {cls.rank}); "
                        "only 'multi' classes and write-grant-gated req "
                        "mutexes may nest at one rank")
    _record_edge(top, cls.name, tag)
    held.append(_Held(cls.name, cls.rank, group, trylock, write, iid, site))


def pop(iid: int) -> None:
    """Remove the most recent held entry for instance ``iid`` (locks are
    not always released LIFO -- e.g. the quiesce mutex bounce)."""
    held = getattr(_tls, "held", None)
    if not held:
        return
    for i in range(len(held) - 1, -1, -1):
        if held[i].iid == iid:
            del held[i]
            return


class WitnessLock:
    """Instrumented ``threading.Lock`` returned by ``named_lock`` when the
    witness is on. Implements ``_is_owned`` so ``threading.Condition``
    delegates ownership checks instead of probing with a trylock (which
    would perturb the held stack); ``Condition.wait`` releases and
    reacquires through :meth:`release`/:meth:`acquire`, so the held stack
    stays accurate across waits."""

    __slots__ = ("_lock", "cls", "group", "_owner")

    def __init__(self, cls: LockClass, group: object = None) -> None:
        self._lock = threading.Lock()
        self.cls = cls
        self.group = group
        self._owner: Optional[int] = None

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if blocking:
            # check BEFORE blocking: the point is to report the deadlock
            # instead of hanging in it
            check_and_push(self.cls, self.group, id(self), trylock=False)
            got = self._lock.acquire(True, timeout)
            if not got:  # timeout: undo the push
                pop(id(self))
                return False
        else:
            got = self._lock.acquire(False)
            if not got:
                return False
            check_and_push(self.cls, self.group, id(self), trylock=True)
        self._owner = threading.get_ident()
        return True

    def release(self) -> None:
        self._owner = None
        pop(id(self))
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def _is_owned(self) -> bool:
        return self._owner == threading.get_ident()

    __enter__ = acquire

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<WitnessLock {self.cls.name} group={self.group!r}>"


# ------------------------------------------------------- virtual entities
def push_virtual(cls: LockClass, group: object, iid: int,
                 write: bool = False, trylock: bool = False) -> None:
    """Track a virtual lock entity (the rwlock grant) on the held stack.
    Called from req.py's hooks, which fire OUTSIDE the rwlock's internal
    condition lock so no false cond -> rwlock edge is recorded."""
    check_and_push(cls, group, iid, trylock=trylock, write=write, depth=4)


def pop_virtual(iid: int) -> None:
    pop(iid)


def held_classes() -> List[str]:
    """The calling thread's held stack, outermost first (for tests)."""
    return [h.cls for h in _stack()]


# ------------------------------------------------------------ global state
def clear_violations() -> List[str]:
    """Drain the latched violation list (tests that provoke violations on
    purpose call this in their cleanup). Preserves the edge graphs."""
    with _glock:
        drained = violations[:]
        del violations[:]
    return drained


def reset() -> None:
    """Full reset: latched violations AND both edge graphs."""
    with _glock:
        del violations[:]
        _class_edges.clear()
        _iedges.clear()
        _ilabel.clear()


def dump_graph() -> dict:
    """The observed class-level edge graph + any latched violations, in a
    JSON-serializable shape (the CI lock-edge artifact)."""
    with _glock:
        edges = [
            {"src": s, "dst": d, "tag": t, "count": n}
            for (s, d, t), n in sorted(_class_edges.items())
        ]
        return {"edges": edges, "violations": violations[:]}


def dump_graph_to(path: str) -> None:
    with open(path, "w") as fh:
        json.dump(dump_graph(), fh, indent=2, sort_keys=True)
