"""Static + runtime concurrency discipline for the swap path (ISSUE 10).

Three layers, one source of truth:

  * :mod:`.lock_order` -- the declared lock hierarchy. Every lock class in
    the system has a name and a rank here; ``named_lock`` is the zero-cost
    construction wrapper the rest of the tree uses.
  * :mod:`.lint` -- AST static lint (``python -m repro.analysis.lint src/``)
    that flags rank violations visible lexically, blocking calls under the
    MP mutex, bare ``threading.Lock()`` construction outside the registry,
    and deprecated ``TaijiSystem.read/write/ms_addr`` shim calls.
  * :mod:`.witness` -- the runtime lock-order witness (lockdep-lite).
    ``TAIJI_LOCKDEP=1`` makes ``named_lock`` return instrumented locks that
    record per-thread acquisition stacks, build the observed rank-edge
    graph, and raise on inversion or cross-thread cycle formation.
"""
from .lock_order import (  # noqa: F401
    ANTI_EDGES,
    LOCK_CLASSES,
    LockOrderViolation,
    STATE,
    disable,
    enable,
    named_lock,
)
