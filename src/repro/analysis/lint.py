"""AST concurrency lint (ISSUE 10 tentpole, part 2).

Run as::

    python -m repro.analysis.lint src/

Walks every ``*.py`` under the given paths and reports, with
``path:line:col CODE`` findings:

  * **TJL001** -- a lock acquisition whose *lexical* ``with``-stack (or
    ``.acquire()``/``.release()`` bracket) violates the declared ranks in
    :mod:`.lock_order`, including the declared anti-edges.
  * **TJL002** -- a known-blocking call (``time.sleep``,
    ``zlib.compress``/``decompress``, a foreign condvar ``.wait``) inside
    a lexical scope holding a ``NO_BLOCKING_UNDER`` class (the MP mutex:
    the fault fast path's latency budget).
  * **TJL003** -- bare ``threading.Lock()``/``RLock``/``Semaphore`` (or
    zero-arg ``Condition()``) construction outside the registry: every
    lock must be built via ``named_lock`` so it carries a declared class.
  * **TJL004** -- calls to the deprecated ``TaijiSystem.read/write/
    ms_addr`` shims (PR 5 moved everything to ``GuestSpace``).

Lock expressions are resolved through ``LINT_BINDINGS`` (attribute name,
scoped by enclosing class), simple local aliases (``lock =
req.mp_mutex``), and an explicit trailing pragma comment on the line::

    with reqs._lock:   # lock: req.tree

Unresolvable expressions are skipped -- cross-function nesting is the
runtime witness's job; the lint never guesses.
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .lock_order import (
    ANTI_EDGES,
    BLOCKING_CALLS,
    LINT_BINDINGS,
    LOCK_CLASSES,
    NO_BLOCKING_UNDER,
    RANK,
)

# the registry implementation itself constructs the raw locks
_REGISTRY_FILES = ("lock_order.py", "witness.py")
_BARE_CTORS = ("Lock", "RLock", "Semaphore", "BoundedSemaphore")
_DEPRECATED_SHIMS = ("read", "write")


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.code} {self.message}"


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c" for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: str, source: str) -> None:
        self.path = path
        self.lines = source.splitlines()
        self.findings: List[Finding] = []
        self._class_stack: List[str] = []
        # lexical held stack: (lock class, receiver base name) -- the
        # base distinguishes `req.mp_cond` from `other.mp_cond` for the
        # same-cond wait exemption
        self._held: List[Tuple[str, Optional[str]]] = []
        self._aliases: Dict[str, str] = {}  # local name -> lock class
        self._in_analysis_pkg = any(
            path.replace("\\", "/").endswith("repro/analysis/" + f)
            for f in _REGISTRY_FILES)

    # ------------------------------------------------------------- helpers
    def _emit(self, node: ast.AST, code: str, message: str) -> None:
        self.findings.append(Finding(
            self.path, node.lineno, node.col_offset, code, message))

    def _pragma_class(self, node: ast.AST) -> Optional[str]:
        line = self.lines[node.lineno - 1] if node.lineno <= len(self.lines) \
            else ""
        marker = "# lock:"
        i = line.find(marker)
        if i < 0:
            return None
        name = line[i + len(marker):].strip().split()[0]
        return name if name in LOCK_CLASSES else None

    def _resolve(self, expr: ast.AST) -> Optional[str]:
        """Map a lock expression to a declared class name, or None."""
        if isinstance(expr, ast.Subscript):
            return self._resolve(expr.value)
        if isinstance(expr, ast.Name):
            return self._aliases.get(expr.id)
        if isinstance(expr, ast.Attribute):
            attr = expr.attr
            if (isinstance(expr.value, ast.Name)
                    and expr.value.id == "self" and self._class_stack):
                cls = LINT_BINDINGS.get((self._class_stack[-1], attr))
                if cls is not None:
                    return cls
            return LINT_BINDINGS.get((None, attr))
        return None

    def _check_acquire(self, node: ast.AST, cls: str) -> None:
        """TJL001: rank/anti-edge check against the lexical held stack."""
        for held, _base in self._held:
            anti = ANTI_EDGES.get((held, cls))
            if anti is not None:
                self._emit(node, "TJL001",
                           f"anti-edge: acquiring '{cls}' while holding "
                           f"'{held}' -- {anti}")
                return
            if RANK[held] > RANK[cls]:
                self._emit(node, "TJL001",
                           f"rank inversion: acquiring '{cls}' (rank "
                           f"{RANK[cls]}) while holding '{held}' (rank "
                           f"{RANK[held]})")
                return
            if RANK[held] == RANK[cls] and not LOCK_CLASSES[cls].multi:
                self._emit(node, "TJL001",
                           f"same-rank nesting: acquiring '{cls}' while "
                           f"holding '{held}' (both rank {RANK[cls]}); "
                           "only the runtime witness can prove this safe "
                           "(write-grant gate)")
                return

    # -------------------------------------------------------- scope plumbing
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_function(self, node) -> None:
        held, aliases = self._held, self._aliases
        self._held, self._aliases = [], {}
        self.generic_visit(node)
        self._held, self._aliases = held, aliases

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_Assign(self, node: ast.Assign) -> None:
        # simple alias:  lock = req.mp_mutex
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            cls = (self._pragma_class(node)
                   or (self._resolve(node.value)
                       if isinstance(node.value,
                                     (ast.Attribute, ast.Subscript, ast.Name))
                       else None))
            if cls is not None:
                self._aliases[node.targets[0].id] = cls
        self.generic_visit(node)

    # ------------------------------------------------------------ with-stack
    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        pragma = self._pragma_class(node)
        for item in node.items:
            cls = pragma or self._resolve(item.context_expr)
            if cls is None:
                continue
            self._check_acquire(item.context_expr, cls)
            self._held.append((cls, self._base_of(item.context_expr)))
            pushed += 1
        for child in node.body:
            self.visit(child)
        del self._held[len(self._held) - pushed:]

    visit_AsyncWith = visit_With

    # ----------------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            attr = func.attr
            recv = func.value
            if attr in ("acquire", "__enter__"):
                cls = self._resolve(recv)
                if cls is not None:
                    if self._blocking_args(node):
                        self._check_acquire(node, cls)
                    self._held.append((cls, self._base_of(recv)))
            elif attr in ("release", "__exit__"):
                cls = self._resolve(recv)
                if cls is not None:
                    self._pop_held(cls)
            elif attr in ("acquire_read", "acquire_write"):
                cls = self._resolve(recv)
                if cls == "req.rwlock":
                    if self._blocking_args(node):
                        self._check_acquire(node, cls)
                    self._held.append((cls, self._base_of(recv)))
            elif attr in ("release_read", "release_write"):
                cls = self._resolve(recv)
                if cls == "req.rwlock":
                    self._pop_held(cls)
            elif attr == "wait":
                self._check_wait(node, recv)
            elif attr == "ms_addr":
                self._emit(node, "TJL004",
                           "deprecated TaijiSystem.ms_addr shim; use "
                           "GuestSpace.addr_of / gfn-relative APIs")
            elif attr in _DEPRECATED_SHIMS and self._system_receiver(recv):
                self._emit(node, "TJL004",
                           f"deprecated TaijiSystem.{attr} shim; use "
                           f"GuestSpace.{attr}(gfn, ..., off=...)")
        self._check_blocking_call(node)
        self._check_bare_ctor(node)
        self.generic_visit(node)

    @staticmethod
    def _blocking_args(node: ast.Call) -> bool:
        for kw in node.keywords:
            if kw.arg == "blocking" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
        if node.args and isinstance(node.args[0], ast.Constant):
            return bool(node.args[0].value)
        return True

    @staticmethod
    def _system_receiver(recv: ast.AST) -> bool:
        if isinstance(recv, ast.Name):
            return recv.id == "system"
        return isinstance(recv, ast.Attribute) and recv.attr == "system"

    def _no_blocking_scope(self) -> Optional[str]:
        for held, _base in self._held:
            if held in NO_BLOCKING_UNDER:
                return held
        return None

    @staticmethod
    def _base_of(expr: ast.AST) -> Optional[str]:
        if isinstance(expr, ast.Subscript):
            expr = expr.value
        name = _dotted(expr)
        return name.split(".", 1)[0] if name else None

    def _pop_held(self, cls: str) -> None:
        for i in range(len(self._held) - 1, -1, -1):
            if self._held[i][0] == cls:
                del self._held[i]
                return

    def _check_wait(self, node: ast.Call, recv: ast.AST) -> None:
        scope = self._no_blocking_scope()
        if scope is None:
            return
        cls = self._resolve(recv)
        if cls is None:
            return  # unknown receiver: never guess
        base = self._base_of(recv)
        if any(h == cls and b == base for h, b in self._held):
            # the cond of a held lock: wait releases it (the Fig 8
            # (3.3) same-MP wait)
            return
        self._emit(node, "TJL002",
                   f"condvar wait on '{cls}' inside a '{scope}' scope "
                   "(blocks the fault path's mutex)")

    def _check_blocking_call(self, node: ast.Call) -> None:
        scope = self._no_blocking_scope()
        if scope is None:
            return
        name = _dotted(node.func)
        if name in BLOCKING_CALLS:
            self._emit(node, "TJL002",
                       f"blocking call {name}() inside a '{scope}' scope "
                       "(the MP mutex bounds the fault path's tail "
                       "latency)")

    def _check_bare_ctor(self, node: ast.Call) -> None:
        if self._in_analysis_pkg:
            return
        name = _dotted(node.func)
        if name is None or not name.startswith("threading."):
            return
        ctor = name.split(".", 1)[1]
        if ctor in _BARE_CTORS or (ctor == "Condition" and not node.args):
            self._emit(node, "TJL003",
                       f"bare {name}() construction; build locks via "
                       "repro.analysis.lock_order.named_lock so they "
                       "carry a declared class/rank")


# ------------------------------------------------------------------ driver
def lint_file(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 0, exc.offset or 0, "TJL000",
                        f"syntax error: {exc.msg}")]
    linter = _FileLinter(path, source)
    linter.visit(tree)
    return linter.findings


def lint_paths(paths) -> List[Finding]:
    import os
    findings: List[Finding] = []
    for root in paths:
        if os.path.isfile(root):
            findings.extend(lint_file(root))
            continue
        for dirpath, _dirnames, filenames in os.walk(root):
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fn)))
    findings.sort(key=lambda f: (f.path, f.line, f.col))
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.analysis.lint <path> [path ...]",
              file=sys.stderr)
        return 2
    findings = lint_paths(argv)
    for f in findings:
        print(f)
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
