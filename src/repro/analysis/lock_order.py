"""The declared lock hierarchy (ISSUE 10 tentpole, part 1).

One registry naming every lock class in the system, with a total rank
order. The rule the witness and the lint both enforce:

    a blocking acquisition must be of strictly greater rank than every
    lock the thread already holds.

Trylock acquisitions (``blocking=False``) are exempt from the order check
-- a trylock cannot deadlock -- but the lock still joins the held stack
so everything acquired *under* it is checked. Same-rank nesting is only
legal for

  * classes marked ``multi`` (independent same-purpose instances, e.g.
    per-PCPU quiesce locks) -- the witness then tracks instance-level
    edges and raises on cross-thread cycle formation instead; and
  * ``req.mp_mutex`` under ``req.mp_mutex`` when the thread holds the
    *write grant* of the second req's rwlock (the PR 3 critical-zone
    bailout: reclaim-under-fault only touches an MS it has exclusively
    trylocked, so the nesting cannot participate in a cycle).

History note: the folklore ordering from the PR 1-3 era comments was
"tree -> rwlock -> mp_mutex -> backend". The audit for this registry
showed the real invariant is the *reverse* for the tree lock: critical-
zone reclaim runs under a req's ``mp_mutex`` and calls
``ReqTree.get_or_create`` (tree lock), so ``req.tree`` ranks *above*
``req.mp_mutex`` -- and the constraint documented at
``ReqTree.quiesce_fast_faults`` ("the mutex bounce must not nest under
it") is declared below as the explicit anti-edge
``("req.tree", "req.mp_mutex")``.

This module is imported by every lock-holding module in the tree, so it
must stay stdlib-only (no ``repro`` imports at module scope).
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Optional, Tuple


class LockOrderViolation(RuntimeError):
    """A declared-rank inversion, anti-edge hit, or lock-order cycle."""


@dataclass(frozen=True)
class LockClass:
    name: str
    rank: int
    doc: str
    multi: bool = False  # independent same-class instances may nest


LOCK_CLASSES: Dict[str, LockClass] = {c.name: c for c in (
    # -- application layer: may call arbitrarily deep into the engine
    LockClass("pcpu", 3,
              "per-PCPU quiesce locks (hotswitch SMP-call stop points); "
              "held across full translated accesses", multi=True),
    LockClass("app", 5,
              "application-side stores: elastic_kv/elastic_params maps, "
              "DMA pin registry, hotswitch allocator", multi=True),
    LockClass("gfn", 8, "TaijiSystem._gfn_lock: the free-GFN pool"),
    # -- the req entity (paper Fig 8): grant before mutex
    LockClass("req.rwlock", 10,
              "per-req reader/writer grant (virtual: serializes active "
              "swap-out/prefetch vs passive fault-ins)"),
    LockClass("req.mp_mutex", 20,
              "per-req MP mutex: bitmap/state transitions, the fault "
              "fast path's only lock"),
    LockClass("req.rwlock.cond", 22,
              "RWLockWriterCancel's internal condition lock (acquired "
              "under a req mutex by the trylock bailout probe)"),
    # -- shared metadata structures
    LockClass("req.tree", 30,
              "ReqTree._lock: GFN -> req map; ranks ABOVE req.mp_mutex "
              "(critical-zone reclaim calls get_or_create under a req "
              "mutex; see the anti-edge below)"),
    LockClass("mpool", 35, "metadata slab pool (record allocation, under "
              "the tree lock in get_or_create/remove)"),
    LockClass("blocktable", 40,
              "BlockTable._lock: multi-field PTE transitions"),
    LockClass("slot", 45,
              "PhysicalMemory slot shard freelists + magazine registry "
              "(one shard lock at a time, never nested)"),
    # -- backend tiers
    LockClass("backend.shard", 50, "BackendStore per-kind/per-shard stripe"),
    LockClass("backend.ext", 52,
              "BackendStore._ext_lock: extent directory (zlib decompress "
              "IS deliberately called under it -- extent rows must not "
              "be re-read mid-consume)"),
    LockClass("backend.pool", 54, "BackendStore._pool_lock: backing pool"),
    LockClass("backend.disk", 55, "BackendStore._disk_lock: disk tier"),
    LockClass("backend.remote", 56,
              "BackendStore._remote_lock: remote-peer replica tier"),
    # -- reclaim machinery
    LockClass("lru", 60, "MultiLevelLRU._lock (probe phase is lock-free)"),
    LockClass("watermark", 62, "WatermarkPolicy._lock: reclaim hysteresis"),
    LockClass("entry", 64, "EntryOps._lock: hot-upgrade entry gate "
              "(registered fns run outside it)"),
    LockClass("sched.rq", 66, "RunQueue.lock (tasks run outside it)"),
    # -- leaves: telemetry may be recorded under anything
    LockClass("metrics", 70,
              "leaf telemetry: latency rings, timelines, span tracer, "
              "fleet trace recorder", multi=True),
)}

RANK: Dict[str, int] = {name: c.rank for name, c in LOCK_CLASSES.items()}

# Declared anti-edges: (held, acquired) pairs that are violations no
# matter what the ranks say -- each encodes a documented invariant with
# its own error message. The one below is req.py's quiesce contract:
# "the mutex bounce must not nest under [the tree lock]" (reclaim paths
# acquire the tree lock while holding a req mutex, so tree -> mp_mutex
# would close a cycle with mp_mutex -> tree).
ANTI_EDGES: Dict[Tuple[str, str], str] = {
    ("req.tree", "req.mp_mutex"):
        "req.py quiesce contract: the mp_mutex bounce must not nest under "
        "the tree lock (critical-zone reclaim takes the tree lock while "
        "holding a req mutex -- ReqTree.quiesce_fast_faults)",
}

# ---------------------------------------------------------------- lint data
# Lock classes under which *blocking* calls are forbidden (the fault
# fast path's latency budget). backend.ext is deliberately NOT here.
NO_BLOCKING_UNDER: FrozenSet[str] = frozenset({"req.mp_mutex"})

# dotted call names the lint treats as blocking
BLOCKING_CALLS: FrozenSet[str] = frozenset({
    "time.sleep", "zlib.compress", "zlib.decompress",
})

# Attribute -> lock-class bindings for the static lint, keyed by
# (enclosing class name | None, attribute name). The None key is only
# used for attribute names that are unambiguous tree-wide.
LINT_BINDINGS: Dict[Tuple[Optional[str], str], str] = {
    (None, "mp_mutex"): "req.mp_mutex",
    (None, "mp_cond"): "req.mp_mutex",       # Condition over the mutex
    (None, "rwlock"): "req.rwlock",
    (None, "_gfn_lock"): "gfn",
    (None, "_ext_lock"): "backend.ext",
    (None, "_pool_lock"): "backend.pool",
    (None, "_disk_lock"): "backend.disk",
    (None, "_remote_lock"): "backend.remote",
    (None, "_mag_registry_lock"): "slot",
    (None, "_shard_locks"): "slot",
    (None, "pcpu_locks"): "pcpu",
    ("RWLockWriterCancel", "_cond"): "req.rwlock.cond",
    ("ReqTree", "_lock"): "req.tree",
    ("Mpool", "_lock"): "mpool",
    ("BlockTable", "_lock"): "blocktable",
    ("PhysicalMemory", "_lock"): "slot",
    ("BackendStore", "_locks"): "backend.shard",
    ("MultiLevelLRU", "_lock"): "lru",
    ("WatermarkPolicy", "_lock"): "watermark",
    ("EntryOps", "_lock"): "entry",
    ("EntryOps", "_drained"): "entry",
    ("RunQueue", "lock"): "sched.rq",
    ("LatencyRing", "_lock"): "metrics",
    ("Timeline", "_lock"): "metrics",
    ("SpanTracer", "_lock"): "metrics",
    ("TraceRecorder", "_lock"): "metrics",
    ("DMARegistry", "_lock"): "app",
    ("ElasticKVCache", "_lock"): "app",
    ("ElasticExpertCache", "_lock"): "app",
    ("PlainMemorySystem", "_alloc_lock"): "app",
}


# ----------------------------------------------------------------- switch
@dataclass
class _State:
    """Witness switch. ``on`` is read with one attribute load + truthiness
    check on the instrumented paths; everything else only pays at lock
    *construction* time (``named_lock`` decides the type once)."""

    on: bool = field(default_factory=lambda: os.environ.get(
        "TAIJI_LOCKDEP", "") not in ("", "0"))


STATE = _State()


def enable() -> None:
    """Turn the witness on for locks constructed from now on."""
    STATE.on = True


def disable() -> None:
    STATE.on = False


def named_lock(cls_name: str, group: object = None):
    """Construct a lock of declared class ``cls_name``.

    With the witness off (the default) this returns a raw
    ``threading.Lock()`` -- zero overhead, not even a wrapper. With
    ``TAIJI_LOCKDEP=1`` (or :func:`enable`) it returns a
    :class:`~repro.analysis.witness.WitnessLock` that records the
    acquisition stack and enforces the declared ranks.

    ``group`` links same-entity locks for the gate exemption (a req's
    ``mp_mutex`` and its rwlock grant share the req's GFN as group).
    """
    if not STATE.on:
        return threading.Lock()
    from . import witness  # deferred: witness imports this module
    return witness.WitnessLock(LOCK_CLASSES[cls_name], group)
