"""Serving driver with Taiji elastic KV cache.

``python -m repro.launch.serve --arch <id> --reduced`` runs a multi-turn
serving simulation on CPU: more live sequences than physical KV capacity,
idle sequences cooling down and getting swapped to the compressed
backend, scheduled batches faulting their blocks back in before each
decode step (the DMA pin contract). Prints the paper's metrics: fault
latency percentiles, residency, backend composition, water levels.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.reduce import reduced_config
from repro.core.config import LRUConfig, SchedulerConfig
from repro.core.elastic_kv import ElasticKVCache, KVGeometry, make_kv_taiji_config
from repro.core.system import TaijiSystem
from repro.models import model as M


def run_serving(cfg, *, n_seqs: int, phys_blocks: int, turns: int,
                batch: int, prompt_len: int, gen_len: int, seed: int = 0,
                verbose: bool = True):
    rng = jax.random.PRNGKey(seed)
    params = M.init_params(rng, cfg)

    geom = KVGeometry(n_layers=M.attn_layer_count(cfg),
                      kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                      block_tokens=cfg.kv_block_tokens, dtype_bytes=2)
    # virtual space sized for the demo's worst case (every sequence grows
    # to prompt + turns*gen tokens); physical stays at phys_blocks -- the
    # gap is Taiji's elastic memory
    bt = geom.block_tokens
    worst_blocks = n_seqs * (-(-(prompt_len + turns * gen_len) // bt))
    over = max(0.5, worst_blocks / phys_blocks - 1.0)
    tcfg = make_kv_taiji_config(
        geom, phys_blocks, overcommit=over,
        lru=LRUConfig(scan_interval_s=0.002, workers=2, stabilize_scans=1),
        scheduler=SchedulerConfig(cycle_ms=2.0, shards=2))
    system = TaijiSystem(tcfg)
    system.start_background()
    cache = ElasticKVCache(geom, system)

    npr = np.random.default_rng(seed)
    seq_state = {}
    for sid in range(n_seqs):
        cache.create_sequence(sid)
        # host-side mirror of each sequence's KV (what the device would DMA)
        for t in range(prompt_len):
            kv = npr.standard_normal(
                (geom.n_layers, 2, geom.kv_heads, geom.head_dim)).astype(np.float16)
            cache.append_kv(sid, kv)
        seq_state[sid] = prompt_len

    step_times = []
    for turn in range(turns):
        batch_ids = npr.choice(n_seqs, size=batch, replace=False)
        t0 = time.perf_counter()
        with cache.prepare_step(batch_ids):      # swap-in + pin (DMA contract)
            # decode gen_len tokens for the scheduled batch
            for _ in range(gen_len):
                for sid in batch_ids:
                    kv = npr.standard_normal(
                        (geom.n_layers, 2, geom.kv_heads, geom.head_dim)
                    ).astype(np.float16)
                    cache.append_kv(int(sid), kv)
                    seq_state[int(sid)] += 1
        step_times.append(time.perf_counter() - t0)
        if verbose and (turn + 1) % max(1, turns // 10) == 0:
            res = cache.residency()
            print(f"turn {turn+1:3d}: residency={res} free_ms={system.phys.free_count}")

    stats = system.stats()
    if verbose:
        print("\n--- Taiji metrics (paper §5 counters) ---")
        print("fault latency:", stats["metrics"]["fault_latency"])
        print("swapped out MS:", stats["metrics"]["ms_swapped_out"],
              " swapped in MP:", stats["metrics"]["mp_swapped_in"])
        print("zero/compressed MPs:", stats["metrics"]["zero_mps"],
              "/", stats["metrics"]["compressed_mps"],
              " compression ratio:", f"{stats['metrics']['compression_ratio']:.3f}")
        print("mpool:", {k: round(v, 3) if isinstance(v, float) else v
                         for k, v in stats["mpool"].items()})
        print(f"mean scheduled-batch latency: {np.mean(step_times)*1e3:.2f} ms")
    system.close()
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--n-seqs", type=int, default=24)
    ap.add_argument("--phys-blocks", type=int, default=48)
    ap.add_argument("--turns", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    run_serving(cfg, n_seqs=args.n_seqs, phys_blocks=args.phys_blocks,
                turns=args.turns, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len)


if __name__ == "__main__":
    main()
