"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Scheme (DESIGN.md §5):
  * ``model`` axis = tensor parallel (attention heads, FFN hidden, Mamba
    d_inner, vocab for the LM head, MoE expert dim = expert parallel);
  * ``data`` axis = batch data-parallel + ZeRO-3 FSDP on parameters and
    optimizer state (sharded on d_model-sized dims, all-gathered per
    scanned layer);
  * ``pod`` axis (multi-pod mesh) = outer data parallel: batch sharded
    over (pod, data), parameters replicated across pods (baseline; the
    §Perf log explores FSDP over pods).

Every rule is divisibility-guarded: an axis is only assigned if it evenly
divides the dim, so one rule set serves all ten archs (e.g. 14-head
qwen2-0.5b simply leaves heads unsharded on a 16-way model axis).
"""
from __future__ import annotations

import inspect
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> AbstractMesh:
    """Version-compat ``AbstractMesh`` constructor.

    jax 0.4.x takes one ``shape_tuple`` of ``(name, size)`` pairs; jax
    0.5+ takes ``(axis_sizes, axis_names)`` positionally. Dispatch on the
    constructor signature so rule code and tests build device-free meshes
    the same way against either API.
    """
    params = tuple(inspect.signature(AbstractMesh.__init__).parameters)
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def axis_size(mesh: Mesh, name: Optional[str]) -> int:
    return mesh.shape[name] if name and name in mesh.shape else 1


class ShardingRules:
    def __init__(self, cfg: ArchConfig, mesh: Mesh,
                 tp_axis: str = "model", fsdp_axis: str = "data",
                 pod_axis: Optional[str] = None,
                 fsdp_over_pod: bool = False) -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.tp = tp_axis
        self.fsdp = fsdp_axis if fsdp_axis in mesh.shape else None
        self.pod = pod_axis if (pod_axis and pod_axis in mesh.shape) else None
        # batch shards over (pod, data)
        if self.pod:
            self.batch_axes: Any = (self.pod, fsdp_axis)
        else:
            self.batch_axes = fsdp_axis
        # beyond-paper option: FSDP over (pod, data) instead of data only
        self.fsdp_spec = ((self.pod, self.fsdp) if (fsdp_over_pod and self.pod)
                          else self.fsdp)

    # -------------------------------------------------------------- helpers
    def _fit(self, dim: int, axis) -> Optional[Any]:
        """Assign ``axis`` to a dim only if it divides evenly."""
        if axis is None:
            return None
        if isinstance(axis, tuple):
            total = 1
            for a in axis:
                if a is None:
                    return None
                total *= axis_size(self.mesh, a)
            return axis if dim % total == 0 else self._fit(dim, axis[-1])
        return axis if dim % axis_size(self.mesh, axis) == 0 else None

    def _spec(self, shape: Tuple[int, ...], *last_dims) -> P:
        """Right-aligned spec: assign rules to the trailing dims."""
        lead = len(shape) - len(last_dims)
        entries = [None] * lead
        for i, axis in enumerate(last_dims):
            entries.append(self._fit(shape[lead + i], axis))
        return P(*entries)

    # ------------------------------------------------------------ parameters
    def param_pspecs(self, param_shapes) -> Any:
        cfg = self.cfg
        tp, fsdp = self.tp, self.fsdp_spec

        def tp_if(cond):
            return tp if cond else None

        tp_size = axis_size(self.mesh, tp)
        tp_q = tp_if(cfg.n_heads and cfg.n_heads % tp_size == 0)
        tp_kv = tp_if(cfg.n_kv_heads and cfg.n_kv_heads % tp_size == 0)
        tp_ep = None
        if cfg.moe is not None and cfg.moe.n_routed % tp_size == 0:
            tp_ep = tp

        def rule(path, leaf) -> P:
            keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
            name = keys[-1]
            shape = leaf.shape
            in_moe = "moe" in keys or name.startswith("shared_")

            if name == "embed":
                if cfg.tie_embeddings:
                    # tied: keep vocab-major so the logits matmul comes out
                    # vocab-sharded (Megatron-style vocab parallelism)
                    return self._spec(shape, tp, None)
                # d_model over the model axis: the token-gather output then
                # reshards with one small all-gather instead of the SPMD
                # partitioner's involuntary full replication (multi-pod)
                return self._spec(shape, None, tp)
            if name == "lm_head":
                return self._spec(shape, fsdp, tp)
            if name == "frontend_proj":
                return self._spec(shape, None, fsdp)
            if name in ("final_norm",) or name.startswith("ln"):
                return P(*([None] * len(shape)))
            # attention
            if name == "wq":
                return self._spec(shape, fsdp, tp_q)
            if name in ("wk", "wv"):
                return self._spec(shape, fsdp, tp_kv)
            if name == "wo":
                return self._spec(shape, tp_q, fsdp)
            if name == "bq":
                return self._spec(shape, tp_q)
            if name in ("bk", "bv"):
                return self._spec(shape, tp_kv)
            if name in ("q_norm", "k_norm"):
                return P(*([None] * len(shape)))
            # MoE
            if name == "router":
                return self._spec(shape, fsdp, None)
            if in_moe and name in ("w_gate", "w_up"):
                return self._spec(shape, tp_ep, fsdp, None)
            if in_moe and name == "w_down":
                return self._spec(shape, tp_ep, None, fsdp)
            if name in ("shared_gate", "shared_up"):
                return self._spec(shape, fsdp, tp)
            if name == "shared_down":
                return self._spec(shape, tp, fsdp)
            # dense MLP
            if name in ("w_gate", "w_up"):
                return self._spec(shape, fsdp, tp)
            if name == "w_down":
                return self._spec(shape, tp, fsdp)
            # mamba
            if name == "in_proj":
                return self._spec(shape, fsdp, tp)
            if name == "conv_w":
                return self._spec(shape, None, tp)
            if name in ("conv_b", "dt_bias", "D"):
                return self._spec(shape, tp)
            if name == "x_proj":
                return self._spec(shape, tp, None)
            if name == "dt_proj":
                return self._spec(shape, None, tp)
            if name == "A_log":
                return self._spec(shape, tp, None)
            if name == "out_proj":
                return self._spec(shape, tp, fsdp)
            return P(*([None] * len(shape)))

        return jax.tree_util.tree_map_with_path(rule, param_shapes)

    def state_pspecs(self, state_shapes) -> Any:
        """TrainState specs: step replicated; params/opt share param rules."""
        params = self.param_pspecs(state_shapes.params)
        return type(state_shapes)(
            step=P(),
            params=params,
            opt=type(state_shapes.opt)(mu=params, nu=params),
        )

    # ----------------------------------------------------------------- data
    def batch_pspecs(self, batch_shapes: Dict[str, Any]) -> Dict[str, P]:
        out = {}
        for k, v in batch_shapes.items():
            if k == "mrope_pos":        # (3, B, S)
                out[k] = P(None, self._fit(v.shape[1], self.batch_axes), None)
            else:                        # (B, ...) leading batch
                out[k] = P(self._fit(v.shape[0], self.batch_axes),
                           *([None] * (len(v.shape) - 1)))
        return out

    def cache_pspecs(self, cache_shapes: Dict[str, Any], batch: int) -> Dict[str, P]:
        cfg = self.cfg
        tp_size = axis_size(self.mesh, self.tp)
        tp_di = self.tp if (cfg.d_inner and cfg.d_inner % tp_size == 0) else None
        # batch too small to shard (long_500k B=1): shard blocks over data
        b_ax = self._fit(batch, self.batch_axes)
        out: Dict[str, P] = {}
        for k, v in cache_shapes.items():
            if k == "kv_pool":
                if len(v.shape) == 7:    # per_seq: (La, B, mbs, bt, 2, KV, hd)
                    out[k] = P(None, self._fit(v.shape[1], self.batch_axes),
                               None, None, None, None, None)
                else:                    # global: (La, NB, bt, 2, KV, hd)
                    out[k] = P(None, self._fit(v.shape[1], self.batch_axes),
                               None, None, None, None)
            elif k == "block_table":    # (B, mbs)
                out[k] = P(b_ax, None)
            elif k == "kv_len":         # (B,)
                out[k] = P(b_ax)
            elif k == "conv_state":     # (Lm, B, dc-1, DI)
                out[k] = P(None, b_ax, None, tp_di)
            elif k == "ssm_state":      # (Lm, B, DI, DS)
                out[k] = P(None, b_ax, tp_di, None)
            else:
                out[k] = P(*([None] * len(v.shape)))
        return out

    # -------------------------------------------------------------- helpers
    def named(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    def make_axis_ctx(self, batch: Optional[int] = None):
        """Activation-sharding context for model-internal constraints."""
        from repro import shard_ctx
        cfg = self.cfg
        tp_size = axis_size(self.mesh, self.tp)
        batch_axes = self.batch_axes
        if batch is not None and self._fit(batch, batch_axes) is None:
            batch_axes = None
        return shard_ctx.AxisCtx(
            batch=batch_axes,
            tp=self.tp,
            heads_ok=bool(cfg.n_heads and cfg.n_heads % tp_size == 0),
            kv_heads_ok=bool(cfg.n_kv_heads and cfg.n_kv_heads % tp_size == 0),
            vocab_ok=cfg.vocab % tp_size == 0,
            d_inner_ok=bool(cfg.d_inner and cfg.d_inner % tp_size == 0),
            experts_ok=bool(cfg.moe is not None
                            and cfg.moe.n_routed % tp_size == 0),
            ffn_ok=bool(cfg.d_ff and cfg.d_ff % tp_size == 0),
        )
