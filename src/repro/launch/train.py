"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the available devices (CPU smoke / single TPU host) or
lowers for the production mesh. Fault-tolerant: resumes from the latest
checkpoint (params + optimizer + data cursor), saves atomically every
``--ckpt-every`` steps, and tolerates preemption at any point.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.reduce import reduced_config
from repro.data.pipeline import SyntheticPipeline
from repro.optim import adamw
from repro.train import steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(1, args.steps // 20),
                                state_dtype=cfg.opt_dtype)

    rng = jax.random.PRNGKey(args.seed)
    state = steps.init_train_state(rng, cfg, opt_cfg)
    pipe = SyntheticPipeline(cfg, args.batch, args.seq, seed=args.seed)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state, manifest = ckpt.restore(state)
        pipe.restore(manifest["pipeline"])
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(functools.partial(steps.train_step, cfg=cfg,
                                        opt_cfg=opt_cfg), donate_argnums=(0,))

    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = (time.time() - t0) / max(1, i + 1 - start_step)
            print(f"step {i+1:5d} loss={loss:.4f} grad_norm={gn:.3f} "
                  f"({dt*1e3:.0f} ms/step)")
            assert np.isfinite(loss), "loss diverged"
        if ckpt is not None and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, state, pipe.snapshot())
    if ckpt is not None:
        ckpt.save(args.steps, state, pipe.snapshot())
    print("training done")


if __name__ == "__main__":
    main()
