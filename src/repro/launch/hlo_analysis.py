"""While-loop-aware HLO cost extraction for the roofline analysis.

``compiled.cost_analysis()`` visits each computation ONCE -- a scanned
94-layer model reports one layer's FLOPs. This module re-derives the three
roofline inputs from the optimized HLO text with loop trip counts applied:

  * **flops**: 2 * prod(result_dims) * prod(contracting_dims) per ``dot``
    (batch dims are part of the result product), plus 1 flop/element for
    fusion outputs (elementwise epilogue proxy);
  * **hbm bytes**: for every buffer-producing op at the post-fusion top
    level (fusion/dot/copy/collective/scatter/...), result bytes + operand
    bytes (views -- gte/bitcast/tuple/parameter/constant -- excluded);
  * **collective bytes**: result bytes per collective family
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute).

A ``while`` op contributes trip_count x (body + condition); trip counts
come from the single integer ``constant(N)`` in the condition computation
(the shape XLA emits for counted loops; verified against this repo's
scans). Everything is computed per device -- the SPMD module is already
partitioned.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s/*]+?)\s+"
    r"([\w\-]+)\((.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose "result" is a view, not a materialized buffer
_VIEW_OPS = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "after-all", "iota"}


def _shape_dims(tok: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(tok)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    type_str: str
    opname: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    shapes: Dict[str, str]           # op name -> result type string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # bytes produced/consumed inside loops nested >= 2 deep: per-tile
    # working sets (flash q/kv tiles, mamba chunk scans) that a fused TPU
    # kernel holds in VMEM -- excluded from the kernel-adjusted memory term
    tile_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.tile_bytes += other.tile_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_type.items():
            self.collective_by_type[k] += v * mult

    @property
    def hbm_bytes_kernel_adjusted(self) -> float:
        return self.hbm_bytes - self.tile_bytes


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    params_shapes: Dict[str, str] = {}
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*{", line)
            if m:
                is_entry, name, params = m.groups()
                cur = Computation(name=name, ops=[], shapes={})
                if is_entry:
                    entry = name
                # parameter shapes appear in the header: pname: type
                for pm in re.finditer(r"([\w.\-]+):\s*(\([^)]*\)|[\w\[\],]+)",
                                      params):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opname, rest = m.groups()
        # operands: %refs before any attr section
        paren = rest.split("),")[0]
        operands = re.findall(r"%([\w.\-]+)", paren)
        cur.ops.append(OpInfo(name=name, type_str=type_str.strip(),
                              opname=opname, operands=operands, attrs=rest))
        cur.shapes[name] = type_str.strip()
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Counted-loop heuristic: the single int constant in the condition."""
    best = 1
    for op in cond.ops:
        if op.opname == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + op.attrs)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    dt, dims = _shape_dims(op.type_str.strip())
    if not dt:
        return 0.0
    result_elems = 1
    for d in dims:
        result_elems *= d
    # contracting size from the lhs operand's shape
    mc = re.search(r"lhs_contracting_dims={([\d,]*)}", op.attrs)
    k = 1
    if mc and op.operands:
        lhs_type = comp.shapes.get(op.operands[0], "")
        _, lhs_dims = _shape_dims(lhs_type.strip())
        for idx in (int(i) for i in mc.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * result_elems * k


def analyze(hlo: str) -> Cost:
    comps, entry = parse_computations(hlo)
    memo: Dict[tuple, Cost] = {}

    def cost_of(name: str, depth: int, stack=()) -> Cost:
        key = (name, min(depth, 2))
        if key in memo:
            return memo[key]
        if name in stack or name not in comps:
            return Cost()
        comp = comps[name]
        c = Cost()
        # ops defined in this computation whose results we charge directly
        op_by_name = {o.name: o for o in comp.ops}

        def crosses_boundary(operand: str) -> bool:
            """True if the operand reads a buffer not produced (and already
            charged) by a non-view op in this computation -- i.e. a
            computation parameter / loop-carried buffer / weight read."""
            seen = set()
            cur_name = operand
            while cur_name in op_by_name and cur_name not in seen:
                seen.add(cur_name)
                o = op_by_name[cur_name]
                if o.opname not in _VIEW_OPS:
                    return False          # produced here; counted as result
                if o.opname in ("constant", "iota"):
                    return False
                if o.opname == "parameter":
                    return True
                if not o.operands:
                    return True
                cur_name = o.operands[0]
            return True                   # parameter named in the header

        for op in comp.ops:
            base = op.opname
            if base == "while":
                mb = re.search(r"body=%([\w.\-]+)", op.attrs)
                mc = re.search(r"condition=%([\w.\-]+)", op.attrs)
                if mb and mc and mc.group(1) in comps:
                    trips = _trip_count(comps[mc.group(1)])
                    c.add(cost_of(mb.group(1), depth + 1, stack + (name,)), trips)
                    c.add(cost_of(mc.group(1), depth + 1, stack + (name,)), trips)
                continue
            if base in ("call", "conditional", "async-start"):
                for callee in re.findall(r"(?:calls|to)=%([\w.\-]+)", op.attrs):
                    c.add(cost_of(callee, depth, stack + (name,)))
                # conditional: charge all branches once (upper bound)
                for callee in re.findall(
                        r"(?:true_computation|false_computation|branch_computations)="
                        r"{?%([\w.\-]+)", op.attrs):
                    c.add(cost_of(callee, depth, stack + (name,)))
                continue
            if base in _VIEW_OPS:
                continue
            rbytes = _type_bytes(op.type_str)
            obytes = sum(_type_bytes(comp.shapes.get(o, ""))
                         for o in op.operands if crosses_boundary(o))
            c.hbm_bytes += rbytes + obytes
            if depth >= 2:
                c.tile_bytes += rbytes + obytes
            if base == "dot":
                c.flops += _dot_flops(op, comp)
            elif base == "convolution":
                # proxy: 2 * result_elems * (operand1 elems / out_channels)
                c.flops += 2.0 * _type_bytes(op.type_str)
            elif base == "fusion":
                _, dims = _shape_dims(op.type_str.strip())
                n = 1
                for d in dims:
                    n *= d
                c.flops += n          # 1 flop/element epilogue proxy
            for coll in _COLLECTIVES:
                if base == coll or base.startswith(coll + "-start"):
                    c.collective_bytes += rbytes
                    c.collective_by_type[coll] += rbytes
                    break
        memo[key] = c
        return c

    return cost_of(entry, 0)


# hardware constants (TPU v5e-class, per assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (conservative single link)


def roofline_terms(cost: Cost) -> Dict[str, float]:
    """Roofline terms in seconds per step, per chip.

    ``memory_s`` uses the kernel-adjusted bytes: tile working sets inside
    depth>=2 loops (flash q/kv tiles, mamba chunk scans) stay in VMEM on
    the fused TPU kernel path and are not HBM traffic on the target.
    ``memory_fusion_s`` keeps the raw fusion-boundary upper bound.
    """
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.hbm_bytes_kernel_adjusted / HBM_BW
    memory_fusion_s = cost.hbm_bytes / HBM_BW
    collective_s = cost.collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {**terms, "memory_fusion_s": memory_fusion_s, "dominant": dom,
            "roofline_fraction": (compute_s / bound) if bound else 0.0,
            "overlap_fraction": (bound / total) if total else 0.0}
