"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the abstract batch for a cell;
``state_specs``/``cache_specs`` build the abstract TrainState / decode
cache. The dry-run lowers against these (weak-type-correct, shardable).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.train import steps

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    if kind == "decode":
        out = {"tokens": SDS((B,), jnp.int32)}
        if cfg.mrope_sections is not None:
            out["mrope_pos"] = SDS((3, B, 1), jnp.int32)
        return out

    out: Dict[str, Any] = {}
    if cfg.family == "audio":
        out["features"] = SDS((B, S, cfg.frontend_dim), jnp.float32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
    if cfg.family == "vlm":
        out["vision_embeds"] = SDS((B, cfg.max_vision_tokens, cfg.d_model),
                                   jnp.float32)
        out["mrope_pos"] = SDS((3, B, S), jnp.int32)
    if kind == "train":
        out["labels"] = SDS((B, S), jnp.int32)
        if cfg.family == "vlm":
            out["loss_mask"] = SDS((B, S), jnp.float32)
    return out


def opt_config(cfg: ArchConfig) -> adamw.AdamWConfig:
    return adamw.AdamWConfig(state_dtype=cfg.opt_dtype)


def state_specs(cfg: ArchConfig) -> Any:
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        lambda r: steps.init_train_state(r, cfg, opt_config(cfg)), rng)


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    return jax.eval_shape(lambda: M.init_cache(cfg, batch, max_seq))
