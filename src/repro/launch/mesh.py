"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on CPU; smoke tests and benches see 1 device.

Production target: TPU v5e pods -- 16 x 16 = 256 chips per pod
(("data", "model")), and 2 pods = 512 chips multi-pod
(("pod", "data", "model")). At >2 pods the same function takes
``pods=N``; the pod axis is the scale-out axis (DESIGN.md §5).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    if multi_pod:
        shape = (pods, 16, 16)
        axes = ("pod", "data", "model")
    else:
        shape = (16, 16)
        axes = ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (examples, tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
