import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this lowers the real step function (train_step /
prefill_step / serve_step) under the production mesh with explicit
in/out shardings, compiles it, and records:

  * ``compiled.memory_analysis()``  -- proves the cell fits per-device HBM;
  * ``compiled.cost_analysis()``    -- HLO FLOPs / bytes for §Roofline;
  * collective operand bytes parsed from the optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) -- the roofline's collective term.

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``;
benchmarks/roofline.py and EXPERIMENTS.md read them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import functools
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_cells, cell_skip_reason, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules
from repro.launch import specs as SP
from repro.models import model as M
from repro.train import steps

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:%[\w.\-]+|ROOT [%\w.\-]+) = (.*?) ([\w\-]+)\(", line)
        if not m:
            continue
        restype, opname = m.groups()
        base = opname
        for c in _COLLECTIVES:
            if base == c or base.startswith(c + "-start") or base.startswith(c + "."):
                nbytes = sum(_shape_bytes(t) for t in _SHAPE_RE.findall(restype)
                             for t in [t[0] + "[" + t[1] + "]"])
                out[c] += nbytes
                counts[c] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# §Perf hillclimb variants: config/mesh transforms applied on top of the
# baseline (see EXPERIMENTS.md §Perf for the hypothesis -> result log)
import dataclasses as _dc


def _v_per_seq_pool(cfg):
    return _dc.replace(cfg, kv_pool_layout="per_seq")


def _v_grouped_moe(cfg):
    return _dc.replace(cfg, moe=_dc.replace(cfg.moe, grouped_dispatch=True))


VARIANTS = {
    # cell A: paged-gather locality for elastic decode
    "perseq": (_v_per_seq_pool, None),
    # cell B: grouped MoE dispatch (shard-local sorts)
    "groupedmoe": (_v_grouped_moe, None),
    # cell C: same 256 chips, (32 data x 8 model) logical view so 40-head
    # attention shards (heads 40%8==0, kv 8%8==0, batch 256%32==0)
    "mesh32x8": (None, (32, 8)),
    # combos for further iterations
    "groupedmoe_mesh32x8": (_v_grouped_moe, (32, 8)),
}


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  variant: str = ""):
    cfg = get_config(arch)
    mesh_shape = None
    if variant:
        fn, mesh_shape = VARIANTS[variant]
        if fn is not None:
            cfg = fn(cfg)
    shape = SHAPES[shape_name]
    if mesh_shape is not None:
        assert not multi_pod, "variant meshes are single-pod"
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(cfg, mesh, pod_axis="pod" if multi_pod else None)
    opt_cfg = SP.opt_config(cfg)

    from repro import shard_ctx
    import contextlib
    ctx = rules.make_axis_ctx(batch=shape.global_batch)
    with contextlib.ExitStack() as stack:
        stack.enter_context(shard_ctx.use(ctx))
        stack.enter_context(mesh)
        return _build_lowered_inner(cfg, shape, mesh, rules, opt_cfg)


def _build_lowered_inner(cfg, shape, mesh, rules, opt_cfg):
    if True:
        if shape.kind == "train":
            state_sds = SP.state_specs(cfg)
            batch_sds = SP.input_specs(cfg, shape)
            state_sh = rules.named(rules.state_pspecs(state_sds))
            batch_sh = rules.named(rules.batch_pspecs(batch_sds))
            fn = functools.partial(steps.train_step, cfg=cfg, opt_cfg=opt_cfg)
            jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            params_sds = jax.eval_shape(
                lambda r: M.init_params(r, cfg),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            batch_sds = SP.input_specs(cfg, shape)
            params_sh = rules.named(rules.param_pspecs(params_sds))
            batch_sh = rules.named(rules.batch_pspecs(batch_sds))
            fn = functools.partial(steps.prefill_step, cfg=cfg)
            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            params_sds = jax.eval_shape(
                lambda r: M.init_params(r, cfg),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            B, S = shape.global_batch, shape.seq_len
            cache_sds = SP.cache_specs(cfg, B, S)
            in_sds = SP.input_specs(cfg, shape)
            params_sh = rules.named(rules.param_pspecs(params_sds))
            cache_sh = rules.named(rules.cache_pspecs(cache_sds, B))
            tok_sh = rules.named(
                rules.batch_pspecs({"tokens": in_sds["tokens"]}))["tokens"]
            if "mrope_pos" in in_sds:
                mp_sh = rules.named(
                    rules.batch_pspecs({"mrope_pos": in_sds["mrope_pos"]}))["mrope_pos"]

                def fn(params, tokens, cache, mrope_pos):
                    return steps.serve_step(params, tokens, cache, cfg,
                                            mrope_pos=mrope_pos)
                jitted = jax.jit(fn, in_shardings=(params_sh, tok_sh,
                                                   cache_sh, mp_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_sds, in_sds["tokens"],
                                       cache_sds, in_sds["mrope_pos"])
            else:
                def fn(params, tokens, cache):
                    return steps.serve_step(params, tokens, cache, cfg)
                jitted = jax.jit(fn, in_shardings=(params_sh, tok_sh, cache_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_sds, in_sds["tokens"], cache_sds)
    return lowered, mesh, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             print_analysis: bool = True, variant: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if variant:
        mesh_name += f"__{variant}"
    t0 = time.time()
    lowered, mesh, cfg = build_lowered(arch, shape_name, multi_pod, variant)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # loop-trip-aware roofline inputs (cost_analysis counts scan bodies once)
    from repro.launch import hlo_analysis as HA
    loop_cost = HA.analyze(hlo)
    terms = HA.roofline_terms(loop_cost)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": mesh.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops", 0.0) if cost else None,
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0) if cost else None,
        "collectives": coll,
        "loop_aware": {
            "flops_per_device": loop_cost.flops,
            "hbm_bytes_per_device": loop_cost.hbm_bytes,
            "collective_bytes_per_device": loop_cost.collective_bytes,
            "collective_by_type": loop_cost.collective_by_type,
        },
        "roofline": terms,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory_analysis": None,
    }
    if mem is not None:
        result["memory_analysis"] = {
            k: getattr(mem, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    if print_analysis:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print("  memory_analysis:", result["memory_analysis"])
        print("  loop-aware: flops=%.3e hbm=%.3e coll=%.3e (per device)"
              % (loop_cost.flops, loop_cost.hbm_bytes,
                 loop_cost.collective_bytes))
        print("  roofline: compute=%.3fs memory=%.3fs collective=%.3fs "
              "dominant=%s fraction=%.3f"
              % (terms["compute_s"], terms["memory_s"], terms["collective_s"],
                 terms["dominant"], terms["roofline_fraction"]))

    ART_DIR.mkdir(parents=True, exist_ok=True)
    out = ART_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    out.write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="", choices=[""] + list(VARIANTS))
    args = ap.parse_args()

    cells = []
    if args.all:
        for a, s, skip in all_cells():
            if skip:
                print(f"SKIP {a} x {s}: {skip}")
                continue
            cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        skip = cell_skip_reason(args.arch, args.shape)
        if skip:
            print(f"SKIP {args.arch} x {args.shape}: {skip}")
            return
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            if args.skip_existing and (
                    ART_DIR / f"{arch}__{shape}__{mesh_name}.json").exists():
                print(f"EXISTS {arch} x {shape} x {mesh_name}")
                continue
            try:
                run_cell(arch, shape, mp, variant=args.variant)
            except Exception as e:  # record failures, keep going
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"FAIL {arch} x {shape} x {mesh_name}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nDRY-RUN OK")


if __name__ == "__main__":
    main()
