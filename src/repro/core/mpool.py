"""mpool -- the pinned metadata arena (paper §4.1.1).

    "we design a metadata pool (mpool) that allocates full pages and slab
     memory at various granularities. All Taiji metadata is allocated from
     this pool, whose memory is pinned and excluded from swapping, ensuring
     GPA = HPA ... Centralized metadata management also prevents
     fragmentation."

Faithfulness notes:
  * The arena is a real byte region carved out of the managed physical
    memory (the first ``mpool_reserve_ms`` sections), pinned and identity
    mapped -- the GPA=HPA contract.
  * Two allocation families, as in the paper: **full pages** (used for the
    block/EPT tables and IOMMU-analogue tables) and **slab** objects at
    power-of-two size classes (used for swap/LRU records, bitmaps, CRCs).
    Fig 13a reports the split (68.53% full pages / 31.47% slab); the
    benchmark reads the same split from :meth:`stats`.
  * Persistent metadata (bitmaps, CRC arrays, per-MP state) lives *inside*
    the arena as numpy views, which is what makes hot-upgrade inheritance
    literal: the new engine module re-attaches to the same buffers without
    any conversion (paper §4.4 "Data Plane Compatibility").
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from ..analysis.lock_order import named_lock
from .errors import MpoolExhaustedError

_MIN_CLASS = 32  # smallest slab object, bytes


class Handle:
    """A view into the arena. ``offset``/``nbytes`` are stable across upgrades."""

    __slots__ = ("offset", "nbytes", "_arena")

    def __init__(self, arena: "Mpool", offset: int, nbytes: int) -> None:
        self._arena = arena
        self.offset = offset
        self.nbytes = nbytes

    def view(self, dtype=np.uint8) -> np.ndarray:
        dt = np.dtype(dtype)
        count = self.nbytes // dt.itemsize
        return self._arena.buffer[self.offset : self.offset + self.nbytes].view(dt)[:count]

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Handle(off={self.offset}, n={self.nbytes})"


class _SlabPage:
    """One arena page dedicated to a single size class."""

    __slots__ = ("page", "cls_bytes", "free_slots", "nslots")

    def __init__(self, page: Handle, cls_bytes: int) -> None:
        self.page = page
        self.cls_bytes = cls_bytes
        self.nslots = page.nbytes // cls_bytes
        self.free_slots: List[int] = list(range(self.nslots - 1, -1, -1))


class Mpool:
    """Pinned page + slab allocator over a fixed byte arena."""

    def __init__(self, buffer: np.ndarray, page_bytes: int) -> None:
        if buffer.dtype != np.uint8 or buffer.ndim != 1:
            raise ValueError("mpool arena must be a flat uint8 buffer")
        if len(buffer) % page_bytes:
            raise ValueError("arena size must be a multiple of page_bytes")
        self.buffer = buffer
        self.page_bytes = page_bytes
        self.n_pages = len(buffer) // page_bytes

        self._lock = named_lock("mpool")
        self._free_pages: List[int] = list(range(self.n_pages - 1, -1, -1))
        # size-class -> list of slab pages with free slots
        self._partial: Dict[int, List[_SlabPage]] = {}
        # offset -> (slab_page, slot) for frees
        self._slab_index: Dict[int, tuple] = {}

        # accounting (Fig 13a): full-page vs slab usage, peak
        self.page_bytes_used = 0
        self.slab_bytes_used = 0
        self.peak_bytes_used = 0

    # ------------------------------------------------------------ full pages
    def alloc_page(self) -> Handle:
        with self._lock:
            return self._alloc_page_locked(slab=False)

    def _alloc_page_locked(self, slab: bool) -> Handle:
        if not self._free_pages:
            raise MpoolExhaustedError(
                f"mpool exhausted: {self.n_pages} pages in use "
                "(the paper sizes the reserve with >2x headroom)")
        idx = self._free_pages.pop()
        if not slab:
            self.page_bytes_used += self.page_bytes
            self._bump_peak()
        h = Handle(self, idx * self.page_bytes, self.page_bytes)
        h.view()[:] = 0
        return h

    def free_page(self, h: Handle) -> None:
        with self._lock:
            self.page_bytes_used -= self.page_bytes
            self._free_pages.append(h.offset // self.page_bytes)

    # ------------------------------------------------------------------ slab
    @staticmethod
    def size_class(nbytes: int) -> int:
        c = _MIN_CLASS
        while c < nbytes:
            c <<= 1
        return c

    def slab_alloc(self, nbytes: int) -> Handle:
        cls = self.size_class(nbytes)
        if cls > self.page_bytes:
            raise ValueError(f"slab object {nbytes}B exceeds page size; use alloc_page")
        with self._lock:
            pages = self._partial.setdefault(cls, [])
            if not pages:
                pages.append(_SlabPage(self._alloc_page_locked(slab=True), cls))
            sp = pages[-1]
            slot = sp.free_slots.pop()
            if not sp.free_slots:
                pages.pop()          # full: drop from the partial list
            off = sp.page.offset + slot * cls
            self._slab_index[off] = (sp, slot)
            self.slab_bytes_used += cls
            self._bump_peak()
        h = Handle(self, off, cls)
        h.view()[:] = 0
        return h

    def slab_free(self, h: Handle) -> None:
        with self._lock:
            sp, slot = self._slab_index.pop(h.offset)
            was_full = not sp.free_slots
            sp.free_slots.append(slot)
            self.slab_bytes_used -= sp.cls_bytes
            if was_full:
                self._partial.setdefault(sp.cls_bytes, []).append(sp)

    # ------------------------------------------------------------ accounting
    def _bump_peak(self) -> None:
        used = self.page_bytes_used + self.slab_bytes_used
        if used > self.peak_bytes_used:
            self.peak_bytes_used = used

    def stats(self) -> Dict[str, float]:
        used = self.page_bytes_used + self.slab_bytes_used
        total = len(self.buffer)
        return {
            "reserved_bytes": total,
            "used_bytes": used,
            "peak_bytes": self.peak_bytes_used,
            "utilization": used / total if total else 0.0,
            "full_page_bytes": self.page_bytes_used,
            "slab_bytes": self.slab_bytes_used,
            "full_page_fraction": (self.page_bytes_used / used) if used else 0.0,
            "slab_fraction": (self.slab_bytes_used / used) if used else 0.0,
        }
