"""Watermark-based swapping policy (paper §4.2.2 end, Fig 14e / 15a).

    "Three watermarks are set: high, low, and min. Swapping starts when
     memory drops below low and stops when it rises above high. min marks
     critically low memory, triggering proactive swap-out during page
     faults to avoid prolonged low-memory states."

The policy is a pure decision function over the free-MS count; the swap
engine consults it from the background reclaim task (BACK priority) and
from the fault path (min watermark).
"""
from __future__ import annotations

from ..analysis.lock_order import named_lock
from .config import TaijiConfig


class WatermarkPolicy:
    def __init__(self, cfg: TaijiConfig) -> None:
        self.cfg = cfg
        managed = cfg.n_phys_ms - cfg.mpool_reserve_ms
        wm = cfg.watermark
        self.high_ms = max(1, int(managed * wm.high))
        self.low_ms = max(1, int(managed * wm.low))
        self.min_ms = max(0, int(managed * wm.min))
        self._lock = named_lock("watermark")
        self._reclaiming = False
        # Epoch-published fast-path view (ISSUE 8): background steps and
        # slow-path allocations write these plain attributes; the fault
        # fast path reads them instead of walking free-list lengths under
        # the mp_mutex. Staleness is bounded by the publish cadence (one
        # scheduler cycle / background step / slow-path alloc). The
        # conservative direction is preserved: a published ``critical``
        # only ever *declines* the inline allocation, and the slow path
        # re-verifies with the live count before acting on it. Start
        # conservative until the first publish.
        self.published_free_ms = -1
        self.published_critical = True

    def publish(self, free_ms: int) -> int:
        """Epoch-publish the watermark view of ``free_ms`` (plain attribute
        stores -- atomic under the GIL, no lock). Returns ``free_ms``."""
        self.published_free_ms = free_ms
        self.published_critical = free_ms <= self.min_ms
        return free_ms

    # ------------------------------------------------------------- decisions
    def should_start_reclaim(self, free_ms: int) -> bool:
        """Background reclaim starts below ``low`` (or ``high`` if eager)."""
        threshold = self.high_ms if self.cfg.watermark.eager_below_high else self.low_ms
        with self._lock:
            if free_ms < threshold:
                self._reclaiming = True
            return self._reclaiming and free_ms < self.high_ms

    def should_stop_reclaim(self, free_ms: int) -> bool:
        """Reclaim stops once free memory rises above ``high``."""
        with self._lock:
            if free_ms >= self.high_ms:
                self._reclaiming = False
                return True
            return False

    def is_critical(self, free_ms: int) -> bool:
        """Below ``min``: proactive synchronous swap-out on the fault path."""
        return free_ms <= self.min_ms

    def reclaim_target(self, free_ms: int) -> int:
        """How many MSs to reclaim to get back above ``high``."""
        return max(0, self.high_ms - free_ms)

    # ----------------------------------------------------------- batch sizing
    def reclaim_batch_ms(self, free_ms: int) -> int:
        """Whole-MS batch size for one background reclaim round.

        Bounded by the configured round size and by the deficit back to
        ``high`` -- the round never picks more MSs than it needs, so the
        batched swap path doesn't overshoot the watermark band.
        """
        return max(1, min(self.cfg.watermark.reclaim_batch,
                          self.reclaim_target(free_ms)))

    def critical_batch_ms(self, free_ms: int) -> int:
        """Synchronous fault-path reclaim batch: sized by the deficit below
        ``min`` so a single fault never drags out a long reclaim."""
        deficit = self.min_ms - free_ms + 1
        return max(1, min(self.cfg.watermark.reclaim_batch, deficit))

    @property
    def reclaiming(self) -> bool:
        with self._lock:
            return self._reclaiming

    # ------------------------------------------------------------- reporting
    def zone(self, free_ms: int) -> str:
        """Watermark zone for fleet snapshots (coordination hook).

        ``ok`` above high, ``band`` inside the reclaim hysteresis band,
        ``low`` below low (reclaim definitely active), ``critical`` at or
        below min (fault-path synchronous reclaim).
        """
        if free_ms <= self.min_ms:
            return "critical"
        if free_ms < self.low_ms:
            return "low"
        if free_ms < self.high_ms:
            return "band"
        return "ok"

    def describe(self) -> dict:
        return {"high": self.high_ms, "low": self.low_ms, "min": self.min_ms}
