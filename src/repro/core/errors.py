"""Error types for the Taiji elastic-memory core."""
from __future__ import annotations


class TaijiError(Exception):
    """Base class for all Taiji errors."""


class OutOfMemoryError(TaijiError):
    """No physical MS available and reclaim could not free one."""


class MpoolExhaustedError(TaijiError):
    """The pinned metadata pool has no space left (paper reserves headroom)."""


class CorruptionError(TaijiError):
    """CRC mismatch on swap-in (paper §7.1 data-correctness guard)."""


class PinnedError(TaijiError):
    """Attempted to swap out a pinned (DMA / mpool) section."""


class ABIMismatchError(TaijiError):
    """Hot-upgrade metadata ABI incompatibility (paper §4.4)."""


class InvalidStateError(TaijiError):
    """An MS/MP state-machine transition was attempted out of order."""
