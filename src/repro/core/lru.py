"""Parallel multi-level LRU (paper §4.2.1, Fig 7).

Six sets from hot end to cold end:

    HOT -- HOT_INT -- ACTIVE -- INACTIVE -- COLD_INT -- COLD

  * Accessed pages move one level toward HOT (transient single-MP accesses
    inside a huge page cannot jump a page straight to HOT -- the
    intermediate sets smooth fluctuations, "time-based stabilization").
  * Pages whose state is unchanged for ``stabilize_scans`` consecutive
    scans drift one level toward COLD.
  * Within each set, elements are ordered by arrival time: the head of the
    COLD set is the coldest page and is reclaimed first.
  * One LRU task per shard (per-PCPU in the paper) scans its own slice of
    the GFN space; a per-worker **scan cache** buffers results and applies
    them to the shared sets in one short critical section, reducing lock
    contention.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional

from ..analysis.lock_order import named_lock
from .config import TaijiConfig

HOT, HOT_INT, ACTIVE, INACTIVE, COLD_INT, COLD = range(6)
N_LEVELS = 6
LEVEL_NAMES = ("HOT", "HOT_INT", "ACTIVE", "INACTIVE", "COLD_INT", "COLD")


class MultiLevelLRU:
    def __init__(self, cfg: TaijiConfig,
                 accessed_probe: Callable[[int], bool]) -> None:
        """``accessed_probe(gfn)`` test-and-clears the access bit (EPT A-bit)."""
        self.cfg = cfg
        self.accessed_probe = accessed_probe
        self._lock = named_lock("lru")
        # level -> OrderedDict[gfn -> unchanged_scan_count]
        self._sets: List[OrderedDict] = [OrderedDict() for _ in range(N_LEVELS)]
        self._level_of: Dict[int, int] = {}
        self.scan_rounds = 0

    # ------------------------------------------------------------- tracking
    def track(self, gfn: int, level: int = ACTIVE) -> None:
        with self._lock:
            if gfn in self._level_of:
                return
            self._sets[level][gfn] = 0
            self._level_of[gfn] = level

    def untrack(self, gfn: int) -> None:
        with self._lock:
            lvl = self._level_of.pop(gfn, None)
            if lvl is not None:
                self._sets[lvl].pop(gfn, None)

    def note_swapped_out(self, gfn: int) -> None:
        """Swapped pages leave the LRU until they come back."""
        self.untrack(gfn)

    def note_swapped_in(self, gfn: int) -> None:
        """Fault-driven swap-ins join the hot set (paper Fig 14d)."""
        with self._lock:
            old = self._level_of.pop(gfn, None)
            if old is not None:
                self._sets[old].pop(gfn, None)
            self._sets[HOT][gfn] = 0
            self._level_of[gfn] = HOT

    def note_swapped_in_batch(self, gfns: List[int]) -> None:
        """Apply a batch of deferred fast-path swap-in notes (ISSUE 8).

        One lock acquisition for the whole drained pending ring; entries
        join HOT in drain order, so LRU ordering is eventually-exact but
        the per-fault cost never lands on the fault budget.
        """
        with self._lock:
            sets, level_of = self._sets, self._level_of
            hot = sets[HOT]
            for gfn in gfns:
                old = level_of.pop(gfn, None)
                if old is not None:
                    sets[old].pop(gfn, None)
                hot[gfn] = 0
                level_of[gfn] = HOT

    # ---------------------------------------------------------------- scans
    def scan_shard(self, shard: int, n_shards: int) -> int:
        """One scan round over this shard's slice. Returns pages moved.

        Phase 1 (lock-free): probe access bits into the scan cache.
        Phase 2 (one short critical section): apply buffered moves.
        """
        with self._lock:
            shard_gfns = [g for g in self._level_of if g % n_shards == shard]

        cache: List[tuple] = []
        limit = self.cfg.lru.scan_cache_size
        moved = 0
        for gfn in shard_gfns:
            cache.append((gfn, self.accessed_probe(gfn)))
            if len(cache) >= limit:
                moved += self._apply(cache)
                cache = []
        moved += self._apply(cache)
        self.scan_rounds += 1
        return moved

    def _apply(self, cache: List[tuple]) -> int:
        if not cache:
            return 0
        moved = 0
        stab = self.cfg.lru.stabilize_scans
        with self._lock:
            for gfn, accessed in cache:
                lvl = self._level_of.get(gfn)
                if lvl is None:          # raced with swap-out
                    continue
                if accessed:
                    new = max(HOT, lvl - 1)
                    if new != lvl:
                        self._move(gfn, lvl, new)
                        moved += 1
                    else:
                        self._sets[lvl][gfn] = 0
                else:
                    count = self._sets[lvl][gfn] + 1
                    if count >= stab and lvl < COLD:
                        self._move(gfn, lvl, lvl + 1)
                        moved += 1
                    else:
                        self._sets[lvl][gfn] = min(count, stab)
        return moved

    def _move(self, gfn: int, src: int, dst: int) -> None:
        self._sets[src].pop(gfn)
        self._sets[dst][gfn] = 0          # arrival-time order: append at tail
        self._level_of[gfn] = dst

    # ------------------------------------------------------------ selection
    def pick_cold(self, n: int, include_cold_int: bool = False) -> List[int]:
        """Coldest-first reclaim candidates (head of the COLD set first)."""
        out: List[int] = []
        with self._lock:
            for lvl in ([COLD, COLD_INT] if include_cold_int else [COLD]):
                it = iter(self._sets[lvl])
                while len(out) < n:
                    try:
                        out.append(next(it))
                    except StopIteration:
                        break
                if len(out) >= n:
                    break
        return out

    def pick_coldest_any(self, n: int) -> List[int]:
        """Forced reclaim under critical pressure: walk from the cold end
        toward the hot end and take the relatively coldest pages (the min
        watermark's proactive swap-out must always make progress)."""
        out: List[int] = []
        with self._lock:
            for lvl in range(COLD, HOT - 1, -1):
                for gfn in self._sets[lvl]:
                    out.append(gfn)
                    if len(out) >= n:
                        return out
        return out

    # ------------------------------------------------------------- counters
    def counts(self) -> Dict[str, int]:
        with self._lock:
            return {LEVEL_NAMES[i]: len(s) for i, s in enumerate(self._sets)}

    def hot_count(self) -> int:
        with self._lock:
            return len(self._sets[HOT]) + len(self._sets[HOT_INT]) + len(self._sets[ACTIVE])

    def cold_count(self) -> int:
        with self._lock:
            return len(self._sets[INACTIVE]) + len(self._sets[COLD_INT]) + len(self._sets[COLD])

    def level_of(self, gfn: int) -> Optional[int]:
        with self._lock:
            return self._level_of.get(gfn)

    def tracked(self) -> int:
        with self._lock:
            return len(self._level_of)

    def check_invariants(self) -> None:
        with self._lock:
            seen = set()
            for lvl, s in enumerate(self._sets):
                for gfn in s:
                    assert gfn not in seen, f"gfn {gfn} in two sets"
                    seen.add(gfn)
                    assert self._level_of[gfn] == lvl
            assert seen == set(self._level_of)
