"""Parallel low-latency swap engine (paper §4.2.2, Fig 8).

Task types, as in the paper:

  * ``Fault_in``  -- passive, page-fault triggered. Read-locks the req
    (cancelling any active writer), performs an exactly-once MP swap-in
    guarded by the ``bm_in`` bitmap, and merges the MS when the last MP
    returns. Latency-critical: P90 < 10 us (O2).
  * ``Swap_out``  -- active, proactive reclamation. Write-locks the req
    (serialized, cancellable between MPs), unmaps each MP *before* copying
    it to the backend (the bm_in bit doubles as an in-flight IO latch so a
    racing fault waits rather than reading torn data), splits the mapping
    at the first MP and reclaims the physical MS after the last.
  * ``Swap_in``   -- active prefetch/compaction. Write-locked like
    Swap_out; used by the framework integration to prefetch blocks for the
    next step (beyond-paper overlap) and to re-merge fragmented MSs.

Watermark integration: the background reclaim round runs at BACK priority
under hv_sched; the min watermark triggers synchronous proactive swap-out
on the fault/allocation path (§4.2.2 end).
"""
from __future__ import annotations

import time
import zlib as _zlib
from typing import List, Optional

import numpy as _np

from .backend import BackendStore
from .config import TaijiConfig
from .errors import CorruptionError, OutOfMemoryError, PinnedError
from .lru import MultiLevelLRU
from ..obs.tracer import (ST_BACKEND_LOAD, ST_BACKEND_STORE, ST_FAULT_ALLOC,
                          ST_FAULT_BACKEND, ST_FAULT_COPY, ST_FAULT_DESC,
                          ST_FAULT_MUTEX, ST_FAULT_READAHEAD, ST_FAULT_TOTAL,
                          ST_READAHEAD_DECODE, ST_SWAP_GATHER, ST_SWAP_IN,
                          ST_SWAP_OUT, ST_SWAP_SCATTER)
from .metrics import (FK_COMPRESSED, FK_FAST, FK_OTHER, FK_READAHEAD,
                      FK_ZERO, Metrics)
from .ms import (H_PFN, H_PRESENT, H_STATE, K_COMPRESSED, K_FREE,
                 K_NONE, K_ZERO, MS_RESIDENT, MS_SWAPPED)
from .req import Req, ReqTree
from .virt import F_PINNED, NO_PFN, VirtualizationLayer
from .watermark import WatermarkPolicy

_perf_ns = time.perf_counter_ns
_U64 = _np.uint64
_MASK64 = 0xFFFFFFFFFFFFFFFF


class SwapEngine:
    def __init__(self, cfg: TaijiConfig, virt: VirtualizationLayer,
                 backend: BackendStore, reqs: ReqTree, lru: MultiLevelLRU,
                 watermark: WatermarkPolicy, metrics: Metrics) -> None:
        self.cfg = cfg
        self.virt = virt
        self.backend = backend
        self.reqs = reqs
        self.lru = lru
        self.watermark = watermark
        self.metrics = metrics

        # fault fast-path working set, hoisted out of the per-fault budget:
        # the O(1) descriptor table, the flat physical buffer, geometry
        # constants and the constant zero-page CRC
        self._ft = reqs.table
        # descriptor-table views hoisted one level further (ISSUE 8): the
        # arrays are built once, so the fast path loads them off self
        # instead of chasing reqs.table each fault
        self._u64 = reqs.table.u64
        self._i64 = reqs.table.i64
        self._a8 = reqs.table.a8
        self._u32 = reqs.table.u32
        self._hdr = reqs.table.hdr
        self._reqrows = reqs.table.reqs
        self._phys = virt.phys
        self._buf = virt.phys.buffer
        self._flags = virt.table.flags   # stable array, built once
        self._ms_bytes = cfg.ms_bytes
        self._mp_bytes = cfg.mp_bytes
        self._mps = cfg.mps_per_ms
        self._zero_crc = backend.zero_crc
        self._crc_on = cfg.backend.crc_enabled
        self._fast = cfg.swap.fast_fault_enabled and reqs.table.enabled
        self._readahead = cfg.swap.readahead_enabled
        # contention-free admission state (ISSUE 8): the fast path reads
        # the epoch-published watermark flag instead of recomputing
        # is_critical(free_ms) under the mp_mutex, and defers LRU joins
        # into a lock-free pending ring (plain list; append/pop are
        # GIL-atomic) drained off the fault budget
        self._wm = watermark
        self._lru_pending: List[int] = []
        watermark.publish(virt.free_ms)  # first epoch: faults before the
        # first background round see the true initial zone
        # stage-attributed span tracer (repro.obs); None unless
        # ObsConfig.enabled -- every traced site guards on `is not None`
        self._tr = metrics.tracer
        if cfg.swap.use_pallas_kernels:
            # device data path for the batched MP copies: gather on
            # swap-out, scatter on swap-in (kernels/swap_copy.py,
            # interpret mode off-TPU so CI validates the kernel bodies)
            from ..kernels import ops as _kops
            self._kernel_gather = _kops.batch_gather
            self._kernel_scatter = _kops.batch_scatter
        else:
            self._kernel_gather = None
            self._kernel_scatter = None
        # deferred fast-path counters ride the ring flush; tell it whether
        # each fast fault performed a CRC compare
        metrics.fault_ring.count_crc = self._crc_on

        # install ourselves as the virtualization layer's fault handler and
        # per-MP presence probe (EPT-violation exit -> Fault_in)
        virt.fault_handler = self.fault_in
        virt.mp_present_probe = self._mp_present

    # ------------------------------------------------------------ presence
    def _mp_present(self, gfn: int, mp: int) -> bool:
        req = self._ft.reqs[gfn]
        if req is None:
            return True
        return req.mp_present(mp)

    # ========================================================== Fault_in ==
    def fault_in(self, gfn: int, mp: int) -> None:
        """Passive swap-in of one MP; parallel across MPs and MSs.

        Zero-page ultrafast path (the production-dominant 76.79% case,
        Fig 15c): descriptor-table loads + memset + constant-CRC compare +
        in-word bitmap clear under the req's short ``mp_mutex`` only. No
        rbtree walk, no read-write-lock round trip, no condition-variable
        wait, no per-fault zlib call, and the latency sample is one ring
        store. First faults into a fully swapped MS allocate their slot
        inline (exactly-once, same mutex the locked path allocates
        under). Safe without the rwlock because every writer mutation of
        record state happens inside the same ``mp_mutex`` critical
        sections (Fig 8 (3.3)/(4.1)); a fault that cannot take this exit
        (non-zero kind, in-flight IO) falls back to the locked scalar
        path, which still cancels active writers (2.2).
        """
        t0 = _perf_ns()
        m = self.metrics
        tr = self._tr
        m.faults += 1
        if self._flags[gfn] & F_PINNED:   # lock-free read
            # fault on a registered DMA range: intercepted DMAR exception
            m.dmar_intercepts += 1
        req = self._reqrows[gfn]
        if req is None:
            raise OutOfMemoryError(f"fault on unmanaged swapped gfn {gfn}")

        if self._fast:
            hdr, bmo, bmi, kio, cro = req.fdesc
            w = mp >> 6
            bit = 1 << (mp & 63)
            u64 = self._u64
            i64 = self._i64
            done = 0
            pfn = -1
            lock = req.mp_mutex
            if tr is not None:
                t_lk = _perf_ns()
            lock.acquire()
            try:
                if tr is not None:
                    t_in = _perf_ns()
                    tr.push(ST_FAULT_MUTEX, t_lk, t_in - t_lk)
                ow = 0
                # validity re-check under the mutex: hdr=-1 means
                # teardown quiesced the GFN, and the row must still hold
                # OUR req -- a free+realloc can re-arm the gate for a new
                # req (even at the same slab base) while we hold the old
                # one's mutex (ABA)
                if self._reqrows[gfn] is req and self._hdr[gfn] >= 0:
                    ow = int(u64[bmo + w])
                    if not ow & bit:
                        done = 2            # another fault already resolved it
                    elif (self._a8[kio + mp] == K_ZERO
                          and not int(u64[bmi + w]) & bit):
                        # pfn >= 0 here means MS_PARTIAL: with bm_out set
                        # the state cannot be RESIDENT, and SWAPPED
                        # implies pfn=-1
                        pfn = int(i64[hdr + H_PFN])
                        if pfn < 0 and i64[hdr + H_STATE] == MS_SWAPPED \
                                and not self._wm.published_critical:
                            # exactly-once first-in alloc (Fig 8 state).
                            # Only the magazine/leaf-locked slot pop is
                            # allowed here: the critical/exhausted case
                            # must reclaim through the slow path, whose
                            # rwlock read grant is what lets a concurrent
                            # reclaimer's non-blocking write acquisition
                            # skip this MS (holding mp_mutex while waiting
                            # on another req's mutex could cycle). The
                            # published critical flag is stale by at most
                            # one publish cadence, and only in the safe
                            # direction: a stale `critical` sends us to
                            # the slow path, which re-verifies against
                            # the live free count
                            if tr is not None:
                                t_al = _perf_ns()
                            slot = self._phys.try_alloc_slot()
                            if slot is not None:
                                pfn = slot
                                req.record.on_first_swap_in(pfn)
                                self.virt.table.map_split(gfn, pfn)
                                # LRU join deferred off the fault budget:
                                # drained by step_background / slow-path
                                # entry / reclaim (eventually-exact order)
                                self._lru_pending.append(gfn)
                            if tr is not None:
                                tr.push(ST_FAULT_ALLOC, t_al,
                                        _perf_ns() - t_al)
                if tr is not None:
                    t_cp = _perf_ns()
                    tr.push(ST_FAULT_DESC, t_in, t_cp - t_in)
                if pfn >= 0:
                    o = pfn * self._ms_bytes + mp * self._mp_bytes
                    self._buf[o : o + self._mp_bytes] = 0
                    if self._crc_on and self._u32[cro + mp] != self._zero_crc:
                        m.crc_checks += 1
                        m.crc_failures += 1
                        raise CorruptionError(
                            f"zero-page CRC mismatch gfn={gfn} mp={mp}")
                    u64[bmo + w] = ow & ~bit & _MASK64
                    self._a8[kio + mp] = K_NONE
                    pc = int(i64[hdr + H_PRESENT]) + 1
                    i64[hdr + H_PRESENT] = pc
                    # fault_zero_pages / fault_fast_path / crc_checks are
                    # deferred to the ring flush (FK_FAST tag); the
                    # exactly-once witness stays immediate
                    m.mp_swapped_in += 1
                    if pc == self._mps:     # last MP: merge (7)
                        # merge only when the bitmaps agree: an active
                        # writer's in-flight chunk is still counted in
                        # present_count (its decrement is deferred to
                        # chunk publish), so pc can transiently read
                        # mps_per_ms while chunk MPs sit latched -- the
                        # true last fault after the publish merges
                        rec = req.record
                        if not (rec.bm_out.any() or rec.bm_in.any()):
                            rec.on_last_swap_in()
                            self.virt.table.merge(gfn, pfn)
                            m.ms_swapped_in += 1
                            req.mp_cond.notify_all()
                    done = 1
                    if tr is not None:
                        tr.push(ST_FAULT_COPY, t_cp, _perf_ns() - t_cp)
            finally:
                lock.release()
            if done:
                fk = FK_ZERO | FK_FAST if done == 1 else FK_OTHER
                dur = _perf_ns() - t0
                m.fault_ring.push(dur, fk)
                if tr is not None:
                    tr.push(ST_FAULT_TOTAL, t0, dur, fk)
                return

        # slow path: locked scalar reference (cancels any active writer, 2.2)
        if self._lru_pending:
            # drain deferred fast-path LRU joins at slow-path entry so any
            # reclaim decision made below sees current ordering
            self.drain_lru_pending()
        if tr is not None:
            t_rw = _perf_ns()
        req.rwlock.acquire_read()
        try:
            if tr is not None:
                tr.push(ST_FAULT_MUTEX, t_rw, _perf_ns() - t_rw)
            fk = self._fault_in_locked(req, gfn, mp)
        finally:
            req.rwlock.release_read()
        dur = _perf_ns() - t0
        m.fault_ring.push(dur, fk)
        if tr is not None:
            tr.push(ST_FAULT_TOTAL, t0, dur, fk)

    def _fault_in_locked(self, req: Req, gfn: int, mp: int) -> int:
        """Locked scalar fault path. Returns the fault-kind code (FK_*)."""
        rec = req.record
        # inlined bitmap ops: the fault path carries the 10us-P90 budget
        # (O2), so word read-modify-writes act directly on the arena words
        # instead of going through per-bit helper calls
        w = mp >> 6
        bit = 1 << (mp & 63)
        tr = self._tr
        if tr is not None:
            t_lk = _perf_ns()
        with req.mp_cond:
            # wait out any in-flight IO on this MP (exactly-once, Fig 8 3.3)
            while int(rec.bm_in[w]) & bit:
                req.mp_cond.wait()
            if tr is not None:
                # mutex stage covers cond acquire + the IO-latch wait
                t_d0 = _perf_ns()
                tr.push(ST_FAULT_MUTEX, t_lk, t_d0 - t_lk)
            if not int(rec.bm_out[w]) & bit:
                if tr is not None:
                    tr.push(ST_FAULT_DESC, t_d0, _perf_ns() - t_d0)
                return FK_OTHER             # another fault already resolved it
            first_in = rec.state == MS_SWAPPED
            if first_in:
                if tr is not None:
                    t_al = _perf_ns()
                pfn = self._alloc_slot_critical()
                rec.on_first_swap_in(pfn)   # exactly-once alloc (Fig 8 state)
                self.virt.table.map_split(gfn, pfn)
                # the MS holds a physical slot again: it joins the hot set
                # now (Fig 14d) so partially-resident MSs stay reclaimable
                self.lru.note_swapped_in(gfn)
                if tr is not None:
                    # attribute slot allocation (and any synchronous
                    # critical reclaim inside it) to its own child stage
                    tr.push(ST_FAULT_ALLOC, t_al, _perf_ns() - t_al)
            else:
                pfn = rec.pfn
            kind = int(rec.kinds[mp])
            crc = int(rec.crc[mp])

            if kind == K_ZERO:
                # zero-page fast path (76.79% of production swap-ins,
                # Fig 15c): memset + constant-CRC check under the mutex --
                # no IO-latch round trip, no backend call
                if tr is not None:
                    t_cp = _perf_ns()
                    tr.push(ST_FAULT_DESC, t_d0, t_cp - t_d0)
                self.virt.phys.mp_view(pfn, mp)[:] = 0
                if self.cfg.backend.crc_enabled:
                    self.metrics.crc_checks += 1
                    if crc != self.backend.zero_crc:
                        self.metrics.crc_failures += 1
                        raise CorruptionError(
                            f"zero-page CRC mismatch gfn={gfn} mp={mp}")
                self.metrics.fault_zero_pages += 1
                rec.bm_out[w] = _U64(int(rec.bm_out[w]) & ~bit & _MASK64)
                rec.kinds[mp] = K_NONE
                rec.present_count += 1
                self.metrics.mp_swapped_in += 1
                if rec.present_count == self.cfg.mps_per_ms:
                    rec.on_last_swap_in()
                    self.virt.table.merge(gfn, rec.pfn)       # (7)
                    self.metrics.ms_swapped_in += 1
                req.mp_cond.notify_all()
                if tr is not None:
                    tr.push(ST_FAULT_COPY, t_cp, _perf_ns() - t_cp)
                return FK_ZERO

            rec.bm_in[w] = _U64(int(rec.bm_in[w]) | bit)
            ra = None
            if self._readahead and kind == K_COMPRESSED:
                # extent readahead (paper §3.3/Fig 8 parallel swapping):
                # the first fault into a compressed extent decompresses
                # the whole stream anyway -- claim every still-swapped
                # sibling MP (bm_in latch, exactly-once) so one pass
                # materializes them all and N future faults never happen
                ra = self._claim_extent_readahead(rec, gfn, mp)
            if tr is not None:
                tr.push(ST_FAULT_DESC, t_d0, _perf_ns() - t_d0)

        if ra is not None:
            return self._readahead_fill(req, gfn, mp, crc, pfn, ra)

        # backend IO outside the mutex (readers of other MPs stay parallel)
        if tr is not None:
            t_b = _perf_ns()
        ok = False
        try:
            self.backend.load(gfn, mp, kind, crc, self.virt.phys.mp_view(pfn, mp))
            ok = True
        finally:
            if tr is not None:
                t_p = _perf_ns()
                tr.push(ST_FAULT_BACKEND, t_b, t_p - t_b)
            with req.mp_cond:
                rec.bm_in[w] = _U64(int(rec.bm_in[w]) & ~bit & _MASK64)
                if ok:
                    rec.bm_out[w] = _U64(int(rec.bm_out[w]) & ~bit & _MASK64)
                    rec.kinds[mp] = K_NONE
                    rec.present_count += 1
                    self.metrics.mp_swapped_in += 1
                    if rec.present_count == self.cfg.mps_per_ms:
                        rec.on_last_swap_in()
                        self.virt.table.merge(gfn, rec.pfn)   # (7)
                        self.metrics.ms_swapped_in += 1
                req.mp_cond.notify_all()
            if tr is not None:
                tr.push(ST_FAULT_COPY, t_p, _perf_ns() - t_p)
        if kind == K_COMPRESSED:
            return FK_COMPRESSED
        return FK_ZERO if kind == K_FREE else FK_OTHER

    # ------------------------------------------------------ extent readahead
    def _claim_extent_readahead(self, rec, gfn: int, mp: int):
        """Claim the faulting extent's still-swapped sibling MPs.

        Called under ``mp_cond``. Returns ``(eid, my_row, idxs, rows,
        crcs)`` with ``idxs`` the claimed sibling MP index vector (bm_in
        latched here) and ``rows`` their extent rows, or ``None`` when the
        entry is a standalone blob. Only siblings whose live backend entry
        still references this extent are eligible (a consumed-then-re-
        swapped MP may appear in the stored member list with stale rows).
        """
        probe = self.backend.extent_members(gfn, mp)
        if probe is None:
            return None
        eid, my_row, live = probe
        # pure-int word math: numpy scatter ufuncs (np.bitwise_or.at) cost
        # tens of us per call on the target box, so eligibility and the
        # bm_in latch are computed on Python ints over the few (<= 8)
        # bitmap words and stored back one word at a time
        bm_out, bm_in = rec.bm_out, rec.bm_in
        nw = len(bm_out)
        ow = [int(x) for x in bm_out]
        iw = [int(x) for x in bm_in]
        claim: List[tuple] = []
        cw = [0] * nw                           # claimed-bit mask per word
        for mpj, row in live:
            if mpj == mp:
                continue
            wj = mpj >> 6
            b = 1 << (mpj & 63)
            if ow[wj] & b and not iw[wj] & b:
                claim.append((mpj, row))
                cw[wj] |= b
        if not claim:
            return eid, my_row, None, None
        for wj in range(nw):
            if cw[wj]:
                bm_in[wj] = _U64(iw[wj] | cw[wj])    # IO latch (Fig 8 3.3)
        return eid, my_row, claim, cw

    def _readahead_fill(self, req: Req, gfn: int, mp: int, crc: int,
                        pfn: int, ra) -> int:
        """Materialize the faulting MP and its claimed extent siblings.

        One decompress, one scatter into the resident MS frame. CRCs are
        verified per row before any backend entry is consumed. Readahead
        must not change observable semantics: a corrupt *sibling* row is
        simply left swapped out (it keeps failing detectably when it is
        actually faulted) while the good rows publish; only a corrupt
        *faulting* row raises.
        """
        eid, my_row, claim, cw = ra
        rec = req.record
        m = self.metrics
        mb = self._mp_bytes
        n_extra = 0 if claim is None else len(claim)
        my_ok = False
        good: List[int] = []
        tr = self._tr
        if tr is not None:
            t_ra = _perf_ns()
        try:
            # one decompress + ONE whole-extent CRC (per-row crc32 calls
            # cost more than the check is worth; the record CRCs remain
            # the scalar path's per-row guarantee)
            if tr is not None:
                t_dec = _perf_ns()
            raw, crc_ok = self.backend.extent_payload(
                gfn, eid, verify=self._crc_on)
            if tr is not None:
                tr.push(ST_READAHEAD_DECODE, t_dec, _perf_ns() - t_dec)
            arr = _np.frombuffer(raw, dtype=_np.uint8)
            frame = self.virt.phys.ms_view(pfn)
            # (mp, row) pairs ascend together (extents store ascending MP
            # order), so the scatter collapses into a few contiguous-run
            # slice copies instead of one fancy-index gather per call
            pairs = sorted(([] if claim is None else claim) + [(mp, my_row)])
            start = 0
            while start < len(pairs):
                end = start + 1
                while (end < len(pairs)
                       and pairs[end][0] == pairs[end - 1][0] + 1
                       and pairs[end][1] == pairs[end - 1][1] + 1):
                    end += 1
                mp0, r0 = pairs[start]
                cnt = end - start
                frame[mp0 * mb:(mp0 + cnt) * mb] = \
                    arr[r0 * mb:(r0 + cnt) * mb]
                start = end
            if not self._crc_on:
                my_ok = True
                good = [p[0] for p in pairs if p[0] != mp]
            elif crc_ok:
                m.crc_checks += 1 + n_extra
                my_ok = True
                good = [p[0] for p in pairs if p[0] != mp]
            else:
                # whole-extent CRC failed: salvage row by row against the
                # record CRCs -- corrupt siblings stay swapped out (they
                # keep failing detectably when actually faulted)
                m.crc_checks += 1 + n_extra
                for mpj, rowj in pairs:
                    want = crc if mpj == mp else int(rec.crc[mpj])
                    row_ok = _zlib.crc32(
                        frame[mpj * mb:(mpj + 1) * mb]) == want
                    if not row_ok:
                        m.crc_failures += 1
                    elif mpj == mp:
                        my_ok = True
                    else:
                        good.append(mpj)
            consumed = ([mp] if my_ok else []) + good
            if consumed:
                self.backend.consume_extent_rows(gfn, eid, consumed)
        finally:
            with req.mp_cond:
                # release every latch (ours + claimed) and publish the
                # verified rows, all with per-word int stores
                nw = len(rec.bm_in)
                rel = list(cw) if claim is not None else [0] * nw
                rel[mp >> 6] |= 1 << (mp & 63)
                bm_in = rec.bm_in
                for wj in range(nw):
                    if rel[wj]:
                        bm_in[wj] = _U64(int(bm_in[wj]) & ~rel[wj] & _MASK64)
                publish = ([mp] if my_ok else []) + good
                if publish:
                    pw = [0] * nw
                    kinds = rec.kinds
                    for mpj in publish:
                        pw[mpj >> 6] |= 1 << (mpj & 63)
                        kinds[mpj] = K_NONE
                    bm_out = rec.bm_out
                    for wj in range(nw):
                        if pw[wj]:
                            bm_out[wj] = _U64(
                                int(bm_out[wj]) & ~pw[wj] & _MASK64)
                    rec.present_count += len(publish)
                    m.mp_swapped_in += len(publish)
                    if my_ok:
                        m.fault_compressed_pages += 1
                    if good:
                        m.readahead_extents += 1
                        m.fault_readahead_mps += len(good)
                    if rec.present_count == self.cfg.mps_per_ms:
                        rec.on_last_swap_in()
                        self.virt.table.merge(gfn, rec.pfn)   # (7)
                        m.ms_swapped_in += 1
                req.mp_cond.notify_all()
        if not my_ok:
            raise CorruptionError(
                f"CRC mismatch gfn={gfn} mp={mp} (extent {eid})")
        if tr is not None:
            # tag 1 = sibling MPs were actually materialized
            tr.push(ST_FAULT_READAHEAD, t_ra, _perf_ns() - t_ra,
                    1 if good else 0)
        return FK_READAHEAD if good else FK_COMPRESSED

    # ========================================================== Swap_out ==
    def swap_out_ms(self, gfn: int, *, blocking_lock: bool = True,
                    batched: Optional[bool] = None) -> int:
        """Active swap-out of all resident MPs of one MS.

        Returns MPs swapped out. Aborts promptly when cancelled by a
        reader (returns partial progress; the MS remains consistent).
        ``batched=None`` follows ``cfg.swap.batch_enabled``; the scalar
        per-MP path is kept for A/B benchmarking and as the semantic
        reference the equivalence tests compare against.
        """
        if self.virt.table.is_pinned(gfn):
            raise PinnedError(f"gfn {gfn} is pinned (mpool/DMA)")
        pfn = int(self.virt.table.pfn[gfn])
        if pfn == NO_PFN:
            return 0
        req = self.reqs.get_or_create(gfn, pfn)      # (1.1)/(1.2)
        grant = req.rwlock.acquire_write(blocking=blocking_lock)  # (2)
        if grant is None:
            return 0
        t0 = _perf_ns()
        if batched is None:
            batched = self.cfg.swap.batch_enabled
        try:
            if batched:
                done = self._swap_out_batched(req, gfn, grant)
            else:
                done = self._swap_out_scalar(req, gfn, grant)
        finally:
            req.rwlock.release_write(grant)
        self.metrics.swap_out_latency.record(_perf_ns() - t0)
        return done

    def swap_out_mps(self, gfn: int, mps, *, blocking_lock: bool = True,
                     batched: Optional[bool] = None) -> int:
        """Active swap-out restricted to the given MP indices.

        Same state machine as :meth:`swap_out_ms`, but only the listed
        MPs move to the backend; MPs already swapped out or mid-IO are
        skipped. The migration import path uses this to rebuild the
        source MS's resident/swapped split on the destination through
        the batched store machinery (store_batch extents).
        """
        idxs = _np.asarray(mps, dtype=_np.int64)
        if len(idxs) == 0:
            return 0
        if self.virt.table.is_pinned(gfn):
            raise PinnedError(f"gfn {gfn} is pinned (mpool/DMA)")
        pfn = int(self.virt.table.pfn[gfn])
        if pfn == NO_PFN:
            return 0
        req = self.reqs.get_or_create(gfn, pfn)
        grant = req.rwlock.acquire_write(blocking=blocking_lock)
        if grant is None:
            return 0
        t0 = _perf_ns()
        if batched is None:
            batched = self.cfg.swap.batch_enabled
        try:
            if batched:
                done = self._swap_out_batched(req, gfn, grant, todo=idxs)
            else:
                done = self._swap_out_scalar(req, gfn, grant,
                                             mps=[int(i) for i in idxs])
        finally:
            req.rwlock.release_write(grant)
        self.metrics.swap_out_latency.record(_perf_ns() - t0)
        return done

    def _swap_out_scalar(self, req: Req, gfn: int, grant,
                         mps: Optional[List[int]] = None) -> int:
        rec = req.record
        done = 0
        for mp in (range(self.cfg.mps_per_ms) if mps is None else mps):
            if grant.cancelled:                   # reader bumped us (2.2)
                self.metrics.writer_cancels += 1
                break
            with req.mp_cond:
                if rec.is_swapped_out(mp) or rec.is_swapping_in(mp):
                    continue
                if rec.state == MS_RESIDENT:      # first MP: split (4.1)
                    self.virt.table.split(gfn)
                    rec.on_first_swap_out()
                # unmap before copy: bm_out makes the MP non-present,
                # bm_in latches the in-flight IO so faults wait
                rec.set_swapped_out(mp, True)
                rec.set_swapping_in(mp, True)
                pfn_now = rec.pfn

            data = self.virt.phys.mp_view(pfn_now, mp).copy()
            kind, crc = self.backend.store(gfn, mp, data)     # (5)

            with req.mp_cond:
                rec.kinds[mp] = kind
                rec.crc[mp] = crc
                rec.set_swapping_in(mp, False)
                rec.present_count -= 1
                done += 1
                self.metrics.mp_swapped_out += 1
                if rec.present_count == 0:        # last MP: reclaim
                    rec.on_last_swap_out()
                    self.virt.table.unmap(gfn)
                    self.virt.phys.free_slot(pfn_now)
                    self.lru.note_swapped_out(gfn)
                    self.metrics.ms_swapped_out += 1
                req.mp_cond.notify_all()
        return done

    def _swap_out_batched(self, req: Req, gfn: int, grant,
                          todo: Optional[_np.ndarray] = None) -> int:
        """Swap out in MP index-vector chunks (tentpole data path).

        Each chunk runs the scalar path's exact state transitions, but on
        a whole index vector at once: one bitmap scatter marks the chunk
        non-present + IO-latched, one gather copies it, one
        ``store_batch`` call zero-detects/CRCs/compresses it, and one
        scatter publishes the kinds/CRCs. Cancellation (Fig 8 (2.2)) is
        honoured between chunks, so ``cfg.swap.batch_mps`` bounds a
        racing reader's wait.
        """
        rec = req.record
        cfg = self.cfg
        chunk = max(1, cfg.swap.batch_mps)
        done = 0
        tr = self._tr
        if tr is not None:
            t_so = _perf_ns()
        # the write lock excludes faults and other writers, so the resident
        # set is fixed for the whole task: derive the MP index vector once
        # and walk it in cancellation-checked chunks (an explicit ``todo``
        # subset is intersected with it, so already-swapped MPs are inert)
        with req.mp_cond:
            resident = rec.resident_indices()
            todo = resident if todo is None else todo[
                _np.isin(todo, resident)]
        for lo in range(0, len(todo), chunk):
            if grant.cancelled:
                self.metrics.writer_cancels += 1
                break
            idxs = todo[lo:lo + chunk]
            with req.mp_cond:
                if rec.state == MS_RESIDENT:      # first MP: split (4.1)
                    self.virt.table.split(gfn)
                    rec.on_first_swap_out()
                # unmap before copy, latch in-flight IO (scalar semantics)
                rec.set_swapped_out_batch(idxs, True)
                rec.set_swapping_in_batch(idxs, True)
                pfn_now = rec.pfn

            ms = self.virt.phys.ms_view(pfn_now).reshape(
                cfg.mps_per_ms, cfg.mp_bytes)
            if tr is not None:
                t_g = _perf_ns()
            if self._kernel_gather is not None:
                data = self._kernel_gather(ms, idxs)
            else:
                data = ms[idxs]                   # fancy index: a copy (5)
            if tr is not None:
                t_st = _perf_ns()
                tr.push(ST_SWAP_GATHER, t_g, t_st - t_g)
            kinds, crcs = self.backend.store_batch(gfn, idxs, data)
            if tr is not None:
                tr.push(ST_BACKEND_STORE, t_st, _perf_ns() - t_st)

            with req.mp_cond:
                rec.kinds[idxs] = kinds
                rec.crc[idxs] = crcs
                rec.set_swapping_in_batch(idxs, False)
                rec.present_count -= len(idxs)
                done += len(idxs)
                self.metrics.mp_swapped_out += len(idxs)
                self.metrics.mp_swapped_out_batched += len(idxs)
                self.metrics.swap_out_batches += 1
                if rec.present_count == 0:        # last MP: reclaim
                    rec.on_last_swap_out()
                    self.virt.table.unmap(gfn)
                    self.virt.phys.free_slot(pfn_now)
                    self.lru.note_swapped_out(gfn)
                    self.metrics.ms_swapped_out += 1
                req.mp_cond.notify_all()
        if tr is not None:
            tr.push(ST_SWAP_OUT, t_so, _perf_ns() - t_so)
        return done

    # =========================================================== Swap_in ==
    def swap_in_ms(self, gfn: int, *, batched: Optional[bool] = None) -> int:
        """Active prefetch swap-in of all swapped MPs of one MS."""
        req = self.reqs.lookup(gfn)
        if req is None:
            return 0
        grant = req.rwlock.acquire_write()
        t0 = _perf_ns()
        if batched is None:
            batched = self.cfg.swap.batch_enabled
        done = 0
        try:
            if batched:
                done = self._swap_in_batched(req, gfn, grant)
            else:
                done = self._swap_in_scalar(req, gfn, grant)
        finally:
            req.rwlock.release_write(grant)
        self.metrics.swap_in_latency.record(_perf_ns() - t0)
        return done

    def _swap_in_scalar(self, req: Req, gfn: int, grant) -> int:
        rec = req.record
        done = 0
        for mp in range(self.cfg.mps_per_ms):
            if grant.cancelled:
                self.metrics.writer_cancels += 1
                break
            with req.mp_cond:
                if not rec.is_swapped_out(mp) or rec.is_swapping_in(mp):
                    continue
            # delegate to the fault path's exactly-once machinery
            self._fault_in_locked(req, gfn, mp)
            done += 1
        return done

    def _swap_in_batched(self, req: Req, gfn: int, grant) -> int:
        """Prefetch swap-in in MP index-vector chunks.

        Mirrors ``_fault_in_locked`` chunk-wise: exactly-once first-in
        allocation, the bm_in IO latch held across the bulk backend load,
        and the merge on the last MP. Zero rows are memset vectorized
        inside ``load_batch`` (no per-MP backend round trip).
        """
        rec = req.record
        cfg = self.cfg
        chunk = max(1, cfg.swap.batch_mps)
        done = 0
        tr = self._tr
        if tr is not None:
            t_si = _perf_ns()
        # swapped-out set is fixed while we hold the write lock (faults
        # block; the IO latch below covers the store side): scan once
        with req.mp_cond:
            todo = rec.swapped_out_indices()
        for lo in range(0, len(todo), chunk):
            if grant.cancelled:
                self.metrics.writer_cancels += 1
                break
            idxs = todo[lo:lo + chunk]
            with req.mp_cond:
                # re-filter under the mutex: the zero-page fast path does
                # not take the rwlock, so an MP from the once-scanned todo
                # list may have been fault-resolved between chunks
                idxs = idxs[[rec.is_swapped_out(int(i))
                             and not rec.is_swapping_in(int(i))
                             for i in idxs]]
                if len(idxs) == 0:
                    continue
                if rec.state == MS_SWAPPED:
                    pfn = self._alloc_slot_critical()
                    rec.on_first_swap_in(pfn)     # exactly-once alloc
                    self.virt.table.map_split(gfn, pfn)
                    self.lru.note_swapped_in(gfn)
                pfn = rec.pfn
                kinds = rec.kinds[idxs].copy()
                crcs = rec.crc[idxs].copy()
                rec.set_swapping_in_batch(idxs, True)   # IO latch (3.3)

            ms = self.virt.phys.ms_view(pfn).reshape(
                cfg.mps_per_ms, cfg.mp_bytes)
            ok = False
            try:
                if len(idxs) == cfg.mps_per_ms:
                    # whole-MS chunk: decode straight into the MS frame
                    if tr is not None:
                        t_bl = _perf_ns()
                    self.backend.load_batch(gfn, idxs, kinds, crcs, ms)
                    if tr is not None:
                        tr.push(ST_BACKEND_LOAD, t_bl, _perf_ns() - t_bl)
                else:
                    out = _np.empty((len(idxs), cfg.mp_bytes), dtype=_np.uint8)
                    if tr is not None:
                        t_bl = _perf_ns()
                    self.backend.load_batch(gfn, idxs, kinds, crcs, out)
                    if tr is not None:
                        t_sc = _perf_ns()
                        tr.push(ST_BACKEND_LOAD, t_bl, t_sc - t_bl)
                    if self._kernel_scatter is not None:
                        # write back only the scattered rows: a racing
                        # guest write to a non-latched MP of this frame
                        # must not be clobbered by the pool snapshot
                        res = self._kernel_scatter(ms, idxs, out)
                        ms[idxs] = res[idxs]
                    else:
                        ms[idxs] = out
                    if tr is not None:
                        tr.push(ST_SWAP_SCATTER, t_sc, _perf_ns() - t_sc)
                ok = True
            finally:
                with req.mp_cond:
                    rec.set_swapping_in_batch(idxs, False)
                    if ok:
                        rec.set_swapped_out_batch(idxs, False)
                        rec.kinds[idxs] = K_NONE
                        rec.present_count += len(idxs)
                        done += len(idxs)
                        self.metrics.mp_swapped_in += len(idxs)
                        self.metrics.swap_in_batches += 1
                        if rec.present_count == cfg.mps_per_ms:
                            rec.on_last_swap_in()
                            self.virt.table.merge(gfn, rec.pfn)   # (7)
                            self.metrics.ms_swapped_in += 1
                    req.mp_cond.notify_all()
        if tr is not None:
            tr.push(ST_SWAP_IN, t_si, _perf_ns() - t_si)
        return done

    # ===================================================== reclaim rounds ==
    def reclaim_round(self, budget_s: Optional[float] = None) -> int:
        """One background reclaim round (BACK priority task body).

        The round issues whole-MS batches: the watermark policy sizes the
        candidate pick from the distance back to ``high`` (never more MSs
        than the deficit), and each MS moves through the batched swap-out
        path. ``budget_s`` is the hv_sched quantum handed to the BACK
        task; the round stops starting new MS batches once it is spent,
        so batch sizing composes with the scheduler's time slicing.
        """
        # drain deferred fast-path LRU joins first so pick_cold sees every
        # resident MS, then epoch-publish the zone the fast path reads
        if self._lru_pending:
            self.drain_lru_pending()
        free = self._wm.publish(self.virt.free_ms)
        self.metrics.free_ms_timeline.record(free)
        if not self.watermark.should_start_reclaim(free):
            return 0
        deadline = (time.monotonic() + budget_s) if budget_s else None
        batch = self.watermark.reclaim_batch_ms(free)
        candidates = self.lru.pick_cold(batch)
        if not candidates:
            # §4.2.2: "halting reclaim between low and high if no cold
            # pages exist" -- fall back to cold-intermediate only when the
            # pressure is real (below low)
            if free < self.watermark.low_ms:
                candidates = self.lru.pick_cold(batch, include_cold_int=True)
            if not candidates:
                return 0
        reclaimed = 0
        for gfn in candidates:
            if self.watermark.should_stop_reclaim(self.virt.free_ms):
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
            try:
                reclaimed += self.swap_out_ms(gfn, blocking_lock=False)
            except PinnedError:
                continue
        self.metrics.reclaim_rounds += 1
        self._wm.publish(self.virt.free_ms)  # round raised free: re-publish
        return reclaimed

    def _alloc_slot_critical(self) -> int:
        """Allocate a physical MS; below the min watermark (or on
        exhaustion), proactively swap out cold MSs synchronously.

        This is the slow path that re-verifies the epoch-published
        critical flag against the LIVE free count (exact, conservative
        direction of ISSUE 8) -- and re-publishes, so a stale flag heals
        on the first slow-path visit.
        """
        slot = self.virt.phys.try_alloc_slot()
        if slot is not None and not self.watermark.is_critical(
                self._wm.publish(self.virt.free_ms)):
            return slot
        if slot is not None:
            # critical but not exhausted: kick a synchronous reclaim too,
            # sized by the watermark deficit (whole-MS batches)
            self.metrics.proactive_reclaims += 1
            n = self.watermark.critical_batch_ms(self.virt.free_ms)
            for gfn in self.lru.pick_cold(n, include_cold_int=True):
                try:
                    self.swap_out_ms(gfn, blocking_lock=False)
                except PinnedError:
                    pass
            return slot
        # exhausted: must reclaim synchronously until a slot frees up;
        # prefer cold pages but force relatively-cold ones if none aged yet
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            self.metrics.proactive_reclaims += 1
            # a resident MS whose fast-path LRU join is still pending is
            # invisible to the pickers: drain first so exhaustion never
            # misses reclaimable memory (try_alloc_slot already stole any
            # magazine-cached slots before reporting None)
            if self._lru_pending:
                self.drain_lru_pending()
            cands = self.lru.pick_cold(4, include_cold_int=True)
            if not cands:
                cands = self.lru.pick_coldest_any(4)
            for gfn in cands:
                try:
                    self.swap_out_ms(gfn, blocking_lock=False)
                except PinnedError:
                    continue
            slot = self.virt.phys.try_alloc_slot()
            if slot is not None:
                return slot
            if not cands:
                time.sleep(0.001)
        raise OutOfMemoryError("no physical MS and no cold pages to reclaim")

    # ----------------------------------------------- deferred-work drains --
    def drain_lru_pending(self) -> None:
        """Apply deferred fast-path ``note_swapped_in`` joins (ISSUE 8).

        The fast path appends GFNs to a plain list (GIL-atomic); the
        drain pops from the SAME list object, so a racing append is never
        lost and each note is applied exactly once. Drained at
        ``step_background``, slow-path fault entry, reclaim-round start
        and exhaustion -- LRU ordering is eventually-exact, never paid on
        the fault budget.
        """
        pend = self._lru_pending
        batch: List[int] = []
        while True:
            try:
                batch.append(pend.pop())
            except IndexError:
                break
        if batch:
            self.lru.note_swapped_in_batch(batch)

    def publish_epoch(self) -> None:
        """Background-cadence refresh: drain deferred LRU joins and
        epoch-publish the watermark view the fault fast path reads.
        Registered as an hv_sched cycle hook and called from
        ``step_background``."""
        if self._lru_pending:
            self.drain_lru_pending()
        self._wm.publish(self.virt.free_ms)

    def drain_deferred(self) -> int:
        """Full drain hook for reclaim/teardown (ISSUE 8): apply pending
        LRU joins AND return every magazine-cached slot to its home
        shard, then re-publish. Returns the number of slots drained."""
        self.drain_lru_pending()
        drained = self.virt.phys.drain_magazines()
        self._wm.publish(self.virt.free_ms)
        return drained

    # ------------------------------------------------------------ utilities
    def resident_cold_fraction(self) -> float:
        hot, cold = self.lru.hot_count(), self.lru.cold_count()
        return cold / (hot + cold) if (hot + cold) else 0.0

    def ms_fully_swapped(self, gfn: int) -> bool:
        """``True`` when every MP of ``gfn`` lives in the backend.

        The remote-peer tier replicates exactly this population: a
        fully-swapped MS has no physical frame to lose, so its entire
        guest-visible content is a backend export -- the cheapest and
        highest-value unit to place on a peer (ISSUE 9). A point-in-time
        read under the MP mutex; the fleet's stepped mode is
        single-threaded, so for the controller it is exact.
        """
        req = self.reqs.lookup(gfn)
        if req is None:
            return False
        rec = req.record
        with req.mp_cond:
            return rec.state == MS_SWAPPED and rec.present_count == 0
