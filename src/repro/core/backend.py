"""Swap backend stores (paper §4.2.2 "backend", §7.2, Fig 15c).

    "Taiji uses in-memory zero pages and compression, prioritizing zero
     pages to minimize backend latency."  (§4.2.2)
    "Taiji's backend storage supports zero, compressed, free pages, remote
     memory, and disks."  (§7.2)

Store selection per MP on swap-out:
  1. zero page  -- store nothing but the kind tag; swap-in is a memset.
  2. free page  -- guest-reported free pages: drop content, rebuild zeroed
     on swap-in (disabled by default, as in production, §7.2).
  3. compressed -- lossless (zlib level 1 ~ lz4-class latency); the paper
     reports a 47.63% compressed/raw ratio over this population.
  4. disk       -- optional fallback tier for bursts beyond elasticity.

All stores are exact (lossless): CRC32 over the original MP guards the
round trip (§7.1). The *lossy* int8 KV-cache backend used by the device
integration is a beyond-paper option and lives in kernels/compress.py.
"""
from __future__ import annotations

import os
import threading
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from .config import TaijiConfig
from .errors import CorruptionError
from .metrics import Metrics
from .ms import K_COMPRESSED, K_DISK, K_FREE, K_NONE, K_ZERO


class BackendStore:
    """Unified backend over the zero/free/compressed/disk tiers."""

    def __init__(self, cfg: TaijiConfig, metrics: Metrics) -> None:
        self.cfg = cfg
        self.metrics = metrics
        self._lock = threading.Lock()
        self._compressed: Dict[Tuple[int, int], bytes] = {}
        self._disk_offsets: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self._disk_file = None
        self._disk_tail = 0
        if cfg.backend.disk_fallback_path:
            self._disk_file = open(cfg.backend.disk_fallback_path, "w+b")
        self._free_page_probe = None  # guest free-page detector hook (§7.2)
        # CRC of an all-zero MP is constant: the zero-page fault fast path
        # compares against it instead of recomputing a CRC per fault
        self.zero_crc = zlib.crc32(bytes(cfg.mp_bytes))

    # ------------------------------------------------------------- swap-out
    def store(self, gfn: int, mp: int, data: np.ndarray) -> Tuple[int, int]:
        """Store one MP. Returns (backend_kind, crc32-of-original)."""
        bk = self.cfg.backend
        crc = zlib.crc32(data) if bk.crc_enabled else 0
        raw = data.tobytes()

        if bk.free_page_enabled and self._free_page_probe is not None \
                and self._free_page_probe(gfn, mp):
            # guest says the page is free: drop content entirely
            return K_FREE, crc

        if bk.zero_page_enabled and not np.any(data):
            self.metrics.backend_zero_mps += 1
            return K_ZERO, crc

        if bk.compression_enabled:
            blob = zlib.compress(raw, bk.compression_level)
            if len(blob) < len(raw):
                with self._lock:
                    self._compressed[(gfn, mp)] = blob
                self.metrics.backend_compressed_mps += 1
                self.metrics.backend_raw_bytes += len(raw)
                self.metrics.backend_stored_bytes += len(blob)
                return K_COMPRESSED, crc

        if self._disk_file is not None:
            with self._lock:
                off = self._disk_tail
                self._disk_file.seek(off)
                self._disk_file.write(raw)
                self._disk_tail += len(raw)
                self._disk_offsets[(gfn, mp)] = (off, len(raw))
            return K_DISK, crc

        # incompressible and no disk tier: store verbatim in the
        # compressed map (zswap does the same for incompressible pages)
        with self._lock:
            self._compressed[(gfn, mp)] = raw
        self.metrics.backend_compressed_mps += 1
        self.metrics.backend_raw_bytes += len(raw)
        self.metrics.backend_stored_bytes += len(raw)
        return K_COMPRESSED, crc

    # -------------------------------------------------------------- swap-in
    def load(self, gfn: int, mp: int, kind: int, crc: int, out: np.ndarray) -> None:
        """Load one MP into ``out`` (a view of the physical MS). Verifies CRC."""
        if kind == K_ZERO or kind == K_FREE:
            out[:] = 0
            self.metrics.fault_zero_pages += 1
        elif kind == K_COMPRESSED:
            with self._lock:
                blob = self._compressed.pop((gfn, mp))
            raw = zlib.decompress(blob) if len(blob) < len(out) else blob
            if len(raw) != len(out):
                # stored verbatim (incompressible path)
                raw = blob
            out[:] = np.frombuffer(raw, dtype=np.uint8)
            self.metrics.fault_compressed_pages += 1
        elif kind == K_DISK:
            with self._lock:
                off, n = self._disk_offsets.pop((gfn, mp))
                self._disk_file.seek(off)
                raw = self._disk_file.read(n)
            out[:] = np.frombuffer(raw, dtype=np.uint8)
        elif kind == K_NONE:
            raise CorruptionError(f"no backend entry for gfn={gfn} mp={mp}")
        else:
            raise CorruptionError(f"unknown backend kind {kind}")

        if self.cfg.backend.crc_enabled:
            self.metrics.crc_checks += 1
            actual = zlib.crc32(out)
            if actual != crc:
                self.metrics.crc_failures += 1
                raise CorruptionError(
                    f"CRC mismatch gfn={gfn} mp={mp}: {actual:#x} != {crc:#x}")

    def drop(self, gfn: int, mp: int, kind: int) -> None:
        """Discard a stored MP without loading (e.g. MS freed by the guest)."""
        if kind == K_COMPRESSED:
            with self._lock:
                self._compressed.pop((gfn, mp), None)
        elif kind == K_DISK:
            with self._lock:
                self._disk_offsets.pop((gfn, mp), None)

    # ------------------------------------------------------------- accounting
    def stored_bytes(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._compressed.values())

    def set_free_page_probe(self, probe) -> None:
        self._free_page_probe = probe

    def close(self) -> None:
        if self._disk_file is not None:
            path = self._disk_file.name
            self._disk_file.close()
            if os.path.exists(path):
                os.unlink(path)
