"""Swap backend stores (paper §4.2.2 "backend", §7.2, Fig 15c).

    "Taiji uses in-memory zero pages and compression, prioritizing zero
     pages to minimize backend latency."  (§4.2.2)
    "Taiji's backend storage supports zero, compressed, free pages, remote
     memory, and disks."  (§7.2)

Store selection per MP on swap-out:
  1. zero page  -- store nothing but the kind tag; swap-in is a memset.
  2. free page  -- guest-reported free pages: drop content, rebuild zeroed
     on swap-in (disabled by default, as in production, §7.2).
  3. compressed -- lossless (zlib level 1 ~ lz4-class latency); the paper
     reports a 47.63% compressed/raw ratio over this population.
  4. disk       -- optional fallback tier for bursts beyond elasticity.

All stores are exact (lossless): CRC32 over the original MP guards the
round trip (§7.1). The *lossy* int8 KV-cache backend used by the device
integration is a beyond-paper option and lives in kernels/compress.py.

Concurrency: the former single global lock is split per kind and per
shard -- the compressed tier stripes its lock by ``(gfn, mp)`` hash
(``cfg.backend.lock_shards``), the disk tier has its own lock -- so
parallel swaps of different MSs no longer serialize on one mutex. The
batched entry points (:meth:`store_batch` / :meth:`load_batch`) move a
whole MP index vector per call: one vectorized zero scan, CRCs only for
non-zero rows (the zero-page CRC is a constant), and one lock acquisition
per touched shard instead of one per MP.

Extents: a batch's non-zero rows are concatenated and compressed as ONE
zlib stream (an *extent*); per-MP map entries are ``("x", extent_id,
row)`` references. One zlib call amortizes the per-call setup cost that
dominates 4 KiB-page compression, and cross-row redundancy compresses
better than row-at-a-time. A scalar fault on an extent row decompresses
the extent once and caches it raw so sibling faults are slice-only; with
``SwapConfig.readahead_enabled`` the swap engine goes further and
materializes every still-swapped sibling row on the first fault
(:meth:`extent_members` / :meth:`consume_extent_rows`). The map format
is process-local (never in the mpool arena), so none of this changes a
persistent ABI.

Entry tagging: every in-memory map value carries an explicit kind
subcode -- ``("z", blob)`` zlib-compressed, ``("v", raw)`` verbatim
(incompressible), ``("x", eid, row)`` extent reference -- instead of the
old ``len(blob) < len(out)`` sniffing, which silently double-decoded a
verbatim page whose bytes happened to look short.
"""
from __future__ import annotations

import os
import threading
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..analysis.lock_order import named_lock
from ..obs.tracer import (ST_BACKEND_REMOTE_GET, ST_BACKEND_REMOTE_PUT,
                          ST_KERNEL_LOAD, ST_KERNEL_STORE, ST_SWAP_COMPRESS,
                          ST_SWAP_DECOMPRESS)
from .config import TaijiConfig
from .errors import CorruptionError
from .metrics import Metrics
from .ms import K_COMPRESSED, K_DISK, K_FREE, K_NONE, K_ZERO

_perf_ns = time.perf_counter_ns

# ------------------------------------------------- modeled tier latency
# Per-tier service times as *data, not measurement* (the tracehm/flatmem
# discipline: `Memory(capacity, read_lat, write_lat)` accrues a declared
# latency per access, so placement policies are comparable on a laptop
# before any real transport exists). Values are per-MP figures for the
# in-production tiers the paper names (§7.2): a zero fill is a memset, a
# compressed load is one lz4-class decompress share, disk is an NVMe
# read, and the remote tier is one RTT on a DPU-to-DPU RDMA fabric
# (DxPU-class fabrics measure 10-20us round trips at 4KiB). `load_batch`
# accrues these into `modeled_load_ns`; the remote put/get paths accrue
# `REMOTE_*_LAT_NS` into `remote_modeled_ns`.
TIER_READ_LAT_NS = {K_ZERO: 500, K_FREE: 500, K_COMPRESSED: 2_500,
                    K_DISK: 100_000}
REMOTE_READ_LAT_NS = 12_000    # peer DRAM fetch: one RTT + payload
REMOTE_WRITE_LAT_NS = 18_000   # replica placement: RTT + remote store


def modeled_policy_ns(n_local: int, n_remote: int, policy: str) -> int:
    """Modeled total swap-in service time under a placement policy.

    flatmem's FastSwap/SlowSwap/SmartSwap trio, recast for the
    zero-copy-free world of modeled latencies: ``fast`` keeps every
    payload in local compressed DRAM (cheapest loads, no durability),
    ``slow`` pushes everything to the remote peer tier (every load pays
    the RTT), ``smart`` is the deployed split -- locals load locally and
    only the replicated fully-swapped population pays remote latency
    when (and only when) recovery actually needs a peer copy.
    """
    local = TIER_READ_LAT_NS[K_COMPRESSED]
    total = n_local + n_remote
    if policy == "fast":
        return total * local
    if policy == "slow":
        return total * REMOTE_READ_LAT_NS
    if policy == "smart":
        return n_local * local + n_remote * REMOTE_READ_LAT_NS
    raise ValueError(f"unknown placement policy {policy!r}")


class _Extent:
    """One batch-compressed extent: a joint zlib stream over N MP rows.

    ``mps[row]`` maps each row back to its MP index (the readahead path
    materializes siblings through it); ``remaining`` counts live rows;
    ``dropped`` counts rows discarded via :meth:`BackendStore.drop` so
    their integer-spread share of ``stored_len`` can be returned to the
    compression accounting exactly.
    """

    __slots__ = ("payload", "is_raw", "remaining", "stored_len", "mps",
                 "total", "dropped", "crc", "verified", "tags")

    def __init__(self, payload: bytes, stored_len: int, mps: List[int],
                 crc: int, tags: Optional[np.ndarray] = None) -> None:
        self.payload = payload       # zlib stream, or raw once cached
        self.is_raw = False
        self.remaining = len(mps)
        self.stored_len = stored_len
        self.mps = mps               # row -> MP index
        self.total = len(mps)
        self.dropped = 0
        # whole-extent CRC over the raw concatenation: readahead verifies
        # the decompressed buffer with ONE crc32 call instead of one per
        # row (verified latches so sibling materializations skip recheck)
        self.crc = crc
        self.verified = False
        # device-side per-row Fletcher tags (kernels/crc32c.py) when the
        # Pallas data path is on; None on the host-only path
        self.tags = tags


class BackendStore:
    """Unified backend over the zero/free/compressed/disk tiers."""

    def __init__(self, cfg: TaijiConfig, metrics: Metrics) -> None:
        self.cfg = cfg
        self.metrics = metrics
        # per-shard lock stripe over the compressed map; each (gfn, mp) key
        # maps to exactly one stripe, so per-key ops never race. Values are
        # explicitly tagged tuples: ("z", blob) zlib, ("v", raw) verbatim,
        # ("x", eid, row) extent reference into self._extents.
        self._locks: List[threading.Lock] = [
            named_lock("backend.shard")
            for _ in range(max(1, cfg.backend.lock_shards))]
        self._compressed: Dict[Tuple[int, int], tuple] = {}
        # batch extents: (gfn, eid) -> _Extent; the payload is the zlib
        # stream until the first partial load caches it raw, stored_len
        # stays the compressed size so accounting is unaffected
        self._ext_lock = named_lock("backend.ext")
        self._extents: Dict[Tuple[int, int], _Extent] = {}
        self._ext_seq = 0
        # per-kind lock: the disk tier appends through its own mutex
        self._disk_lock = named_lock("backend.disk")
        self._disk_offsets: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self._disk_file = None
        self._disk_tail = 0
        if cfg.backend.disk_fallback_path:
            self._disk_file = open(cfg.backend.disk_fallback_path, "w+b")
        self._free_page_probe = None  # guest free-page detector hook (§7.2)
        # CRC of an all-zero MP is constant: the zero-page fault fast path
        # compares against it instead of recomputing a CRC per fault
        self.zero_crc = zlib.crc32(bytes(cfg.mp_bytes))
        hp = getattr(cfg.swap, "hot_path", None)
        if cfg.swap.use_pallas_kernels:
            from ..kernels import ops as _kops
            self._kernel_zero_detect = _kops.batch_zero_detect
            # device-side Fletcher integrity tags per extent row; the
            # zlib CRCs stored in MS records are unchanged (hot-upgrade
            # ABI stays byte-compatible), this is an extra check the
            # device can run without the host
            self._kernel_checksum = _kops.batch_checksum
        else:
            self._kernel_zero_detect = None
            self._kernel_checksum = None
        # extent (de)compression worker pool (HotPathConfig.compress_workers):
        # zlib releases the GIL, so extents compress in parallel; results
        # always merge in submission order so the stored bytes are
        # identical for any worker count. Lazily created: most systems in
        # tests never swap enough to need it.
        self._pool = None
        self._pool_lock = named_lock("backend.pool")
        self._pool_workers = int(hp.compress_workers) if hp is not None else 0
        # decoded-extent LRU (ISSUE 8): bounded cache of decompressed
        # extent payloads keyed (gfn, eid), guarded by _ext_lock. With it
        # enabled, extents keep their compressed payload and sibling-MP
        # faults / readahead serve decoded bytes from here -- skipping
        # zlib entirely on a hit -- while decoded retention stays bounded
        # at `extent_cache_entries` buffers instead of one raw buffer per
        # live extent. Inserts verify against the stored whole-extent CRC
        # (a corrupt stream never enters the cache); entries die with
        # their extent (drop / consume_extent_rows) or by LRU eviction.
        # 0 keeps the legacy decompress-in-place behavior.
        self._ext_cache_cap = (int(getattr(hp, "extent_cache_entries", 0) or 0)
                               if hp is not None else 0)
        from collections import OrderedDict as _OD
        self._ext_cache: "Dict[Tuple[int, int], bytes]" = _OD()
        self.ext_cache_hits = 0
        self.ext_cache_misses = 0
        # remote-peer tier (ISSUE 9): replica blobs this store holds ON
        # BEHALF OF other nodes, keyed (owner_node_id, gfn). The fleet
        # controller brokers placement (leases) and calls remote_put /
        # remote_get / remote_drop through the owning NodeAgent; a
        # single-node system never touches this map. Blobs are opaque
        # (zlib over the owner's export image) with their own CRC, so a
        # peer can hand back bytes it cannot interpret.
        self._remote_lock = named_lock("backend.remote")
        self._remote: Dict[Tuple[int, int], Tuple[bytes, int]] = {}
        self.remote_puts = 0
        self.remote_gets = 0
        self.remote_drops = 0
        self.remote_held_bytes = 0
        self.remote_modeled_ns = 0     # accrued REMOTE_*_LAT_NS (data)
        self.modeled_load_ns = 0       # accrued TIER_READ_LAT_NS (data)
        # stage-attributed tracing (repro.obs): spans for the compress
        # fan-out and the device kernel calls; None when disabled
        self._tr = metrics.tracer

    def _compress_pool(self):
        """The lazy extent-compression pool, or ``None`` for the serial
        path (``compress_workers <= 1``)."""
        if self._pool_workers <= 1:
            return None
        if self._pool is None:
            with self._pool_lock:
                if self._pool is None:
                    from concurrent.futures import ThreadPoolExecutor
                    self._pool = ThreadPoolExecutor(
                        max_workers=self._pool_workers,
                        thread_name_prefix="taiji-ext")
        return self._pool

    def _shard_idx(self, gfn: int, mp: int) -> int:
        return (gfn * 1000003 + mp) % len(self._locks)

    def _shard(self, gfn: int, mp: int) -> threading.Lock:
        return self._locks[self._shard_idx(gfn, mp)]

    # ------------------------------------------------------------- swap-out
    def store(self, gfn: int, mp: int, data: np.ndarray) -> Tuple[int, int]:
        """Store one MP. Returns (backend_kind, crc32-of-original)."""
        bk = self.cfg.backend
        crc = zlib.crc32(data) if bk.crc_enabled else 0
        raw = data.tobytes()

        if bk.free_page_enabled and self._free_page_probe is not None \
                and self._free_page_probe(gfn, mp):
            # guest says the page is free: drop content entirely
            return K_FREE, crc

        if bk.zero_page_enabled and not np.any(data):
            self.metrics.backend_zero_mps += 1
            return K_ZERO, crc

        if bk.compression_enabled:
            blob = zlib.compress(raw, bk.compression_level)
            if len(blob) < len(raw):
                with self._shard(gfn, mp):
                    self._compressed[(gfn, mp)] = ("z", blob)
                self.metrics.backend_compressed_mps += 1
                self.metrics.backend_raw_bytes += len(raw)
                self.metrics.backend_stored_bytes += len(blob)
                return K_COMPRESSED, crc

        if self._disk_file is not None:
            with self._disk_lock:
                off = self._disk_tail
                self._disk_file.seek(off)
                self._disk_file.write(raw)
                self._disk_tail += len(raw)
                self._disk_offsets[(gfn, mp)] = (off, len(raw))
            return K_DISK, crc

        # incompressible and no disk tier: store verbatim in the
        # compressed map (zswap does the same for incompressible pages)
        with self._shard(gfn, mp):
            self._compressed[(gfn, mp)] = ("v", raw)
        self.metrics.backend_compressed_mps += 1
        self.metrics.backend_raw_bytes += len(raw)
        self.metrics.backend_stored_bytes += len(raw)
        return K_COMPRESSED, crc

    # -------------------------------------------------------------- swap-in
    def _read_entry(self, gfn: int, mp: int, kind: int,
                    out: np.ndarray) -> Optional[tuple]:
        """Materialize one stored MP into ``out`` without consuming it.

        Shared by the consuming :meth:`load` (fault path) and the
        non-consuming :meth:`peek` (migration export). Returns the
        compressed-map entry -- ``load`` needs it to release an extent
        row -- or ``None`` for zero/free/disk kinds.
        """
        if kind == K_ZERO or kind == K_FREE:
            out[:] = 0
            return None
        if kind == K_COMPRESSED:
            with self._shard(gfn, mp):
                entry = self._compressed.get((gfn, mp))
            if entry is None:
                raise CorruptionError(
                    f"no backend entry for gfn={gfn} mp={mp}")
            tag = entry[0]
            if tag == "x":                        # extent reference
                n = self.cfg.mp_bytes
                row = entry[2]
                raw = self._ext_peek(gfn, entry[1])[row * n:(row + 1) * n]
            elif tag == "z":                      # zlib blob
                raw = zlib.decompress(entry[1])
            else:                                 # "v": stored verbatim
                raw = entry[1]
            out[:] = np.frombuffer(raw, dtype=np.uint8)
            return entry
        if kind == K_DISK:
            with self._disk_lock:
                loc = self._disk_offsets.get((gfn, mp))
                if loc is None:
                    raise CorruptionError(
                        f"no disk entry for gfn={gfn} mp={mp}")
                self._disk_file.seek(loc[0])
                raw = self._disk_file.read(loc[1])
            out[:] = np.frombuffer(raw, dtype=np.uint8)
            return None
        if kind == K_NONE:
            raise CorruptionError(f"no backend entry for gfn={gfn} mp={mp}")
        raise CorruptionError(f"unknown backend kind {kind}")

    def peek(self, gfn: int, mp: int, kind: int, crc: int,
             out: np.ndarray) -> None:
        """Non-consuming :meth:`load`: fill ``out`` with the stored MP and
        verify its CRC, leaving the backend entry and the compression
        accounting untouched.

        The migration export path reads a source MS's swapped state
        through this, so a rejected or failed migration leaves the source
        exactly as it was. Not a fault: the fault_* page counters are not
        bumped (CRC checks still are).
        """
        self._read_entry(gfn, mp, kind, out)
        if self.cfg.backend.crc_enabled:
            self.metrics.crc_checks += 1
            actual = zlib.crc32(out)
            if actual != crc:
                self.metrics.crc_failures += 1
                raise CorruptionError(
                    f"CRC mismatch gfn={gfn} mp={mp}: {actual:#x} != {crc:#x}")

    def load(self, gfn: int, mp: int, kind: int, crc: int, out: np.ndarray) -> None:
        """Load one MP into ``out`` (a view of the physical MS).

        Verifies the CRC *before* consuming the backend entry, so a
        corrupt MP keeps failing detectably on every retry instead of
        losing its data to the first failed attempt.
        """
        entry = self._read_entry(gfn, mp, kind, out)
        if kind == K_ZERO or kind == K_FREE:
            self.metrics.fault_zero_pages += 1
        elif kind == K_COMPRESSED:
            self.metrics.fault_compressed_pages += 1

        if self.cfg.backend.crc_enabled:
            self.metrics.crc_checks += 1
            actual = zlib.crc32(out)
            if actual != crc:
                self.metrics.crc_failures += 1
                raise CorruptionError(
                    f"CRC mismatch gfn={gfn} mp={mp}: {actual:#x} != {crc:#x}")

        # verified: consume the entry
        if kind == K_COMPRESSED:
            with self._shard(gfn, mp):
                self._compressed.pop((gfn, mp), None)
            if entry[0] == "x":
                self._ext_release(gfn, entry[1], 1)
        elif kind == K_DISK:
            with self._disk_lock:
                self._disk_offsets.pop((gfn, mp), None)

    def drop(self, gfn: int, mp: int, kind: int) -> None:
        """Discard a stored MP without loading (e.g. MS freed by the guest).

        Dropped pages leave the compression accounting too: they exit the
        swapped population without a round trip, so keeping their bytes in
        ``backend_raw_bytes``/``backend_stored_bytes`` would skew
        ``compression_ratio`` ever further on long runs with guest frees.
        Extent rows return an exact integer-spread share of the extent's
        compressed size.
        """
        if kind == K_COMPRESSED:
            with self._shard(gfn, mp):
                entry = self._compressed.pop((gfn, mp), None)
            if entry is None:
                return
            m = self.metrics
            tag = entry[0]
            if tag == "x":
                with self._ext_lock:
                    ext = self._extents.get((gfn, entry[1]))
                    if ext is not None:
                        d = ext.dropped
                        share = (ext.stored_len * (d + 1) // ext.total
                                 - ext.stored_len * d // ext.total)
                        ext.dropped = d + 1
                        ext.remaining -= 1
                        if ext.remaining == 0:
                            del self._extents[(gfn, entry[1])]
                            self._ext_cache.pop((gfn, entry[1]), None)
                        m.backend_raw_bytes -= self.cfg.mp_bytes
                        m.backend_stored_bytes -= share
            else:                                 # "z" or "v" blob
                m.backend_raw_bytes -= self.cfg.mp_bytes
                m.backend_stored_bytes -= len(entry[1])
        elif kind == K_DISK:
            with self._disk_lock:
                self._disk_offsets.pop((gfn, mp), None)

    # ----------------------------------------------------------------- extents
    def _ext_cache_insert(self, key: Tuple[int, int], ext: _Extent,
                          raw: bytes) -> None:
        """Insert decoded bytes into the bounded LRU (caller holds
        ``_ext_lock``). Verifies against the stored whole-extent CRC
        first -- an unverifiable stream is served to the caller (whose
        own salvage path handles corruption) but never cached."""
        if self.cfg.backend.crc_enabled and not ext.verified:
            if zlib.crc32(raw) != ext.crc:
                return
            ext.verified = True
        cache = self._ext_cache
        cache[key] = raw
        while len(cache) > self._ext_cache_cap:
            cache.popitem(last=False)

    def _ext_raw(self, key: Tuple[int, int], ext: _Extent,
                 count: bool = True) -> bytes:
        """Raw payload of one extent. Callers hold ``_ext_lock``.

        Legacy mode (``extent_cache_entries == 0``): decompress + cache
        in place on the extent exactly once, so sibling rows are
        slice-only but the raw buffer lives as long as the extent. Cache
        mode: decoded payloads live in the bounded LRU instead -- a hit
        skips zlib entirely; after eviction the extent re-decompresses
        from its (still-compressed) payload."""
        if ext.is_raw:
            return ext.payload
        if self._ext_cache_cap <= 0:
            ext.payload = zlib.decompress(ext.payload)
            ext.is_raw = True
            return ext.payload
        cache = self._ext_cache
        raw = cache.get(key)
        if raw is not None:
            cache.move_to_end(key)
            if count:
                self.ext_cache_hits += 1
            return raw
        if count:
            self.ext_cache_misses += 1
        raw = zlib.decompress(ext.payload)
        self._ext_cache_insert(key, ext, raw)
        return raw

    def _ext_peek(self, gfn: int, eid: int, count: bool = True) -> bytes:
        """Return the whole raw buffer of an extent without consuming any
        rows (decompresses on first touch; cached raw thereafter).
        ``count=False`` skips the hit/miss counters -- used by
        :meth:`load_batch` right after :meth:`_ext_prefetch_raw` already
        charged this extent, so each touch is counted exactly once."""
        with self._ext_lock:
            return self._ext_raw((gfn, eid), self._extents[(gfn, eid)],
                                 count=count)

    def _ext_prefetch_raw(self, gfn: int, eids: List[int]) -> None:
        """Decompress several extents' payloads concurrently through the
        worker pool, then install the raw buffers under ``_ext_lock``.

        Purely an optimization of :meth:`_ext_peek`: installation
        rechecks ``is_raw`` so a racing decompress (scalar fault, other
        batch) simply wins the cache; the bytes are identical either way.
        """
        pool = self._compress_pool()
        with self._ext_lock:
            todo = []
            for eid in eids:
                ext = self._extents.get((gfn, eid))
                if ext is None or ext.is_raw:
                    continue
                if self._ext_cache_cap > 0:
                    if (gfn, eid) in self._ext_cache:
                        # readahead served from the decoded-extent LRU:
                        # count the hit and refresh recency, exactly as a
                        # scalar fault through _ext_raw would (ISSUE 9)
                        self._ext_cache.move_to_end((gfn, eid))
                        self.ext_cache_hits += 1
                        continue
                    self.ext_cache_misses += 1
                todo.append((eid, ext.payload))
        if not todo:
            return
        if pool is not None and len(todo) > 1:
            raws = list(pool.map(zlib.decompress, [p for _, p in todo]))
        else:
            raws = [zlib.decompress(p) for _, p in todo]
        with self._ext_lock:
            for (eid, _), raw in zip(todo, raws):
                ext = self._extents.get((gfn, eid))
                if ext is None or ext.is_raw:
                    continue
                if self._ext_cache_cap > 0:
                    if (gfn, eid) not in self._ext_cache:
                        self._ext_cache_insert((gfn, eid), ext, raw)
                else:
                    ext.payload = raw
                    ext.is_raw = True

    def _ext_verify_tags(self, gfn: int, eid: int, arr: np.ndarray) -> None:
        """Device-side integrity check: recompute the extent's per-row
        Fletcher tags through the kernel and compare with the tags taken
        at store time. The zlib CRC check against the MS record still
        runs afterwards -- this is the check a DPU offload can run
        without host help."""
        with self._ext_lock:
            ext = self._extents.get((gfn, eid))
            tags = ext.tags if ext is not None else None
        if tags is None:
            return
        actual = np.asarray(self._kernel_checksum(arr))
        if (actual != tags).any():
            bad = int(np.flatnonzero(actual != tags)[0])
            self.metrics.crc_failures += 1
            raise CorruptionError(
                f"extent tag mismatch gfn={gfn} eid={eid} row={bad}")

    def _ext_release(self, gfn: int, eid: int, count: int) -> None:
        """Consume ``count`` rows of an extent, freeing it on the last."""
        with self._ext_lock:
            ext = self._extents.get((gfn, eid))
            if ext is None:
                return
            ext.remaining -= count
            if ext.remaining <= 0:
                del self._extents[(gfn, eid)]
                self._ext_cache.pop((gfn, eid), None)

    # ------------------------------------------------- extent readahead API
    def extent_members(self, gfn: int, mp: int):
        """Probe whether ``(gfn, mp)`` is stored as an extent row.

        Returns ``(eid, row, live)`` where ``live`` is the list of
        ``(mp, row)`` pairs whose *current* map entry still references
        this extent -- a member that was consumed and later re-swapped
        points at a different entry and must not be materialized from the
        stale row. ``None`` for standalone blobs. Nothing is consumed;
        the swap engine claims sibling MPs under the req's MP mutex (its
        ``bm_in`` latch makes the later :meth:`consume_extent_rows` safe).
        """
        with self._shard(gfn, mp):
            entry = self._compressed.get((gfn, mp))
        if entry is None or entry[0] != "x":
            return None
        eid = entry[1]
        with self._ext_lock:
            ext = self._extents.get((gfn, eid))
            if ext is None:
                return None
            members = list(ext.mps)
        live = []
        for row, mpj in enumerate(members):
            # plain dict read: per-key mutations happen under the caller's
            # req mutex / bm latches, so this view is stable for the caller
            if self._compressed.get((gfn, mpj)) == ("x", eid, row):
                live.append((mpj, row))
        return eid, entry[2], live

    def extent_payload(self, gfn: int, eid: int, verify: bool = False):
        """Whole raw extent buffer for readahead (decompressed exactly once).

        Returns ``(raw, crc_ok)``. With ``verify`` the raw buffer is
        checked against the whole-extent CRC -- one crc32 call covers
        every row, and the result latches so sibling materializations
        skip the recheck. ``crc_ok=False`` tells the engine to fall back
        to per-row salvage against the record CRCs.
        """
        with self._ext_lock:
            ext = self._extents[(gfn, eid)]
            raw = self._ext_raw((gfn, eid), ext)
            if not verify or ext.verified:
                return raw, True
            want = ext.crc
        ok = zlib.crc32(raw) == want
        if ok:
            with self._ext_lock:
                cur = self._extents.get((gfn, eid))
                if cur is ext:
                    ext.verified = True
        return raw, ok

    def consume_extent_rows(self, gfn: int, eid: int, mps: List[int]) -> None:
        """Retire ``mps`` rows of one extent after a verified readahead.

        Callers must hold every row's ``bm_in`` latch (exactly-once per
        MP), so each key is popped at most once. One lock acquisition per
        touched shard, not one per MP.
        """
        by_shard: Dict[int, List[int]] = {}
        for mp in mps:
            by_shard.setdefault(self._shard_idx(gfn, mp), []).append(mp)
        for shard, shard_mps in by_shard.items():
            with self._locks[shard]:
                for mp in shard_mps:
                    self._compressed.pop((gfn, mp), None)
        self._ext_release(gfn, eid, len(mps))

    # ================================================= remote-peer tier ==
    def remote_put(self, owner: int, gfn: int, blob: bytes,
                   crc: int) -> bool:
        """Hold a replica blob for ``(owner, gfn)`` on behalf of a peer.

        Idempotent overwrite: a re-replication after partial progress
        replaces the held bytes and re-counts the space exactly. Returns
        ``True`` (placement admission -- zone checks -- is the
        controller's job, not the store's).
        """
        tr = self._tr
        t0 = _perf_ns() if tr is not None else 0
        with self._remote_lock:
            prev = self._remote.get((owner, gfn))
            if prev is not None:
                self.remote_held_bytes -= len(prev[0])
            self._remote[(owner, gfn)] = (blob, crc)
            self.remote_puts += 1
            self.remote_held_bytes += len(blob)
            self.remote_modeled_ns += REMOTE_WRITE_LAT_NS
        if tr is not None:
            tr.push(ST_BACKEND_REMOTE_PUT, t0, _perf_ns() - t0)
        return True

    def remote_get(self, owner: int, gfn: int) -> Optional[bytes]:
        """Fetch (without consuming) the replica held for ``(owner,
        gfn)``. Verifies the blob against its put-time CRC -- a bit-rot
        replica returns ``None`` rather than corrupt bytes, and the
        caller treats it like a missing copy."""
        tr = self._tr
        t0 = _perf_ns() if tr is not None else 0
        with self._remote_lock:
            entry = self._remote.get((owner, gfn))
            self.remote_gets += 1
            self.remote_modeled_ns += REMOTE_READ_LAT_NS
        if tr is not None:
            tr.push(ST_BACKEND_REMOTE_GET, t0, _perf_ns() - t0)
        if entry is None:
            return None
        blob, crc = entry
        if zlib.crc32(blob) != crc:
            self.metrics.crc_failures += 1
            return None
        return blob

    def remote_drop(self, owner: int, gfn: int) -> bool:
        """Release the replica held for ``(owner, gfn)`` (lease broken:
        owner wrote the MS, freed it, or the lease moved elsewhere)."""
        with self._remote_lock:
            entry = self._remote.pop((owner, gfn), None)
            if entry is None:
                return False
            self.remote_drops += 1
            self.remote_held_bytes -= len(entry[0])
        return True

    def remote_held(self) -> int:
        """Number of peer replicas currently held by this store."""
        with self._remote_lock:
            return len(self._remote)

    # ================================================== batched data path ==
    def store_batch(self, gfn: int, mps: np.ndarray, data: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Store ``data[i]`` (uint8 rows) as MP ``mps[i]`` of ``gfn``.

        Returns ``(kinds, crcs)`` aligned with ``mps``. Observationally
        identical to ``store`` called per row: same kind selection, same
        zlib CRCs, same round-trip bytes. The on-backend representation
        may differ -- without a disk tier, non-zero rows are stored as one
        joint extent rather than per-row blobs. One vectorized zero scan
        covers the whole batch; zero rows reuse the constant zero-page
        CRC instead of recomputing it.
        """
        bk = self.cfg.backend
        k = len(mps)
        assert data.shape == (k, self.cfg.mp_bytes)
        kinds = np.full(k, K_NONE, dtype=np.uint8)
        crcs = np.zeros(k, dtype=np.uint32)
        tr = self._tr

        if self._kernel_zero_detect is not None:
            if tr is not None:
                t_k = _perf_ns()
            zero = self._kernel_zero_detect(data)
            if tr is not None:
                tr.push(ST_KERNEL_STORE, t_k, _perf_ns() - t_k)
        else:
            zero = ~data.any(axis=1)

        free_rows: List[int] = []
        if bk.free_page_enabled and self._free_page_probe is not None:
            free_rows = [i for i in range(k)
                         if self._free_page_probe(gfn, int(mps[i]))]

        if bk.crc_enabled:
            # an all-zero row's CRC is the constant zero-page CRC, so only
            # non-zero rows pay a crc32 pass
            crcs[:] = self.zero_crc
            nz = np.flatnonzero(~zero).tolist()
            if nz:
                crcs[nz] = [zlib.crc32(data[i]) for i in nz]

        if free_rows:
            kinds[free_rows] = K_FREE

        zero_rows = np.flatnonzero(zero) if bk.zero_page_enabled else np.empty(0, int)
        zero_rows = [i for i in zero_rows if kinds[i] == K_NONE]
        kinds[zero_rows] = K_ZERO
        self.metrics.backend_zero_mps += len(zero_rows)

        # compress the remainder as extents: one zlib stream over a run of
        # concatenated rows amortizes the per-call setup that dominates
        # small-page compression and exploits cross-row redundancy.
        # ``extent_max_rows`` caps each stream so the first fault into an
        # extent (which decompresses it whole) has a bounded latency.
        rest = np.flatnonzero(kinds == K_NONE)
        raw_total = stored_total = compressed_n = 0
        pending: Dict[int, List[Tuple[Tuple[int, int], object]]] = {}
        disk_rows: List[Tuple[int, bytes]] = []
        # the extent fast path only applies without a disk tier: with one
        # configured, kind selection must stay scalar-identical (each
        # incompressible row spills to disk, not into a resident extent)
        use_extent = bk.compression_enabled and self._disk_file is None
        if len(rest) and use_extent:
            max_rows = max(1, bk.extent_max_rows)
            leftovers: List[np.ndarray] = []
            # chunk boundaries are fixed by extent_max_rows, zlib.compress
            # is deterministic, and the pool merges in submission order:
            # the stored bytes are identical for any worker count
            chunks = [rest[lo:lo + max_rows]
                      for lo in range(0, len(rest), max_rows)]
            raw_cats = [data[sub].tobytes() for sub in chunks]
            level = bk.compression_level
            pool = self._compress_pool() if len(chunks) > 1 else None
            # one swap_compress span covers the whole fan-out's wall time
            # on the issuing thread (per-worker spans would overlap and
            # sum past the enclosing backend_store span)
            if tr is not None:
                t_z = _perf_ns()
            if pool is not None:
                ext_blobs = list(pool.map(
                    lambda rc: zlib.compress(rc, level), raw_cats))
            else:
                ext_blobs = [zlib.compress(rc, level) for rc in raw_cats]
            if tr is not None:
                tr.push(ST_SWAP_COMPRESS, t_z, _perf_ns() - t_z)
            row_tags = None
            if self._kernel_checksum is not None:
                # one device kernel call tags every extent row in the batch
                if tr is not None:
                    t_k = _perf_ns()
                row_tags = np.asarray(self._kernel_checksum(data))
                if tr is not None:
                    tr.push(ST_KERNEL_STORE, t_k, _perf_ns() - t_k)
            for sub, raw_cat, ext_blob in zip(chunks, raw_cats, ext_blobs):
                if len(ext_blob) >= len(raw_cat):
                    leftovers.append(sub)     # incompressible: per-row path
                    continue
                ext_mps = [int(mps[i]) for i in sub]
                ext_crc = zlib.crc32(raw_cat) if bk.crc_enabled else 0
                tags = row_tags[sub].copy() if row_tags is not None else None
                with self._ext_lock:
                    eid = self._ext_seq
                    self._ext_seq += 1
                    self._extents[(gfn, eid)] = _Extent(
                        ext_blob, len(ext_blob), ext_mps, ext_crc, tags)
                for row, i in enumerate(sub):
                    kinds[i] = K_COMPRESSED
                    mp = ext_mps[row]
                    pending.setdefault(self._shard_idx(gfn, mp), []).append(
                        (((gfn, mp)), ("x", eid, row)))
                compressed_n += len(sub)
                raw_total += len(raw_cat)
                stored_total += len(ext_blob)
            rest = (np.concatenate(leftovers) if leftovers
                    else rest[:0])
        if tr is not None and len(rest):
            t_z = _perf_ns()
        for i in rest:
            # per-row fallback: same tier order as the scalar store()
            raw = data[i].tobytes()
            blob = None
            if bk.compression_enabled:
                z = zlib.compress(raw, bk.compression_level)
                if len(z) < len(raw):
                    blob = z
            if blob is None and self._disk_file is not None:
                disk_rows.append((int(i), raw))
                kinds[i] = K_DISK
                continue
            # verbatim ("v") when incompressible, like the scalar store()
            entry = ("z", blob) if blob is not None else ("v", raw)
            kinds[i] = K_COMPRESSED
            compressed_n += 1
            raw_total += len(raw)
            stored_total += len(entry[1])
            mp = int(mps[i])
            pending.setdefault(self._shard_idx(gfn, mp), []).append(
                ((gfn, mp), entry))
        if tr is not None and len(rest):
            tr.push(ST_SWAP_COMPRESS, t_z, _perf_ns() - t_z)

        # one lock acquisition per touched shard, not one per MP
        for shard, entries in pending.items():
            with self._locks[shard]:
                for key, entry in entries:
                    self._compressed[key] = entry
        if disk_rows:
            with self._disk_lock:
                for i, raw in disk_rows:
                    off = self._disk_tail
                    self._disk_file.seek(off)
                    self._disk_file.write(raw)
                    self._disk_tail += len(raw)
                    self._disk_offsets[(gfn, int(mps[i]))] = (off, len(raw))

        self.metrics.backend_compressed_mps += compressed_n
        self.metrics.backend_raw_bytes += raw_total
        self.metrics.backend_stored_bytes += stored_total
        self.metrics.backend_batch_stores += 1
        return kinds, crcs

    def load_batch(self, gfn: int, mps: np.ndarray, kinds: np.ndarray,
                   crcs: np.ndarray, out: np.ndarray) -> None:
        """Load MPs ``mps`` into the rows of ``out``; verifies CRCs.

        Zero/free rows are memset in one vectorized write and their CRCs
        checked against the constant zero-page CRC without touching the
        data; compressed/disk rows read their blobs with one lock
        acquisition per touched shard.

        All-or-nothing: backend entries are only consumed after every
        row's CRC verifies, so one corrupted MP doesn't take the rest of
        the chunk's data with it -- the caller can retry or fault the
        good rows individually, and the bad row keeps failing detectably.
        """
        bk = self.cfg.backend
        k = len(mps)
        assert out.shape == (k, self.cfg.mp_bytes)
        kinds = np.asarray(kinds)
        crcs = np.asarray(crcs)

        if np.any(kinds == K_NONE):
            i = int(np.flatnonzero(kinds == K_NONE)[0])
            raise CorruptionError(
                f"no backend entry for gfn={gfn} mp={int(mps[i])}")
        if np.any(kinds > K_DISK):        # kinds are dense 0..K_DISK
            raise CorruptionError(
                f"unknown backend kind {int(kinds.max())}")

        zero_mask = (kinds == K_ZERO) | (kinds == K_FREE)
        zero_rows = np.flatnonzero(zero_mask)
        if len(zero_rows):
            out[zero_rows] = 0
            self.metrics.fault_zero_pages += len(zero_rows)
            if bk.crc_enabled:
                self.metrics.crc_checks += len(zero_rows)
                bad = zero_rows[crcs[zero_rows] != self.zero_crc]
                if len(bad):
                    self.metrics.crc_failures += len(bad)
                    raise CorruptionError(
                        f"zero-page CRC mismatch gfn={gfn} "
                        f"mp={int(mps[int(bad[0])])}")

        comp_rows = np.flatnonzero(kinds == K_COMPRESSED)
        by_shard: Dict[int, List[int]] = {}
        by_ext: Dict[int, List[Tuple[int, int]]] = {}
        tr = self._tr
        if len(comp_rows):
            for i in comp_rows:
                by_shard.setdefault(
                    self._shard_idx(gfn, int(mps[i])), []).append(int(i))
            blobs: Dict[int, tuple] = {}
            for shard, rows in by_shard.items():
                with self._locks[shard]:
                    for i in rows:
                        blobs[i] = self._compressed[(gfn, int(mps[i]))]
            n = self.cfg.mp_bytes
            if tr is not None:
                t_dz = _perf_ns()
            for i in comp_rows:
                entry = blobs[int(i)]
                tag = entry[0]
                if tag == "x":                # extent ref: bulk-extract below
                    by_ext.setdefault(entry[1], []).append((int(i), entry[2]))
                elif tag == "z":
                    out[i] = np.frombuffer(zlib.decompress(entry[1]),
                                           dtype=np.uint8)
                else:                         # "v": stored verbatim
                    out[i] = np.frombuffer(entry[1], dtype=np.uint8)
            prefetched = len(by_ext) > 1
            if prefetched:
                # decompress the batch's extents in parallel (zlib drops
                # the GIL); each payload installs idempotently under the
                # extent lock, so racing a concurrent scalar fault is safe
                self._ext_prefetch_raw(gfn, list(by_ext))
            if tr is not None:
                tr.push(ST_SWAP_DECOMPRESS, t_dz, _perf_ns() - t_dz)
            for eid, pairs in by_ext.items():
                # one decompress + one scatter for all rows of this extent
                if tr is not None:
                    t_p = _perf_ns()
                raw = self._ext_peek(gfn, eid, count=not prefetched)
                if tr is not None:
                    # near-zero when the prefetch above already cached raw
                    tr.push(ST_SWAP_DECOMPRESS, t_p, _perf_ns() - t_p)
                arr = np.frombuffer(raw, dtype=np.uint8).reshape(-1, n)
                if self._kernel_checksum is not None:
                    if tr is not None:
                        t_k = _perf_ns()
                    self._ext_verify_tags(gfn, eid, arr)
                    if tr is not None:
                        tr.push(ST_KERNEL_LOAD, t_k, _perf_ns() - t_k)
                out[[p[0] for p in pairs]] = arr[[p[1] for p in pairs]]
            self.metrics.fault_compressed_pages += len(comp_rows)

        disk_rows = np.flatnonzero(kinds == K_DISK)
        if len(disk_rows):
            with self._disk_lock:
                for i in disk_rows:
                    off, n = self._disk_offsets[(gfn, int(mps[i]))]
                    self._disk_file.seek(off)
                    out[i] = np.frombuffer(self._disk_file.read(n),
                                           dtype=np.uint8)

        if bk.crc_enabled:
            data_rows = np.flatnonzero(~zero_mask).tolist()
            self.metrics.crc_checks += len(data_rows)
            want = crcs.tolist()
            for i in data_rows:
                actual = zlib.crc32(out[i])
                if actual != want[i]:
                    self.metrics.crc_failures += 1
                    raise CorruptionError(
                        f"CRC mismatch gfn={gfn} mp={int(mps[i])}: "
                        f"{actual:#x} != {want[i]:#x}")

        # every row verified: consume the entries (single pass per shard)
        for shard, rows in by_shard.items():
            with self._locks[shard]:
                for i in rows:
                    self._compressed.pop((gfn, int(mps[i])), None)
        for eid, pairs in by_ext.items():
            self._ext_release(gfn, eid, len(pairs))
        if len(disk_rows):
            with self._disk_lock:
                for i in disk_rows:
                    self._disk_offsets.pop((gfn, int(mps[i])), None)
        self.metrics.backend_batch_loads += 1
        # per-tier modeled service delay (data, not measurement): the
        # declared TIER_READ_LAT_NS figures accrue per row so placement
        # policies compare on modeled time regardless of host speed
        self.modeled_load_ns += (
            len(zero_rows) * TIER_READ_LAT_NS[K_ZERO]
            + len(comp_rows) * TIER_READ_LAT_NS[K_COMPRESSED]
            + len(disk_rows) * TIER_READ_LAT_NS[K_DISK])

    # ------------------------------------------------------------- accounting
    def stored_bytes(self) -> int:
        # lock stripes guard per-key mutation; summing a point-in-time
        # snapshot of the values only needs the GIL
        standalone = sum(len(e[1]) for e in list(self._compressed.values())
                         if e[0] != "x")
        extents = sum(e.stored_len for e in list(self._extents.values()))
        return standalone + extents

    def stats(self) -> Dict[str, int]:
        """Point-in-time operational counters: decoded-extent LRU
        hit/miss (ISSUE 9 satellite) and the remote-peer tier's held
        replicas and modeled latency totals."""
        with self._ext_lock:
            ext_entries = len(self._ext_cache)
        with self._remote_lock:
            remote_held = len(self._remote)
            remote_bytes = self.remote_held_bytes
        return {
            "ext_cache_hits": self.ext_cache_hits,
            "ext_cache_misses": self.ext_cache_misses,
            "ext_cache_entries": ext_entries,
            "remote_puts": self.remote_puts,
            "remote_gets": self.remote_gets,
            "remote_drops": self.remote_drops,
            "remote_held": remote_held,
            "remote_held_bytes": remote_bytes,
            "remote_modeled_ns": self.remote_modeled_ns,
            "modeled_load_ns": self.modeled_load_ns,
        }

    def set_free_page_probe(self, probe) -> None:
        self._free_page_probe = probe

    def close(self) -> None:
        with self._ext_lock:
            self._ext_cache.clear()
        with self._remote_lock:
            self._remote.clear()
            self.remote_held_bytes = 0
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
        if self._disk_file is not None:
            path = self._disk_file.name
            self._disk_file.close()
            if os.path.exists(path):
                os.unlink(path)
