"""TaijiSystem -- the assembled elastic-memory system.

Wires together the virtualization layer, mpool, backend, req tree, LRU,
watermark policy, swap engine, hv_sched and DMA registry, and exposes the
guest-facing API (allocate/free elastic MSs, read/write through the block
table). This is what the hot-switch produces from a running plain system
and what the framework integrations (elastic_kv / elastic_params) drive.
"""
from __future__ import annotations

import sys
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..analysis.lock_order import named_lock
from . import scheduler as sched
from .backend import BackendStore
from .config import TaijiConfig
from .dma import DMARegistry
from .errors import InvalidStateError
from .guest import GuestSpace
from .lru import MultiLevelLRU
from .metrics import Metrics
from .mpool import Mpool
from .req import ReqTree
from .swap import SwapEngine
from .virt import NO_PFN, PhysicalMemory, VirtualizationLayer
from .watermark import WatermarkPolicy


# once-per-site dedup for the deprecation shims: a hot loop driving a shim
# (a not-yet-migrated benchmark) must not pay -- or spam -- one warning per
# call, but distinct call sites each still get their one warning.  Keyed by
# the caller's (filename, lineno); never reset, matching the "warn once"
# contract rather than the warnings-filter lifecycle.
_warned_sites = set()


def _warn_deprecated(old: str, new: str) -> None:
    frame = sys._getframe(2)
    site = (frame.f_code.co_filename, frame.f_lineno)
    if site in _warned_sites:
        return
    _warned_sites.add(site)
    warnings.warn(f"{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


class TaijiSystem:
    def __init__(self, cfg: TaijiConfig,
                 phys: Optional[PhysicalMemory] = None) -> None:
        cfg.validate()
        self.cfg = cfg
        self.phys = phys or PhysicalMemory(cfg)
        self.mpool = Mpool(self.phys.mpool_arena(), cfg.mp_bytes)
        self.metrics = Metrics()
        if cfg.obs.enabled:
            # attach before any component constructs: backend/engine/guest
            # cache ``metrics.tracer`` once at their own __init__
            from repro.obs.tracer import SpanTracer
            self.metrics.tracer = SpanTracer(cap=cfg.obs.ring_capacity,
                                             max_spans=cfg.obs.max_spans)
        self.virt = VirtualizationLayer(cfg, self.phys, self.mpool)
        self.backend = BackendStore(cfg, self.metrics)
        self.reqs = ReqTree(cfg, self.mpool)
        self.lru = MultiLevelLRU(cfg, self.virt.table.test_and_clear_accessed)
        self.watermark = WatermarkPolicy(cfg)
        self.engine = SwapEngine(cfg, self.virt, self.backend, self.reqs,
                                 self.lru, self.watermark, self.metrics)
        self.scheduler = sched.HvScheduler(cfg, tracer=self.metrics.tracer)
        # epoch publishing (ISSUE 8): every scheduler cycle refreshes the
        # watermark view the fault fast path reads and drains deferred
        # LRU joins; stepped mode gets the same refresh in step_background
        self.scheduler.add_cycle_hook(self.engine.publish_epoch)
        self.dma = DMARegistry(self.virt, self.engine, self.metrics)

        self._gfn_lock = named_lock("gfn")
        self._free_gfns: List[int] = list(
            range(cfg.n_virt_ms - 1, cfg.mpool_reserve_ms - 1, -1))
        self._background_started = False
        self.module_version = 1          # bumped by hot upgrades
        self._guest: Optional[GuestSpace] = None

    @property
    def tracer(self):
        """The system's :class:`repro.obs.tracer.SpanTracer`, or ``None``
        when ``cfg.obs.enabled`` is False."""
        return self.metrics.tracer

    @property
    def guest(self) -> GuestSpace:
        """The canonical :class:`~.guest.GuestSpace` for this system --
        the one sanctioned guest-memory surface.  Lazily created so every
        caller (integrations, fleet, shims) shares one observer list."""
        if self._guest is None:
            self._guest = GuestSpace(self)
        return self._guest

    # ---------------------------------------------------------- guest alloc
    def guest_alloc_ms(self) -> int:
        """Allocate one virtual MS (elastic: may trigger reclaim)."""
        with self._gfn_lock:
            if not self._free_gfns:
                raise InvalidStateError("virtual address space exhausted")
            gfn = self._free_gfns.pop()
        pfn = self.engine._alloc_slot_critical()
        self.virt.table.map_huge(gfn, pfn)
        self.phys.ms_view(pfn)[:] = 0
        self.lru.track(gfn)
        return gfn

    def guest_free_ms(self, gfn: int) -> None:
        # ordering matters vs. the background reclaimer: leave the LRU
        # first (no new reclaim picks), then take the req's write lock to
        # wait out any in-flight swap task before tearing the MS down.
        # Drain the deferred fast-path LRU ring before untracking, else a
        # later drain would re-track this gfn after it is freed
        self.engine.drain_lru_pending()
        self.lru.untrack(gfn)
        req = self.reqs.lookup(gfn)
        grant = req.rwlock.acquire_write() if req is not None else None
        # the write lock quiesces locked faults and writers; the zero-page
        # fast path never takes it, so additionally invalidate the fault
        # descriptor and bounce through the MP mutex before teardown
        self.reqs.quiesce_fast_faults(gfn)
        try:
            pfn = int(self.virt.table.pfn[gfn])
            if req is not None:
                rec = req.record
                for mp in range(self.cfg.mps_per_ms):
                    if rec.is_swapped_out(mp):
                        self.backend.drop(gfn, mp, int(rec.kinds[mp]))
            if pfn != NO_PFN:
                if self.virt.table.is_split(gfn):
                    self.virt.table.merge(gfn, pfn)  # normalize before unmap
                self.virt.table.unmap(gfn)
                self.phys.free_slot(pfn)
        finally:
            if grant is not None:
                req.rwlock.release_write(grant)
        if req is not None:
            self.reqs.remove(gfn)
        # a fast fault that raced the teardown may have enqueued this gfn
        # between the drain above and the quiesce; after quiesce no new
        # notes are possible, so one more drain+untrack leaves nothing
        # stale in the LRU
        self.engine.drain_lru_pending()
        self.lru.untrack(gfn)
        with self._gfn_lock:
            self._free_gfns.append(gfn)

    # ------------------------------------------------------ export / import
    def export_ms(self, gfn: int) -> Tuple[np.ndarray, np.ndarray]:
        """Portable image of one MS: ``(rows, resident)``.

        ``rows`` is the guest-visible byte content of every MP (shape
        ``(mps_per_ms, mp_bytes)``); ``resident`` marks which MPs held a
        physical frame at export time. Non-mutating: swapped MPs are read
        through the backend's CRC-verified :meth:`~.backend.BackendStore.peek`
        without consuming their entries, so a migration that is later
        rejected (or fails read-verify) leaves this node untouched.
        Also the read-verify primitive itself -- exporting the imported
        copy yields its guest-visible bytes without faulting anything in.
        """
        cfg = self.cfg
        req = self.reqs.lookup(gfn)
        grant = req.rwlock.acquire_write() if req is not None else None
        try:
            rows = np.zeros((cfg.mps_per_ms, cfg.mp_bytes), dtype=np.uint8)
            resident = np.ones(cfg.mps_per_ms, dtype=bool)
            if req is not None:
                rec = req.record
                # snapshot record state under the MP mutex: the zero-page
                # fast path mutates bitmaps there without taking the rwlock
                with req.mp_cond:
                    swapped = rec.swapped_out_indices()
                    kinds = rec.kinds[swapped].copy()
                    crcs = rec.crc[swapped].copy()
                for j, mp in enumerate(swapped):
                    mp = int(mp)
                    resident[mp] = False
                    self.backend.peek(gfn, mp, int(kinds[j]), int(crcs[j]),
                                      rows[mp])
            pfn = int(self.virt.table.pfn[gfn])
            if pfn != NO_PFN:
                frame = self.phys.ms_view(pfn).reshape(cfg.mps_per_ms,
                                                       cfg.mp_bytes)
                res_idx = np.flatnonzero(resident)
                rows[res_idx] = frame[res_idx]
            return rows, resident
        finally:
            if grant is not None:
                req.rwlock.release_write(grant)

    def import_ms(self, rows: np.ndarray, resident: np.ndarray) -> int:
        """Admit one exported MS image; returns the new gfn.

        Allocates a fresh MS, materializes the guest-visible bytes, then
        rebuilds the source's resident/swapped split by swapping the
        non-resident MPs back out through the batched store machinery
        (store_batch extents), so a migrated MS lands with the same
        elasticity state it left with.
        """
        cfg = self.cfg
        rows = np.ascontiguousarray(rows, dtype=np.uint8)
        if rows.shape != (cfg.mps_per_ms, cfg.mp_bytes):
            raise ValueError(
                f"MS image shape {rows.shape} != "
                f"({cfg.mps_per_ms}, {cfg.mp_bytes})")
        gfn = self.guest_alloc_ms()
        pfn = int(self.virt.table.pfn[gfn])
        self.phys.ms_view(pfn).reshape(cfg.mps_per_ms, cfg.mp_bytes)[:] = rows
        swapped = np.flatnonzero(~np.asarray(resident, dtype=bool))
        if len(swapped):
            self.engine.swap_out_mps(gfn, swapped)
        return gfn

    # ------------------------------------------- guest I/O (deprecated shims)
    # The sanctioned surface is ``self.guest`` (repro.core.guest.GuestSpace);
    # these shims stay byte-equivalent by delegating through it, so
    # observers attached to the canonical GuestSpace still see shimmed
    # accesses (tests/test_guest_api.py pins both properties).
    def write(self, gva: int, data: bytes) -> None:
        _warn_deprecated("TaijiSystem.write(gva, data)",
                         "TaijiSystem.guest.write(gfn, data, off=...)")
        self.guest.write_gva(gva, data)

    def read(self, gva: int, nbytes: int) -> bytes:
        _warn_deprecated("TaijiSystem.read(gva, nbytes)",
                         "TaijiSystem.guest.read(gfn, nbytes, off=...)")
        return self.guest.read_gva(gva, nbytes)

    def ms_addr(self, gfn: int, mp: int = 0, off: int = 0) -> int:
        _warn_deprecated("TaijiSystem.ms_addr(gfn, mp, off)",
                         "TaijiSystem.guest.addr_of(gfn, mp, off)")
        return self.guest.addr_of(gfn, mp, off)

    # ------------------------------------------------------------ background
    def start_background(self) -> None:
        """Register LRU scan + reclaim as BACK tasks and start hv_sched."""
        if self._background_started:
            return
        self._background_started = True
        nw = self.cfg.lru.workers

        def make_scan(shard: int):
            def scan(_quantum: float) -> bool:
                self.lru.scan_shard(shard, nw)
                return True
            return scan

        for w in range(nw):
            self.scheduler.add_task(w, f"lru/{w}", sched.BACK, make_scan(w))

        def reclaim(quantum: float) -> bool:
            # the hv_sched quantum bounds the round: reclaim stops starting
            # new whole-MS batches once its BACK slice is spent
            self.engine.reclaim_round(budget_s=quantum)
            return True

        self.scheduler.add_task(0, "reclaim", sched.BACK, reclaim)

        def idle(_quantum: float) -> bool:
            self.metrics.hot_cold_timeline.record(self.engine.resident_cold_fraction())
            return True

        self.scheduler.add_task(0, "idle-stats", sched.IDLE, idle)
        self.scheduler.start()

    def stop_background(self) -> None:
        if self._background_started:
            self.scheduler.stop()
            self._background_started = False

    def step_background(self, *, reclaim: bool = True) -> int:
        """One synchronous background round (deterministic stepped mode).

        The fleet layer drives many nodes from a single event loop: each
        fleet tick runs every LRU scan shard once and -- when the
        controller's stagger window says so -- one reclaim round, exactly
        what the hv_sched BACK tasks would do, minus the wall-clock
        slicing. Must not be mixed with ``start_background``.

        Returns the number of MPs reclaimed this round.
        """
        if self._background_started:
            raise InvalidStateError(
                "step_background conflicts with running hv_sched threads")
        self.engine.publish_epoch()     # drain deferred joins + re-publish
        nw = self.cfg.lru.workers
        for w in range(nw):
            self.lru.scan_shard(w, nw)
        if not reclaim:
            return 0
        return self.engine.reclaim_round()

    # ---------------------------------------------------------------- stats
    def snapshot(self) -> Dict[str, object]:
        """Structured node snapshot for the fleet control plane.

        ``deterministic`` holds only event counters/occupancy (byte-stable
        across replays of the same seeded trace); ``latency`` carries the
        timing-dependent percentiles separately.
        """
        self.metrics.sync()              # fold pending latency-ring samples
        self.engine.drain_lru_pending()  # LRU counts reflect drained state
        free = self.phys.free_count
        return {
            "deterministic": {
                "module_version": self.module_version,
                "free_ms": free,
                "zone": self.watermark.zone(free),
                "n_reqs": len(self.reqs),
                "lru": self.lru.counts(),
                "metrics": self.metrics.deterministic_snapshot(),
            },
            "latency": {
                "fault": self.metrics.fault_latency.snapshot(),
                "swap_out": self.metrics.swap_out_latency.snapshot(),
                "swap_in": self.metrics.swap_in_latency.snapshot(),
            },
        }

    def stats(self) -> Dict[str, object]:
        return {
            "module_version": self.module_version,
            "free_ms": self.phys.free_count,
            "watermarks": self.watermark.describe(),
            "lru": self.lru.counts(),
            "mpool": self.mpool.stats(),
            "metrics": self.metrics.snapshot(),
            "n_reqs": len(self.reqs),
            "backend_stored_bytes": self.backend.stored_bytes(),
            "backend": self.backend.stats(),
            "slot_alloc": self.phys.alloc_stats(),
        }

    def close(self) -> None:
        self.stop_background()
        # teardown drain hook (ISSUE 8): magazine-cached slots return to
        # their shards and deferred LRU joins apply, so anything reading
        # the carcass (chaos accounting, tests) sees exact state
        self.engine.drain_deferred()
        self.backend.close()
