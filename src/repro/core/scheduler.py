"""hv_sched -- the Taiji resource scheduler (paper §4.3, Fig 9).

Per-shard (per-PCPU) run queues with four priority classes:

    FRONT -- switched VCPUs (here: foreground train/serve step work)
    FCPU  -- reserved for hot-plugged VCPUs (CPU elasticity, §7.4)
    BACK  -- background elasticity tasks (lru scans, swap/reclaim)
    IDLE  -- idle housekeeping

Static configuration assigns each class a proportional share of every
scheduling cycle; dynamically the scheduler (1) penalizes tasks that
overrun their quantum, shrinking their slice for the next cycles, (2)
reallocates unused slices to tasks of the same or lower priority, and (3)
lets operators adjust the shard set and shares at runtime -- all three
mechanisms from the paper.

Hot-upgrade hook: each worker thread re-reads its ``loop_entry`` every
iteration (the HOST_RIP handoff analogue, §4.4): swapping the entry
redirects the shard to the new module's scheduler loop at a safe point.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List

from ..analysis.lock_order import named_lock
from ..obs.tracer import ST_SCHED_TASK
from .config import TaijiConfig

FRONT, FCPU, BACK, IDLE = range(4)
CLASS_NAMES = ("FRONT", "FCPU", "BACK", "IDLE")


class Task:
    """A cooperative task. ``fn(quantum_s) -> bool`` (True = more work)."""

    __slots__ = ("name", "cls", "fn", "penalty_left", "penalty_factor",
                 "runtime_s", "runs", "overruns", "done")

    def __init__(self, name: str, cls: int, fn: Callable[[float], bool]) -> None:
        self.name = name
        self.cls = cls
        self.fn = fn
        self.penalty_left = 0
        self.penalty_factor = 1.0
        self.runtime_s = 0.0
        self.runs = 0
        self.overruns = 0
        self.done = False


class RunQueue:
    """Per-shard run queue with four priority classes."""

    def __init__(self) -> None:
        self.classes: List[List[Task]] = [[], [], [], []]
        self.lock = named_lock("sched.rq")
        # accounting: per-class runtime for fairness checks (Fig 14b)
        self.class_runtime_s = [0.0, 0.0, 0.0, 0.0]

    def add(self, task: Task) -> None:
        with self.lock:
            self.classes[task.cls].append(task)

    def remove(self, task: Task) -> None:
        with self.lock:
            try:
                self.classes[task.cls].remove(task)
            except ValueError:
                pass


class HvScheduler:
    def __init__(self, cfg: TaijiConfig, tracer=None) -> None:
        self.cfg = cfg
        # stage-attributed tracing (repro.obs): one sched_task span per
        # task run, tagged with the priority class; None when disabled
        self._tr = tracer
        sc = cfg.scheduler
        self.n_shards = sc.shards
        self.rqs = [RunQueue() for _ in range(self.n_shards)]
        self._shares = [sc.share_front, sc.share_fcpu, sc.share_back, sc.share_idle]
        self._back_enabled = [True] * self.n_shards
        self._threads: List[threading.Thread] = []
        self._running = False
        self.cycles = 0
        # hot-upgrade handoff: workers re-read this every iteration
        self.loop_entry: Callable[[int], None] = self._run_cycle
        self._rr: Dict[int, List[int]] = {s: [0, 0, 0, 0] for s in range(self.n_shards)}
        # adaptive idle backoff (SchedulerConfig.idle_backoff_max): sleep
        # multiplier per shard; grows while cycles do no real work so an
        # idle manager stops stealing GIL slices from foreground decode
        self._idle_mult = [1.0] * self.n_shards
        # per-cycle hooks (ISSUE 8): cheap epoch-publish/drain callbacks
        # run at the top of every shard-0 cycle (one publisher is enough;
        # hooks must be fast and must not raise for long)
        self._cycle_hooks: List[Callable[[], None]] = []

    def add_cycle_hook(self, fn: Callable[[], None]) -> None:
        """Register a callback run once per shard-0 scheduling cycle.

        The swap engine uses this to epoch-publish the watermark zone and
        drain deferred fast-path LRU joins on the background cadence --
        the staleness bound of the published view is one cycle
        (``SchedulerConfig.cycle_ms``, stretched by idle backoff)."""
        self._cycle_hooks.append(fn)

    # ------------------------------------------------------------- task API
    def add_task(self, shard: int, name: str, cls: int,
                 fn: Callable[[float], bool]) -> Task:
        t = Task(name, cls, fn)
        self.rqs[shard % self.n_shards].add(t)
        # new work: snap the shard out of idle backoff at its next wakeup
        self._idle_mult[shard % self.n_shards] = 1.0
        return t

    def hotplug_vcpu(self, shard: int, name: str,
                     fn: Callable[[float], bool]) -> Task:
        """CPU elasticity (§7.4): a hot-plugged VCPU lands in FCPU and is
        scheduled like a switched VCPU once it receives time slices."""
        return self.add_task(shard, name, FCPU, fn)

    def remove_task(self, shard: int, task: Task) -> None:
        self.rqs[shard % self.n_shards].remove(task)

    # -------------------------------------------------------- dynamic knobs
    def set_shares(self, front: float, fcpu: float, back: float, idle: float) -> None:
        if front + fcpu + back + idle > 1.0 + 1e-9:
            raise ValueError("shares must sum to <= 1")
        self._shares = [front, fcpu, back, idle]

    def set_back_enabled(self, shard: int, enabled: bool) -> None:
        """Operator control of which shards may run background tasks."""
        self._back_enabled[shard] = enabled

    # ------------------------------------------------------------ main loop
    def start(self) -> None:
        if self._running:
            return
        self._running = True
        for s in range(self.n_shards):
            th = threading.Thread(target=self._worker, args=(s,),
                                  name=f"hv_sched/{s}", daemon=True)
            self._threads.append(th)
            th.start()

    def stop(self, timeout: float = 2.0) -> None:
        self._running = False
        for th in self._threads:
            th.join(timeout)
        self._threads.clear()

    def _worker(self, shard: int) -> None:
        while self._running:
            # re-read loop_entry each iteration: the HOST_RIP handoff point
            entry = self.loop_entry
            entry(shard)

    # one scheduling cycle for one shard
    def _run_cycle(self, shard: int) -> None:
        if shard == 0:
            for hook in self._cycle_hooks:
                try:
                    hook()
                except Exception:
                    pass  # hooks are advisory; same policy as task errors
        cycle_s = self.cfg.scheduler.cycle_ms / 1e3
        rq = self.rqs[shard]
        start = time.perf_counter()
        deadline = start + cycle_s
        budgets = [cycle_s * s for s in self._shares]
        if not self._back_enabled[shard]:
            budgets[FRONT] += budgets[BACK]
            budgets[BACK] = 0.0
        carry = 0.0
        spent_cycle = 0.0
        for cls in (FRONT, FCPU, BACK, IDLE):
            if cls == BACK and not self._back_enabled[shard]:
                # disabled shard: BACK must not inherit carried slices
                # either (a penalized FRONT task's unused slice would
                # otherwise leak here); pass the carry straight to IDLE
                continue
            # unused slices flow downward, but never past the cycle end:
            # a class can only spend what remains of this cycle
            budget = min(budgets[cls] + carry,
                         max(0.0, deadline - time.perf_counter()))
            spent_cap = budgets[cls] + carry
            unused = self._run_class(rq, shard, cls, budget)
            spent_cycle += max(0.0, budget - unused)
            carry = max(0.0, spent_cap - (budget - unused))
        self.cycles += 1
        # adaptive idle backoff: a cycle whose tasks barely ran (empty LRU
        # slices, watermark satisfied) doubles this shard's sleep, up to
        # idle_backoff_max cycles; any working cycle snaps it back to 1.
        # An idle manager must not steal GIL slices from foreground decode
        # (paper Fig 11: within 3% of native).
        sc = self.cfg.scheduler
        if spent_cycle < cycle_s * sc.idle_spent_frac:
            self._idle_mult[shard] = min(self._idle_mult[shard] * 2.0,
                                         max(1.0, sc.idle_backoff_max))
        else:
            self._idle_mult[shard] = 1.0
        # sleep out the remainder of the (possibly stretched) cycle so
        # shares are honored in wall-clock terms even when queues are empty
        elapsed = time.perf_counter() - start
        sleep_s = cycle_s * self._idle_mult[shard] - elapsed
        if sleep_s > 0 and self._running:
            time.sleep(sleep_s)

    def _run_class(self, rq: RunQueue, shard: int, cls: int, budget: float) -> float:
        """Run tasks of one class round-robin within ``budget``.

        Returns the unused budget (reallocated to lower classes).
        """
        if budget <= 0:
            return 0.0
        with rq.lock:
            tasks = [t for t in rq.classes[cls] if not t.done]
        if not tasks:
            return budget
        spent_total = 0.0
        quantum = budget / max(1, len(tasks))
        idx0 = self._rr[shard][cls]
        self._rr[shard][cls] = (idx0 + 1) % max(1, len(tasks))
        overrun_penalty = self.cfg.scheduler.overrun_penalty
        for i in range(len(tasks)):
            t = tasks[(idx0 + i) % len(tasks)]
            if spent_total >= budget:
                break
            q = quantum * t.penalty_factor
            t0 = time.perf_counter()
            try:
                more = t.fn(q)
            except Exception:
                more = False
            dt = time.perf_counter() - t0
            tr = self._tr
            if tr is not None:
                tr.push(ST_SCHED_TASK, int(t0 * 1e9), int(dt * 1e9), cls)
            t.runtime_s += dt
            t.runs += 1
            spent_total += dt
            rq.class_runtime_s[cls] += dt
            # overrun = exceeded the granted quantum by 50% and by an
            # absolute margin (filters thread-scheduling jitter)
            if dt > q * 1.5 and dt - q > 5e-4:
                t.overruns += 1
                t.penalty_factor = overrun_penalty
                t.penalty_left = self.cfg.scheduler.penalty_cycles
            elif t.penalty_left > 0:
                t.penalty_left -= 1
                if t.penalty_left == 0:
                    t.penalty_factor = 1.0
            if not more:
                t.done = True
                rq.remove(t)
        return max(0.0, budget - spent_total)

    # ------------------------------------------------------------- fairness
    def class_runtime(self) -> Dict[str, float]:
        out = {n: 0.0 for n in CLASS_NAMES}
        for rq in self.rqs:
            for cls, n in enumerate(CLASS_NAMES):
                out[n] += rq.class_runtime_s[cls]
        return out
