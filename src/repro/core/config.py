"""Configuration for the Taiji elastic-memory core.

Mirrors the paper's deployed configuration by default:
  * MS ("memory section") = 2 MiB huge page, MP ("memory page") = 4 KiB,
    i.e. 512 MPs per MS (paper §4.2.2).
  * 32 GB physical + 16 GB virtual elastic memory = +50% elasticity
    (paper §5.3.2) -- expressed here as a ratio so tests can scale down.
  * high/low/min watermarks (paper §4.2.2, Fig 14e).
  * scheduler shares for FRONT/FCPU/BACK/IDLE (paper §4.3, Fig 9).

Everything is a plain dataclass: configs are hashable/serializable and carry
an ABI version so hot-upgrade can verify compatibility (paper §4.4 "Data
Plane Compatibility").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

ABI_VERSION = 1


@dataclasses.dataclass(frozen=True)
class LRUConfig:
    """Multi-level hot/cold set parameters (paper §4.2.1, Fig 7)."""

    scan_interval_s: float = 0.050      # periodic scan cadence per worker
    levels: int = 6                     # HOT, HOT_INT, ACTIVE, INACTIVE, COLD_INT, COLD
    # number of consecutive unchanged scans before a page moves one level
    # toward the hot or cold end ("time-based stabilization", §4.2.1)
    stabilize_scans: int = 2
    scan_cache_size: int = 256          # per-worker scan cache (reduces lock contention)
    workers: int = 2                    # parallel LRU tasks (per-PCPU in the paper)


@dataclasses.dataclass(frozen=True)
class WatermarkConfig:
    """Free-memory watermarks in MS units as fractions of physical MSs."""

    high: float = 0.20   # stop reclaim above this much free memory
    low: float = 0.10    # start background reclaim below this
    min: float = 0.03    # critically low: reclaim synchronously on the fault path
    # optional policy knobs (§4.2.2: "Policies can be tuned")
    reclaim_batch: int = 8          # MSs per background reclaim round
    eager_below_high: bool = False  # start reclaim below *high* to pre-arm for bursts


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """hv_sched static shares + dynamic adjustment (paper §4.3)."""

    cycle_ms: float = 10.0
    # static proportional shares per priority class, must sum to <= 1.0
    share_front: float = 0.70
    share_fcpu: float = 0.05
    share_back: float = 0.20
    share_idle: float = 0.05
    # dynamic adjustment: penalty factor applied to a task's slice after it
    # overruns its quantum, and the number of cycles the penalty persists
    overrun_penalty: float = 0.5
    penalty_cycles: int = 3
    shards: int = 2                 # number of scheduler shards (PCPUs/CPs)
    # adaptive idle backoff: a shard whose cycle spends (almost) none of
    # its budget -- empty LRU slices, watermark satisfied -- doubles its
    # sleep up to ``idle_backoff_max`` cycles, then snaps back to 1 the
    # moment a cycle does real work. This is hv_sched's "unused slices
    # flow to FRONT" taken to its wall-clock conclusion: an idle manager
    # must not steal GIL/CPU slices from the foreground decode step
    # (paper Fig 11: benchmarks within 3% of native). Reclaim reaction
    # worst-case grows to idle_backoff_max * cycle_ms, still far inside
    # the high->low watermark gap; the critical path (min watermark)
    # reclaims synchronously and never waits on a BACK wakeup.
    idle_backoff_max: float = 16.0
    # a cycle counts as idle when its tasks spent under this fraction of
    # the cycle actually running
    idle_spent_frac: float = 0.05


@dataclasses.dataclass(frozen=True)
class HotPathConfig:
    """The unified hot-path surface (ISSUE 6): every knob that decides
    how a guest access or swap batch is serviced, in one documented
    place.

    * ``fast_fault`` -- zero-page ultrafast fault path: resolve a
      zero-kind fault through the O(1) fault-descriptor table under the
      req's short MP mutex only (no read-write lock round trip, no
      condition-variable wait, constant-CRC compare). The locked scalar
      path is kept as the A/B semantic reference.
    * ``readahead`` -- extent readahead: the first fault into a
      compressed extent decompresses the whole extent anyway, so
      materialize *all* its still-swapped sibling MPs into the resident
      MS in one pass; N future faults become zero faults and the
      decompress cost is paid exactly once (paper §3.3/Fig 8 parallel
      swapping, amortized).
    * ``pallas_kernels`` -- route the batched data path through the
      Pallas kernels in ``repro.kernels`` (zero-detect scan, Fletcher
      extent tags, gather/scatter swap copies) instead of numpy/zlib
      host ops -- the device entry point for a TPU backend;
      interpret-mode on CPU, so the host path stays the default. The
      per-MP CRC stored in MS records is zlib.crc32 on both paths
      (records stay byte-compatible, hot-upgrade ABI §4.4); the Fletcher
      checksum (kernels/crc32c.py, ops.batch_checksum) is the
      device-side integrity tag computed per extent. Lossless compression
      remains host zlib (the kernel ``compress.py`` is the *lossy* int8
      KV tier and never feeds the exact backend).
    * ``compress_workers`` -- fan ``store_batch``/``load_batch`` extent
      (de)compression across a worker pool. zlib releases the GIL, so
      extents compress in parallel; results merge in submission order,
      making the stored bytes identical for ANY worker count (pinned by
      tests/test_hotpath_batch.py). ``<= 1`` keeps the serial path.
    * ``slot_shards`` / ``magazine_size`` -- contention-free first-in
      slot allocation (ISSUE 8): ``PhysicalMemory``'s free-slot list is
      sharded into ``slot_shards`` per-shard freelists fronted by
      per-thread *magazines* of up to ``magazine_size`` cached slots. A
      faulting thread refills its magazine under ONE shard lock and then
      serves first-in allocations lock-free; frees return to the slot's
      home shard. ``magazine_size <= 0`` keeps the legacy single-list
      path (one global lock), the A/B reference. The default batch is
      sized so refill amortization keeps the *uncontended* path within
      ~10% of the legacy single-lock pop (ISSUE 9): refills are lazy --
      paid only when a magazine runs dry -- so a bigger batch means
      strictly fewer lock acquires on both the single- and multi-thread
      paths.
    * ``extent_cache_entries`` -- bounded decoded-extent LRU in
      ``BackendStore``: decompressed extent payloads are kept in an LRU
      of this many entries (verified against the stored whole-extent CRC
      on insert, invalidated when the extent is dropped/consumed) so
      sibling-MP faults and readahead hitting a cached extent skip zlib
      entirely while decoded retention stays bounded. ``0`` keeps the
      legacy decompress-in-place behavior (unbounded per-live-extent raw
      caching).
    """

    fast_fault: bool = True      # O(1)-descriptor zero-page fast path
    readahead: bool = True       # materialize whole extents on first fault
    pallas_kernels: bool = False # device kernels for the batched data path
    compress_workers: int = 4    # parallel extent (de)compression pool
    slot_shards: int = 4         # per-shard free-slot freelists
    magazine_size: int = 16      # per-thread slot magazine (0 = legacy list)
    extent_cache_entries: int = 8  # decoded-extent LRU (0 = legacy in-place)
    # remote-peer swap tier (ISSUE 9): number of peer replicas the fleet
    # controller maintains for each fully swapped-out MS. ``0`` disables
    # the tier (single-box TaijiSystem behavior is ALWAYS unaffected --
    # replication is controller-driven, the local swap path never blocks
    # on a peer). ``1`` is the deployed setting; >1 is reserved.
    remote_tier: int = 1

    @classmethod
    def legacy_scalar(cls) -> "HotPathConfig":
        """The pre-batching scalar reference profile: locked faults, no
        readahead, host numpy/zlib, serial compression, single-list slot
        allocation, in-place extent decode, no remote-peer tier. The A/B
        baseline benchmarks and semantic-equivalence tests measure
        against."""
        return cls(fast_fault=False, readahead=False,
                   pallas_kernels=False, compress_workers=0,
                   slot_shards=1, magazine_size=0, extent_cache_entries=0,
                   remote_tier=0)


@dataclasses.dataclass(frozen=True)
class SwapConfig:
    """Batched swap data-path knobs (paper §4.2.2 "parallel swapping").

    The engine moves MPs in batches of ``batch_mps`` index-vector chunks
    derived from the ``bm_in``/``bm_out`` bitmaps; cancellation (Fig 8
    (2.2)) is honoured between chunks, so ``batch_mps`` bounds how long a
    racing fault waits on an active writer. ``batch_mps <= 0`` disables
    batching entirely (scalar per-MP path, kept for A/B benchmarks).

    Fault/data-path servicing knobs live in :class:`HotPathConfig`
    (``hot_path``). The historical scalar field names
    (``fast_fault_enabled`` / ``readahead_enabled`` /
    ``use_pallas_kernels``) are kept as aliases: passing them to the
    constructor populates ``hot_path``, reading them reflects
    ``hot_path``, and configs pickled before ``hot_path`` existed
    unpickle with an equivalent one synthesized (``__setstate__``).
    When both ``hot_path`` and a legacy flag are passed explicitly, the
    legacy flag wins (this is what ``dataclasses.replace(cfg.swap,
    fast_fault_enabled=...)`` produces).
    """

    batch_enabled: bool = True
    batch_mps: int = 64              # MPs per backend bulk call / cancel point
    hot_path: Optional[HotPathConfig] = None
    # legacy aliases -- resolved into hot_path by __post_init__
    fast_fault_enabled: Optional[bool] = None
    readahead_enabled: Optional[bool] = None
    use_pallas_kernels: Optional[bool] = None

    def __post_init__(self) -> None:
        hp = self.hot_path if self.hot_path is not None else HotPathConfig()
        overrides = {}
        if self.fast_fault_enabled is not None \
                and bool(self.fast_fault_enabled) != hp.fast_fault:
            overrides["fast_fault"] = bool(self.fast_fault_enabled)
        if self.readahead_enabled is not None \
                and bool(self.readahead_enabled) != hp.readahead:
            overrides["readahead"] = bool(self.readahead_enabled)
        if self.use_pallas_kernels is not None \
                and bool(self.use_pallas_kernels) != hp.pallas_kernels:
            overrides["pallas_kernels"] = bool(self.use_pallas_kernels)
        if overrides:
            hp = dataclasses.replace(hp, **overrides)
        # aliases always mirror hot_path so old readers see one truth
        object.__setattr__(self, "hot_path", hp)
        object.__setattr__(self, "fast_fault_enabled", hp.fast_fault)
        object.__setattr__(self, "readahead_enabled", hp.readahead)
        object.__setattr__(self, "use_pallas_kernels", hp.pallas_kernels)

    def __setstate__(self, state) -> None:
        # configs pickled before hot_path existed restore a plain field
        # dict; synthesize the HotPathConfig from the legacy scalars so
        # old pickles keep working (hot-upgrade ABI promise)
        if isinstance(state, tuple):          # (dict, slots) pickle form
            merged = {}
            for part in state:
                if part:
                    merged.update(part)
            state = merged
        state = dict(state)
        if state.get("hot_path") is None:
            state["hot_path"] = HotPathConfig(
                fast_fault=bool(state.get("fast_fault_enabled", True)),
                readahead=bool(state.get("readahead_enabled", True)),
                pallas_kernels=bool(state.get("use_pallas_kernels", False)))
        hp = state["hot_path"]
        if not hasattr(hp, "slot_shards"):
            # HotPathConfig pickled before the ISSUE-8 fields existed:
            # rebuild so the allocator/cache knobs get their defaults
            hp = HotPathConfig(
                fast_fault=hp.fast_fault, readahead=hp.readahead,
                pallas_kernels=hp.pallas_kernels,
                compress_workers=hp.compress_workers)
            state["hot_path"] = hp
        elif not hasattr(hp, "remote_tier"):
            # pickled before the ISSUE-9 remote tier existed: rebuild so
            # the new knob gets its default
            hp = HotPathConfig(
                fast_fault=hp.fast_fault, readahead=hp.readahead,
                pallas_kernels=hp.pallas_kernels,
                compress_workers=hp.compress_workers,
                slot_shards=hp.slot_shards,
                magazine_size=hp.magazine_size,
                extent_cache_entries=hp.extent_cache_entries)
            state["hot_path"] = hp
        state["fast_fault_enabled"] = hp.fast_fault
        state["readahead_enabled"] = hp.readahead
        state["use_pallas_kernels"] = hp.pallas_kernels
        state.setdefault("batch_enabled", True)
        state.setdefault("batch_mps", 64)
        for key, value in state.items():
            object.__setattr__(self, key, value)


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Swap backend stores (paper §4.2.2 backend + §7.2)."""

    zero_page_enabled: bool = True
    compression_enabled: bool = True
    compression_level: int = 1       # zlib level; level 1 ~ lz4-class latency
    # §7.2: free-page detection disabled in production (zone-lock overhead)
    free_page_enabled: bool = False
    # optional fallback tiers; "remote memory and disks act as fallback"
    disk_fallback_path: str | None = None
    crc_enabled: bool = True         # §7.1 CRC to guarantee correctness
    # per-kind/per-shard lock split for the in-memory tiers (Palladium-style
    # sharding of per-tenant state); keys hash by (gfn, mp) across shards
    lock_shards: int = 8
    # cap on rows per batch extent: bounds the worst-case passive-fault
    # latency (first fault into an extent decompresses the whole stream)
    # at a small cost in cross-row compression and per-call amortization
    extent_max_rows: int = 16


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability: stage-attributed span tracing (``repro.obs``).

    Off by default: with ``enabled=False`` no ``SpanTracer`` is
    constructed and every instrumented call site costs exactly one
    ``is not None`` branch (the GuestSpace empty-observer discipline).
    Spans are wall-clock telemetry only -- they never enter
    ``deterministic_snapshot``, so capture/replay and chaos determinism
    are identical with tracing on or off.
    """

    enabled: bool = False
    ring_capacity: int = 4096     # encoded spans buffered between flushes
    max_spans: int = 200_000      # retained decoded spans (Chrome export)


@dataclasses.dataclass(frozen=True)
class TaijiConfig:
    """Top-level configuration of the elastic-memory system."""

    # geometry -- defaults mirror the paper (2 MiB MS / 4 KiB MP); tests and
    # the KV-cache integration scale these down/up per use case.
    ms_bytes: int = 2 * 1024 * 1024
    mps_per_ms: int = 512
    n_phys_ms: int = 64              # physical capacity in MSs
    overcommit_ratio: float = 0.50   # +50% virtual elastic memory (paper O3)

    mpool_reserve_ms: int = 4        # pinned metadata arena, in MSs (paper: 400 MB)

    lru: LRUConfig = dataclasses.field(default_factory=LRUConfig)
    watermark: WatermarkConfig = dataclasses.field(default_factory=WatermarkConfig)
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    backend: BackendConfig = dataclasses.field(default_factory=BackendConfig)
    swap: SwapConfig = dataclasses.field(default_factory=SwapConfig)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)

    abi_version: int = ABI_VERSION
    # reserved fields for forward-compatible hot upgrades (paper §4.4)
    _reserved: Tuple[int, ...] = (0, 0, 0, 0)

    @property
    def mp_bytes(self) -> int:
        return self.ms_bytes // self.mps_per_ms

    @property
    def n_virt_ms(self) -> int:
        """Total virtual MSs visible to the guest (physical + elastic)."""
        return int(round(self.n_phys_ms * (1.0 + self.overcommit_ratio)))

    @property
    def n_elastic_ms(self) -> int:
        return self.n_virt_ms - self.n_phys_ms

    def validate(self) -> None:
        if self.ms_bytes % self.mps_per_ms:
            raise ValueError("ms_bytes must be divisible by mps_per_ms")
        if self.mp_bytes % 8:
            raise ValueError("mp_bytes must be a multiple of 8")
        if self.n_phys_ms <= self.mpool_reserve_ms:
            raise ValueError("physical memory must exceed the mpool reserve")
        wm = self.watermark
        if not (0.0 <= wm.min <= wm.low <= wm.high < 1.0):
            raise ValueError("watermarks must satisfy 0 <= min <= low <= high < 1")
        sc = self.scheduler
        total = sc.share_front + sc.share_fcpu + sc.share_back + sc.share_idle
        if total > 1.0 + 1e-9:
            raise ValueError("scheduler shares must sum to <= 1.0")
        if self.backend.lock_shards < 1:
            raise ValueError("backend.lock_shards must be >= 1")
        hp = self.swap.hot_path
        if hp is not None:
            if getattr(hp, "slot_shards", 1) < 1:
                raise ValueError("hot_path.slot_shards must be >= 1")
            if getattr(hp, "magazine_size", 0) < 0:
                raise ValueError("hot_path.magazine_size must be >= 0")
            if getattr(hp, "extent_cache_entries", 0) < 0:
                raise ValueError("hot_path.extent_cache_entries must be >= 0")
            if not 0 <= getattr(hp, "remote_tier", 0) <= 1:
                raise ValueError("hot_path.remote_tier must be 0 or 1")
        if self.obs.ring_capacity < 1 or self.obs.max_spans < 0:
            raise ValueError("obs ring_capacity must be >= 1, max_spans >= 0")


def small_test_config(**overrides) -> TaijiConfig:
    """A reduced configuration for fast unit tests."""
    base = dict(
        ms_bytes=16 * 1024,
        mps_per_ms=8,
        n_phys_ms=24,
        overcommit_ratio=0.5,
        mpool_reserve_ms=2,
        lru=LRUConfig(scan_interval_s=0.002, workers=2, stabilize_scans=1,
                      scan_cache_size=32),
        scheduler=SchedulerConfig(cycle_ms=2.0, shards=2),
    )
    base.update(overrides)
    cfg = TaijiConfig(**base)
    cfg.validate()
    return cfg
