"""Swap request entities and their concurrency control (paper §4.2.2, Fig 8).

Four atomicity layers, reproduced 1:1:

  1. **req abstraction** -- one req per MS, unique, stored in a red-black
     tree for efficient page-fault lookup; independent MS-level locks allow
     parallel swaps of different MSs.
  2. **read-write lock** -- active tasks (Swap_out / prefetch Swap_in) are
     serialized via the write lock; passive fault-driven swap-ins take read
     locks and run in parallel. On conflict, a *cancel* mechanism makes the
     write-locked task exit promptly (Fig 8 (2.2)).
  3. **execution bitmaps** -- ``bm_out`` (already swapped out) gates what may
     swap in; ``bm_in`` (currently swapping in) gives exactly-once swap-in
     per MP when multiple faults hit the same MP (Fig 8 (3.3)).
  4. **MS/MP state control** -- exactly-once split/reclaim/alloc/merge at
     defined transitions (Fig 8 (4.1)/(7)), guarded by the per-req mutex.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from .config import TaijiConfig
from .errors import InvalidStateError
from .mpool import Mpool
from .ms import MSRecord
from .rbtree import RBTree


class WriteGrant:
    """Held by the single active writer; readers set ``cancelled``."""

    __slots__ = ("cancelled",)

    def __init__(self) -> None:
        self.cancelled = False


class RWLockWriterCancel:
    """MS-level read-write lock with writer cancellation.

    Readers (passive fault swap-ins) may always make progress: if a writer
    holds the lock, arriving readers flag it for cancellation and block
    until it exits (the writer polls :attr:`WriteGrant.cancelled` at safe
    points and aborts promptly). Writers are mutually exclusive and wait
    for all readers to drain.
    """

    def __init__(self) -> None:
        self._cond = threading.Condition()
        self._readers = 0
        self._writer: Optional[WriteGrant] = None
        self.cancel_count = 0  # stats: how often readers bumped a writer

    # --------------------------------------------------------------- readers
    def acquire_read(self) -> None:
        with self._cond:
            if self._writer is not None and not self._writer.cancelled:
                self._writer.cancelled = True
                self.cancel_count += 1
            while self._writer is not None:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    # --------------------------------------------------------------- writers
    def acquire_write(self, blocking: bool = True) -> Optional[WriteGrant]:
        with self._cond:
            if not blocking and (self._writer is not None or self._readers > 0):
                return None
            while self._writer is not None or self._readers > 0:
                self._cond.wait()
            self._writer = WriteGrant()
            return self._writer

    def release_write(self, grant: WriteGrant) -> None:
        with self._cond:
            if self._writer is not grant:
                raise InvalidStateError("releasing a write grant not held")
            self._writer = None
            self._cond.notify_all()


class Req:
    """Per-MS swap request entity: record + lock + fine-grained MP mutex."""

    __slots__ = ("gfn", "record", "rwlock", "mp_mutex", "mp_cond")

    def __init__(self, gfn: int, record: MSRecord) -> None:
        self.gfn = gfn
        self.record = record
        self.rwlock = RWLockWriterCancel()
        # short mutex guarding bitmap/state transitions (word-level CAS in
        # the kernel; a tiny critical section here), plus a condition used
        # by faults waiting on an in-flight IO for the same MP (Fig 8 (3.3))
        self.mp_mutex = threading.Lock()
        self.mp_cond = threading.Condition(self.mp_mutex)

    # convenience accessors used by the virtualization layer's presence probe
    def mp_present(self, mp: int) -> bool:
        r = self.record
        return not r.is_swapped_out(mp) and not r.is_swapping_in(mp)


class ReqTree:
    """All reqs, keyed by GFN in a red-black tree (paper Fig 8 (1.1-1.3))."""

    def __init__(self, cfg: TaijiConfig, mpool: Mpool) -> None:
        self.cfg = cfg
        self.mpool = mpool
        self._tree = RBTree()
        self._lock = threading.Lock()
        # fast-path cache: dict lookups are O(1); the RB tree remains the
        # authoritative ordered structure (and is what property tests check)
        self._cache: Dict[int, Req] = {}

    def lookup(self, gfn: int) -> Optional[Req]:
        req = self._cache.get(gfn)
        if req is not None:
            return req
        with self._lock:
            return self._tree.find(gfn)

    def get_or_create(self, gfn: int, pfn: int) -> Req:
        """Fetch the req for ``gfn`` or create one on initial swap-out."""
        req = self.lookup(gfn)
        if req is not None:
            return req
        with self._lock:
            req = self._tree.find(gfn)
            if req is None:
                record = MSRecord.allocate(self.cfg, self.mpool, gfn, pfn)
                req = Req(gfn, record)
                self._tree.insert(gfn, req)
                self._cache[gfn] = req
            return req

    def remove(self, gfn: int) -> None:
        with self._lock:
            req: Req = self._tree.delete(gfn)
            self._cache.pop(gfn, None)
            self.mpool.slab_free(req.record.handle)

    def __len__(self) -> int:
        return len(self._tree)

    def items(self):
        with self._lock:
            return list(self._tree.items())

    def check_invariants(self) -> None:
        with self._lock:
            self._tree.check_invariants()
            for gfn, req in self._tree.items():
                assert req.gfn == gfn == req.record.gfn
