"""Swap request entities and their concurrency control (paper §4.2.2, Fig 8).

Four atomicity layers, reproduced 1:1:

  1. **req abstraction** -- one req per MS, unique, stored in a red-black
     tree for efficient page-fault lookup; independent MS-level locks allow
     parallel swaps of different MSs.
  2. **read-write lock** -- active tasks (Swap_out / prefetch Swap_in) are
     serialized via the write lock; passive fault-driven swap-ins take read
     locks and run in parallel. On conflict, a *cancel* mechanism makes the
     write-locked task exit promptly (Fig 8 (2.2)).
  3. **execution bitmaps** -- ``bm_out`` (already swapped out) gates what may
     swap in; ``bm_in`` (currently swapping in) gives exactly-once swap-in
     per MP when multiple faults hit the same MP (Fig 8 (3.3)).
  4. **MS/MP state control** -- exactly-once split/reclaim/alloc/merge at
     defined transitions (Fig 8 (4.1)/(7)), guarded by the per-req mutex.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np

from ..analysis.lock_order import STATE as _LOCKDEP, named_lock
from .config import TaijiConfig
from .errors import InvalidStateError
from .mpool import Mpool
from .ms import MSRecord, record_field_offsets
from .rbtree import RBTree


class WriteGrant:
    """Held by the single active writer; readers set ``cancelled``."""

    __slots__ = ("cancelled",)

    def __init__(self) -> None:
        self.cancelled = False


class RWLockWriterCancel:
    """MS-level read-write lock with writer cancellation.

    Readers (passive fault swap-ins) may always make progress: if a writer
    holds the lock, arriving readers flag it for cancellation and block
    until it exits (the writer polls :attr:`WriteGrant.cancelled` at safe
    points and aborts promptly). Writers are mutually exclusive and wait
    for all readers to drain.

    Lockdep: the grant itself is a *virtual* lock entity of class
    ``req.rwlock`` (rank below the mp_mutex -- a grant is taken before
    the mutex, never under it except by trylock). The hooks fire outside
    the internal condition lock so the witness never sees a false
    cond -> rwlock edge; with the witness off each hook costs one
    truthiness check. ``group`` links the grant to the owning req's
    mp_mutex for the gate exemption (PR 3 bailout).
    """

    def __init__(self, group: object = None) -> None:
        self._cond = threading.Condition(named_lock("req.rwlock.cond", group))
        self._readers = 0
        self._writer: Optional[WriteGrant] = None
        self._group = group
        self.cancel_count = 0  # stats: how often readers bumped a writer

    # --------------------------------------------------------------- readers
    def acquire_read(self) -> None:
        if _LOCKDEP.on:
            from ..analysis import witness
            witness.push_virtual(witness.RWLOCK_CLASS, self._group,
                                 id(self), write=False)
        with self._cond:
            if self._writer is not None and not self._writer.cancelled:
                self._writer.cancelled = True
                self.cancel_count += 1
            while self._writer is not None:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()
        if _LOCKDEP.on:
            from ..analysis import witness
            witness.pop_virtual(id(self))

    # --------------------------------------------------------------- writers
    def acquire_write(self, blocking: bool = True) -> Optional[WriteGrant]:
        if _LOCKDEP.on and blocking:
            from ..analysis import witness
            witness.push_virtual(witness.RWLOCK_CLASS, self._group,
                                 id(self), write=True)
        with self._cond:
            if not blocking and (self._writer is not None or self._readers > 0):
                return None
            while self._writer is not None or self._readers > 0:
                self._cond.wait()
            self._writer = WriteGrant()
            grant = self._writer
        if _LOCKDEP.on and not blocking:
            from ..analysis import witness
            witness.push_virtual(witness.RWLOCK_CLASS, self._group,
                                 id(self), write=True, trylock=True)
        return grant

    def release_write(self, grant: WriteGrant) -> None:
        with self._cond:
            if self._writer is not grant:
                raise InvalidStateError("releasing a write grant not held")
            self._writer = None
            self._cond.notify_all()
        if _LOCKDEP.on:
            from ..analysis import witness
            witness.pop_virtual(id(self))


class Req:
    """Per-MS swap request entity: record + lock + fine-grained MP mutex."""

    __slots__ = ("gfn", "record", "rwlock", "mp_mutex", "mp_cond", "fdesc")

    def __init__(self, gfn: int, record: MSRecord) -> None:
        self.gfn = gfn
        self.record = record
        self.rwlock = RWLockWriterCancel(group=gfn)
        # short mutex guarding bitmap/state transitions (word-level CAS in
        # the kernel; a tiny critical section here), plus a condition used
        # by faults waiting on an in-flight IO for the same MP (Fig 8 (3.3)).
        # The GFN group ties the mutex to the rwlock grant above: nesting
        # mp_mutex under mp_mutex is legal only with the write grant held
        self.mp_mutex = named_lock("req.mp_mutex", group=gfn)
        self.mp_cond = threading.Condition(self.mp_mutex)
        # plain-int arena offsets (header/bm_out/bm_in/kinds/crc), filled
        # by FaultDescTable.register -- the fault fast path unpacks this
        # tuple instead of chasing record attributes / numpy boxing
        self.fdesc = None

    # convenience accessors used by the virtualization layer's presence probe
    def mp_present(self, mp: int) -> bool:
        r = self.record
        return not r.is_swapped_out(mp) and not r.is_swapping_in(mp)


class FaultDescTable:
    """Flat O(1) fault descriptors, indexed by GFN (ISSUE 3 tentpole).

    The page-fault path carries the paper's 10 us P90 budget (O2), so it
    cannot afford an rbtree walk plus ``Req``/``MSRecord`` attribute
    chasing per fault. This table keeps, per GFN, the *arena offsets* of
    the req's persistent record fields -- header word (state/pfn/present),
    ``bm_out``/``bm_in`` bitmap words, backend kinds and per-MP CRCs --
    plus typed views of the whole mpool arena to index with them. A fault
    reads everything it needs with a couple of array loads; the red-black
    tree remains the slow-path source of truth (and what the property
    tests check).

    The offsets live as a plain-int tuple on each :class:`Req`
    (``fdesc``: header/bm_out/bm_in/kinds/crc indexes into the typed
    views) so the hot path pays one list index + one tuple unpack instead
    of five numpy scalar boxings; the ``hdr`` column is the flat per-GFN
    validity word (also what invariant checks compare against).

    Consistency: rows are published by :meth:`register` *after* the req is
    fully constructed (``reqs[gfn]`` is the publication gate) and retired
    by :meth:`unregister` under the ReqTree lock. All *uses* that mutate
    record state happen under the owning req's ``mp_mutex``, exactly like
    the locked path, so descriptor reads are never torn.
    """

    def __init__(self, cfg: TaijiConfig, arena: np.ndarray) -> None:
        self.cfg = cfg
        n = cfg.n_virt_ms
        self.n = n
        self._off = record_field_offsets(cfg)
        # typed whole-arena views (the arena is 8-byte sized and aligned)
        self.a8 = arena
        self.i64 = arena.view(np.int64)
        self.u64 = arena.view(np.uint64)
        self.u32 = arena.view(np.uint32)
        # hdr < 0 means "no descriptor" (set last on register, first on
        # retire); the field offsets themselves ride on Req.fdesc
        self.hdr = np.full(n, -1, dtype=np.int64)     # int64 index of header
        self.reqs: List[Optional[Req]] = [None] * n
        # slab offsets are size-class aligned (>= 32B), so the 8-byte
        # fields always align; the uint32 CRC column only aligns when the
        # kinds column (mps_per_ms bytes) is a multiple of 4
        self.enabled = cfg.mps_per_ms % 4 == 0

    def register(self, gfn: int, req: Req) -> None:
        base = req.record.handle.offset
        off = self._off
        req.fdesc = (base >> 3, (base + off["bm_out"]) >> 3,
                     (base + off["bm_in"]) >> 3, base + off["kinds"],
                     (base + off["crc"]) >> 2)
        self.hdr[gfn] = base >> 3
        self.reqs[gfn] = req

    def unregister(self, gfn: int) -> None:
        self.reqs[gfn] = None
        self.hdr[gfn] = -1

    def quiesce(self, gfn: int) -> None:
        """Teardown barrier: make the GFN invisible to the lock-light
        fault fast path and wait out any in-flight fast fault.

        The fast path re-validates ``hdr[gfn]`` *after* acquiring the
        req's ``mp_mutex``, so clearing it here and then bouncing through
        the mutex guarantees no fast fault can still be touching the
        frame or record when the caller proceeds to unmap/free. The req
        row stays published for slow-path parity; :meth:`unregister`
        retires it fully.
        """
        req = self.reqs[gfn]
        self.hdr[gfn] = -1
        if req is not None:
            req.mp_mutex.acquire()
            req.mp_mutex.release()


class ReqTree:
    """All reqs, keyed by GFN in a red-black tree (paper Fig 8 (1.1-1.3))."""

    def __init__(self, cfg: TaijiConfig, mpool: Mpool) -> None:
        self.cfg = cfg
        self.mpool = mpool
        self._tree = RBTree()
        self._lock = named_lock("req.tree")
        # fast-path cache: dict lookups are O(1); the RB tree remains the
        # authoritative ordered structure (and is what property tests check)
        self._cache: Dict[int, Req] = {}
        # O(1) fault descriptors over the mpool arena (survives hot
        # upgrades with the tree: record handles are stable)
        self.table = FaultDescTable(cfg, mpool.buffer)

    def lookup(self, gfn: int) -> Optional[Req]:
        req = self._cache.get(gfn)
        if req is not None:
            return req
        with self._lock:
            return self._tree.find(gfn)

    def get_or_create(self, gfn: int, pfn: int) -> Req:
        """Fetch the req for ``gfn`` or create one on initial swap-out."""
        req = self.lookup(gfn)
        if req is not None:
            return req
        with self._lock:
            req = self._tree.find(gfn)
            if req is None:
                record = MSRecord.allocate(self.cfg, self.mpool, gfn, pfn)
                req = Req(gfn, record)
                self._tree.insert(gfn, req)
                self._cache[gfn] = req
                self.table.register(gfn, req)
            return req

    def quiesce_fast_faults(self, gfn: int) -> None:
        """See :meth:`FaultDescTable.quiesce` (called by MS teardown
        after it holds the req's write lock). Deliberately does NOT take
        the tree lock: the mutex bounce must not nest under it (reclaim
        paths acquire the tree lock while holding a req mutex), and the
        row read + validity store are GIL-atomic. This constraint is
        machine-checked: it is the declared anti-edge
        ``("req.tree", "req.mp_mutex")`` in
        :mod:`repro.analysis.lock_order`, and the runtime witness raises
        on any nest that violates it (tests/test_lockdep.py)."""
        self.table.quiesce(gfn)

    def remove(self, gfn: int) -> None:
        with self._lock:
            self.table.unregister(gfn)
            req: Req = self._tree.delete(gfn)
            self._cache.pop(gfn, None)
            self.mpool.slab_free(req.record.handle)

    def __len__(self) -> int:
        return len(self._tree)

    def items(self):
        with self._lock:
            return list(self._tree.items())

    def check_invariants(self) -> None:
        with self._lock:
            self._tree.check_invariants()
            for gfn, req in self._tree.items():
                assert req.gfn == gfn == req.record.gfn
                assert self.table.reqs[gfn] is req
                assert int(self.table.hdr[gfn]) == req.record.handle.offset >> 3
                assert req.fdesc is not None and req.fdesc[0] == \
                    req.record.handle.offset >> 3
