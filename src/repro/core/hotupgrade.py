"""Hot-upgrade (paper §4.4, Fig 10).

Taiji splits into ``tj.ko`` (entry, never upgraded) and ``tj_hv_x.ko``
(main functionality, upgradable). We reproduce all three mechanisms:

  * **Data-plane compatibility** -- persistent metadata (MS records in the
    mpool arena) has a fixed ABI with reserved fields; the new module
    *attaches* to the same bytes (``MSRecord(..., attach=True)`` verifies
    the ABI version) with no conversion.
  * **Operation entry points** -- :class:`EntryOps` is the ``devtj``
    f_ops_g analogue: every external call goes through one global table;
    an upgrade atomically repoints table entries to the new module after
    in-flight calls drain (refcounted).
  * **VCPU execution transition** -- hv_sched workers re-read
    ``loop_entry`` every iteration; the upgrade installs the new module's
    scheduler loop (the HOST_RIP update), so each shard hands off at its
    next safe point without stopping.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional

from ..analysis.lock_order import named_lock
from .config import ABI_VERSION
from .errors import ABIMismatchError
from .ms import MSRecord, record_nbytes
from .swap import SwapEngine
from .system import TaijiSystem


class EntryOps:
    """tj.ko: the stable, never-upgraded entry module."""

    def __init__(self) -> None:
        self._ops: Dict[str, Callable] = {}
        self._inflight = 0
        self._lock = named_lock("entry")
        self._drained = threading.Condition(self._lock)

    def register(self, name: str, fn: Callable) -> None:
        with self._lock:
            self._ops[name] = fn

    def call(self, name: str, *args, **kwargs):
        with self._lock:
            fn = self._ops[name]
            self._inflight += 1
        try:
            return fn(*args, **kwargs)
        finally:
            with self._lock:
                self._inflight -= 1
                if self._inflight == 0:
                    self._drained.notify_all()

    def swap_all(self, new_ops: Dict[str, Callable], timeout: float = 5.0) -> None:
        """Atomically repoint every entry after in-flight calls complete.

        "All updates occur only after calls to the old module complete."
        """
        with self._lock:
            deadline = time.monotonic() + timeout
            while self._inflight > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("in-flight entry calls did not drain")
                self._drained.wait(remaining)
            self._ops.update(new_ops)


class EngineModule:
    """tj_hv_x.ko: one version of the main functionality.

    Subclasses may change internal behaviour but must keep the metadata
    ABI. ``attach`` re-validates every persistent record against the ABI
    before taking over -- an incompatible module refuses to load.
    """

    VERSION = 1
    ABI = ABI_VERSION

    def __init__(self, system: TaijiSystem) -> None:
        self.system = system
        self.engine: Optional[SwapEngine] = None

    # ------------------------------------------------------------- lifecycle
    def attach(self) -> None:
        sys = self.system
        if self.ABI != sys.cfg.abi_version:
            raise ABIMismatchError(
                f"module ABI {self.ABI} != system ABI {sys.cfg.abi_version}")
        expected = record_nbytes(sys.cfg)
        # inherit metadata directly: re-open every persistent record from
        # the same arena bytes, verifying layout (no conversion)
        for gfn, req in sys.reqs.items():
            rec = MSRecord(sys.cfg, req.record.handle, attach=True)
            if rec.handle.nbytes < expected or rec.gfn != gfn:
                raise ABIMismatchError(f"record for gfn {gfn} incompatible")
            req.record = rec
        # a fresh engine instance (new code) over the inherited state
        self.engine = self.make_engine()

    def make_engine(self) -> SwapEngine:
        sys = self.system
        return SwapEngine(sys.cfg, sys.virt, sys.backend, sys.reqs, sys.lru,
                          sys.watermark, sys.metrics)

    # entry-point table served through tj.ko
    def ops(self) -> Dict[str, Callable]:
        assert self.engine is not None
        return {
            "fault_in": self.engine.fault_in,
            "swap_out_ms": self.engine.swap_out_ms,
            "swap_in_ms": self.engine.swap_in_ms,
            "reclaim_round": self.engine.reclaim_round,
            "version": lambda: self.VERSION,
        }

    # the scheduler loop this module provides (HOST_RIP target)
    def sched_loop(self) -> Callable[[int], None]:
        return self.system.scheduler._run_cycle


class EngineModuleV2(EngineModule):
    """An upgraded module: same ABI, improved reclaim batching.

    Demonstrates a real behavioural change shipped by hot-upgrade: reclaim
    rounds take the cold-intermediate set into account immediately and use
    a doubled batch, converging to the high watermark in fewer rounds.
    """

    VERSION = 2

    def make_engine(self) -> SwapEngine:
        engine = super().make_engine()
        base_reclaim = engine.reclaim_round

        def reclaim_round_v2(budget_s=None) -> int:
            t0 = time.monotonic()
            n = base_reclaim(budget_s)
            if n > 0:                       # keep pressure while productive,
                # but within the same hv_sched quantum, not a second one
                rem = (None if budget_s is None
                       else budget_s - (time.monotonic() - t0))
                if rem is None or rem > 0:
                    n += base_reclaim(rem)
            return n

        engine.reclaim_round = reclaim_round_v2  # type: ignore[assignment]
        return engine


def install_module(system: TaijiSystem, entry: EntryOps,
                   module: EngineModule) -> None:
    """First-time load: attach and register all entry points."""
    module.attach()
    for name, fn in module.ops().items():
        entry.register(name, fn)
    system.scheduler.loop_entry = module.sched_loop()
    system.module_version = module.VERSION


def hot_upgrade(system: TaijiSystem, entry: EntryOps,
                new_module: EngineModule) -> None:
    """Upgrade the running module to ``new_module`` without service stop."""
    # 1) load + verify the new module against the live metadata (ABI gate)
    new_module.attach()
    # 2) VCPU execution transition: repoint the scheduler loop; every shard
    #    hands off at its next iteration boundary (HOST_RIP update)
    system.scheduler.loop_entry = new_module.sched_loop()
    # 3) repoint all operation entry points after old calls drain
    entry.swap_all(new_module.ops())
    system.module_version = new_module.VERSION
