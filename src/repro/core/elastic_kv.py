"""Elastic paged KV cache -- Taiji applied to LLM serving.

The DPU analogy (DESIGN.md §2): a serving node statically reserves KV
space for its *maximum* concurrent sequences, but most sequences are idle
between turns -- exactly the paper's "reserved for peak, cold in practice"
memory. Taiji makes that reservation elastic:

  * one MS per (sequence, KV block): ``block_tokens`` tokens x all layers
    x K+V, so swap decisions happen at the paper's huge-page granularity
    while faults resolve at MP granularity;
  * idle sequences cool down in the multi-level LRU and get swapped to the
    zero/compressed backend by the watermark-driven reclaim task;
  * scheduling a sequence for decode = the DMA-range contract: its blocks
    are swapped in *before* the step and pinned while the step (the
    "no-retry DMA device") is in flight;
  * the device-side data plane reads KV through the block table inside the
    paged-attention kernel (kernels/paged_attention.py) -- the EPT walk on
    the I/O path.

All guest memory flows through one :class:`~.guest.GuestSpace` (the
sanctioned surface), so attaching a ``TraceRecorder`` to the space turns
a live serving workload into a replayable fleet trace with zero cache
changes.

Beyond-paper: ``prefetch_async`` overlaps the next batch's swap-ins with
the current step (double buffering), recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..analysis.lock_order import named_lock
from .config import TaijiConfig
from .guest import GuestSpace
from .system import TaijiSystem
from .virt import F_SPLIT, NO_PFN


@dataclasses.dataclass(frozen=True)
class KVGeometry:
    n_layers: int
    kv_heads: int
    head_dim: int
    block_tokens: int = 16
    dtype_bytes: int = 2        # bf16 on device

    @property
    def block_bytes(self) -> int:
        # K and V for all layers of one block of tokens
        return (self.block_tokens * self.n_layers * 2 * self.kv_heads
                * self.head_dim * self.dtype_bytes)

    @property
    def tokens_per_block(self) -> int:
        return self.block_tokens


def _mpool_reserve_ms(ms_bytes: int, mps: int, n_phys: int,
                      overcommit: float) -> int:
    """Size the pinned arena for the virtual space with 2x headroom
    (the paper reserves 400 MB and reports <50% average utilization)."""
    n_virt = int(round((n_phys) * (1.0 + overcommit))) + 2
    per_gfn = (192 + 6 * mps) + 16          # MS record slab + table words
    need = 2 * (n_virt * per_gfn + 4 * ms_bytes)
    return max(2, -(-need // ms_bytes))


def make_kv_taiji_config(geom: KVGeometry, n_phys_blocks: int,
                         overcommit: float = 0.5, **overrides) -> TaijiConfig:
    """Size a Taiji config so one MS == one KV block."""
    ms_bytes = geom.block_bytes
    mps = 8
    while ms_bytes // mps < 512 and mps > 1:
        mps //= 2
    reserve = _mpool_reserve_ms(ms_bytes, mps, n_phys_blocks, overcommit)
    base = dict(
        ms_bytes=ms_bytes,
        mps_per_ms=mps,
        n_phys_ms=n_phys_blocks + reserve,
        mpool_reserve_ms=reserve,
        overcommit_ratio=overcommit,
    )
    base.update(overrides)
    return TaijiConfig(**base)


class _PrefetchThread(threading.Thread):
    """Prefetch worker whose failures surface instead of dying silently:
    the exception is stored on the thread object and re-raised on
    ``join()`` (once the worker has actually finished)."""

    def __init__(self, **kw) -> None:
        super().__init__(**kw)
        self.exc: Optional[BaseException] = None

    def run(self) -> None:
        try:
            super().run()
        except BaseException as e:      # noqa: BLE001 - surfaced on join
            self.exc = e

    def join(self, timeout: Optional[float] = None) -> None:
        super().join(timeout)
        if self.exc is not None and not self.is_alive():
            raise self.exc


class ElasticKVCache:
    """Host-side elastic KV block store for a serving node.

    Accepts either a :class:`GuestSpace` or a :class:`TaijiSystem` (its
    canonical ``.guest`` space is used), so capture/policy observers
    attached to the space see every cache operation.
    """

    def __init__(self, geom: KVGeometry,
                 space: Union[GuestSpace, TaijiSystem]) -> None:
        self.geom = geom
        self.space = space.guest if isinstance(space, TaijiSystem) else space
        self.system = self.space.system      # telemetry / legacy accessors
        self._lock = named_lock("app")
        # seq_id -> list of gfns (one per block) and token count
        self._blocks: Dict[int, List[int]] = {}
        self._tokens: Dict[int, int] = {}

    # ------------------------------------------------------------ sequences
    def create_sequence(self, seq_id: int) -> None:
        with self._lock:
            if seq_id in self._blocks:
                raise ValueError(f"sequence {seq_id} exists")
            self._blocks[seq_id] = []
            self._tokens[seq_id] = 0

    def drop_sequence(self, seq_id: int) -> None:
        with self._lock:
            gfns = self._blocks.pop(seq_id, [])
            self._tokens.pop(seq_id, None)
        for gfn in gfns:
            self.space.free_ms(gfn)

    def seq_len(self, seq_id: int) -> int:
        return self._tokens[seq_id]

    def blocks_of(self, seq_id: int) -> List[int]:
        return list(self._blocks[seq_id])

    # --------------------------------------------------------------- writes
    def append_kv(self, seq_id: int, kv_token: np.ndarray) -> None:
        """Append one token's KV (shape: [n_layers, 2, kv_heads, head_dim])."""
        g = self.geom
        expect = (g.n_layers, 2, g.kv_heads, g.head_dim)
        if kv_token.shape != expect:
            raise ValueError(f"kv shape {kv_token.shape} != {expect}")
        raw = kv_token.astype(np.float16 if g.dtype_bytes == 2 else np.float32)
        with self._lock:
            t = self._tokens[seq_id]
            blocks = self._blocks[seq_id]
        slot = t % g.block_tokens
        if slot == 0:                      # new block needed
            gfn = self.space.alloc_ms()
            with self._lock:
                blocks.append(gfn)
        gfn = blocks[t // g.block_tokens]
        self.space.write(gfn, raw.tobytes(), off=slot * raw.nbytes)
        with self._lock:
            self._tokens[seq_id] = t + 1

    # ---------------------------------------------------------------- reads
    def _block_dtype_shape(self):
        g = self.geom
        dt = np.float16 if g.dtype_bytes == 2 else np.float32
        return dt, (g.block_tokens, g.n_layers, 2, g.kv_heads, g.head_dim)

    def read_block(self, seq_id: int, block_idx: int) -> np.ndarray:
        """Read one block back as [block_tokens, n_layers, 2, kv_heads, head_dim]."""
        return self.read_blocks(seq_id, [block_idx])[0]

    def read_blocks(self, seq_id: int,
                    block_idxs: Optional[Sequence[int]] = None) -> np.ndarray:
        """Read several blocks of one sequence in a single batched gather
        (default: all of them): one residency probe, one observer
        dispatch, one ``[n_blocks, block_tokens, n_layers, 2, kv_heads,
        head_dim]`` result.  This is the attention hot path -- per-block
        ``view().load()`` paid the full translate/bounds/observer stack
        per block."""
        with self._lock:
            blocks = self._blocks[seq_id]
            gfns = (list(blocks) if block_idxs is None
                    else [blocks[i] for i in block_idxs])
        dt, shape = self._block_dtype_shape()
        return self.space.gather(gfns, dt, shape)

    # ------------------------------------------------------------- stepping
    def prepare_step(self, seq_ids: Sequence[int]):
        """Swap in + pin all blocks of the scheduled batch.

        Returns the DMA pin context; use ``with cache.prepare_step(b): step()``.
        Missing blocks are faulted in (this is where fault latency is paid
        and measured); pinned blocks cannot be reclaimed mid-step.
        """
        gfns: List[int] = []
        with self._lock:
            for sid in seq_ids:
                gfns.extend(self._blocks[sid])
        return self.space.pin(gfns)

    def prefetch_async(self, seq_ids: Sequence[int]) -> threading.Thread:
        """Beyond-paper: overlap next batch's swap-ins with the current step.

        Returns the worker thread; a failure inside the worker is stored
        on it and re-raised by ``join()`` rather than vanishing with the
        daemon thread.
        """
        with self._lock:
            gfns = [g for sid in seq_ids for g in self._blocks.get(sid, [])]
        system = self.space.system

        def work() -> None:
            # one vectorized residency probe over the whole candidate set
            # (only swapped or split MSs can need a swap-in) instead of a
            # req lookup per block; the watermark guard stays per-MS so a
            # long prefetch still yields to the pinned in-flight step
            g = np.asarray(gfns, dtype=np.int64)
            if not g.size:
                return
            table = system.virt.table
            cand = ((table.pfn[g] == NO_PFN)
                    | ((table.flags[g] & F_SPLIT) != 0))
            for gfn in (int(x) for x in g[cand]):
                # opportunistic: never compete with the pinned in-flight
                # step for the last free slots
                if system.phys.free_count <= system.watermark.low_ms:
                    return
                req = system.reqs.lookup(gfn)
                if req is not None and req.record.swapped_out_count() > 0:
                    system.engine.swap_in_ms(gfn)

        th = _PrefetchThread(target=work, name="kv-prefetch", daemon=True)
        th.start()
        return th

    # ------------------------------------------------------------ telemetry
    def residency(self) -> Dict[str, int]:
        with self._lock:
            all_gfns = [g for bl in self._blocks.values() for g in bl]
        res = self.space.residency(all_gfns)
        return {"resident_blocks": res["resident"],
                "swapped_blocks": res["swapped"],
                "total_blocks": res["total"]}
