"""Taiji elastic-memory core (the paper's contribution, adapted to TPU/JAX).

Layering (bottom up):
  config/errors/metrics -> mpool -> virt (block table = EPT analogue)
  -> ms/req (records + concurrency) -> backend -> lru -> watermark
  -> swap (engine) -> scheduler (hv_sched) -> system (facade)
  -> hotswitch / hotupgrade -> dma
  -> guest (GuestSpace: the one sanctioned guest-memory surface)
  -> elastic_kv / elastic_params (framework integrations)
"""
from .config import (ABI_VERSION, BackendConfig, LRUConfig, SchedulerConfig,
                     TaijiConfig, WatermarkConfig, small_test_config)
from .errors import (ABIMismatchError, CorruptionError, InvalidStateError,
                     MpoolExhaustedError, OutOfMemoryError, PinnedError,
                     TaijiError)
from .guest import GuestObserver, GuestSpace, MSView
from .system import TaijiSystem
from .hotswitch import PlainMemorySystem, hot_switch
from .hotupgrade import EngineModule, EngineModuleV2, EntryOps, hot_upgrade, install_module

__all__ = [
    "ABI_VERSION", "BackendConfig", "LRUConfig", "SchedulerConfig",
    "TaijiConfig", "WatermarkConfig", "small_test_config",
    "TaijiError", "OutOfMemoryError", "MpoolExhaustedError",
    "CorruptionError", "PinnedError", "ABIMismatchError", "InvalidStateError",
    "GuestObserver", "GuestSpace", "MSView",
    "TaijiSystem", "PlainMemorySystem", "hot_switch",
    "EntryOps", "EngineModule", "EngineModuleV2", "install_module", "hot_upgrade",
]
