"""Runtime metrics for the elastic-memory core.

The paper evaluates Taiji with fault-latency percentiles (Fig 14f / 15d),
water-level timelines (Fig 14e / 15a), hot/cold page counts (Fig 14c/d,
15b), backend composition (Fig 15c) and metadata utilization (Fig 13a).
This module provides the counters/histograms those benchmarks read.

The fault path is latency-critical (P90 < 10 us), so ``LatencyHistogram``
records with integer bucket math only -- no allocation, no locking beyond
the GIL (single bytecode ops on ints are atomic in CPython).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from ..analysis.lock_order import named_lock

# fault-kind codes recorded alongside each latency sample (3 tag bits in
# the ring encoding: 2 kind bits + the fast-path flag)
FK_ZERO, FK_COMPRESSED, FK_READAHEAD, FK_OTHER = 0, 1, 2, 3
FK_NAMES = ("zero", "compressed", "readahead", "other")
# fast-path zero faults push FK_ZERO | FK_FAST and defer their pure-stat
# counter increments (fault_zero_pages / fault_fast_path / crc_checks) to
# the vectorized ring flush -- three attribute read-modify-writes off the
# 10us budget; the exactly-once witnesses (faults, mp_swapped_in) stay
# immediate
FK_FAST = 4


class LatencyHistogram:
    """Fixed-bucket nanosecond latency histogram.

    Buckets are powers of two from 256 ns to ~67 ms plus an overflow bucket.
    """

    _BASE_SHIFT = 8          # first bucket: < 2**8 ns
    _NBUCKETS = 20
    _RESERVOIR = 200_000     # exact samples kept for precise percentiles
    # bucket upper bounds for the vectorized LatencyRing fold: searchsorted
    # (side="right") over these reproduces record()'s bit_length bucketing
    _bounds = np.int64(1) << (np.arange(20, dtype=np.int64) + 8)

    def __init__(self) -> None:
        self.buckets = [0] * (self._NBUCKETS + 1)
        self.count = 0
        self.total_ns = 0
        self.max_ns = 0
        self.samples = []    # bounded exact reservoir (list.append ~50ns)

    def record(self, ns: int) -> None:
        idx = max(0, ns.bit_length() - self._BASE_SHIFT)
        if idx > self._NBUCKETS:
            idx = self._NBUCKETS
        self.buckets[idx] += 1
        self.count += 1
        self.total_ns += ns
        if ns > self.max_ns:
            self.max_ns = ns
        if len(self.samples) < self._RESERVOIR:
            self.samples.append(ns)

    def percentile(self, p: float) -> float:
        """Percentile in ns: exact from the reservoir when available."""
        if self.count == 0:
            return 0.0
        if self.samples:
            s = sorted(self.samples)
            return float(s[min(len(s) - 1, int(p * len(s)))])
        target = p * self.count
        seen = 0
        for i, c in enumerate(self.buckets):
            seen += c
            if seen >= target:
                return float(1 << (i + self._BASE_SHIFT))
        return float(self.max_ns)

    def fraction_below(self, ns: int) -> float:
        """Fraction of samples below ``ns``."""
        if self.count == 0:
            return 1.0
        if self.samples:
            return sum(1 for s in self.samples if s < ns) / len(self.samples)
        seen = 0
        for i, c in enumerate(self.buckets):
            upper = 1 << (i + self._BASE_SHIFT)
            if upper > ns:
                break
            seen += c
        return seen / self.count

    @property
    def mean_ns(self) -> float:
        return self.total_ns / self.count if self.count else 0.0

    def merge(self, other: "LatencyHistogram") -> None:
        """Fold ``other`` into this histogram (fleet-wide aggregation).

        Exact reservoirs are kept only while every sample fits; once any
        source overflows, the merged reservoir would over-weight whichever
        node merged first (``percentile`` prefers samples whenever
        present), so it is dropped and percentiles fall back to the
        unbiased bucket math.
        """
        both_complete = (len(self.samples) == self.count
                         and len(other.samples) == other.count
                         and self.count + other.count <= self._RESERVOIR)
        for i, c in enumerate(other.buckets):
            self.buckets[i] += c
        self.count += other.count
        self.total_ns += other.total_ns
        if other.max_ns > self.max_ns:
            self.max_ns = other.max_ns
        if both_complete:
            self.samples.extend(other.samples)
        else:
            self.samples = []

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean_us": self.mean_ns / 1e3,
            "p50_us": self.percentile(0.50) / 1e3,
            "p90_us": self.percentile(0.90) / 1e3,
            "p99_us": self.percentile(0.99) / 1e3,
            "max_us": self.max_ns / 1e3,
        }


class LatencyRing:
    """Preallocated numpy ring in front of latency histograms.

    ``LatencyHistogram.record`` costs ~1 us of Python bucket math per
    sample -- on a 10 us fault budget the measurement inflates the thing
    being measured. The ring's :meth:`push` is a single encoded int64
    store (``(ns + 1) << 3 | tag``, where tag is 2 kind bits plus the
    ``FK_FAST`` flag; the +1 makes 0 an empty-slot sentinel); bucketing,
    totals and the exact reservoir are folded in vectorized batches by
    :meth:`flush` (when the ring fills, and from ``Metrics.sync()``
    before any read).

    Concurrency: pushes are GIL-serialized single stores; :meth:`flush`
    zeroes the slots it copied, so a push racing a flush can never be
    folded twice. A racing push can at worst land in a slot the flush
    already copied and be dropped -- an accepted stats-only loss, which
    for a dropped fast-path sample also undercounts the deferred
    ``fault_zero_pages``/``fault_fast_path``/``crc_checks`` stats by
    one. The exactly-once witnesses (``faults``, ``mp_swapped_in``) are
    incremented on the fault path itself and stay exact; deterministic
    (single-threaded, stepped) replays lose nothing.
    """

    __slots__ = ("_buf", "_pos", "_cap", "_lock", "hist", "by_kind",
                 "metrics", "count_crc")

    def __init__(self, hist: "LatencyHistogram",
                 by_kind: Dict[str, "LatencyHistogram"],
                 metrics: "Metrics" = None, cap: int = 4096) -> None:
        self._buf = np.zeros(cap, dtype=np.int64)
        self._pos = 0
        self._cap = cap
        self._lock = named_lock("metrics")
        self.hist = hist
        self.by_kind = by_kind
        self.metrics = metrics       # deferred fast-path counter target
        self.count_crc = True        # engine clears when CRC is disabled

    def push(self, ns: int, kind: int) -> None:
        p = self._pos
        if p >= self._cap:
            self.flush()
            p = self._pos
            if p >= self._cap:           # racing pushers refilled the ring
                p = self._cap - 1        # overwrite the tail (stats-only)
        self._buf[p] = ((ns + 1) << 3) | kind
        self._pos = p + 1

    def flush(self) -> None:
        with self._lock:
            n = self._pos
            if n == 0:
                return
            enc = self._buf[:n].copy()
            self._buf[:n] = 0            # stale-slot guard vs racing pushes
            self._pos = 0
        enc = enc[enc != 0]              # skip empty/already-folded slots
        if len(enc) == 0:
            return
        ns = (enc >> 3) - 1
        kinds = enc & 3
        self._fold(self.hist, ns)
        for code, name in enumerate(FK_NAMES):
            sel = ns[kinds == code]
            if len(sel):
                self._fold(self.by_kind[name], sel)
        m = self.metrics
        if m is not None:
            fast = int(np.count_nonzero(enc & FK_FAST))
            if fast:
                m.fault_zero_pages += fast
                m.fault_fast_path += fast
                if self.count_crc:
                    m.crc_checks += fast

    @staticmethod
    def _fold(hist: "LatencyHistogram", ns: np.ndarray) -> None:
        """Vectorized equivalent of ``hist.record`` over a batch."""
        # bucket index = max(0, bit_length - BASE_SHIFT), computed exactly
        # via searchsorted over the power-of-two bucket upper bounds
        bounds = hist._bounds
        idx = np.searchsorted(bounds, ns, side="right")
        counts = np.bincount(idx, minlength=hist._NBUCKETS + 1)
        for i in np.flatnonzero(counts):
            hist.buckets[int(i)] += int(counts[i])
        hist.count += len(ns)
        hist.total_ns += int(ns.sum())
        mx = int(ns.max())
        if mx > hist.max_ns:
            hist.max_ns = mx
        room = hist._RESERVOIR - len(hist.samples)
        if room > 0:
            hist.samples.extend(ns[:room].tolist())


class Timeline:
    """Append-only (t, value) series, e.g. free-memory water level."""

    def __init__(self, maxlen: int = 100_000) -> None:
        self._lock = named_lock("metrics")
        self._t0 = time.perf_counter()
        self.points: List[tuple] = []
        self._maxlen = maxlen

    def record(self, value: float) -> None:
        with self._lock:
            if len(self.points) < self._maxlen:
                self.points.append((time.perf_counter() - self._t0, value))


class Metrics:
    """All counters for one Taiji instance."""

    def __init__(self) -> None:
        # fault path (passive swap-in) latency -- the paper's headline
        # metric. Stored privately; the public ``fault_latency`` /
        # ``fault_latency_by_kind`` properties sync the ring first so
        # direct readers always see settled histograms.
        self._fault_latency = LatencyHistogram()
        # per-kind split (zero / compressed / extent-readahead / other) for
        # the latency-budget breakdown in benchmarks/fault_latency.py
        self._fault_latency_by_kind: Dict[str, LatencyHistogram] = {
            name: LatencyHistogram() for name in FK_NAMES}
        # the fault path records through this ring (one int64 store per
        # fault); flushed on reads and by sync()
        self.fault_ring = LatencyRing(self._fault_latency,
                                      self._fault_latency_by_kind, self)
        # active-task latencies
        self.swap_out_latency = LatencyHistogram()
        self.swap_in_latency = LatencyHistogram()

        # counters (GIL-atomic int += in single ops is fine for stats)
        self.faults = 0
        self.fault_zero_pages = 0
        self.fault_compressed_pages = 0
        self.fault_fast_path = 0         # zero faults resolved lock-light
        self.readahead_extents = 0       # extents materialized by readahead
        self.fault_readahead_mps = 0     # sibling MPs materialized (beyond 1)
        self.ms_swapped_out = 0
        self.ms_swapped_in = 0
        self.mp_swapped_out = 0
        self.mp_swapped_in = 0
        # batched data path (one batch == one store_batch/load_batch chunk)
        self.swap_out_batches = 0
        self.swap_in_batches = 0
        self.mp_swapped_out_batched = 0  # numerator for mean batch size
        self.backend_batch_stores = 0
        self.backend_batch_loads = 0
        self.writer_cancels = 0          # rw-lock cancel events (paper Fig 8 (2.2))
        self.crc_checks = 0
        self.crc_failures = 0
        self.dmar_intercepts = 0         # faults on registered DMA ranges (paper §7.1)
        self.reclaim_rounds = 0
        self.proactive_reclaims = 0      # min-watermark synchronous reclaims

        # backend composition (paper Fig 15c)
        self.backend_zero_mps = 0
        self.backend_compressed_mps = 0
        self.backend_raw_bytes = 0
        self.backend_stored_bytes = 0

        self.free_ms_timeline = Timeline()
        self.hot_cold_timeline = Timeline()

        # stage-attributed span tracer (repro.obs) -- None unless
        # ObsConfig.enabled; instrumented call sites cache this and guard
        # with a single `is not None` branch. Wall-clock telemetry only:
        # never part of deterministic_snapshot().
        self.tracer = None

    @property
    def fault_latency(self) -> LatencyHistogram:
        """Fault-latency histogram, with pending ring samples folded in."""
        self.fault_ring.flush()
        return self._fault_latency

    @property
    def fault_latency_by_kind(self) -> Dict[str, LatencyHistogram]:
        """Per-kind fault histograms, with pending ring samples folded in."""
        self.fault_ring.flush()
        return self._fault_latency_by_kind

    def sync(self) -> None:
        """Fold pending latency-ring samples into the histograms and the
        deferred fast-path stat counters."""
        self.fault_ring.flush()

    def reset_fault_latency(self) -> None:
        """Discard fault-latency samples (benchmark warmup separation).

        Event counters are untouched -- only the timing histograms and
        their ring restart, so a benchmark can measure steady state
        without cold-start samples."""
        count_crc = self.fault_ring.count_crc
        self._fault_latency = LatencyHistogram()
        self._fault_latency_by_kind = {
            name: LatencyHistogram() for name in FK_NAMES}
        self.fault_ring = LatencyRing(self._fault_latency,
                                      self._fault_latency_by_kind, self)
        self.fault_ring.count_crc = count_crc

    def render_prom(self, tracer=None, prefix: str = "taiji") -> str:
        """Prometheus text exposition of counters/gauges/histograms (and
        per-stage span aggregates when tracing is enabled). Lazy import:
        ``repro.obs.prom`` reads this object duck-typed, so core keeps no
        hard dependency on the obs package."""
        from repro.obs.prom import render_prom as _render
        return _render(self, tracer if tracer is not None else self.tracer,
                       prefix=prefix)

    def compression_ratio(self) -> float:
        """stored/raw over the compressed population (paper: 47.63%)."""
        if self.backend_raw_bytes == 0:
            return 1.0
        return self.backend_stored_bytes / self.backend_raw_bytes

    def deterministic_snapshot(self) -> Dict[str, int]:
        """Pure event counters -- no wall-clock derived values.

        Syncs the latency ring first: fast-path faults defer their stat
        counters to the flush (the deferred *counts* are deterministic
        even though the latency values are not).

        Replaying the same seeded trace through a stepped (round-based)
        fleet must produce byte-identical snapshots; latency histograms
        and timelines are inherently timing-dependent, so fleet replay
        determinism is asserted over exactly this view.
        """
        self.sync()
        return {
            "faults": self.faults,
            "fault_zero_pages": self.fault_zero_pages,
            "fault_compressed_pages": self.fault_compressed_pages,
            "fault_fast_path": self.fault_fast_path,
            "readahead_extents": self.readahead_extents,
            "fault_readahead_mps": self.fault_readahead_mps,
            "ms_swapped_out": self.ms_swapped_out,
            "ms_swapped_in": self.ms_swapped_in,
            "mp_swapped_out": self.mp_swapped_out,
            "mp_swapped_in": self.mp_swapped_in,
            "swap_out_batches": self.swap_out_batches,
            "swap_in_batches": self.swap_in_batches,
            "mp_swapped_out_batched": self.mp_swapped_out_batched,
            "backend_batch_stores": self.backend_batch_stores,
            "backend_batch_loads": self.backend_batch_loads,
            "writer_cancels": self.writer_cancels,
            "crc_checks": self.crc_checks,
            "crc_failures": self.crc_failures,
            "dmar_intercepts": self.dmar_intercepts,
            "reclaim_rounds": self.reclaim_rounds,
            "proactive_reclaims": self.proactive_reclaims,
            "backend_zero_mps": self.backend_zero_mps,
            "backend_compressed_mps": self.backend_compressed_mps,
            "backend_raw_bytes": self.backend_raw_bytes,
            "backend_stored_bytes": self.backend_stored_bytes,
        }

    def snapshot(self) -> Dict[str, object]:
        self.sync()
        return {
            "faults": self.faults,
            "fault_latency": self.fault_latency.snapshot(),
            "fault_latency_by_kind": {
                name: h.snapshot()
                for name, h in self.fault_latency_by_kind.items()},
            "fault_fast_path": self.fault_fast_path,
            "readahead_extents": self.readahead_extents,
            "fault_readahead_mps": self.fault_readahead_mps,
            "ms_swapped_out": self.ms_swapped_out,
            "ms_swapped_in": self.ms_swapped_in,
            "mp_swapped_out": self.mp_swapped_out,
            "mp_swapped_in": self.mp_swapped_in,
            "swap_out_batches": self.swap_out_batches,
            "swap_in_batches": self.swap_in_batches,
            "mean_swap_out_batch_mps": (
                self.mp_swapped_out_batched / self.swap_out_batches
                if self.swap_out_batches else 0.0),
            "writer_cancels": self.writer_cancels,
            "crc_failures": self.crc_failures,
            "zero_mps": self.backend_zero_mps,
            "compressed_mps": self.backend_compressed_mps,
            "compression_ratio": self.compression_ratio(),
        }
