"""Red-black tree keyed by integer GFN.

The paper stores all swap request entities (``req``) in a red-black tree so
a page fault can locate the req for the faulting address efficiently
(§4.2.2: "All reqs are unique and stored in a red-black tree for efficient
page-fault lookup"). We reproduce that structure rather than substituting a
hash map so the lookup path has the same asymptotics and supports
floor-lookup (find the req covering an address range).

Not thread safe by itself; the swap engine guards it with a short mutex,
matching the kernel's tree-lock discipline.
"""
from __future__ import annotations

from typing import Any, Iterator, Optional

RED = 0
BLACK = 1


class _Node:
    __slots__ = ("key", "value", "left", "right", "parent", "color")

    def __init__(self, key: int, value: Any) -> None:
        self.key = key
        self.value = value
        self.left: Optional[_Node] = None
        self.right: Optional[_Node] = None
        self.parent: Optional[_Node] = None
        self.color = RED


class RBTree:
    def __init__(self) -> None:
        self.root: Optional[_Node] = None
        self.size = 0

    # ------------------------------------------------------------- rotations
    def _rotate_left(self, x: _Node) -> None:
        y = x.right
        assert y is not None
        x.right = y.left
        if y.left is not None:
            y.left.parent = x
        y.parent = x.parent
        if x.parent is None:
            self.root = y
        elif x is x.parent.left:
            x.parent.left = y
        else:
            x.parent.right = y
        y.left = x
        x.parent = y

    def _rotate_right(self, x: _Node) -> None:
        y = x.left
        assert y is not None
        x.left = y.right
        if y.right is not None:
            y.right.parent = x
        y.parent = x.parent
        if x.parent is None:
            self.root = y
        elif x is x.parent.right:
            x.parent.right = y
        else:
            x.parent.left = y
        y.right = x
        x.parent = y

    # ---------------------------------------------------------------- insert
    def insert(self, key: int, value: Any) -> None:
        """Insert key -> value; keys must be unique (reqs are unique)."""
        node = _Node(key, value)
        parent, cur = None, self.root
        while cur is not None:
            parent = cur
            if key < cur.key:
                cur = cur.left
            elif key > cur.key:
                cur = cur.right
            else:
                raise KeyError(f"duplicate key {key}")
        node.parent = parent
        if parent is None:
            self.root = node
        elif key < parent.key:
            parent.left = node
        else:
            parent.right = node
        self.size += 1
        self._insert_fixup(node)

    def _insert_fixup(self, z: _Node) -> None:
        while z.parent is not None and z.parent.color == RED:
            gp = z.parent.parent
            assert gp is not None
            if z.parent is gp.left:
                y = gp.right
                if y is not None and y.color == RED:
                    z.parent.color = BLACK
                    y.color = BLACK
                    gp.color = RED
                    z = gp
                else:
                    if z is z.parent.right:
                        z = z.parent
                        self._rotate_left(z)
                    z.parent.color = BLACK
                    gp.color = RED
                    self._rotate_right(gp)
            else:
                y = gp.left
                if y is not None and y.color == RED:
                    z.parent.color = BLACK
                    y.color = BLACK
                    gp.color = RED
                    z = gp
                else:
                    if z is z.parent.left:
                        z = z.parent
                        self._rotate_right(z)
                    z.parent.color = BLACK
                    gp.color = RED
                    self._rotate_left(gp)
        assert self.root is not None
        self.root.color = BLACK

    # ---------------------------------------------------------------- lookup
    def find(self, key: int) -> Any:
        cur = self.root
        while cur is not None:
            if key < cur.key:
                cur = cur.left
            elif key > cur.key:
                cur = cur.right
            else:
                return cur.value
        return None

    def floor(self, key: int) -> Any:
        """Value with the greatest key <= ``key`` (covering-range lookup)."""
        cur, best = self.root, None
        while cur is not None:
            if cur.key == key:
                return cur.value
            if cur.key < key:
                best = cur
                cur = cur.right
            else:
                cur = cur.left
        return best.value if best is not None else None

    # ---------------------------------------------------------------- delete
    def _minimum(self, node: _Node) -> _Node:
        while node.left is not None:
            node = node.left
        return node

    def _transplant(self, u: _Node, v: Optional[_Node]) -> None:
        if u.parent is None:
            self.root = v
        elif u is u.parent.left:
            u.parent.left = v
        else:
            u.parent.right = v
        if v is not None:
            v.parent = u.parent

    def delete(self, key: int) -> Any:
        z = self.root
        while z is not None and z.key != key:
            z = z.left if key < z.key else z.right
        if z is None:
            raise KeyError(key)
        value = z.value
        y, y_color = z, z.color
        if z.left is None:
            x, x_parent = z.right, z.parent
            self._transplant(z, z.right)
        elif z.right is None:
            x, x_parent = z.left, z.parent
            self._transplant(z, z.left)
        else:
            y = self._minimum(z.right)
            y_color = y.color
            x = y.right
            if y.parent is z:
                x_parent = y
            else:
                x_parent = y.parent
                self._transplant(y, y.right)
                y.right = z.right
                y.right.parent = y
            self._transplant(z, y)
            y.left = z.left
            y.left.parent = y
            y.color = z.color
        self.size -= 1
        if y_color == BLACK:
            self._delete_fixup(x, x_parent)
        return value

    def _delete_fixup(self, x: Optional[_Node], parent: Optional[_Node]) -> None:
        while x is not self.root and (x is None or x.color == BLACK):
            if parent is None:
                break
            if x is parent.left:
                w = parent.right
                if w is not None and w.color == RED:
                    w.color = BLACK
                    parent.color = RED
                    self._rotate_left(parent)
                    w = parent.right
                if w is None:
                    x, parent = parent, parent.parent
                    continue
                wl_black = w.left is None or w.left.color == BLACK
                wr_black = w.right is None or w.right.color == BLACK
                if wl_black and wr_black:
                    w.color = RED
                    x, parent = parent, parent.parent
                else:
                    if wr_black:
                        if w.left is not None:
                            w.left.color = BLACK
                        w.color = RED
                        self._rotate_right(w)
                        w = parent.right
                    assert w is not None
                    w.color = parent.color
                    parent.color = BLACK
                    if w.right is not None:
                        w.right.color = BLACK
                    self._rotate_left(parent)
                    x, parent = self.root, None
            else:
                w = parent.left
                if w is not None and w.color == RED:
                    w.color = BLACK
                    parent.color = RED
                    self._rotate_right(parent)
                    w = parent.left
                if w is None:
                    x, parent = parent, parent.parent
                    continue
                wl_black = w.left is None or w.left.color == BLACK
                wr_black = w.right is None or w.right.color == BLACK
                if wl_black and wr_black:
                    w.color = RED
                    x, parent = parent, parent.parent
                else:
                    if wl_black:
                        if w.right is not None:
                            w.right.color = BLACK
                        w.color = RED
                        self._rotate_left(w)
                        w = parent.left
                    assert w is not None
                    w.color = parent.color
                    parent.color = BLACK
                    if w.left is not None:
                        w.left.color = BLACK
                    self._rotate_right(parent)
                    x, parent = self.root, None
        if x is not None:
            x.color = BLACK

    # ------------------------------------------------------------- iteration
    def items(self) -> Iterator[tuple]:
        stack, cur = [], self.root
        while stack or cur is not None:
            while cur is not None:
                stack.append(cur)
                cur = cur.left
            cur = stack.pop()
            yield cur.key, cur.value
            cur = cur.right

    def __len__(self) -> int:
        return self.size

    def __contains__(self, key: int) -> bool:
        return self.find(key) is not None

    # ------------------------------------------------------------ validation
    def check_invariants(self) -> int:
        """Verify RB invariants; returns black-height. Used by property tests."""

        def rec(node: Optional[_Node]) -> int:
            if node is None:
                return 1
            if node.color == RED:
                for c in (node.left, node.right):
                    if c is not None and c.color == RED:
                        raise AssertionError("red node with red child")
            lh = rec(node.left)
            rh = rec(node.right)
            if lh != rh:
                raise AssertionError("black-height mismatch")
            if node.left is not None and node.left.key >= node.key:
                raise AssertionError("BST order violated (left)")
            if node.right is not None and node.right.key <= node.key:
                raise AssertionError("BST order violated (right)")
            return lh + (1 if node.color == BLACK else 0)

        if self.root is not None and self.root.color != BLACK:
            raise AssertionError("root must be black")
        return rec(self.root)
