"""DMA protection and data correctness (paper §7.1).

    "as current DMA devices lack retry support, swapping memory must be
     avoided to prevent corruption ... Taiji lets applications specify DMA
     ranges for protection and ensures timely swap-in before access. Taiji
     also intercepts DMAR exceptions and uses CRC to ensure correctness."

On the TPU side the "DMA device" is a dispatched XLA step: once launched it
cannot retry a missing block, so every block a step may touch is pinned for
the step duration. The registry supports both long-lived application tags
(``register_range``) and per-step pins (``pin_for_step`` context).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Iterable, List, Set

from ..analysis.lock_order import named_lock
from .metrics import Metrics
from .swap import SwapEngine
from .virt import NO_PFN, VirtualizationLayer


class DMARegistry:
    def __init__(self, virt: VirtualizationLayer, engine: SwapEngine,
                 metrics: Metrics) -> None:
        self.virt = virt
        self.engine = engine
        self.metrics = metrics
        self._lock = named_lock("app")
        # gfn -> pin refcount (a gfn may be in several active ranges/steps)
        self._pins: Dict[int, int] = {}
        self._ranges: Dict[str, List[int]] = {}

    # ------------------------------------------------------- range tagging
    def register_range(self, tag: str, gfns: Iterable[int]) -> None:
        """Application-specified DMA range: swap-in now, pin until dropped."""
        gfns = list(gfns)
        for gfn in gfns:
            self._ensure_resident(gfn)
        with self._lock:
            self._ranges[tag] = gfns
            for gfn in gfns:
                self._pin_locked(gfn)

    def drop_range(self, tag: str) -> None:
        with self._lock:
            gfns = self._ranges.pop(tag, [])
            for gfn in gfns:
                self._unpin_locked(gfn)

    # ----------------------------------------------------------- step pins
    @contextmanager
    def pin_for_step(self, gfns: Iterable[int]):
        """Pin a working set for one in-flight step (DMA cannot retry)."""
        gfns = list(gfns)
        for gfn in gfns:
            self._ensure_resident(gfn)
        with self._lock:
            for gfn in gfns:
                self._pin_locked(gfn)
        try:
            yield
        finally:
            with self._lock:
                for gfn in gfns:
                    self._unpin_locked(gfn)

    # ------------------------------------------------------------ internals
    def _ensure_resident(self, gfn: int) -> None:
        """Timely swap-in before access (§7.1)."""
        req = self.engine.reqs.lookup(gfn)
        if req is not None and req.record.swapped_out_count() > 0:
            self.engine.swap_in_ms(gfn)
        if int(self.virt.table.pfn[gfn]) == NO_PFN:
            # fully swapped and no req progress -- fault in MP 0 to allocate
            self.engine.swap_in_ms(gfn)

    def _pin_locked(self, gfn: int) -> None:
        c = self._pins.get(gfn, 0)
        self._pins[gfn] = c + 1
        if c == 0:
            self.virt.table.set_pinned(gfn, True)

    def _unpin_locked(self, gfn: int) -> None:
        c = self._pins.get(gfn, 0) - 1
        if c <= 0:
            self._pins.pop(gfn, None)
            self.virt.table.set_pinned(gfn, False)
        else:
            self._pins[gfn] = c

    def pinned_gfns(self) -> Set[int]:
        with self._lock:
            return set(self._pins)
