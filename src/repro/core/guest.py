"""GuestSpace -- the one sanctioned guest-memory surface.

Taiji's promise is elasticity that is transparent to upper-layer
applications, but transparency only composes if every upper layer talks
to the same surface. Before this module, `ElasticKVCache`,
`ElasticExpertCache` and the fleet `NodeAgent` each drove
``TaijiSystem.read/write/ms_addr/guest_alloc_ms`` through their own glue,
so cross-cutting concerns (workload capture, verification, per-tenant
accounting, policy hooks) had no seam to hook.  ``GuestSpace`` is that
seam -- tracehm records at the access layer for the same reason: one
well-placed indirection layer owns everything that wants to see guest
accesses.

The API is gfn-relative (an MS handle plus an offset) rather than raw
guest-virtual addresses: callers never do address arithmetic, and every
access is bounds-checked against one MS.  Raw-GVA entry points
(``read_gva``/``write_gva``) exist for the ``TaijiSystem`` deprecation
shims and for code that already holds a packed address.

Observers (:class:`GuestObserver`) see every alloc/free/access/tick.
``repro.fleet.trace.TraceRecorder`` is the flagship observer: it turns a
live serving workload into a replayable fleet trace (see
``repro.fleet.capture``).  The observer list is almost always empty, so
the hot path pays one truthiness check.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from .virt import NO_PFN


class GuestObserver:
    """Protocol for guest-memory event observers (no-op base class).

    ``on_access`` fires after the access succeeded; ``data`` carries the
    bytes written (writes), the bytes returned (reads), or ``None`` for
    zero-length residency hints (batched touch / pin).
    """

    def on_alloc(self, gfn: int) -> None:  # pragma: no cover - no-op base
        pass

    def on_free(self, gfn: int) -> None:  # pragma: no cover - no-op base
        pass

    def on_access(self, gfn: int, off: int, nbytes: int, is_write: bool,
                  data: Optional[bytes] = None) -> None:  # pragma: no cover
        pass

    def on_tick(self, rounds: int) -> None:  # pragma: no cover - no-op base
        pass


class MSView:
    """Typed window onto one MS: a dtype/shape bound to (gfn, offset).

    Guest memory is elastic -- the backing frame can be swapped out and
    faulted back between accesses -- so a view cannot hand out a live
    ndarray.  ``load()`` reads (faulting as needed) and ``store()``
    writes, both through the instrumented GuestSpace path.
    """

    __slots__ = ("space", "gfn", "dtype", "shape", "off", "nbytes")

    def __init__(self, space: "GuestSpace", gfn: int, dtype, shape,
                 off: int = 0) -> None:
        self.space = space
        self.gfn = gfn
        self.dtype = np.dtype(dtype)
        self.shape = tuple(shape)
        self.off = off
        self.nbytes = int(np.prod(self.shape)) * self.dtype.itemsize
        if off < 0 or off + self.nbytes > space.cfg.ms_bytes:
            raise ValueError(
                f"view [{off}, {off + self.nbytes}) exceeds MS "
                f"({space.cfg.ms_bytes} bytes)")

    def load(self) -> np.ndarray:
        raw = self.space.read(self.gfn, self.nbytes, off=self.off)
        return np.frombuffer(raw, dtype=self.dtype).reshape(self.shape)

    def store(self, arr: np.ndarray) -> None:
        if tuple(arr.shape) != self.shape:
            raise ValueError(f"array shape {arr.shape} != view {self.shape}")
        arr = np.ascontiguousarray(arr, dtype=self.dtype)
        self.space.write(self.gfn, arr.tobytes(), off=self.off)


class GuestSpace:
    """The guest-facing elastic-memory API over one :class:`TaijiSystem`.

    alloc/free, bounds-checked read/write, typed per-MS views, batched
    touch and pin residency hints -- with an observer protocol so capture
    and policy layers see every operation without per-caller glue.
    ``TaijiSystem.guest`` returns the canonical instance for a system.
    """

    def __init__(self, system, observers: Sequence[GuestObserver] = ()) -> None:
        self.system = system
        self.cfg = system.cfg
        self._observers: List[GuestObserver] = list(observers)
        # hot-path caches: read/write sit on benchmarked access paths, so
        # pay plain locals instead of attribute chains per call
        self._ms_bytes = system.cfg.ms_bytes
        self._guest_read = system.virt.guest_read
        self._guest_write = system.virt.guest_write

    # ------------------------------------------------------------ observers
    def attach(self, observer: GuestObserver) -> GuestObserver:
        self._observers.append(observer)
        return observer

    def detach(self, observer: GuestObserver) -> None:
        self._observers.remove(observer)

    # ----------------------------------------------------------- alloc/free
    def alloc_ms(self) -> int:
        """Allocate one elastic MS (may trigger reclaim); returns its gfn."""
        gfn = self.system.guest_alloc_ms()
        for obs in self._observers:
            obs.on_alloc(gfn)
        return gfn

    def free_ms(self, gfn: int) -> None:
        self.system.guest_free_ms(gfn)
        for obs in self._observers:
            obs.on_free(gfn)

    # ----------------------------------------------------------- addressing
    def addr_of(self, gfn: int, mp: int = 0, off: int = 0) -> int:
        """Packed guest-virtual address of (gfn, mp, off)."""
        return gfn * self.cfg.ms_bytes + mp * self.cfg.mp_bytes + off

    # ------------------------------------------------------------------ I/O
    def write(self, gfn: int, data: bytes, off: int = 0) -> None:
        """Write ``data`` at ``off`` within one MS (may span MPs)."""
        ms_bytes = self._ms_bytes
        nbytes = len(data)
        # off == ms_bytes would resolve (and fault!) the *next* MS even
        # for a zero-length access, so the offset itself must be in-MS
        if off < 0 or off >= ms_bytes or off + nbytes > ms_bytes:
            raise ValueError(
                f"write [{off}, {off + nbytes}) exceeds MS "
                f"({ms_bytes} bytes)")
        self._guest_write(gfn * ms_bytes + off, data)
        if self._observers:
            data = bytes(data)
            for obs in self._observers:
                obs.on_access(gfn, off, nbytes, True, data)

    def read(self, gfn: int, nbytes: Optional[int] = None,
             off: int = 0) -> bytes:
        """Read ``nbytes`` at ``off`` within one MS (default: to MS end),
        faulting swapped MPs back in."""
        ms_bytes = self._ms_bytes
        if nbytes is None:
            nbytes = ms_bytes - off
        if off < 0 or off >= ms_bytes or nbytes < 0 or off + nbytes > ms_bytes:
            raise ValueError(
                f"read [{off}, {off + nbytes}) exceeds MS "
                f"({ms_bytes} bytes)")
        data = self._guest_read(gfn * ms_bytes + off, nbytes)
        if self._observers:
            for obs in self._observers:
                obs.on_access(gfn, off, nbytes, False, data)
        return data

    # raw-GVA entry points (deprecation shims, packed-address callers)
    def write_gva(self, gva: int, data: bytes) -> None:
        gfn, off = divmod(gva, self._ms_bytes)
        self.write(gfn, data, off=off)

    def read_gva(self, gva: int, nbytes: int) -> bytes:
        gfn, off = divmod(gva, self._ms_bytes)
        return self.read(gfn, nbytes, off=off)

    # ---------------------------------------------------------- typed views
    def view(self, gfn: int, dtype, shape, off: int = 0) -> MSView:
        """Typed per-MS view: ``view(...).load()/store(arr)``."""
        return MSView(self, gfn, dtype, shape, off=off)

    # ------------------------------------------------- residency / pin hints
    def touch(self, gfns: Iterable[int], *, mark_accessed: bool = True) -> int:
        """Batched residency hint: swap each MS's cold MPs back in and mark
        it accessed.  Returns how many MSs actually needed a swap-in.
        Observers see one zero-length access per MS (a ``touch`` op in a
        captured trace), so replays reproduce the faulting pattern."""
        table = self.system.virt.table
        faulted = 0
        gfns = list(gfns)
        for gfn in gfns:
            req = self.system.reqs.lookup(gfn)
            if ((req is not None and req.record.swapped_out_count() > 0)
                    or int(table.pfn[gfn]) == NO_PFN):
                self.system.engine.swap_in_ms(gfn)
                faulted += 1
            if mark_accessed:
                table.mark_accessed(gfn)
        self._notify_touch(gfns)
        return faulted

    def hint_accessed(self, gfns: Iterable[int]) -> None:
        """Mark MSs hot for the LRU without faulting anything in (e.g. a
        router reporting which experts a batch activates)."""
        table = self.system.virt.table
        gfns = list(gfns)
        for gfn in gfns:
            table.mark_accessed(gfn)
        self._notify_touch(gfns)

    @contextmanager
    def pin(self, gfns: Iterable[int]):
        """Swap in + pin a working set for one in-flight step (the DMA
        no-retry contract); unpins on exit."""
        gfns = list(gfns)
        self._notify_touch(gfns)
        with self.system.dma.pin_for_step(gfns):
            yield

    def _notify_touch(self, gfns: Sequence[int]) -> None:
        if self._observers:
            for gfn in gfns:
                for obs in self._observers:
                    obs.on_access(gfn, 0, 0, False, None)

    def residency(self, gfns: Optional[Iterable[int]] = None) -> Dict[str, int]:
        """Resident/swapped MS counts over ``gfns`` (default: every
        guest-allocatable MS with a req record or a frame)."""
        table = self.system.virt.table
        if gfns is None:
            gfns = range(self.cfg.mpool_reserve_ms, self.cfg.n_virt_ms)
            resident = swapped = 0
            for gfn in gfns:
                if int(table.pfn[gfn]) != NO_PFN:
                    resident += 1
                elif self.system.reqs.lookup(gfn) is not None:
                    swapped += 1
        else:
            resident = swapped = 0
            for gfn in gfns:
                if int(table.pfn[gfn]) != NO_PFN:
                    resident += 1
                else:
                    swapped += 1
        return {"resident": resident, "swapped": swapped,
                "total": resident + swapped}

    # ------------------------------------------------------------ background
    def step_background(self, rounds: int = 1, *, reclaim: bool = True) -> int:
        """Run deterministic background rounds (LRU scans + reclaim) and
        tell observers -- captured traces carry the tick so replays age
        and reclaim at the same workload points.  Returns MPs reclaimed."""
        reclaimed = 0
        for _ in range(rounds):
            reclaimed += self.system.step_background(reclaim=reclaim)
        for obs in self._observers:
            obs.on_tick(rounds)
        return reclaimed
