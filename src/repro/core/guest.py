"""GuestSpace -- the one sanctioned guest-memory surface.

Taiji's promise is elasticity that is transparent to upper-layer
applications, but transparency only composes if every upper layer talks
to the same surface. Before this module, `ElasticKVCache`,
`ElasticExpertCache` and the fleet `NodeAgent` each drove
``TaijiSystem.read/write/ms_addr/guest_alloc_ms`` through their own glue,
so cross-cutting concerns (workload capture, verification, per-tenant
accounting, policy hooks) had no seam to hook.  ``GuestSpace`` is that
seam -- tracehm records at the access layer for the same reason: one
well-placed indirection layer owns everything that wants to see guest
accesses.

The API is gfn-relative (an MS handle plus an offset) rather than raw
guest-virtual addresses: callers never do address arithmetic, and every
access is bounds-checked against one MS.  Raw-GVA entry points
(``read_gva``/``write_gva``) exist for the ``TaijiSystem`` deprecation
shims and for code that already holds a packed address.

Observers (:class:`GuestObserver`) see every alloc/free/access/tick.
``repro.fleet.trace.TraceRecorder`` is the flagship observer: it turns a
live serving workload into a replayable fleet trace (see
``repro.fleet.capture``).  The observer list is almost always empty, so
the hot path pays one truthiness check.

Two access tiers (ISSUE 6):

* scalar ``read``/``write`` carry an inline fast path -- when the MS is
  resident and unsplit, the access resolves through direct block-table
  word reads and one physical-buffer slice, skipping the generic
  fault-capable walk entirely (the paper's O2: translated access must
  stay near direct-DRAM cost).
* batch primitives ``read_many``/``write_many``/``gather``/``scatter``
  amortize bounds checks, residency probes, access-bit marking and
  observer dispatch over a whole (gfn, off, nbytes) batch: one numpy
  pass over the triples, one fancy-indexed block-table probe, one
  ``on_access_batch`` observer callback.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.tracer import (ST_GUEST_ACCESS, TAG_GATHER, TAG_READ,
                          TAG_READ_MANY, TAG_SCATTER, TAG_WRITE,
                          TAG_WRITE_MANY)
from .virt import F_ACCESSED, F_SPLIT, NO_PFN

_perf_ns = time.perf_counter_ns

# one observer event: (gfn, off, nbytes, is_write, data)
AccessEvent = Tuple[int, int, int, bool, Optional[bytes]]


class GuestObserver:
    """Protocol for guest-memory event observers (no-op base class).

    ``on_access`` fires after the access succeeded; ``data`` carries the
    bytes written (writes), the bytes returned (reads), or ``None`` for
    zero-length residency hints (batched touch / pin).

    ``on_access_batch`` fires once per batch primitive call
    (``read_many``/``write_many``/``gather``/``scatter``/``touch``); the
    default implementation replays the batch through scalar
    ``on_access``, so observers that only implement the scalar hook --
    ``TraceRecorder`` included -- see event streams identical to the
    equivalent scalar access sequence (pinned by
    tests/test_hotpath_batch.py).
    """

    def on_alloc(self, gfn: int) -> None:  # pragma: no cover - no-op base
        pass

    def on_free(self, gfn: int) -> None:  # pragma: no cover - no-op base
        pass

    def on_access(self, gfn: int, off: int, nbytes: int, is_write: bool,
                  data: Optional[bytes] = None) -> None:  # pragma: no cover
        pass

    def on_access_batch(self, events: Sequence[AccessEvent]) -> None:
        for gfn, off, nbytes, is_write, data in events:
            self.on_access(gfn, off, nbytes, is_write, data)

    def on_tick(self, rounds: int) -> None:  # pragma: no cover - no-op base
        pass


class MSView:
    """Typed window onto one MS: a dtype/shape bound to (gfn, offset).

    Guest memory is elastic -- the backing frame can be swapped out and
    faulted back between accesses -- so a view cannot hand out a live
    ndarray.  ``load()`` reads (faulting as needed) and ``store()``
    writes, both through the instrumented GuestSpace path.
    """

    __slots__ = ("space", "gfn", "dtype", "shape", "off", "nbytes")

    def __init__(self, space: "GuestSpace", gfn: int, dtype, shape,
                 off: int = 0) -> None:
        self.space = space
        self.gfn = gfn
        self.dtype = np.dtype(dtype)
        self.shape = tuple(shape)
        self.off = off
        self.nbytes = int(np.prod(self.shape)) * self.dtype.itemsize
        if off < 0 or off + self.nbytes > space.cfg.ms_bytes:
            raise ValueError(
                f"view [{off}, {off + self.nbytes}) exceeds MS "
                f"({space.cfg.ms_bytes} bytes)")

    def load(self) -> np.ndarray:
        raw = self.space.read(self.gfn, self.nbytes, off=self.off)
        return np.frombuffer(raw, dtype=self.dtype).reshape(self.shape)

    def store(self, arr: np.ndarray) -> None:
        if tuple(arr.shape) != self.shape:
            raise ValueError(f"array shape {arr.shape} != view {self.shape}")
        arr = np.ascontiguousarray(arr, dtype=self.dtype)
        self.space.write(self.gfn, arr.tobytes(), off=self.off)


class GuestSpace:
    """The guest-facing elastic-memory API over one :class:`TaijiSystem`.

    alloc/free, bounds-checked read/write, typed per-MS views, batched
    touch and pin residency hints -- with an observer protocol so capture
    and policy layers see every operation without per-caller glue.
    ``TaijiSystem.guest`` returns the canonical instance for a system.
    """

    def __init__(self, system, observers: Sequence[GuestObserver] = ()) -> None:
        self.system = system
        self.cfg = system.cfg
        self._observers: List[GuestObserver] = list(observers)
        # hot-path caches: read/write sit on benchmarked access paths, so
        # pay plain locals instead of attribute chains per call
        self._ms_bytes = system.cfg.ms_bytes
        self._n_virt = system.cfg.n_virt_ms
        self._guest_read = system.virt.guest_read
        self._guest_write = system.virt.guest_write
        # fast-path state: direct views of the block table and physical
        # buffer.  A resident, unsplit MS resolves with two int32 word
        # reads and one buffer slice -- no lock, same race class as the
        # lock-free ``VirtLayer.translate`` (a concurrent swap-out between
        # probe and copy is the hardware EPT walk racing the fault
        # handler; the access-bit we set first makes the LRU skip the MS).
        self._pfn = system.virt.table.pfn
        self._flags = system.virt.table.flags
        self._buf = system.phys.buffer
        # stage-attributed tracing (repro.obs): one guest_access span per
        # primitive call, tagged with the access kind; None when disabled,
        # so the benchmarked scalar paths pay one truthiness check
        self._tr = system.metrics.tracer

    # ------------------------------------------------------------ observers
    def attach(self, observer: GuestObserver) -> GuestObserver:
        self._observers.append(observer)
        return observer

    def detach(self, observer: GuestObserver) -> None:
        self._observers.remove(observer)

    # ----------------------------------------------------------- alloc/free
    def alloc_ms(self) -> int:
        """Allocate one elastic MS (may trigger reclaim); returns its gfn."""
        gfn = self.system.guest_alloc_ms()
        for obs in self._observers:
            obs.on_alloc(gfn)
        return gfn

    def free_ms(self, gfn: int) -> None:
        self.system.guest_free_ms(gfn)
        for obs in self._observers:
            obs.on_free(gfn)

    # ----------------------------------------------------------- addressing
    def addr_of(self, gfn: int, mp: int = 0, off: int = 0) -> int:
        """Packed guest-virtual address of (gfn, mp, off)."""
        return gfn * self.cfg.ms_bytes + mp * self.cfg.mp_bytes + off

    # ------------------------------------------------------------------ I/O
    def write(self, gfn: int, data: bytes, off: int = 0) -> None:
        """Write ``data`` at ``off`` within one MS (may span MPs)."""
        ms_bytes = self._ms_bytes
        nbytes = len(data)
        # off == ms_bytes would resolve (and fault!) the *next* MS even
        # for a zero-length access, so the offset itself must be in-MS
        if off < 0 or off >= ms_bytes or off + nbytes > ms_bytes:
            raise ValueError(
                f"write [{off}, {off + nbytes}) exceeds MS "
                f"({ms_bytes} bytes)")
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        # fast path: resident, unsplit MS -> direct buffer store
        if 0 <= gfn < self._n_virt:
            pfn = self._pfn[gfn]
            if pfn != NO_PFN and not self._flags[gfn] & F_SPLIT:
                self._flags[gfn] |= F_ACCESSED
                base = int(pfn) * ms_bytes + off
                self._buf[base:base + nbytes] = np.frombuffer(data, np.uint8)
            else:
                self._guest_write(gfn * ms_bytes + off, data)
        else:
            self._guest_write(gfn * ms_bytes + off, data)
        if tr is not None:
            tr.push(ST_GUEST_ACCESS, t0, _perf_ns() - t0, TAG_WRITE)
        if self._observers:
            data = bytes(data)
            for obs in self._observers:
                obs.on_access(gfn, off, nbytes, True, data)

    def read(self, gfn: int, nbytes: Optional[int] = None,
             off: int = 0) -> bytes:
        """Read ``nbytes`` at ``off`` within one MS (default: to MS end),
        faulting swapped MPs back in."""
        ms_bytes = self._ms_bytes
        if nbytes is None:
            nbytes = ms_bytes - off
        if off < 0 or off >= ms_bytes or nbytes < 0 or off + nbytes > ms_bytes:
            raise ValueError(
                f"read [{off}, {off + nbytes}) exceeds MS "
                f"({ms_bytes} bytes)")
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        # fast path: resident, unsplit MS -> direct buffer slice
        if 0 <= gfn < self._n_virt:
            pfn = self._pfn[gfn]
            if pfn != NO_PFN and not self._flags[gfn] & F_SPLIT:
                self._flags[gfn] |= F_ACCESSED
                base = int(pfn) * ms_bytes + off
                data = self._buf[base:base + nbytes].tobytes()
            else:
                data = self._guest_read(gfn * ms_bytes + off, nbytes)
        else:
            data = self._guest_read(gfn * ms_bytes + off, nbytes)
        if tr is not None:
            tr.push(ST_GUEST_ACCESS, t0, _perf_ns() - t0, TAG_READ)
        if self._observers:
            for obs in self._observers:
                obs.on_access(gfn, off, nbytes, False, data)
        return data

    # raw-GVA entry points (deprecation shims, packed-address callers)
    def write_gva(self, gva: int, data: bytes) -> None:
        gfn, off = divmod(gva, self._ms_bytes)
        self.write(gfn, data, off=off)

    def read_gva(self, gva: int, nbytes: int) -> bytes:
        gfn, off = divmod(gva, self._ms_bytes)
        return self.read(gfn, nbytes, off=off)

    # ------------------------------------------------------ batch primitives
    def _batch_probe(self, g: np.ndarray) -> np.ndarray:
        """One fancy-indexed block-table probe for a gfn vector: returns
        the fast-row mask (in-range, resident, unsplit) and marks the
        fast rows accessed in a single vectorized pass."""
        inr = (g >= 0) & (g < self._n_virt)
        gc = np.where(inr, g, 0)
        fast = inr & (self._pfn[gc] != NO_PFN) & ((self._flags[gc] & F_SPLIT) == 0)
        if fast.any():
            # |= with fancy indexing is read-or-write; duplicate gfns are
            # fine because OR-ing the same bit is idempotent (same
            # lock-free idiom as BlockTable.mark_accessed)
            self._flags[g[fast]] |= F_ACCESSED
        return fast

    def _check_batch_bounds(self, o: np.ndarray, n: np.ndarray,
                            what: str) -> None:
        ms_bytes = self._ms_bytes
        bad = (o < 0) | (o >= ms_bytes) | (n < 0) | (o + n > ms_bytes)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"{what}[{i}]: [{int(o[i])}, {int(o[i]) + int(n[i])}) "
                f"exceeds MS ({ms_bytes} bytes)")

    def read_many(self, reqs: Sequence[Tuple[int, int, int]]) -> List[bytes]:
        """Batched read over (gfn, off, nbytes) triples.

        Byte-equivalent to ``[read(g, n, off=o) for g, o, n in reqs]``
        but amortized: one numpy bounds pass, one block-table residency
        probe, one access-bit pass, one observer dispatch.  Rows whose MS
        is swapped/split fall back to the faulting walk individually (the
        fault dominates those rows anyway).
        """
        if not len(reqs):
            return []
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        arr = np.asarray(reqs, dtype=np.int64).reshape(-1, 3)
        g, o, n = arr[:, 0], arr[:, 1], arr[:, 2]
        self._check_batch_bounds(o, n, "read_many")
        fast = self._batch_probe(g)
        ms_bytes = self._ms_bytes
        buf = self._buf
        base = self._pfn[np.where(fast, g, 0)].astype(np.int64) * ms_bytes + o
        # .tolist() once: per-row numpy scalar indexing costs ~100ns a
        # touch, which would hand back most of the amortization win
        fl, bl, nl = fast.tolist(), base.tolist(), n.tolist()
        out: List[bytes] = []
        append = out.append
        for i, b in enumerate(bl):
            if fl[i]:
                append(buf[b:b + nl[i]].tobytes())
            else:
                append(self._guest_read(int(g[i]) * ms_bytes + int(o[i]),
                                        nl[i]))
        if tr is not None:
            tr.push(ST_GUEST_ACCESS, t0, _perf_ns() - t0, TAG_READ_MANY)
        if self._observers:
            gl, ol = g.tolist(), o.tolist()
            events = [(gl[i], ol[i], nl[i], False, out[i])
                      for i in range(len(out))]
            self._dispatch_batch(events)
        return out

    def write_many(self, items: Sequence[Tuple[int, int, bytes]]) -> None:
        """Batched write over (gfn, off, data) triples; byte-equivalent to
        the scalar ``write`` loop with the same amortizations as
        :meth:`read_many`."""
        if not len(items):
            return
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        items = list(items)
        arr = np.asarray([(gfn, off, len(data)) for gfn, off, data in items],
                         dtype=np.int64)
        g, o, n = arr[:, 0], arr[:, 1], arr[:, 2]
        self._check_batch_bounds(o, n, "write_many")
        fast = self._batch_probe(g)
        ms_bytes = self._ms_bytes
        buf = self._buf
        base = self._pfn[np.where(fast, g, 0)].astype(np.int64) * ms_bytes + o
        fl, bl, nl = fast.tolist(), base.tolist(), n.tolist()
        for i, (_, _, data) in enumerate(items):
            if fl[i]:
                b = bl[i]
                buf[b:b + nl[i]] = np.frombuffer(data, np.uint8)
            else:
                self._guest_write(int(g[i]) * ms_bytes + int(o[i]), data)
        if tr is not None:
            tr.push(ST_GUEST_ACCESS, t0, _perf_ns() - t0, TAG_WRITE_MANY)
        if self._observers:
            gl, ol = g.tolist(), o.tolist()
            events = [(gl[i], ol[i], nl[i], True, bytes(data))
                      for i, (_, _, data) in enumerate(items)]
            self._dispatch_batch(events)

    def gather(self, gfns: Sequence[int], dtype=np.uint8,
               shape: Optional[Sequence[int]] = None,
               off: int = 0) -> np.ndarray:
        """Whole-MS typed batch read: stacked ``(len(gfns), *shape)``
        array, one typed window per MS (default: the full MS as uint8).
        Equivalent to ``np.stack([view(g, dtype, shape, off).load() for g
        in gfns])`` minus the per-view dispatch."""
        dtype = np.dtype(dtype)
        if shape is None:
            shape = ((self._ms_bytes - off) // dtype.itemsize,)
        shape = tuple(int(s) for s in shape)
        nbytes = int(np.prod(shape)) * dtype.itemsize
        if off < 0 or off >= self._ms_bytes or off + nbytes > self._ms_bytes:
            raise ValueError(
                f"gather [{off}, {off + nbytes}) exceeds MS "
                f"({self._ms_bytes} bytes)")
        g = np.asarray(list(gfns), dtype=np.int64)
        if g.size == 0:
            return np.empty((0,) + shape, dtype)
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        fast = self._batch_probe(g)
        ms_bytes = self._ms_bytes
        raw = np.empty((g.size, nbytes), np.uint8)
        base = self._pfn[np.where(fast, g, 0)].astype(np.int64) * ms_bytes + off
        fl, bl, gl = fast.tolist(), base.tolist(), g.tolist()
        for i in range(g.size):
            if fl[i]:
                b = bl[i]
                raw[i] = self._buf[b:b + nbytes]
            else:
                raw[i] = np.frombuffer(
                    self._guest_read(gl[i] * ms_bytes + off, nbytes),
                    np.uint8)
        if tr is not None:
            tr.push(ST_GUEST_ACCESS, t0, _perf_ns() - t0, TAG_GATHER)
        if self._observers:
            events = [(gl[i], off, nbytes, False, raw[i].tobytes())
                      for i in range(g.size)]
            self._dispatch_batch(events)
        return raw.view(dtype).reshape((g.size,) + shape)

    def scatter(self, gfns: Sequence[int], arr: np.ndarray,
                off: int = 0) -> None:
        """Whole-MS typed batch write: ``arr[i]`` is stored at ``off`` in
        ``gfns[i]``.  Equivalent to the ``view(...).store(arr[i])`` loop
        minus the per-view dispatch."""
        g = np.asarray(list(gfns), dtype=np.int64)
        arr = np.ascontiguousarray(arr)
        if len(arr) != g.size:
            raise ValueError(f"scatter: {g.size} gfns but {len(arr)} rows")
        if g.size == 0:
            return
        nbytes = arr[0].nbytes
        if off < 0 or off >= self._ms_bytes or off + nbytes > self._ms_bytes:
            raise ValueError(
                f"scatter [{off}, {off + nbytes}) exceeds MS "
                f"({self._ms_bytes} bytes)")
        rows = arr.reshape(g.size, -1).view(np.uint8).reshape(g.size, nbytes)
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        fast = self._batch_probe(g)
        ms_bytes = self._ms_bytes
        base = self._pfn[np.where(fast, g, 0)].astype(np.int64) * ms_bytes + off
        fl, bl, gl = fast.tolist(), base.tolist(), g.tolist()
        for i in range(g.size):
            if fl[i]:
                b = bl[i]
                self._buf[b:b + nbytes] = rows[i]
            else:
                self._guest_write(gl[i] * ms_bytes + off,
                                  rows[i].tobytes())
        if tr is not None:
            tr.push(ST_GUEST_ACCESS, t0, _perf_ns() - t0, TAG_SCATTER)
        if self._observers:
            events = [(gl[i], off, nbytes, True, rows[i].tobytes())
                      for i in range(g.size)]
            self._dispatch_batch(events)

    def _dispatch_batch(self, events: Sequence[AccessEvent]) -> None:
        for obs in self._observers:
            cb = getattr(obs, "on_access_batch", None)
            if cb is not None:
                cb(events)
            else:  # duck-typed observer without the batch hook
                for ev in events:
                    obs.on_access(*ev)

    # ---------------------------------------------------------- typed views
    def view(self, gfn: int, dtype, shape, off: int = 0) -> MSView:
        """Typed per-MS view: ``view(...).load()/store(arr)``."""
        return MSView(self, gfn, dtype, shape, off=off)

    # ------------------------------------------------- residency / pin hints
    def touch(self, gfns: Iterable[int], *, mark_accessed: bool = True) -> int:
        """Batched residency hint: swap each MS's cold MPs back in and mark
        it accessed.  Returns how many MSs actually needed a swap-in.
        Observers see one zero-length access per MS (a ``touch`` op in a
        captured trace), so replays reproduce the faulting pattern."""
        gfns = list(gfns)
        faulted = 0
        if gfns:
            g = np.asarray(gfns, dtype=np.int64)
            # vectorized residency pre-filter: only swapped (NO_PFN) or
            # split MSs can have swapped-out MPs (swap-out always splits
            # first), so resident+unsplit rows skip the req lookup
            cand = (self._pfn[g] == NO_PFN) | ((self._flags[g] & F_SPLIT) != 0)
            for gfn in (int(x) for x in g[cand]):
                req = self.system.reqs.lookup(gfn)
                if ((req is not None and req.record.swapped_out_count() > 0)
                        or int(self._pfn[gfn]) == NO_PFN):
                    self.system.engine.swap_in_ms(gfn)
                    faulted += 1
            if mark_accessed:
                self._flags[g] |= F_ACCESSED
        self._notify_touch(gfns)
        return faulted

    def hint_accessed(self, gfns: Iterable[int]) -> None:
        """Mark MSs hot for the LRU without faulting anything in (e.g. a
        router reporting which experts a batch activates)."""
        gfns = list(gfns)
        if gfns:
            self._flags[np.asarray(gfns, dtype=np.int64)] |= F_ACCESSED
        self._notify_touch(gfns)

    @contextmanager
    def pin(self, gfns: Iterable[int]):
        """Swap in + pin a working set for one in-flight step (the DMA
        no-retry contract); unpins on exit."""
        gfns = list(gfns)
        self._notify_touch(gfns)
        with self.system.dma.pin_for_step(gfns):
            yield

    def _notify_touch(self, gfns: Sequence[int]) -> None:
        if self._observers:
            self._dispatch_batch([(int(gfn), 0, 0, False, None)
                                  for gfn in gfns])

    def residency(self, gfns: Optional[Iterable[int]] = None) -> Dict[str, int]:
        """Resident/swapped MS counts over ``gfns`` (default: every
        guest-allocatable MS with a req record or a frame)."""
        table = self.system.virt.table
        if gfns is None:
            gfns = range(self.cfg.mpool_reserve_ms, self.cfg.n_virt_ms)
            resident = swapped = 0
            for gfn in gfns:
                if int(table.pfn[gfn]) != NO_PFN:
                    resident += 1
                elif self.system.reqs.lookup(gfn) is not None:
                    swapped += 1
        else:
            g = np.asarray(list(gfns), dtype=np.int64)
            resident = int(np.count_nonzero(table.pfn[g] != NO_PFN)) if g.size else 0
            swapped = int(g.size) - resident
        return {"resident": resident, "swapped": swapped,
                "total": resident + swapped}

    # ------------------------------------------------------------ background
    def step_background(self, rounds: int = 1, *, reclaim: bool = True) -> int:
        """Run deterministic background rounds (LRU scans + reclaim) and
        tell observers -- captured traces carry the tick so replays age
        and reclaim at the same workload points.  Returns MPs reclaimed."""
        reclaimed = 0
        for _ in range(rounds):
            reclaimed += self.system.step_background(reclaim=reclaim)
        for obs in self._observers:
            obs.on_tick(rounds)
        return reclaimed
