"""Elastic MoE expert-weight cache -- Taiji applied to sparse models.

MoE expert weights are the cleanest in-model instance of the paper's
observation: capacity provisioned for *all* experts while the router's
empirical distribution keeps a fraction of them hot. One MS holds one
expert's weight shard; router statistics feed the access bits; rarely
routed experts cool down and get compressed out; a scheduled batch whose
router activates a swapped expert faults it back in before dispatch (the
DMA contract again).

Inapplicable to dense architectures -- noted in DESIGN.md
§Arch-applicability; dense archs run without this feature.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Sequence

import numpy as np

from .config import TaijiConfig
from .system import TaijiSystem


def make_expert_taiji_config(expert_bytes: int, n_hot_experts: int,
                             n_experts: int, **overrides) -> TaijiConfig:
    """Size a Taiji config: one MS per expert, physical = hot working set."""
    mps = 16
    while expert_bytes // mps < 1024 and mps > 1:
        mps //= 2
    # align the MS so every MP is a multiple of 8 bytes
    align = 8 * mps
    expert_bytes = -(-expert_bytes // align) * align
    over = max(0.25, n_experts / max(1, n_hot_experts) - 1.0)
    from .elastic_kv import _mpool_reserve_ms
    reserve = _mpool_reserve_ms(expert_bytes, mps, n_hot_experts, over)
    base = dict(
        ms_bytes=expert_bytes,
        mps_per_ms=mps,
        n_phys_ms=n_hot_experts + reserve,
        mpool_reserve_ms=reserve,
        overcommit_ratio=over,
    )
    base.update(overrides)
    return TaijiConfig(**base)


class ElasticExpertCache:
    """Host-side elastic store for per-expert weights of one MoE layer."""

    def __init__(self, system: TaijiSystem, n_experts: int,
                 expert_shape: tuple, dtype=np.float32) -> None:
        self.system = system
        self.n_experts = n_experts
        self.expert_shape = expert_shape
        self.dtype = np.dtype(dtype)
        nbytes = int(np.prod(expert_shape)) * self.dtype.itemsize
        if nbytes > system.cfg.ms_bytes:
            raise ValueError(f"expert ({nbytes}B) exceeds MS ({system.cfg.ms_bytes}B)")
        self._lock = threading.Lock()
        self._gfn: Dict[int, int] = {}
        self.route_counts = np.zeros(n_experts, dtype=np.int64)

    # ------------------------------------------------------------- weights
    def put_expert(self, eid: int, weights: np.ndarray) -> None:
        if weights.shape != self.expert_shape:
            raise ValueError("bad expert shape")
        with self._lock:
            gfn = self._gfn.get(eid)
            if gfn is None:
                gfn = self.system.guest_alloc_ms()
                self._gfn[eid] = gfn
        self.system.write(self.system.ms_addr(gfn),
                          weights.astype(self.dtype).tobytes())

    def get_expert(self, eid: int) -> np.ndarray:
        with self._lock:
            gfn = self._gfn[eid]
        nbytes = int(np.prod(self.expert_shape)) * self.dtype.itemsize
        raw = self.system.read(self.system.ms_addr(gfn), nbytes)
        return np.frombuffer(raw, dtype=self.dtype).reshape(self.expert_shape)

    # ------------------------------------------------------------- routing
    def note_routing(self, expert_ids: Iterable[int]) -> None:
        """Report the router's choices: marks those experts accessed."""
        for eid in set(expert_ids):
            self.route_counts[eid] += 1
            with self._lock:
                gfn = self._gfn.get(eid)
            if gfn is not None:
                self.system.virt.table.mark_accessed(gfn)

    def prepare_dispatch(self, active_experts: Sequence[int]):
        """Swap in + pin the experts the scheduled batch activates."""
        with self._lock:
            gfns = [self._gfn[e] for e in active_experts if e in self._gfn]
        return self.system.dma.pin_for_step(gfns)

    # ------------------------------------------------------------ telemetry
    def residency(self) -> Dict[str, int]:
        from .virt import NO_PFN
        resident = swapped = 0
        with self._lock:
            gfns = list(self._gfn.values())
        for g in gfns:
            if int(self.system.virt.table.pfn[g]) != NO_PFN:
                resident += 1
            else:
                swapped += 1
        return {"resident_experts": resident, "swapped_experts": swapped}
