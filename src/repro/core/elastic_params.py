"""Elastic MoE expert-weight cache -- Taiji applied to sparse models.

MoE expert weights are the cleanest in-model instance of the paper's
observation: capacity provisioned for *all* experts while the router's
empirical distribution keeps a fraction of them hot. One MS holds one
expert's weight shard; router statistics feed the access bits; rarely
routed experts cool down and get compressed out; a scheduled batch whose
router activates a swapped expert faults it back in before dispatch (the
DMA contract again).

Every expert lives behind a typed :class:`~.guest.MSView` on the one
sanctioned :class:`~.guest.GuestSpace` surface, so weight reads/writes
are shape-checked and capture observers see expert churn as a replayable
workload.

Inapplicable to dense architectures -- noted in DESIGN.md
§Arch-applicability; dense archs run without this feature.
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence, Union

import numpy as np

from ..analysis.lock_order import named_lock
from .config import TaijiConfig
from .guest import GuestSpace, MSView
from .system import TaijiSystem


def make_expert_taiji_config(expert_bytes: int, n_hot_experts: int,
                             n_experts: int, **overrides) -> TaijiConfig:
    """Size a Taiji config: one MS per expert, physical = hot working set."""
    mps = 16
    while expert_bytes // mps < 1024 and mps > 1:
        mps //= 2
    # align the MS so every MP is a multiple of 8 bytes
    align = 8 * mps
    expert_bytes = -(-expert_bytes // align) * align
    over = max(0.25, n_experts / max(1, n_hot_experts) - 1.0)
    from .elastic_kv import _mpool_reserve_ms
    reserve = _mpool_reserve_ms(expert_bytes, mps, n_hot_experts, over)
    base = dict(
        ms_bytes=expert_bytes,
        mps_per_ms=mps,
        n_phys_ms=n_hot_experts + reserve,
        mpool_reserve_ms=reserve,
        overcommit_ratio=over,
    )
    base.update(overrides)
    return TaijiConfig(**base)


class ElasticExpertCache:
    """Host-side elastic store for per-expert weights of one MoE layer.

    Accepts either a :class:`GuestSpace` or a :class:`TaijiSystem` (its
    canonical ``.guest`` space is used).
    """

    def __init__(self, space: Union[GuestSpace, TaijiSystem], n_experts: int,
                 expert_shape: tuple, dtype=np.float32) -> None:
        self.space = space.guest if isinstance(space, TaijiSystem) else space
        self.system = self.space.system      # telemetry / legacy accessors
        self.n_experts = n_experts
        self.expert_shape = tuple(expert_shape)
        self.dtype = np.dtype(dtype)
        nbytes = int(np.prod(expert_shape)) * self.dtype.itemsize
        if nbytes > self.space.cfg.ms_bytes:
            raise ValueError(
                f"expert ({nbytes}B) exceeds MS ({self.space.cfg.ms_bytes}B)")
        self._lock = named_lock("app")
        self._view: Dict[int, MSView] = {}    # eid -> typed view of its MS
        self.route_counts = np.zeros(n_experts, dtype=np.int64)

    def _view_of(self, eid: int, create: bool = False) -> MSView:
        with self._lock:
            view = self._view.get(eid)
            if view is None:
                if not create:
                    raise KeyError(eid)
                gfn = self.space.alloc_ms()
                view = self.space.view(gfn, self.dtype, self.expert_shape)
                self._view[eid] = view
        return view

    # ------------------------------------------------------------- weights
    def put_expert(self, eid: int, weights: np.ndarray) -> None:
        if weights.shape != self.expert_shape:
            raise ValueError("bad expert shape")
        self._view_of(eid, create=True).store(weights)

    def get_expert(self, eid: int) -> np.ndarray:
        return self._view_of(eid).load()

    def get_experts(self, eids: Sequence[int]) -> np.ndarray:
        """Fetch several experts in one batched gather: a single
        residency probe + observer dispatch over the whole activation
        set, returning ``[len(eids), *expert_shape]`` (the MoE dispatch
        hot path -- per-expert ``load()`` paid the full stack each)."""
        gfns = [self._view_of(e).gfn for e in eids]
        return self.space.gather(gfns, self.dtype, self.expert_shape)

    # ------------------------------------------------------------- routing
    def note_routing(self, expert_ids: Iterable[int]) -> None:
        """Report the router's choices: marks those experts accessed."""
        gfns = []
        for eid in set(expert_ids):
            self.route_counts[eid] += 1
            with self._lock:
                view = self._view.get(eid)
            if view is not None:
                gfns.append(view.gfn)
        self.space.hint_accessed(gfns)

    def prepare_dispatch(self, active_experts: Sequence[int]):
        """Swap in + pin the experts the scheduled batch activates."""
        with self._lock:
            gfns = [self._view[e].gfn for e in active_experts
                    if e in self._view]
        return self.space.pin(gfns)

    # ------------------------------------------------------------ telemetry
    def residency(self) -> Dict[str, int]:
        with self._lock:
            gfns = [v.gfn for v in self._view.values()]
        res = self.space.residency(gfns)
        return {"resident_experts": res["resident"],
                "swapped_experts": res["swapped"]}
