"""Memory Section (MS) / Memory Page (MP) records and state machines.

Paper §4.2.2: "Taiji manages swapping at memory section (MS, huge page)
granularity but operates at memory page (MP, small page) granularity. A
huge page is fully swapped only when all its small pages are swapped in or
out."

The persistent part of each record lives in the mpool arena (stable ABI,
reserved fields) so a hot upgrade inherits it byte-for-byte (§4.4):

    header  : int64[8]   = [abi, gfn, pfn, present_count, ms_state,
                            reserved x3]
    bm_out  : uint64[nw] = already-swapped-out bitmap   (Fig 8 (3))
    bm_in   : uint64[nw] = currently-swapping-in bitmap (Fig 8 (3.3))
    kinds   : uint8[mps] = backend kind per MP (0 none / 1 zero / 2 comp /
                           3 free / 4 disk)
    crc     : uint32[mps]= per-MP CRC32 (paper §7.1 / §5.3.3 "15 MB for CRC")

MS states (exactly-once transitions, Fig 8 (4.1)/(7)):

    RESIDENT --first MP out (split)--> PARTIAL --last MP out--> SWAPPED
    SWAPPED --first MP in (alloc)--> PARTIAL --last MP in (merge)--> RESIDENT
"""
from __future__ import annotations

import numpy as np

from .config import ABI_VERSION, TaijiConfig
from .errors import ABIMismatchError, InvalidStateError
from .mpool import Handle, Mpool

# MS states
MS_RESIDENT = 0
MS_PARTIAL = 1
MS_SWAPPED = 2

# backend kinds per MP
K_NONE = 0
K_ZERO = 1
K_COMPRESSED = 2
K_FREE = 3
K_DISK = 4

_H_ABI, _H_GFN, _H_PFN, _H_PRESENT, _H_STATE = 0, 1, 2, 3, 4
_HEADER_WORDS = 8

# public header-word indices: the O(1) fault-descriptor table reads the
# header straight out of the arena (int64 loads) without an MSRecord
H_ABI, H_GFN, H_PFN, H_PRESENT, H_STATE = (
    _H_ABI, _H_GFN, _H_PFN, _H_PRESENT, _H_STATE)

_BIT_COLUMN = np.arange(64, dtype=np.uint64)
_ONE = np.uint64(1)


# ------------------------------------------------------- bitmap helpers --
# Vectorized operations over uint64 word arrays (the persistent bm_in /
# bm_out arenas). The batched swap path derives MP index vectors from
# these instead of testing one bit per Python call.

def popcount_words(bm: np.ndarray) -> int:
    """Total set bits across all words."""
    return int(np.count_nonzero((bm[:, None] >> _BIT_COLUMN) & _ONE))


def bitmap_indices(bm: np.ndarray, n: int) -> np.ndarray:
    """Indices (int64, ascending) of set bits in ``[0, n)``.

    Expands word-by-word via shifts rather than byte views so the result
    is endianness-independent (the arena is shared across hot upgrades).
    """
    bits = ((bm[:, None] >> _BIT_COLUMN) & _ONE).reshape(-1)
    return np.flatnonzero(bits[:n])


def iter_set(bm: np.ndarray, n: int):
    """Yield set-bit indices in ``[0, n)`` (scalar convenience walker)."""
    for i in bitmap_indices(bm, n):
        yield int(i)


def set_bits(bm: np.ndarray, idxs: np.ndarray, value: bool) -> None:
    """Set/clear a vector of bit indices in one scatter."""
    if len(idxs) == 0:
        return
    idxs = np.asarray(idxs, dtype=np.int64)
    words = idxs >> 6
    masks = _ONE << (idxs & 63).astype(np.uint64)
    if value:
        np.bitwise_or.at(bm, words, masks)
    else:
        np.bitwise_and.at(bm, words, ~masks)


def record_nbytes(cfg: TaijiConfig) -> int:
    nw = (cfg.mps_per_ms + 63) // 64
    return 8 * _HEADER_WORDS + 8 * nw * 2 + cfg.mps_per_ms + 4 * cfg.mps_per_ms


def record_field_offsets(cfg: TaijiConfig) -> dict:
    """Byte offsets of each persistent field inside one MS record.

    The single source of truth for the record layout, shared by
    :class:`MSRecord` (which builds views) and the fault-descriptor table
    (which indexes the arena directly). Changing the layout is an ABI
    break (bump ``ABI_VERSION``).
    """
    nw = (cfg.mps_per_ms + 63) // 64
    header = 0
    bm_out = 8 * _HEADER_WORDS
    bm_in = bm_out + 8 * nw
    kinds = bm_in + 8 * nw
    crc = kinds + cfg.mps_per_ms
    return {"header": header, "bm_out": bm_out, "bm_in": bm_in,
            "kinds": kinds, "crc": crc}


class MSRecord:
    """Typed views over one persistent MS record in the mpool arena."""

    __slots__ = ("cfg", "handle", "header", "bm_out", "bm_in", "kinds", "crc")

    def __init__(self, cfg: TaijiConfig, handle: Handle, *, attach: bool = False) -> None:
        self.cfg = cfg
        self.handle = handle
        nw = (cfg.mps_per_ms + 63) // 64
        raw = handle.view(np.uint8)
        o = 0
        self.header = raw[o : o + 8 * _HEADER_WORDS].view(np.int64); o += 8 * _HEADER_WORDS
        self.bm_out = raw[o : o + 8 * nw].view(np.uint64); o += 8 * nw
        self.bm_in = raw[o : o + 8 * nw].view(np.uint64); o += 8 * nw
        self.kinds = raw[o : o + cfg.mps_per_ms]; o += cfg.mps_per_ms
        self.crc = raw[o : o + 4 * cfg.mps_per_ms].view(np.uint32)
        if attach:
            if int(self.header[_H_ABI]) != ABI_VERSION:
                raise ABIMismatchError(
                    f"MS record ABI {int(self.header[_H_ABI])} != {ABI_VERSION}")
        else:
            self.header[_H_ABI] = ABI_VERSION

    @classmethod
    def allocate(cls, cfg: TaijiConfig, mpool: Mpool, gfn: int, pfn: int) -> "MSRecord":
        rec = cls(cfg, mpool.slab_alloc(record_nbytes(cfg)))
        rec.header[_H_GFN] = gfn
        rec.header[_H_PFN] = pfn
        rec.header[_H_PRESENT] = cfg.mps_per_ms
        rec.header[_H_STATE] = MS_RESIDENT
        return rec

    # ------------------------------------------------------------ properties
    @property
    def gfn(self) -> int:
        return int(self.header[_H_GFN])

    @property
    def pfn(self) -> int:
        return int(self.header[_H_PFN])

    @pfn.setter
    def pfn(self, v: int) -> None:
        self.header[_H_PFN] = v

    @property
    def present_count(self) -> int:
        return int(self.header[_H_PRESENT])

    @present_count.setter
    def present_count(self, v: int) -> None:
        self.header[_H_PRESENT] = v

    @property
    def state(self) -> int:
        return int(self.header[_H_STATE])

    @state.setter
    def state(self, v: int) -> None:
        self.header[_H_STATE] = v

    # ---------------------------------------------------------------- bitmaps
    @staticmethod
    def _bit(bm: np.ndarray, i: int) -> bool:
        return bool((int(bm[i >> 6]) >> (i & 63)) & 1)

    @staticmethod
    def _set_bit(bm: np.ndarray, i: int, v: bool) -> None:
        w = int(bm[i >> 6])
        if v:
            w |= 1 << (i & 63)
        else:
            w &= ~(1 << (i & 63))
        bm[i >> 6] = np.uint64(w & 0xFFFFFFFFFFFFFFFF)

    def is_swapped_out(self, mp: int) -> bool:
        return self._bit(self.bm_out, mp)

    def set_swapped_out(self, mp: int, v: bool) -> None:
        self._set_bit(self.bm_out, mp, v)

    def is_swapping_in(self, mp: int) -> bool:
        return self._bit(self.bm_in, mp)

    def set_swapping_in(self, mp: int, v: bool) -> None:
        self._set_bit(self.bm_in, mp, v)

    def swapped_out_count(self) -> int:
        return popcount_words(self.bm_out)

    # ------------------------------------------------- batched bitmap views
    def resident_indices(self) -> np.ndarray:
        """MPs neither swapped out nor mid-IO: the swap-out batch input."""
        return bitmap_indices(~(self.bm_out | self.bm_in),
                              self.cfg.mps_per_ms)

    def swapped_out_indices(self) -> np.ndarray:
        """MPs swapped out and not mid-IO: the swap-in batch input."""
        inert = self.bm_out & ~self.bm_in
        return bitmap_indices(inert, self.cfg.mps_per_ms)

    def set_swapped_out_batch(self, idxs: np.ndarray, v: bool) -> None:
        set_bits(self.bm_out, idxs, v)

    def set_swapping_in_batch(self, idxs: np.ndarray, v: bool) -> None:
        set_bits(self.bm_in, idxs, v)

    # -------------------------------------------------------- state machine
    def on_first_swap_out(self) -> None:
        if self.state != MS_RESIDENT:
            raise InvalidStateError(f"split from state {self.state}")
        self.state = MS_PARTIAL

    def on_last_swap_out(self) -> None:
        if self.state != MS_PARTIAL or self.present_count != 0:
            raise InvalidStateError("reclaim before all MPs swapped out")
        self.state = MS_SWAPPED
        self.pfn = -1

    def on_first_swap_in(self, new_pfn: int) -> None:
        if self.state != MS_SWAPPED:
            raise InvalidStateError(f"alloc from state {self.state}")
        self.state = MS_PARTIAL
        self.pfn = new_pfn

    def on_last_swap_in(self) -> None:
        if self.state != MS_PARTIAL or self.present_count != self.cfg.mps_per_ms:
            raise InvalidStateError("merge before all MPs swapped in")
        self.state = MS_RESIDENT
