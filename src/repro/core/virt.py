"""Hybrid virtualization layer (paper §4.1).

Address-space model, kept 1:1 with the paper's:

  * GVA -> GPA via the guest kernel page table (``init_mm``). The Taiji
    module lives inside the guest kernel, so GVA == HVA; for the managed
    region the guest mapping is the identity (kernel linear map), which we
    model with :meth:`AddressSpace.gva_to_gpa`.
  * GPA -> HPA via the **block table** (the EPT analogue), which maps a
    virtual memory section (keyed by GFN) to a physical slot (PFN) at huge
    granularity, or -- after the exactly-once *split* at first MP swap-out --
    at per-MP granularity within the slot.
  * Taiji's own accesses run in "root mode" and bypass the block table
    (single-layer translation, §4.1.1 Fourth), which is only correct for
    GPA == HPA memory: the pinned mpool arena. :meth:`root_access` asserts
    that contract.

Fault model: a guest access to a swapped MP raises :class:`EPTFault`
(= EPT violation VM exit). The swap engine's ``Fault_in`` task resolves it.
On a TPU there is no synchronous fault from inside a compiled step, so the
framework integration (elastic_kv/elastic_params) discovers misses at
step-assembly time and drives the *same* fault path proactively -- see
DESIGN.md §2.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Tuple

import numpy as np

from ..analysis.lock_order import named_lock
from .config import TaijiConfig
from .errors import InvalidStateError, OutOfMemoryError, PinnedError
from .mpool import Handle, Mpool

NO_PFN = -1


class _Magazine(list):
    """Per-thread slot cache: a plain list plus the owning thread's home
    shard index, resolved once at magazine creation so the refill path
    skips a ``get_ident() %% n`` per refill (ISSUE 9)."""

    __slots__ = ("home",)

# flags bits (block-table per-GFN flags)
F_SPLIT = 1 << 0      # MS mapping split to MP granularity
F_PINNED = 1 << 1     # never swap (mpool, registered DMA ranges)
F_ACCESSED = 1 << 2   # accessed since last LRU scan (EPT A-bit analogue)


class EPTFault(Exception):
    """EPT violation: guest touched a non-resident MP."""

    def __init__(self, gfn: int, mp: int) -> None:
        super().__init__(f"EPT fault gfn={gfn} mp={mp}")
        self.gfn = gfn
        self.mp = mp


class PhysicalMemory:
    """The device's physical memory: ``n_phys_ms`` sections of ``ms_bytes``.

    Slot allocation (ISSUE 8): the free-slot list is sharded into
    ``hot_path.slot_shards`` per-shard freelists (a slot's home shard is
    ``pfn % n_shards``) fronted by per-thread *magazines*. A faulting
    thread refills its magazine with up to ``magazine_size`` slots under
    ONE shard lock, then serves allocations from the magazine lock-free
    (``list.pop`` is atomic under the GIL, so each cached slot is handed
    out exactly once even while :meth:`drain_magazines` or an exhausted
    peer steals from the same magazine). Frees return to the slot's home
    shard under that shard's lock only.

    Accounting: ``free_count`` is the sum of shard and magazine lengths.
    Magazine-cached slots have not been handed to any caller, so they
    count as free; the sum is exact at quiescence (tests, snapshots,
    watermark publishes) and skews by at most one in-flight refill batch
    for a few bytecodes mid-refill -- in the conservative (undercount)
    direction.

    ``magazine_size <= 0`` collapses to the legacy single-list path
    (one global lock, identical pop order to the pre-ISSUE-8 code): the
    A/B reference used by ``HotPathConfig.legacy_scalar``.
    """

    def __init__(self, cfg: TaijiConfig) -> None:
        cfg.validate()
        self.cfg = cfg
        self.buffer = np.zeros(cfg.n_phys_ms * cfg.ms_bytes, dtype=np.uint8)
        # slots below mpool_reserve_ms are the pinned metadata arena
        slots: List[int] = list(
            range(cfg.n_phys_ms - 1, cfg.mpool_reserve_ms - 1, -1))
        self.n_managed = cfg.n_phys_ms - cfg.mpool_reserve_ms

        hp = getattr(cfg.swap, "hot_path", None)
        self._mag_size = int(getattr(hp, "magazine_size", 0) or 0)
        n_shards = int(getattr(hp, "slot_shards", 1) or 1)
        if self._mag_size <= 0:
            n_shards = 1  # legacy single-list path
        self._n_shards = max(1, min(n_shards, max(1, len(slots))))
        self._shard_locks = [named_lock("slot") for _ in range(self._n_shards)]
        if self._n_shards == 1:
            self._shards: List[List[int]] = [slots]
        else:
            self._shards = [[] for _ in range(self._n_shards)]
            for pfn in slots:
                self._shards[pfn % self._n_shards].append(pfn)
        # legacy aliases: single-list mode pops/appends through these
        self._lock = self._shard_locks[0]
        self._free_slots = self._shards[0]
        # pre-zipped (lock, shard) pairs: the free path indexes once
        self._homes = list(zip(self._shard_locks, self._shards))
        # per-thread magazines; the registry lets drain/steal walk every
        # magazine regardless of owning thread
        self._tls = threading.local()
        self._magazines: List[List[int]] = []
        self._mag_registry_lock = named_lock("slot")
        self.magazine_refills = 0  # exact: bumped under a shard lock
        if self._mag_size > 0:
            # rebind the allocation entry point per-instance: the hot
            # path then starts at the thread-local load instead of
            # re-testing the mode flag on every allocation (ISSUE 9)
            self.try_alloc_slot = self._try_alloc_magazine  # type: ignore[method-assign]

    # ------------------------------------------------------------ allocation
    def _magazine(self) -> _Magazine:
        mag = getattr(self._tls, "mag", None)
        if mag is None:
            mag = self._tls.mag = _Magazine()
            mag.home = threading.get_ident() % self._n_shards
            with self._mag_registry_lock:
                self._magazines.append(mag)
        return mag

    def _refill_and_pop(self, mag: List[int]) -> Optional[int]:
        """Refill ``mag`` from a shard under one lock; return one slot.

        Exception-free (ISSUE 9): shards are peeked lock-free before
        taking their lock -- a racy non-empty peek is re-checked under
        the lock, a racy empty peek at worst defers to the next shard
        (the steal pass below still finds every cached slot), so the
        near-exhaustion tail no longer pays one lock acquire per empty
        shard per allocation.
        """
        take = self._mag_size + 1
        home = getattr(mag, "home", 0)
        shards = self._shards
        locks = self._shard_locks
        # common case first, no loop machinery: the home shard has slots
        shard = shards[home]
        if shard:
            with locks[home]:
                if shard:
                    batch = shard[-take:]
                    del shard[-take:]
                    self.magazine_refills += 1
                    slot = batch.pop()
                    if batch:
                        mag.extend(batch)
                    return slot
        n = self._n_shards
        for i in range(1, n):
            j = home + i
            if j >= n:
                j -= n
            shard = shards[j]
            if not shard:  # lock-free peek: skip drained shards
                continue
            with locks[j]:
                if shard:
                    batch = shard[-take:]
                    del shard[-take:]
                    self.magazine_refills += 1
                    slot = batch.pop()
                    if batch:
                        mag.extend(batch)
                    return slot
        # every shard empty: steal from other threads' magazines so
        # cached-but-unused slots never masquerade as exhaustion
        # (exactly-once still holds -- pop is atomic, a slot goes to the
        # stealing thread or the owner, never both). The sentinel check
        # keeps the common all-empty walk free of raised exceptions; the
        # pop can still lose a check-to-pop race, hence the guard.
        for other in self._magazines:
            if other:
                try:
                    return other.pop()
                except IndexError:
                    continue
        return None

    def alloc_slot(self) -> int:
        slot = self.try_alloc_slot()
        if slot is None:
            raise OutOfMemoryError("no free physical MS")
        return slot

    def try_alloc_slot(self) -> Optional[int]:
        # legacy single-list path; magazine instances rebind this name
        # to _try_alloc_magazine at construction
        with self._lock:
            return self._free_slots.pop() if self._free_slots else None

    def _try_alloc_magazine(self) -> Optional[int]:
        # common case is one thread-local load + one atomic pop. The
        # empty-magazine check is a sentinel test, NOT a raised
        # IndexError (ISSUE 9): raising costs ~0.2us under CPython 3.10
        # and fired on every refill entry, which is what sank the
        # single-thread number to 0.56x of the legacy freelist.
        try:
            mag = self._tls.mag
        except AttributeError:  # first alloc on this thread only
            mag = self._magazine()
        if mag:
            try:
                return mag.pop()
            except IndexError:  # lost the check-to-pop race to a
                pass            # concurrent drain/steal -- refill
        return self._refill_and_pop(mag)

    def free_slot(self, pfn: int) -> None:
        lock, shard = self._homes[pfn % self._n_shards]
        with lock:
            shard.append(pfn)

    def drain_magazines(self) -> int:
        """Return every magazine-cached slot to its home shard.

        The drain hook reclaim/teardown uses so the shard lists hold the
        complete free set (``free_count`` is exact either way -- this
        just moves slots out of thread caches). Safe concurrently with
        allocation: each pop is atomic, so a slot is drained or handed
        out, never both. Returns the number of slots drained.
        """
        if self._mag_size <= 0:
            return 0
        drained = 0
        for mag in self._magazines:
            while True:
                try:
                    pfn = mag.pop()
                except IndexError:
                    break
                self.free_slot(pfn)
                drained += 1
        return drained

    @property
    def free_count(self) -> int:
        n = sum(len(s) for s in self._shards)
        if self._mag_size > 0:
            n += sum(len(m) for m in self._magazines)
        return n

    def alloc_stats(self) -> dict:
        """Allocator observability: shard/magazine geometry and traffic."""
        return {
            "slot_shards": self._n_shards,
            "magazine_size": self._mag_size,
            "magazine_cached": (sum(len(m) for m in self._magazines)
                                if self._mag_size > 0 else 0),
            "magazine_refills": self.magazine_refills,
        }

    # ----------------------------------------------------------------- views
    def ms_view(self, pfn: int) -> np.ndarray:
        o = pfn * self.cfg.ms_bytes
        return self.buffer[o : o + self.cfg.ms_bytes]

    def mp_view(self, pfn: int, mp: int) -> np.ndarray:
        o = pfn * self.cfg.ms_bytes + mp * self.cfg.mp_bytes
        return self.buffer[o : o + self.cfg.mp_bytes]

    def mpool_arena(self) -> np.ndarray:
        return self.buffer[: self.cfg.mpool_reserve_ms * self.cfg.ms_bytes]


class BlockTable:
    """The EPT analogue: GFN -> (PFN, flags, per-MP presence).

    Backed by mpool **full pages** (the paper: "68.53% is for full pages
    (EPT and IOMMU page tables)"). Per-MP presence bitmaps for split
    mappings live in the owning req's slab allocation; the table holds the
    huge-granularity word per GFN.
    """

    def __init__(self, cfg: TaijiConfig, mpool: Mpool) -> None:
        self.cfg = cfg
        n = cfg.n_virt_ms
        self._pfn_pages: List[Handle] = []
        self._flag_pages: List[Handle] = []
        per_page = mpool.page_bytes // 4
        need = (n + per_page - 1) // per_page
        pfn_views, flag_views = [], []
        for _ in range(need):
            hp = mpool.alloc_page()
            hf = mpool.alloc_page()
            self._pfn_pages.append(hp)
            self._flag_pages.append(hf)
            pfn_views.append(hp.view(np.int32))
            flag_views.append(hf.view(np.int32))
        self.pfn = np.concatenate(pfn_views)[:n] if len(pfn_views) > 1 else pfn_views[0][:n]
        self.flags = (np.concatenate(flag_views)[:n]
                      if len(flag_views) > 1 else flag_views[0][:n])
        self.pfn[:] = NO_PFN
        self.flags[:] = 0
        self._lock = named_lock("blocktable")

    # NOTE: single-word reads/writes of int32 numpy cells are effectively
    # atomic under the GIL; multi-field transitions take the lock.
    def map_huge(self, gfn: int, pfn: int) -> None:
        with self._lock:
            self.pfn[gfn] = pfn
            self.flags[gfn] &= ~F_SPLIT

    def unmap(self, gfn: int) -> None:
        with self._lock:
            if self.flags[gfn] & F_PINNED:
                raise PinnedError(f"gfn {gfn} is pinned")
            self.pfn[gfn] = NO_PFN
            self.flags[gfn] &= ~F_SPLIT

    def split(self, gfn: int) -> None:
        """Exactly-once split at first MP swap-out (paper Fig 8 (4.1))."""
        with self._lock:
            if self.flags[gfn] & F_SPLIT:
                raise InvalidStateError(f"gfn {gfn} already split")
            self.flags[gfn] |= F_SPLIT

    def merge(self, gfn: int, pfn: int) -> None:
        """Exactly-once merge after last MP swap-in (paper Fig 8 (7))."""
        with self._lock:
            if not self.flags[gfn] & F_SPLIT:
                raise InvalidStateError(f"gfn {gfn} not split")
            self.pfn[gfn] = pfn
            self.flags[gfn] &= ~F_SPLIT

    def map_split(self, gfn: int, pfn: int) -> None:
        """Install a new physical MS for a split mapping (first MP swap-in)."""
        with self._lock:
            self.pfn[gfn] = pfn
            self.flags[gfn] |= F_SPLIT

    def set_pinned(self, gfn: int, pinned: bool) -> None:
        with self._lock:
            if pinned:
                self.flags[gfn] |= F_PINNED
            else:
                self.flags[gfn] &= ~F_PINNED

    def is_pinned(self, gfn: int) -> bool:
        return bool(self.flags[gfn] & F_PINNED)

    def is_split(self, gfn: int) -> bool:
        return bool(self.flags[gfn] & F_SPLIT)

    def mark_accessed(self, gfn: int) -> None:
        self.flags[gfn] |= F_ACCESSED

    def test_and_clear_accessed(self, gfn: int) -> bool:
        with self._lock:
            a = bool(self.flags[gfn] & F_ACCESSED)
            if a:
                self.flags[gfn] &= ~F_ACCESSED
            return a


class AddressSpace:
    """GVA->GPA (guest init_mm, identity over the managed region)."""

    def __init__(self, cfg: TaijiConfig) -> None:
        self.cfg = cfg
        self.limit = cfg.n_virt_ms * cfg.ms_bytes

    def gva_to_gpa(self, gva: int) -> int:
        if not 0 <= gva < self.limit:
            raise ValueError(f"GVA {gva:#x} outside guest address space")
        return gva  # kernel linear map: GVA == HVA, identity to GPA

    def gpa_to_gfn_mp(self, gpa: int) -> Tuple[int, int, int]:
        gfn, off = divmod(gpa, self.cfg.ms_bytes)
        mp, inner = divmod(off, self.cfg.mp_bytes)
        return gfn, mp, inner


class VirtualizationLayer:
    """Ties PhysicalMemory + Mpool + BlockTable + AddressSpace together.

    Created by the hot-switch (hotswitch.py). Guest accesses go through
    :meth:`guest_read` / :meth:`guest_write`; the manager's own metadata
    accesses use :meth:`root_access`.
    """

    def __init__(self, cfg: TaijiConfig, phys: PhysicalMemory, mpool: Mpool) -> None:
        self.cfg = cfg
        self.phys = phys
        self.mpool = mpool
        self.aspace = AddressSpace(cfg)
        self.table = BlockTable(cfg, mpool)
        # fault handler is installed by the swap engine; None -> faults raise
        self.fault_handler = None
        # per-MP presence probe, also installed by the engine (reads the
        # O(1) fault-descriptor table); a plain attribute so the hot
        # translate path pays one load instead of a getattr with default
        self.mp_present_probe = None

        # pin + identity-map the mpool arena (GPA == HPA contract)
        for gfn in range(cfg.mpool_reserve_ms):
            self.table.map_huge(gfn, gfn)
            self.table.set_pinned(gfn, True)

    # ---------------------------------------------------------- translation
    def translate(self, gpa: int) -> Tuple[int, int, int, int]:
        """GPA -> (gfn, mp, inner, pfn); raises EPTFault if non-resident.

        Lock-free: single-word numpy reads are atomic under the GIL; the
        worst race (stale split flag) resolves through the fault path,
        mirroring the hardware EPT walk racing the fault handler.
        """
        gfn, mp, inner = self.aspace.gpa_to_gfn_mp(gpa)
        pfn = int(self.table.pfn[gfn])
        if pfn == NO_PFN:
            raise EPTFault(gfn, mp)
        if int(self.table.flags[gfn]) & F_SPLIT:
            # per-MP presence is tracked by the req; the engine installs a
            # presence probe so translation can consult it.
            probe = self.mp_present_probe
            if probe is not None and not probe(gfn, mp):
                raise EPTFault(gfn, mp)
        return gfn, mp, inner, pfn

    # -------------------------------------------------------- guest accesses
    def _resolve(self, gva: int) -> Tuple[int, int, int, int]:
        gpa = self.aspace.gva_to_gpa(gva)
        while True:
            try:
                out = self.translate(gpa)
                break
            except EPTFault as f:
                if self.fault_handler is None:
                    raise
                self.fault_handler(f.gfn, f.mp)
        gfn = out[0]
        self.table.mark_accessed(gfn)
        return out

    def guest_read(self, gva: int, nbytes: int) -> bytes:
        gfn, mp, inner, pfn = self._resolve(gva)
        off = mp * self.cfg.mp_bytes + inner
        if off + nbytes > self.cfg.ms_bytes:
            raise ValueError("guest access crosses an MS boundary")
        # may cross MP boundaries within the MS: fault remaining MPs too
        end_mp = (off + nbytes - 1) // self.cfg.mp_bytes
        for m in range(mp + 1, end_mp + 1):
            self._resolve(gva - inner - mp * self.cfg.mp_bytes + m * self.cfg.mp_bytes)
        view = self.phys.ms_view(pfn)
        return bytes(view[off : off + nbytes])

    def guest_write(self, gva: int, data: bytes) -> None:
        gfn, mp, inner, pfn = self._resolve(gva)
        off = mp * self.cfg.mp_bytes + inner
        if off + len(data) > self.cfg.ms_bytes:
            raise ValueError("guest access crosses an MS boundary")
        end_mp = (off + len(data) - 1) // self.cfg.mp_bytes
        for m in range(mp + 1, end_mp + 1):
            self._resolve(gva - inner - mp * self.cfg.mp_bytes + m * self.cfg.mp_bytes)
        view = self.phys.ms_view(pfn)
        view[off : off + len(data)] = np.frombuffer(data, dtype=np.uint8)

    # ----------------------------------------------------------- root access
    def root_access(self, gpa: int) -> np.ndarray:
        """Root-mode (single-layer) access: only legal for GPA == HPA memory."""
        gfn = gpa // self.cfg.ms_bytes
        if not self.table.is_pinned(gfn) or int(self.table.pfn[gfn]) != gfn:
            raise InvalidStateError(
                f"root access to non-identity gfn {gfn}: GPA==HPA violated")
        return self.phys.ms_view(gfn)

    # ------------------------------------------------------------- utilities
    @property
    def free_ms(self) -> int:
        return self.phys.free_count

    def resident_gfns(self) -> List[int]:
        return [g for g in range(self.cfg.n_virt_ms)
                if int(self.table.pfn[g]) != NO_PFN and not self.table.is_pinned(g)]
