"""Hot-switch: convert a running plain system into the elastic architecture
(paper §4.1.2, Fig 6).

The plain system is the "host OS": services access memory directly
(identity translation, no swapping). The hot-switch performs, per PCPU,
the two-stage ``switch_vcpu``:

  stage 1: an SMP call quiesces the PCPU at a safe point, saves its
           register state into a fresh VMCS, prepares the EPT (block
           table), and enters root mode (``hv_sched`` takes over the PCPU);
  stage 2: the new VCPU's first instruction re-enters ``switch_vcpu``,
           restores the saved state and resumes the exact execution flow --
           the guest never observes the transition.

Here the "registers" are each service thread's cursor state, the SMP call
is a per-PCPU quiesce lock, and entering non-root mode means the service's
memory accessor is atomically redirected from direct physical access to
block-table translation. Tests verify the paper's transparency claims:
identical memory contents, zero failed service operations across the
switch, and swappability afterwards.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..analysis.lock_order import named_lock
from .config import TaijiConfig
from .system import TaijiSystem
from .virt import PhysicalMemory


@dataclasses.dataclass
class VMCS:
    """Saved per-VCPU state (register file analogue)."""

    vcpu_id: int
    saved_cursor: Dict[str, object]
    host_rip: str = "hv_sched._run_cycle"   # exit entry point (see hotupgrade)
    launched: bool = False


class PlainMemorySystem:
    """The pre-switch host OS: direct physical access, no elasticity.

    Guest MSs are identity-mapped (gfn == pfn). Services run as threads
    issuing reads/writes through :attr:`accessor`, which the hot-switch
    redirects atomically.
    """

    def __init__(self, cfg: TaijiConfig) -> None:
        cfg.validate()
        self.cfg = cfg
        self.phys = PhysicalMemory(cfg)
        self._alloc_lock = named_lock("app")
        self.allocated: List[int] = []
        # pre-switch accessor: identity translation straight to physical
        self.accessor: "MemoryAccessor" = DirectAccessor(self)
        # per-PCPU quiesce locks (the SMP-call stop point)
        self.pcpu_locks = [named_lock("pcpu") for _ in range(cfg.scheduler.shards)]

    def alloc_ms(self) -> int:
        with self._alloc_lock:
            pfn = self.phys.alloc_slot()
            self.allocated.append(pfn)
            return pfn                      # identity: gfn == pfn

    def read(self, pcpu: int, gva: int, n: int) -> bytes:
        with self.pcpu_locks[pcpu % len(self.pcpu_locks)]:
            return self.accessor.read(gva, n)

    def write(self, pcpu: int, gva: int, data: bytes) -> None:
        with self.pcpu_locks[pcpu % len(self.pcpu_locks)]:
            self.accessor.write(gva, data)


class MemoryAccessor:
    def read(self, gva: int, n: int) -> bytes:  # pragma: no cover - interface
        raise NotImplementedError

    def write(self, gva: int, data: bytes) -> None:  # pragma: no cover
        raise NotImplementedError


class DirectAccessor(MemoryAccessor):
    """Host-OS path: VA -> PA via the identity kernel map."""

    def __init__(self, plain: PlainMemorySystem) -> None:
        self.plain = plain

    def read(self, gva: int, n: int) -> bytes:
        return bytes(self.plain.phys.buffer[gva : gva + n])

    def write(self, gva: int, data: bytes) -> None:
        self.plain.phys.buffer[gva : gva + len(data)] = np.frombuffer(
            data, dtype=np.uint8)


class VirtAccessor(MemoryAccessor):
    """Post-switch path: GVA -> GPA -> HPA through the block table."""

    def __init__(self, system: TaijiSystem) -> None:
        self.system = system

    def read(self, gva: int, n: int) -> bytes:
        return self.system.guest.read_gva(gva, n)

    def write(self, gva: int, data: bytes) -> None:
        self.system.guest.write_gva(gva, data)


def hot_switch(plain: PlainMemorySystem,
               on_stage: Optional[Callable[[int, str], None]] = None) -> TaijiSystem:
    """Switch a running plain system into the Taiji elastic architecture.

    Reuses the *same* PhysicalMemory (no copy: the guest's memory stays in
    place); builds the virtualization layer around it; converts each PCPU
    via the two-stage switch; finally redirects the accessor.
    """
    cfg = plain.cfg
    system = TaijiSystem(cfg, phys=plain.phys)

    # identity-map every MS the host OS had allocated (gfn == pfn), so the
    # switched guest sees exactly the memory it had -- then track it in the
    # LRU so it becomes swappable (the whole point of the switch)
    for pfn in plain.allocated:
        system.virt.table.map_huge(pfn, pfn)
        system.lru.track(pfn)
        with system._gfn_lock:
            if pfn in system._free_gfns:
                system._free_gfns.remove(pfn)

    vmcss: List[VMCS] = []
    for pcpu, lock in enumerate(plain.pcpu_locks):
        # ---- SMP call: quiesce this PCPU at a safe point
        with lock:
            if on_stage:
                on_stage(pcpu, "stage1")
            # stage 1: save state into the VMCS, prepare EPT + structures
            vmcs = VMCS(vcpu_id=pcpu, saved_cursor={"pcpu": pcpu,
                                                    "t": time.monotonic()})
            # stage 2: "VMLAUNCH" -- the VCPU resumes the saved flow; from
            # now on this PCPU's accesses translate through the block table
            vmcs.launched = True
            vmcss.append(vmcs)
            if on_stage:
                on_stage(pcpu, "stage2")
        # the PCPU is now a VCPU task under hv_sched; its original
        # execution flow continues (the service thread keeps running)

    # all PCPUs switched: atomically redirect the accessor (single store)
    plain.accessor = VirtAccessor(system)
    system.vmcss = vmcss
    return system
