"""Deterministic synthetic data pipeline.

Produces shardable, reproducible batches for every arch family without
touching disk (this container is offline). The stream is keyed by
(seed, step) so checkpoint/restart resumes the exact cursor -- the
pipeline state is just an integer, which the checkpoint manager persists
(fault-tolerance requirement).

Token streams follow a Zipf-like distribution over the vocab (more
realistic router/embedding load than uniform); audio features are
band-limited noise; vision embeddings are unit-normal patches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int = 0


class SyntheticPipeline:
    def __init__(self, cfg, batch: int, seq_len: int, seed: int = 0) -> None:
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.state = PipelineState(seed=seed)
        # Zipf weights over the vocab (clipped for tractability)
        v = min(cfg.vocab, 65536)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        w = 1.0 / ranks ** 1.1
        self._probs = (w / w.sum()).astype(np.float64)
        self._vocab_eff = v

    # ------------------------------------------------------------- batches
    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.state.seed << 20) ^ self.state.step)
        self.state.step += 1
        cfg, B, S = self.cfg, self.batch, self.seq_len
        batch: Dict[str, np.ndarray] = {}
        if cfg.family == "audio":
            t = np.arange(S)[None, :, None] / 16.0
            phase = rng.uniform(0, 2 * np.pi, (B, 1, cfg.frontend_dim))
            freq = rng.uniform(0.1, 4.0, (B, 1, cfg.frontend_dim))
            batch["features"] = (np.sin(freq * t + phase)
                                 + 0.1 * rng.standard_normal((B, S, cfg.frontend_dim))
                                 ).astype(np.float32)
            batch["labels"] = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
            return batch

        toks = rng.choice(self._vocab_eff, size=(B, S + 1),
                          p=self._probs).astype(np.int32)
        batch["tokens"] = toks[:, :-1]
        batch["labels"] = toks[:, 1:].astype(np.int32)
        if cfg.family == "vlm":
            nv = cfg.max_vision_tokens
            batch["vision_embeds"] = rng.standard_normal(
                (B, nv, cfg.d_model)).astype(np.float32)
            batch["mrope_pos"] = self._mrope_positions(nv, B, S)
            # don't train on the vision prefix
            mask = np.ones((B, S), np.float32)
            mask[:, :nv] = 0.0
            batch["loss_mask"] = mask
        return batch

    def _mrope_positions(self, nv: int, B: int, S: int) -> np.ndarray:
        """M-RoPE ids: vision prefix gets a (t,h,w) grid, text continues 1-D."""
        side = max(1, int(np.sqrt(nv)))
        pos = np.zeros((3, B, S), np.int32)
        idx = np.arange(nv)
        pos[0, :, :nv] = 0                       # one temporal frame
        pos[1, :, :nv] = (idx // side)[None, :]
        pos[2, :, :nv] = (idx % side)[None, :]
        text = np.arange(S - nv) + side          # text resumes after the grid
        for a in range(3):
            pos[a, :, nv:] = text[None, :]
        return pos

    # ---------------------------------------------------- fault tolerance
    def snapshot(self) -> Dict[str, int]:
        return {"seed": self.state.seed, "step": self.state.step}

    def restore(self, snap: Dict[str, int]) -> None:
        self.state = PipelineState(seed=snap["seed"], step=snap["step"])

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
