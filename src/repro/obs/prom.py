"""Prometheus text exposition for Metrics + SpanTracer.

One render path for everything an external scraper (or the future
autoscaler) consumes: the deterministic event counters, the latency
histograms (native power-of-two buckets, in seconds), derived gauges,
and -- when tracing is enabled -- per-stage span aggregates.

The module is import-light on purpose: it reads ``Metrics`` and
``SpanTracer`` duck-typed, so ``repro.core.metrics`` can delegate here
lazily without an import cycle.
"""
from __future__ import annotations

from typing import List


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"')


def _render_hist(lines: List[str], metric: str, hist,
                 labels: str = "") -> None:
    """Render one LatencyHistogram as a Prometheus histogram (seconds)."""
    base = f"{{{labels}" if labels else "{"
    cum = 0
    for i, c in enumerate(hist.buckets):
        cum += c
        if i < hist._NBUCKETS:
            le = (1 << (i + hist._BASE_SHIFT)) / 1e9
            le_s = f"{le:.9f}".rstrip("0").rstrip(".")
        else:
            le_s = "+Inf"
        sep = "," if labels else ""
        lines.append(f'{metric}_bucket{base}{sep}le="{le_s}"}} {cum}')
    lines.append(f"{metric}_sum{base}}} {hist.total_ns / 1e9:.9f}"
                 if labels else f"{metric}_sum {hist.total_ns / 1e9:.9f}")
    lines.append(f"{metric}_count{base}}} {hist.count}"
                 if labels else f"{metric}_count {hist.count}")


def render_prom(metrics, tracer=None, prefix: str = "taiji") -> str:
    """Render ``metrics`` (and optionally a tracer) as Prometheus text.

    ``tracer`` defaults to ``metrics.tracer``; pass an explicit tracer
    (or a merged fleet view) to override.
    """
    if tracer is None:
        tracer = getattr(metrics, "tracer", None)
    lines: List[str] = []

    # deterministic event counters -> counters
    det = metrics.deterministic_snapshot()
    for name in sorted(det):
        lines.append(f"# TYPE {prefix}_{name}_total counter")
        lines.append(f"{prefix}_{name}_total {det[name]}")

    # derived gauges
    lines.append(f"# TYPE {prefix}_compression_ratio gauge")
    lines.append(f"{prefix}_compression_ratio "
                 f"{metrics.compression_ratio():.6f}")

    # latency histograms (seconds; native power-of-two buckets)
    lines.append(f"# TYPE {prefix}_fault_latency_seconds histogram")
    _render_hist(lines, f"{prefix}_fault_latency_seconds",
                 metrics.fault_latency)
    for kind, hist in metrics.fault_latency_by_kind.items():
        if hist.count:
            _render_hist(lines, f"{prefix}_fault_latency_seconds", hist,
                         labels=f'kind="{_esc(kind)}"')
    for name, hist in (("swap_out", metrics.swap_out_latency),
                       ("swap_in", metrics.swap_in_latency)):
        if hist.count:
            lines.append(f"# TYPE {prefix}_{name}_latency_seconds histogram")
            _render_hist(lines, f"{prefix}_{name}_latency_seconds", hist)

    # tracer stage aggregates
    if tracer is not None:
        totals = tracer.totals()
        if totals:
            lines.append(f"# TYPE {prefix}_stage_seconds_total counter")
            lines.append(f"# TYPE {prefix}_stage_spans_total counter")
            lines.append(f"# TYPE {prefix}_stage_max_seconds gauge")
            for stage in sorted(totals):
                t = totals[stage]
                lab = f'stage="{_esc(stage)}"'
                lines.append(f"{prefix}_stage_seconds_total{{{lab}}} "
                             f"{t['total_ns'] / 1e9:.9f}")
                lines.append(f"{prefix}_stage_spans_total{{{lab}}} "
                             f"{t['count']}")
                lines.append(f"{prefix}_stage_max_seconds{{{lab}}} "
                             f"{t['max_ns'] / 1e9:.9f}")
    return "\n".join(lines) + "\n"
