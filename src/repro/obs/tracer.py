"""Stage-attributed span tracing for the swap path.

The headline latency distributions (BENCH_smoke.json) say *what* the
fault/swap path costs; this module says *where*. A :class:`SpanTracer`
is a ``LatencyRing``-style preallocated ring: the hot path records one
span with a single encoded int64 store plus two companion stores
(``t_start_ns`` and thread id) and no allocation; bucketing into
per-(stage, tag) aggregates and the bounded retained-span store happen
in vectorized batches at :meth:`SpanTracer.flush`.

Discipline when disabled: every instrumented call site caches
``metrics.tracer`` (``None`` unless ``ObsConfig.enabled``) and guards
with ``if tr is not None:`` -- the same single-truthiness-branch cost as
the empty-observer check in ``GuestSpace``. Spans are wall-clock
telemetry and never enter ``deterministic_snapshot``; capture/replay and
chaos determinism are untouched by tracing.

Stages form a *static* tree (``STAGES`` below): self-time rollup
subtracts each stage's declared children from its total instead of
reconstructing nesting from timestamps at runtime. For fan-out stages
(the compress pool) the instrumented span covers the fan-out's wall time
on the issuing thread, so child totals cannot exceed the parent through
parallelism.
"""
from __future__ import annotations

import json
import threading
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..analysis.lock_order import named_lock

# --------------------------------------------------------------- stages
# (name, parent-name-or-None). The tree is static: self_time(stage) =
# total(stage) - sum(total(child) for declared children), clamped at 0.
# Instrumentation must keep child spans physically inside one parent
# span of the declared parent stage (on any thread) for the rollup to
# telescope: sum of self-times over a subtree == the root stage's total.
STAGES: Tuple[Tuple[str, Optional[str]], ...] = (
    # fleet NodeAgent wrapper entry (read_at/write_at/read_many/write_many)
    ("node_call", None),
    # one GuestSpace access call (scalar or batch)
    ("guest_access", "node_call"),
    # passive swap-in: whole fault, same interval the fault_ring records
    ("fault_total", "guest_access"),
    ("fault_mutex", "fault_total"),        # mp_mutex / rwlock / cond wait
    ("fault_desc", "fault_total"),         # descriptor lookup + admission
    ("fault_alloc", "fault_desc"),         # first-in slot alloc (+ critical
                                           # sync reclaim when below min)
    ("fault_copy", "fault_total"),         # memset / CRC / bitmap publish
    ("fault_backend", "fault_total"),      # backend decode + copy-in
    ("fault_readahead", "fault_total"),    # whole-extent sibling fill
    ("readahead_decode", "fault_readahead"),   # extent payload decompress
    # SwapEngine batched swap-out pipeline
    ("swap_out", None),
    ("swap_gather", "swap_out"),           # resident-MP gather
    ("backend_store", "swap_out"),         # store_batch wall time
    ("swap_compress", "backend_store"),    # compress fan-out (issuer wall)
    ("kernel_store", "backend_store"),     # pallas zero-scan / extent tags
    ("backend_remote_put", "backend_store"),   # remote-peer tier replica put
    # SwapEngine batched swap-in pipeline
    ("swap_in", None),
    ("backend_load", "swap_in"),           # load_batch wall time
    ("swap_decompress", "backend_load"),   # extent/blob decompress
    ("kernel_load", "backend_load"),       # pallas scatter dispatch
    ("backend_remote_get", "backend_load"),    # remote-peer tier replica get
    ("swap_scatter", "swap_in"),           # decoded rows -> guest MPs
    # hv_sched task execution (tag = priority class)
    ("sched_task", None),
    # fleet control plane
    ("fleet_tick", None),
    ("fleet_recovery", "fleet_tick"),      # dead-node re-placement
    ("fleet_step", "fleet_tick"),          # staggered node background rounds
    ("fleet_upgrade", "fleet_tick"),       # rolling-upgrade driving
    ("fleet_admission", None),
    ("fleet_placement", "fleet_admission"),
)

STAGE_NAMES: Tuple[str, ...] = tuple(name for name, _ in STAGES)
N_STAGES = len(STAGES)
N_TAGS = 8                               # 3 tag bits (fault kind / op / class)

_IDX = {name: i for i, (name, _) in enumerate(STAGES)}
PARENT: Tuple[int, ...] = tuple(
    _IDX[parent] if parent is not None else -1 for _, parent in STAGES)
CHILDREN: Tuple[Tuple[int, ...], ...] = tuple(
    tuple(c for c, p in enumerate(PARENT) if p == s) for s in range(N_STAGES))

# stage-id constants for instrumented call sites
ST_NODE_CALL = _IDX["node_call"]
ST_GUEST_ACCESS = _IDX["guest_access"]
ST_FAULT_TOTAL = _IDX["fault_total"]
ST_FAULT_MUTEX = _IDX["fault_mutex"]
ST_FAULT_DESC = _IDX["fault_desc"]
ST_FAULT_ALLOC = _IDX["fault_alloc"]
ST_FAULT_COPY = _IDX["fault_copy"]
ST_FAULT_BACKEND = _IDX["fault_backend"]
ST_FAULT_READAHEAD = _IDX["fault_readahead"]
ST_READAHEAD_DECODE = _IDX["readahead_decode"]
ST_SWAP_OUT = _IDX["swap_out"]
ST_SWAP_GATHER = _IDX["swap_gather"]
ST_BACKEND_STORE = _IDX["backend_store"]
ST_SWAP_COMPRESS = _IDX["swap_compress"]
ST_KERNEL_STORE = _IDX["kernel_store"]
ST_BACKEND_REMOTE_PUT = _IDX["backend_remote_put"]
ST_SWAP_IN = _IDX["swap_in"]
ST_BACKEND_LOAD = _IDX["backend_load"]
ST_SWAP_DECOMPRESS = _IDX["swap_decompress"]
ST_KERNEL_LOAD = _IDX["kernel_load"]
ST_SWAP_SCATTER = _IDX["swap_scatter"]
ST_BACKEND_REMOTE_GET = _IDX["backend_remote_get"]
ST_SCHED_TASK = _IDX["sched_task"]
ST_FLEET_TICK = _IDX["fleet_tick"]
ST_FLEET_RECOVERY = _IDX["fleet_recovery"]
ST_FLEET_STEP = _IDX["fleet_step"]
ST_FLEET_UPGRADE = _IDX["fleet_upgrade"]
ST_FLEET_ADMISSION = _IDX["fleet_admission"]
ST_FLEET_PLACEMENT = _IDX["fleet_placement"]

# access-op tags for guest_access / node_call spans
TAG_READ, TAG_WRITE, TAG_READ_MANY, TAG_WRITE_MANY, TAG_GATHER, TAG_SCATTER = \
    range(6)
ACCESS_TAG_NAMES = ("read", "write", "read_many", "write_many",
                    "gather", "scatter", "tag6", "tag7")
# fault_total spans reuse the FK kind codes (metrics.FK_*) as tags, with
# bit 2 carrying FK_FAST -- tags 0..7 decode to kind = tag & 3
FAULT_TAG_NAMES = ("zero", "compressed", "readahead", "other",
                   "zero_fast", "compressed_fast", "readahead_fast",
                   "other_fast")

_ENC_SHIFT = 16          # enc = ((dur_ns + 1) << 16) | (stage << 8) | tag


class SpanTracer:
    """Ring-buffered span recorder (``LatencyRing`` discipline).

    ``push(stage, t0_ns, dur_ns, tag)`` is three int64 stores; no lock,
    no allocation. Pushes are GIL-serialized; a push racing a flush can
    at worst be dropped (stats-only loss), never double-folded, because
    flush zeroes the encoded slots it copied and skips ``enc == 0``.

    Aggregates (count / total / max per (stage, tag)) and a bounded
    retained-span store (for Chrome-trace export) are folded under
    ``_lock`` in :meth:`flush`.
    """

    __slots__ = ("_enc", "_t0", "_tid", "_pos", "_cap", "_lock",
                 "_count", "_total", "_max",
                 "_chunks", "_kept", "dropped_spans", "max_spans", "pid")

    def __init__(self, cap: int = 4096, max_spans: int = 200_000,
                 pid: int = 0) -> None:
        self._enc = np.zeros(cap, dtype=np.int64)
        self._t0 = np.zeros(cap, dtype=np.int64)
        self._tid = np.zeros(cap, dtype=np.int64)
        self._pos = 0
        self._cap = cap
        self._lock = named_lock("metrics")
        self._count = np.zeros((N_STAGES, N_TAGS), dtype=np.int64)
        self._total = np.zeros((N_STAGES, N_TAGS), dtype=np.int64)
        self._max = np.zeros((N_STAGES, N_TAGS), dtype=np.int64)
        # retained decoded spans for export: (stage, t0, dur, tag, tid)
        self._chunks: List[Tuple[np.ndarray, ...]] = []
        self._kept = 0
        self.dropped_spans = 0
        self.max_spans = max_spans
        self.pid = pid                     # Chrome-trace process id (node id)

    # ------------------------------------------------------------ hot path
    def push(self, stage: int, t0_ns: int, dur_ns: int, tag: int = 0) -> None:
        p = self._pos
        if p >= self._cap:
            self.flush()
            p = self._pos
            if p >= self._cap:           # racing pushers refilled the ring
                p = self._cap - 1        # overwrite the tail (stats-only)
        self._enc[p] = ((dur_ns + 1) << _ENC_SHIFT) | (stage << 8) | tag
        self._t0[p] = t0_ns
        self._tid[p] = threading.get_ident() & 0x7FFFFFFF
        self._pos = p + 1

    # -------------------------------------------------------------- folding
    def flush(self) -> None:
        with self._lock:
            n = self._pos
            if n == 0:
                return
            enc = self._enc[:n].copy()
            t0 = self._t0[:n].copy()
            tid = self._tid[:n].copy()
            self._enc[:n] = 0            # stale-slot guard vs racing pushes
            self._pos = 0
            keep = enc != 0              # skip empty/already-folded slots
            if not keep.all():
                enc, t0, tid = enc[keep], t0[keep], tid[keep]
            if len(enc) == 0:
                return
            dur = (enc >> _ENC_SHIFT) - 1
            stage = (enc >> 8) & 0xFF
            tag = enc & 0xFF
            np.add.at(self._count, (stage, tag), 1)
            np.add.at(self._total, (stage, tag), dur)
            np.maximum.at(self._max, (stage, tag), dur)
            room = self.max_spans - self._kept
            if room > 0:
                k = min(room, len(enc))
                self._chunks.append((stage[:k], t0[:k], dur[:k],
                                     tag[:k], tid[:k]))
                self._kept += k
                self.dropped_spans += len(enc) - k
            else:
                self.dropped_spans += len(enc)

    # ------------------------------------------------------------ accessors
    @property
    def span_count(self) -> int:
        """Spans folded into aggregates so far (flushes first)."""
        self.flush()
        return int(self._count.sum())

    def stage_count(self, stage: str) -> int:
        self.flush()
        return int(self._count[_IDX[stage]].sum())

    def totals(self) -> Dict[str, Dict[str, object]]:
        """Per-stage aggregate view: count, total/max ns, per-tag split."""
        self.flush()
        out: Dict[str, Dict[str, object]] = {}
        for sid, name in enumerate(STAGE_NAMES):
            cnt = int(self._count[sid].sum())
            if cnt == 0:
                continue
            tags = {
                int(t): {"count": int(self._count[sid, t]),
                         "total_ns": int(self._total[sid, t]),
                         "max_ns": int(self._max[sid, t])}
                for t in np.flatnonzero(self._count[sid])}
            out[name] = {"count": cnt,
                         "total_ns": int(self._total[sid].sum()),
                         "max_ns": int(self._max[sid].max()),
                         "by_tag": tags}
        return out

    def spans(self) -> Iterable[Tuple[int, int, int, int, int]]:
        """Decoded retained spans: (stage_id, t0_ns, dur_ns, tag, tid)."""
        self.flush()
        for stage, t0, dur, tag, tid in self._chunks:
            for i in range(len(stage)):
                yield (int(stage[i]), int(t0[i]), int(dur[i]),
                       int(tag[i]), int(tid[i]))

    def export_chrome(self, path: str) -> int:
        """Write this tracer's spans as Chrome-trace JSON. See
        :func:`export_chrome`."""
        return export_chrome(path, [self])


# ------------------------------------------------------- multi-tracer views
def aggregate(tracers: Iterable[SpanTracer]) -> Tuple[np.ndarray, np.ndarray,
                                                      np.ndarray]:
    """Summed (count, total, max) arrays across tracers (flushes each)."""
    count = np.zeros((N_STAGES, N_TAGS), dtype=np.int64)
    total = np.zeros((N_STAGES, N_TAGS), dtype=np.int64)
    mx = np.zeros((N_STAGES, N_TAGS), dtype=np.int64)
    for tr in tracers:
        tr.flush()
        count += tr._count
        total += tr._total
        np.maximum(mx, tr._max, out=mx)
    return count, total, mx


def stage_tree(tracers: Iterable[SpanTracer]) -> Dict[str, Dict[str, object]]:
    """Aggregated stage tree with self-time rollup.

    Returns ``{stage: {count, total_ns, self_ns, max_ns, parent,
    by_tag}}`` for every stage with at least one span. ``self_ns`` is the
    stage total minus its declared children's totals, clamped at zero
    (a fan-out child running on pool threads can exceed the parent's
    wall time; the clamp keeps the rollup a partition, slightly
    under-attributing the parent in that case).
    """
    count, total, mx = aggregate(list(tracers))
    cnt_s = count.sum(axis=1)
    tot_s = total.sum(axis=1)
    out: Dict[str, Dict[str, object]] = {}
    for sid, (name, parent) in enumerate(STAGES):
        if cnt_s[sid] == 0:
            continue
        child_ns = int(sum(tot_s[c] for c in CHILDREN[sid]))
        out[name] = {
            "count": int(cnt_s[sid]),
            "total_ns": int(tot_s[sid]),
            "self_ns": max(0, int(tot_s[sid]) - child_ns),
            "max_ns": int(mx[sid].max()),
            "parent": parent,
            "by_tag": {int(t): {"count": int(count[sid, t]),
                                "total_ns": int(total[sid, t])}
                       for t in np.flatnonzero(count[sid])},
        }
    return out


def export_chrome(path: str, tracers: Iterable[SpanTracer]) -> int:
    """Write retained spans as Chrome-trace-event JSON (Perfetto/
    chrome://tracing loadable). Returns the number of events written.

    Events are complete-duration (``ph == "X"``) with microsecond ``ts``
    normalized to the earliest retained span, ``pid`` = tracer pid (fleet
    node id) and ``tid`` = recording thread.
    """
    tracers = list(tracers)
    base = None
    for tr in tracers:
        tr.flush()
        for _, t0, _, _, _ in tr._chunks:
            if len(t0):
                lo = int(t0.min())
                base = lo if base is None else min(base, lo)
    base = base or 0
    events = []
    for tr in tracers:
        for stage, t0, dur, tag, tid in tr._chunks:
            names = [STAGE_NAMES[s] for s in stage]
            ts = (t0 - base) / 1e3
            dur_us = dur / 1e3
            for i, name in enumerate(names):
                events.append({
                    "name": name, "cat": "taiji", "ph": "X",
                    "ts": float(ts[i]), "dur": float(dur_us[i]),
                    "pid": int(tr.pid), "tid": int(tid[i]),
                    "args": {"tag": int(tag[i])},
                })
    events.sort(key=lambda e: e["ts"])
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ns"}, f)
    return len(events)
