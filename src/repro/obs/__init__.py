"""Observability: stage-attributed span tracing + telemetry export.

See :mod:`repro.obs.tracer` for the SpanTracer / stage tree /
Chrome-trace export and :mod:`repro.obs.prom` for Prometheus text
exposition. Enabled per-system via ``TaijiConfig.obs``
(``ObsConfig(enabled=True)``); disabled (the default) costs one
``is not None`` branch per instrumented call site.
"""
from .prom import render_prom
from .tracer import (
    SpanTracer,
    STAGES,
    STAGE_NAMES,
    aggregate,
    export_chrome,
    stage_tree,
)

__all__ = [
    "SpanTracer", "STAGES", "STAGE_NAMES",
    "aggregate", "export_chrome", "stage_tree", "render_prom",
]
