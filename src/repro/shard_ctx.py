"""Activation-sharding context.

Model code is mesh-agnostic; the launcher/dry-run installs an
:class:`AxisCtx` describing the active mesh axes, and the model applies
``constrain*`` hints at the key activation cut points (embeddings, per-
layer residual stream, attention heads, MoE dispatch, logits). With no
context installed (single-device smoke tests) every helper is a no-op.

These constraints are what keep XLA's SPMD propagation from replicating
the (tokens x vocab) logits or the MoE dispatch buffers -- see
EXPERIMENTS.md §Perf for the measured before/after.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisCtx:
    batch: Any = None          # axis (or tuple) sharding the batch dim
    tp: Optional[str] = None   # tensor-parallel axis name
    seq: Optional[str] = None  # sequence-parallel axis (long-context cells)
    heads_ok: bool = False     # n_heads divisible by tp
    kv_heads_ok: bool = False
    vocab_ok: bool = False
    d_inner_ok: bool = False
    experts_ok: bool = False
    ffn_ok: bool = False


_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_axis_ctx",
                                                      default=None)


def current() -> Optional[AxisCtx]:
    return _CTX.get()


@contextlib.contextmanager
def use(ctx: Optional[AxisCtx]):
    token = _CTX.set(ctx)
    try:
        yield
    finally:
        _CTX.reset(token)


def _constrain(x, spec: P):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def act(x):
    """Residual stream (B, S, D) or (B, D)."""
    c = current()
    if c is None or c.batch is None:
        return x
    return _constrain(x, P(c.batch, *([None] * (x.ndim - 1))))


def heads(x, kv: bool = False):
    """Per-head activations (B, S, H, hd)."""
    c = current()
    if c is None:
        return x
    ok = c.kv_heads_ok if kv else c.heads_ok
    tp = c.tp if ok else None
    if c.batch is None and tp is None:
        return x
    return _constrain(x, P(c.batch, None, tp, None))


def logits(x):
    """(.., V): vocab over tp when divisible."""
    c = current()
    if c is None:
        return x
    tp = c.tp if c.vocab_ok else None
    if c.batch is None and tp is None:
        return x
    return _constrain(x, P(c.batch, *([None] * (x.ndim - 2)), tp))


def moe_dispatch(x):
    """(E, C, D/F): experts over tp, capacity over batch axes."""
    c = current()
    if c is None:
        return x
    tp = c.tp if c.experts_ok else None
    if tp is None and c.batch is None:
        return x
    return _constrain(x, P(tp, c.batch, None))


def mamba_inner(x):
    """(B, S, DI, DS) scan tensors: d_inner over tp."""
    c = current()
    if c is None:
        return x
    tp = c.tp if c.d_inner_ok else None
    if tp is None and c.batch is None:
        return x
    return _constrain(x, P(c.batch, None, tp, None))


def ffn_hidden(x):
    """(B, S, F): FFN hidden over tp."""
    c = current()
    if c is None:
        return x
    tp = c.tp if c.ffn_ok else None
    if tp is None and c.batch is None:
        return x
    return _constrain(x, P(c.batch, *([None] * (x.ndim - 2)), tp))
