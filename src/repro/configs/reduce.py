"""Reduced same-family configs for CPU smoke tests.

Every reduction keeps the arch's distinguishing features (GQA ratio,
qk-norm, QKV bias, shared+routed fine-grained MoE, 7:1 hybrid interleave,
M-RoPE sections, encoder-onlyness) while shrinking width/depth/vocab so a
forward + train step runs in seconds on one CPU device.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, MambaConfig, MoEConfig

from . import get_config


def reduced_config(arch_id: str) -> ArchConfig:
    full = get_config(arch_id)
    r = {
        "vocab": 512,
        "d_model": 128,
        "attn_chunk_q": 32,
        "attn_chunk_kv": 64,
        "kv_block_tokens": 8,
        "param_dtype": "float32",
        "compute_dtype": "float32",
        "opt_dtype": "float32",
    }
    if full.family == "hybrid":
        r.update(n_layers=8, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
                 moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=256,
                               n_shared=0, freq=2),
                 mamba=MambaConfig(d_state=8, d_conv=4, expand=2, chunk=16))
    elif full.family == "ssm":
        r.update(n_layers=4, n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0,
                 mamba=MambaConfig(d_state=8, d_conv=4, expand=2, chunk=16))
    elif full.family == "moe":
        m = full.moe
        r.update(n_layers=3, n_heads=4,
                 n_kv_heads=4 if full.n_kv_heads == full.n_heads else 2,
                 head_dim=32, d_ff=256,
                 moe=MoEConfig(n_routed=8, top_k=min(m.top_k, 4),
                               d_ff_expert=64, n_shared=m.n_shared,
                               freq=m.freq, first=m.first))
    else:  # dense / audio / vlm
        r.update(n_layers=3, n_heads=4,
                 n_kv_heads=1 if full.n_kv_heads == 1 else 2,
                 head_dim=32, d_ff=256)
        if full.family == "audio":
            r.update(frontend_dim=32, vocab=64)
        if full.family == "vlm":
            r.update(mrope_sections=(4, 6, 6), max_vision_tokens=8)
    return dataclasses.replace(full, **r)
