"""falcon-mamba-7b [ssm]: attention-free mamba1, 64L d_model=4096
vocab=65024, ssm_state=16 [arXiv:2410.05355]."""
from repro.models.config import ArchConfig, MambaConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    vocab=65024,
    d_model=4096,
    n_layers=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    param_dtype="bfloat16",
)
