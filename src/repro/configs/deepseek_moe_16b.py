"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) vocab=102400,
fine-grained MoE: 2 shared + 64 routed top-6, d_ff_expert=1408; first
layer dense (d_ff=10944) [arXiv:2401.06066]."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    vocab=102400,
    d_model=2048,
    n_layers=28,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,                    # layer-0 dense FFN
    moe=MoEConfig(
        n_routed=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        freq=1,
        first=1,                   # layer 0 stays dense
    ),
    rope_theta=1e4,
)
