"""hubert-xlarge [audio]: encoder-only 48L d_model=1280 16H (MHA kv=16)
d_ff=5120 vocab=504 (masked-unit targets) [arXiv:2106.07447].

The conv waveform frontend is a STUB: ``input_specs`` provides
precomputed 512-d frame embeddings (the conv stack's output dim) which the
model projects to d_model. Encoder-only: no decode shapes."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    vocab=504,
    d_model=1280,
    n_layers=48,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    causal=False,                 # bidirectional encoder
    frontend_dim=512,
    rope_theta=1e4,
)
