"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936. GQA + QKV bias [arXiv:2407.10671]. head_dim=64, tied
embeddings (the 0.5B Qwen2 ties lm_head to the embedding)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    vocab=151936,
    d_model=896,
    n_layers=24,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
)
