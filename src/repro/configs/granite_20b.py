"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152. llama-arch code model [arXiv:2405.04324]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    vocab=49152,
    d_model=6144,
    n_layers=52,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    rope_theta=1e5,
    param_dtype="bfloat16",
)
