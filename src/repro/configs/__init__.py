"""Architecture registry: ``--arch <id>`` resolution + shape sets.

Each assigned architecture has its own module with the exact published
config; ``get_config(arch_id)`` resolves it. ``SHAPES`` defines the
assigned input-shape set (shared by all LM-family archs) and
``runnable_cells()`` enumerates the (arch x shape) dry-run matrix with the
assignment's documented skips.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ArchConfig

_MODULES = {
    "qwen3-4b": "qwen3_4b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen2-0.5b": "qwen2_0_5b",
    "granite-20b": "granite_20b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with sub-quadratic sequence mixing (may run long_500k)
SUBQUADRATIC = {"jamba-1.5-large-398b", "falcon-mamba-7b"}
ENCODER_ONLY = {"hubert-xlarge"}


def cell_skip_reason(arch_id: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch_id not in SUBQUADRATIC:
        return "long_500k needs sub-quadratic attention (pure full-attention arch)"
    if arch_id in ENCODER_ONLY and SHAPES[shape].kind == "decode":
        return "encoder-only arch has no decode step"
    return None


def runnable_cells() -> List[Tuple[str, str]]:
    cells = []
    for a in ARCH_IDS:
        for s in SHAPES:
            if cell_skip_reason(a, s) is None:
                cells.append((a, s))
    return cells


def all_cells() -> List[Tuple[str, str, Optional[str]]]:
    return [(a, s, cell_skip_reason(a, s)) for a in ARCH_IDS for s in SHAPES]
