"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536; mamba:attn 7:1 interleave, MoE 16 experts top-2 on
every other layer [arXiv:2403.19887]."""
from repro.models.config import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    vocab=65536,
    d_model=8192,
    n_layers=72,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    hybrid_group=8,                # 1 attention per 8 layers
    attn_index=4,
    moe=MoEConfig(
        n_routed=16,
        top_k=2,
        d_ff_expert=24576,
        n_shared=0,
        freq=2,                    # every other layer (encoded in group body)
    ),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=64),
    rope_theta=1e6,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",          # 398B optimizer state must fit v5e HBM
)
