"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4), MoE 128
routed experts top-8, d_ff_expert=1536, vocab=151936, qk_norm
[hf:Qwen/Qwen3-235B-A22B family]."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    vocab=151936,
    d_model=4096,
    n_layers=94,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,                        # every layer is MoE
    qk_norm=True,
    moe=MoEConfig(
        n_routed=128,
        top_k=8,
        d_ff_expert=1536,
        n_shared=0,
        freq=1,
        first=0,
    ),
    rope_theta=1e6,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",          # 235B optimizer state must fit v5e HBM
)
