"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE (t/h/w sections), QKV bias [arXiv:2409.12191].

The vision tower is a STUB: ``input_specs`` provides precomputed patch
embeddings merged into the prompt prefix plus the 3-axis M-RoPE position
ids (the backbone is the assigned component)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    vocab=151936,
    d_model=1536,
    n_layers=28,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),  # sums to head_dim//2
    max_vision_tokens=256,
    rope_theta=1e6,
)
