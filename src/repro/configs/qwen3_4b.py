"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

qk_norm + GQA; head_dim=128 per the Qwen3 family config [hf:Qwen/Qwen3-8B].
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    vocab=151936,
    d_model=2560,
    n_layers=36,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    qk_norm=True,
    qkv_bias=False,
    rope_theta=1e6,
)
