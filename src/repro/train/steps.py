"""Train / prefill / decode step functions over a TrainState.

These are the functions the launcher jits with explicit shardings and the
dry-run lowers for every (arch x shape x mesh) cell.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import model as M
from repro.optim import adamw


class TrainState(NamedTuple):
    step: jnp.ndarray            # i32 scalar
    params: Any
    opt: adamw.AdamWState


def init_train_state(rng: jax.Array, cfg: ArchConfig,
                     opt_cfg: adamw.AdamWConfig) -> TrainState:
    params = M.init_params(rng, cfg)
    return TrainState(step=jnp.zeros((), jnp.int32),
                      params=params, opt=adamw.init(params, opt_cfg))


def train_step(state: TrainState, batch: Dict[str, jnp.ndarray],
               cfg: ArchConfig, opt_cfg: adamw.AdamWConfig
               ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    def loss(params):
        l, metrics = M.loss_fn(params, cfg, batch)
        return l, metrics

    (loss_val, metrics), grads = jax.value_and_grad(loss, has_aux=True)(state.params)
    new_params, new_opt, opt_metrics = adamw.update(
        grads, state.opt, state.params, state.step, opt_cfg)
    metrics = dict(metrics)
    metrics.update(opt_metrics)
    metrics["loss"] = loss_val
    return TrainState(state.step + 1, new_params, new_opt), metrics


def prefill_step(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Prefill forward -> (last-token logits (B,V), aux)."""
    return M.prefill(params, cfg, batch)


def serve_step(params, tokens: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               cfg: ArchConfig, mrope_pos=None
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step: new token for every sequence against its KV/state."""
    return M.decode_step(params, cfg, tokens, cache, mrope_pos)
