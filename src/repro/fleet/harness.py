"""Replay-equivalence harness: run-twice-compare with a readable
first-divergence report.

The fleet determinism contract -- two replays of the same seeded trace
through identically-configured fleets produce byte-identical
deterministic snapshots -- used to live as ad-hoc assertions scattered
across ``tests/test_fleet.py`` and ``benchmarks/fleet.py``. This module
makes the contract a first-class object shared by the tests, the fleet
benchmark, and the CI chaos gate: build a fleet, replay a trace, compare
the snapshots, and when they diverge say *where* (the JSON path of the
first differing leaves), not just that they differ.

Typical use::

    from repro.fleet.harness import assert_deterministic
    eq = assert_deterministic(gen.lines(), n_nodes=4, domains=2)
    det = eq.runs[0].deterministic       # first run's snapshot dict
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, List, Optional

from ..core.config import TaijiConfig, small_test_config
from .controller import FleetConfig, FleetController
from .node import NodeAgent
from .trace import TraceReplayer


def build_fleet(n_nodes: int = 4, domains: int = 2,
                cfg: Optional[TaijiConfig] = None,
                fleet_cfg: Optional[FleetConfig] = None) -> FleetController:
    """The canonical test/bench fleet: ``n_nodes`` agents round-robined
    over ``domains`` failure domains, one shared TaijiConfig."""
    cfg = cfg or small_test_config()
    nodes = [NodeAgent(i, cfg, failure_domain=i % domains)
             for i in range(n_nodes)]
    return FleetController(nodes, fleet_cfg or FleetConfig())


@dataclasses.dataclass
class ReplayRun:
    """One trace replay: the byte-stable snapshot plus the full result."""

    bytes: bytes            # deterministic snapshot serialization
    result: dict            # full snapshot (deterministic + latency)

    @property
    def deterministic(self) -> dict:
        return self.result["deterministic"]

    @property
    def counters(self) -> dict:
        """The replayer's op counters (``replay`` sub-dict)."""
        return self.result["deterministic"]["replay"]


def replay(lines, *, n_nodes: int = 4, domains: int = 2,
           cfg: Optional[TaijiConfig] = None,
           fleet_cfg: Optional[FleetConfig] = None,
           make_fleet: Optional[Callable[[], FleetController]] = None,
           upgrade_module_cls=None) -> ReplayRun:
    """One full trace replay through a fresh fleet (closed afterwards)."""
    fleet = (make_fleet() if make_fleet is not None
             else build_fleet(n_nodes, domains, cfg, fleet_cfg))
    try:
        rep = TraceReplayer(fleet, lines,
                            upgrade_module_cls=upgrade_module_cls)
        result = rep.run()
        return ReplayRun(bytes=rep.deterministic_bytes(), result=result)
    finally:
        fleet.close()


# ------------------------------------------------------- snapshot diffing
def snapshot_diff(a, b, path: str = "$", limit: int = 8) -> List[str]:
    """Structural diff of two JSON-compatible snapshots: one line per
    differing leaf (``$.path.to.key: left != right``), depth-first, at
    most ``limit`` entries so a totally-divergent replay stays readable."""
    out: List[str] = []
    _diff(a, b, path, out, limit)
    return out


def _diff(a, b, path: str, out: List[str], limit: int) -> None:
    if len(out) >= limit:
        return
    if type(a) is not type(b):
        out.append(f"{path}: type {type(a).__name__} != {type(b).__name__}")
        return
    if isinstance(a, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                out.append(f"{path}.{k}: missing in first")
            elif k not in b:
                out.append(f"{path}.{k}: missing in second")
            else:
                _diff(a[k], b[k], f"{path}.{k}", out, limit)
            if len(out) >= limit:
                return
    elif isinstance(a, list):
        if len(a) != len(b):
            out.append(f"{path}: length {len(a)} != {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            _diff(x, y, f"{path}[{i}]", out, limit)
            if len(out) >= limit:
                return
    elif a != b:
        out.append(f"{path}: {a!r} != {b!r}")


def first_divergence(a: bytes, b: bytes) -> Optional[str]:
    """Readable first-divergence report between two deterministic
    snapshot serializations, or ``None`` when byte-identical."""
    if a == b:
        return None
    diffs = snapshot_diff(json.loads(a.decode()), json.loads(b.decode()))
    if not diffs:
        return "serializations differ but no structural diff found"
    return "; ".join(diffs)


# -------------------------------------------------------- the contract
@dataclasses.dataclass
class Equivalence:
    """Outcome of a run-twice-compare."""

    identical: bool
    runs: List[ReplayRun]
    divergence: Optional[str]

    def report(self) -> str:
        if self.identical:
            return "byte-identical replays"
        return f"replays diverge: {self.divergence}"


def replay_twice(lines, **kw) -> Equivalence:
    """The fleet determinism contract in run-twice-compare form: replay
    the trace through two fresh identically-configured fleets and diff
    the deterministic snapshots."""
    runs = [replay(lines, **kw) for _ in range(2)]
    div = first_divergence(runs[0].bytes, runs[1].bytes)
    return Equivalence(identical=div is None, runs=runs, divergence=div)


def assert_deterministic(lines, **kw) -> Equivalence:
    """replay_twice + assert, with the divergence report as the message."""
    eq = replay_twice(lines, **kw)
    assert eq.identical, eq.report()
    return eq
