"""Fleet control plane: multi-node elasticity orchestration (paper §5,
"across more than 30,000 servers") + trace-driven workload replay +
deterministic chaos (failure injection, live MS migration).

Layering:
  node (NodeAgent = one TaijiSystem + entry table, stepped, killable)
  -> controller (admission, placement, staggered reclaim, rolling
     upgrade, failure recovery, live migration)
  -> trace (TSV format incl. chaos + captured payload ops,
     TraceGen/FailureSchedule synthesis, TraceRecorder capture,
     deterministic TraceReplayer)
  -> capture (real elastic_kv / elastic_params serving loops recorded
     through one instrumented GuestSpace)
  -> harness (run-twice-compare replay equivalence + divergence reports)
"""
from .node import NodeAgent, NodeDeadError, NodeNotServingError
from .controller import (REJECT_MIGRATE_BAD_SRC, REJECT_MIGRATE_NO_DST,
                         REJECT_MIGRATE_VERIFY, REJECT_NO_CAPACITY,
                         REJECT_OVERCOMMIT, FleetConfig, FleetController)
from .trace import (FailureSchedule, TraceGen, TraceHeader, TraceRecorder,
                    TraceReplayer, chaos_trace, decode_payload,
                    encode_payload, page_bytes, page_kind, paper_trace,
                    parse_line, touch_addr)
from .capture import (CapturedTrace, capture_expert_churn,
                      capture_kv_serving)
from .harness import (Equivalence, ReplayRun, assert_deterministic,
                      build_fleet, first_divergence, replay, replay_twice,
                      snapshot_diff)

__all__ = [
    "NodeAgent", "NodeDeadError", "NodeNotServingError",
    "FleetConfig", "FleetController",
    "REJECT_OVERCOMMIT", "REJECT_NO_CAPACITY",
    "REJECT_MIGRATE_BAD_SRC", "REJECT_MIGRATE_NO_DST",
    "REJECT_MIGRATE_VERIFY",
    "FailureSchedule", "TraceGen", "TraceHeader", "TraceRecorder",
    "TraceReplayer", "chaos_trace", "decode_payload", "encode_payload",
    "page_bytes", "page_kind", "paper_trace", "parse_line", "touch_addr",
    "CapturedTrace", "capture_expert_churn", "capture_kv_serving",
    "Equivalence", "ReplayRun", "assert_deterministic", "build_fleet",
    "first_divergence", "replay", "replay_twice", "snapshot_diff",
]
