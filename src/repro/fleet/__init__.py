"""Fleet control plane: multi-node elasticity orchestration (paper §5,
"across more than 30,000 servers") + trace-driven workload replay.

Layering:
  node (NodeAgent = one TaijiSystem + entry table, stepped)
  -> controller (admission, placement, staggered reclaim, rolling upgrade)
  -> trace (TSV format, TraceGen synthesis, deterministic TraceReplayer)
"""
from .node import NodeAgent, NodeNotServingError
from .controller import (REJECT_NO_CAPACITY, REJECT_OVERCOMMIT,
                         FleetConfig, FleetController)
from .trace import (TraceGen, TraceHeader, TraceReplayer, page_bytes,
                    page_kind, paper_trace, parse_line, touch_addr)

__all__ = [
    "NodeAgent", "NodeNotServingError",
    "FleetConfig", "FleetController",
    "REJECT_OVERCOMMIT", "REJECT_NO_CAPACITY",
    "TraceGen", "TraceHeader", "TraceReplayer",
    "page_bytes", "page_kind", "paper_trace", "parse_line", "touch_addr",
]
