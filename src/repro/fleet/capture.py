"""Workload capture: real serving loops -> replayable fleet traces.

The ROADMAP's missing seam -- "trace capture from the elastic_kv /
elastic_params integrations so real serving workloads become replayable
fleet traces" -- closed through the unified GuestSpace surface: the
loops below drive the *actual* integrations (``ElasticKVCache`` decode
turns, ``ElasticExpertCache`` routing churn) against one instrumented
:class:`~repro.core.guest.GuestSpace` with a
:class:`~.trace.TraceRecorder` attached, and hand back trace lines any
fleet can replay.  Because the recorder captures payload (``wdata``) and
content-hash (``rdata``) ops, a replay rewrites the application's real
bytes and verifies every read against what the application saw --
``harness.assert_deterministic`` then proves the run-twice-compare
contract over the captured workload.

Both capture loops are fully seeded and use deterministic stepped
background rounds, so the same seed captures the same trace.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List

import numpy as np

from ..core.config import LRUConfig, TaijiConfig, WatermarkConfig
from ..core.elastic_kv import ElasticKVCache, KVGeometry, make_kv_taiji_config
from ..core.elastic_params import (ElasticExpertCache,
                                   make_expert_taiji_config)
from ..core.system import TaijiSystem
from .trace import OP_RDATA, OP_WDATA, TraceRecorder


@dataclasses.dataclass
class CapturedTrace:
    """One captured workload.

    ``cfg`` is the capture node's TaijiConfig; ``fleet_cfg`` is the
    per-node config a replay fleet should use -- physical capacity is
    scaled down so a multi-node replay sees the same overcommit pressure
    the capture node did (an N-node fleet at full capture size would
    have N times the memory and never reclaim, making the replay a
    write-only exercise).
    """

    name: str
    lines: List[str]
    cfg: TaijiConfig
    fleet_cfg: TaijiConfig
    n_ops: int
    payload_writes: int
    payload_reads: int


def _scaled_node_cfg(cfg: TaijiConfig, managed_ms: int) -> TaijiConfig:
    """Per-node replay config with ``managed_ms`` guest-backing MSs."""
    scaled = dataclasses.replace(
        cfg, n_phys_ms=managed_ms + cfg.mpool_reserve_ms)
    scaled.validate()
    return scaled


def _capture(name: str, cfg: TaijiConfig, fleet_cfg: TaijiConfig,
             seed: int, loop) -> CapturedTrace:
    system = TaijiSystem(cfg)
    space = system.guest
    rec = space.attach(TraceRecorder.for_space(space, seed=seed))
    try:
        loop(system, space)
    finally:
        space.detach(rec)
        system.close()
    counts = rec.op_counts()
    return CapturedTrace(name=name, lines=rec.lines(), cfg=cfg,
                         fleet_cfg=fleet_cfg, n_ops=rec.n_ops,
                         payload_writes=counts.get(OP_WDATA, 0),
                         payload_reads=counts.get(OP_RDATA, 0))


def capture_kv_serving(seed: int = 11, *, n_seqs: int = 6, turns: int = 8,
                       batch: int = 2, smoke: bool = False) -> CapturedTrace:
    """Capture a multi-turn elastic-KV serving loop.

    The loop is the integration's real shape: prompts fill blocks, each
    turn pins + decodes a scheduled batch (appends real fp16 KV), reads
    a block back (content-hash verified at replay), ages the LRU through
    stepped background rounds, and recycles finished conversations.
    """
    if smoke:
        turns = min(turns, 5)
    geom = KVGeometry(n_layers=2, kv_heads=2, head_dim=16, block_tokens=4,
                      dtype_bytes=2)
    # watermarks sit high so elasticity stays active even when the trace
    # is replayed on a fleet with more aggregate physical memory than the
    # capture node (a 2-node replay still ages + reclaims)
    cfg = make_kv_taiji_config(
        geom, n_phys_blocks=16, overcommit=1.2,
        lru=LRUConfig(scan_interval_s=0.001, workers=1, stabilize_scans=1),
        watermark=WatermarkConfig(high=0.5, low=0.3, min=0.05,
                                  reclaim_batch=4))
    prompt, gen = 8, 4
    # a conversation is recycled at max_ctx, which bounds a scheduled
    # batch's pinned working set (batch * max_ctx/block_tokens blocks +
    # in-step allocs) well under physical memory -- the DMA contract
    # says pinned blocks cannot be reclaimed to satisfy a new alloc
    max_ctx = 16
    # replay nodes carry 10 managed MSs each: a 2-node fleet holds 20
    # against a live set that peaks at n_seqs * max_ctx/4 = 24 blocks
    # (admission cap 1.25 * 20 = 25 admits everything), so the replay
    # ages, reclaims and faults like the capture node did.  Replay never
    # pins, so the per-node pin-fit bound does not apply there.
    fleet_cfg = _scaled_node_cfg(cfg, 10)

    def loop(system: TaijiSystem, space) -> None:
        pyrng = random.Random(seed)
        nprng = np.random.default_rng(seed)
        cache = ElasticKVCache(geom, space)

        def token():
            return nprng.standard_normal(
                (geom.n_layers, 2, geom.kv_heads, geom.head_dim)
            ).astype(np.float16)

        for sid in range(n_seqs):
            cache.create_sequence(sid)
            for _ in range(prompt):
                cache.append_kv(sid, token())
        for _turn in range(turns):
            for sid in range(n_seqs):
                if cache.seq_len(sid) + gen > max_ctx:   # finished: recycle
                    cache.drop_sequence(sid)
                    cache.create_sequence(sid)
                    for _ in range(prompt):
                        cache.append_kv(sid, token())
            ids = pyrng.sample(range(n_seqs), batch)
            with cache.prepare_step(ids):                # pin + decode
                for _ in range(gen):
                    for sid in ids:
                        cache.append_kv(sid, token())
            vsid = pyrng.randrange(n_seqs)               # verification read
            nblocks = len(cache.blocks_of(vsid))
            if nblocks:
                cache.read_block(vsid, pyrng.randrange(nblocks))
            space.step_background(2)                     # age + reclaim

    return _capture("kv_serving", cfg, fleet_cfg, seed, loop)


def capture_expert_churn(seed: int = 13, *, n_experts: int = 10,
                         n_hot: int = 4, rounds: int = 10,
                         smoke: bool = False) -> CapturedTrace:
    """Capture MoE expert-weight churn through the elastic expert cache.

    Seeds all experts, then rounds of: routing skewed to a hot set
    (residency hints), dispatch pinning, weight updates for the routed
    experts (real fp32 payloads), a read-back of a random expert
    (faulting cold ones in), and stepped background aging.
    """
    if smoke:
        rounds = min(rounds, 6)
    shape = (24, 16)
    expert_bytes = int(np.prod(shape)) * 4
    cfg = make_expert_taiji_config(
        expert_bytes, n_hot, n_experts,
        lru=LRUConfig(scan_interval_s=0.001, workers=1, stabilize_scans=1),
        watermark=WatermarkConfig(high=0.5, low=0.3, min=0.05,
                                  reclaim_batch=2))
    # n_hot managed MSs per replay node: a 2-node fleet holds 2*n_hot=8
    # physical for 10 live experts -- still overcommitted (cold experts
    # genuinely swapped) while the admission cap (int(1.25*8) = 10)
    # admits every expert
    fleet_cfg = _scaled_node_cfg(cfg, n_hot)

    def loop(system: TaijiSystem, space) -> None:
        pyrng = random.Random(seed)
        nprng = np.random.default_rng(seed)
        cache = ElasticExpertCache(space, n_experts, shape, dtype=np.float32)
        weights = {e: nprng.standard_normal(shape).astype(np.float32)
                   for e in range(n_experts)}
        for e, w in weights.items():
            cache.put_expert(e, w)
        hot = list(range(n_hot))
        for _rnd in range(rounds):
            # routing skewed to the hot set plus an occasional cold pick
            active = sorted(set(pyrng.sample(hot, 2)
                                + [pyrng.randrange(n_experts)]))
            cache.note_routing(active)
            with cache.prepare_dispatch(active):
                pass                                    # the "step"
            for eid in active:                          # optimizer update
                weights[eid] = (weights[eid] + nprng.standard_normal(
                    shape).astype(np.float32) * 0.01)
                cache.put_expert(eid, weights[eid])
            cache.get_expert(pyrng.randrange(n_experts))  # verified read
            space.step_background(2)

    return _capture("expert_churn", cfg, fleet_cfg, seed, loop)
