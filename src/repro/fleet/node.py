"""NodeAgent -- one TaijiSystem as a member of a multi-node fleet.

The paper deploys Taiji "across more than 30,000 servers"; the fleet
layer reproduces the control-plane half of that claim. A NodeAgent wraps
one :class:`TaijiSystem` plus its hot-upgrade entry table (``tj.ko``
analogue) and exposes:

  * deterministic stepped operation -- ``step()`` is one background round
    (LRU scan shards + optionally one reclaim round), driven by the
    fleet controller's event loop instead of hv_sched threads, so fleet
    simulations are exactly reproducible on the single-core container;
  * periodic snapshots -- free-MS, watermark zone, swap/backend counters
    and the upgrade epoch, split into a byte-stable ``deterministic``
    view and a timing-dependent ``latency`` view;
  * per-node rolling-upgrade mechanics -- drain (stop serving), swap the
    engine module through ``core/hotupgrade.py``, resume -- which the
    controller sequences across failure domains.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Set, Type

from ..core.config import TaijiConfig
from ..core.errors import ABIMismatchError, InvalidStateError, TaijiError
from ..core.hotupgrade import EngineModule, EntryOps, hot_upgrade, install_module
from ..core.system import TaijiSystem
from ..obs.tracer import (ST_NODE_CALL, TAG_READ, TAG_READ_MANY, TAG_WRITE,
                          TAG_WRITE_MANY)

_perf_ns = time.perf_counter_ns

# pressure penalty per watermark zone: a node already reclaiming is a
# worse placement target than raw occupancy alone suggests
_ZONE_PENALTY = {"ok": 0.0, "band": 0.25, "low": 0.5, "critical": 1.0}


class NodeNotServingError(InvalidStateError):
    """Raised when guest traffic hits a node that is draining mid-upgrade."""


class NodeDeadError(InvalidStateError):
    """Raised when any traffic hits a killed node (chaos injection)."""


class _PendingUpgrade:
    __slots__ = ("module_cls", "rounds_left")

    def __init__(self, module_cls: Type[EngineModule], rounds: int) -> None:
        self.module_cls = module_cls
        self.rounds_left = rounds


class NodeAgent:
    def __init__(self, node_id: int, cfg: TaijiConfig,
                 failure_domain: int = 0) -> None:
        self.node_id = node_id
        self.cfg = cfg
        self.failure_domain = failure_domain
        self._boot()

        self.allocated: Set[int] = set()
        # remote-peer tier (ISSUE 9): gfns of THIS node that currently
        # have a replica leased out on a peer. Mirrors the controller's
        # lease registry so the guest write path can break a lease in
        # O(1) without asking the controller about every access;
        # `_lease_break` is installed by the FleetController.
        self.leased_gfns: Set[int] = set()
        self._lease_break = None
        self.alive = True                # False after chaos kill()
        self.recoveries = 0              # completed kill->recover cycles
        self.rounds = 0                  # stepped background rounds executed
        self.reclaim_windows = 0         # rounds in which reclaim fired
        self.upgrade_epoch = 0           # completed hot-upgrades
        self.upgrade_failed = False      # last upgrade attempt failed (ABI)
        self._upgrade: Optional[_PendingUpgrade] = None

    def _boot(self) -> None:
        """Fresh system bring-up, shared by __init__ and recover() so a
        recovered node boots exactly like a new one (GA module installed
        through the entry table).  Guest traffic goes through the node's
        canonical GuestSpace -- the one sanctioned surface -- so fleet
        replays hit the same instrumented path as the integrations."""
        self.system = TaijiSystem(self.cfg)
        self.space = self.system.guest
        self.entry = EntryOps()
        install_module(self.system, self.entry, EngineModule(self.system))
        # stage-attributed tracing (repro.obs): the node's tracer tags its
        # spans with the node id so a fleet Chrome trace shows one process
        # track per node; None when disabled. Re-runs on recover() -- a
        # rebooted node gets a fresh tracer like any other subsystem.
        tr = self.system.metrics.tracer
        if tr is not None:
            tr.pid = self.node_id
        self._tr = tr

    # -------------------------------------------------------------- serving
    @property
    def serving(self) -> bool:
        """False while dead or draining mid-upgrade: no guest traffic."""
        return self.alive and self._upgrade is None

    def _check_alive(self) -> None:
        if not self.alive:
            raise NodeDeadError(f"node {self.node_id} is dead")

    def _check_serving(self) -> None:
        self._check_alive()
        if self._upgrade is not None:
            raise NodeNotServingError(
                f"node {self.node_id} is draining for hot-upgrade")

    # ------------------------------------------------------------ kill/recover
    def kill(self) -> None:
        """Chaos injection: this node dies now.

        Its TaijiSystem is torn down (contents are gone, like a crashed
        server); ``allocated`` is left intact so the controller's failure
        recovery knows which committed MSs it must re-place. Idempotent.
        """
        if not self.alive:
            return
        self.alive = False
        self._upgrade = None             # a draining node dies mid-drain
        self.system.close()

    def recover(self) -> None:
        """Bring a killed node back as a fresh, empty, serving member.

        Boots a new TaijiSystem with the GA module installed (a replaced
        server PXE-boots the base image, not whatever was mid-rollout);
        lifetime counters (rounds, upgrade_epoch) survive the identity.
        """
        if self.alive:
            raise InvalidStateError(f"node {self.node_id} is not dead")
        self._boot()
        self.allocated = set()
        self.leased_gfns = set()
        self.upgrade_failed = False
        self.alive = True
        self.recoveries += 1

    # ------------------------------------------------------------- capacity
    @property
    def capacity_ms(self) -> int:
        """Allocatable virtual MSs (the guest-visible elastic space)."""
        return self.cfg.n_virt_ms - self.cfg.mpool_reserve_ms

    @property
    def managed_phys_ms(self) -> int:
        """Physical MSs backing guest memory (excludes the mpool arena)."""
        return self.cfg.n_phys_ms - self.cfg.mpool_reserve_ms

    @property
    def free_ms(self) -> int:
        return self.system.phys.free_count

    def pressure(self) -> float:
        """Placement score: physical occupancy plus a watermark-zone
        penalty, so admission steers new load away from nodes that are
        already reclaiming (or worse, in fault-path reclaim)."""
        free = self.free_ms
        occupancy = 1.0 - free / max(1, self.managed_phys_ms)
        return occupancy + _ZONE_PENALTY[self.system.watermark.zone(free)]

    # -------------------------------------------------------- guest traffic
    def alloc_ms(self) -> int:
        self._check_serving()
        gfn = self.space.alloc_ms()
        self.allocated.add(gfn)
        return gfn

    def free_ms_gfn(self, gfn: int) -> None:
        self._check_serving()
        self._maybe_break_lease(gfn)     # the replicated content dies here
        self.space.free_ms(gfn)
        self.allocated.discard(gfn)

    def _maybe_break_lease(self, gfn: int) -> None:
        """Invalidate the remote replica before a content-changing op.

        Write-path cost when nothing is leased: one truthiness check on
        an empty set. Conservative ordering -- the lease breaks *before*
        the mutation, so a failed write can at worst drop a still-valid
        replica (data stays authoritative on this node), never leave a
        stale replica behind.
        """
        if self.leased_gfns and gfn in self.leased_gfns \
                and self._lease_break is not None:
            self._lease_break(self, gfn)

    def write_mp(self, gfn: int, mp: int, data: bytes) -> None:
        self.write_at(gfn, mp * self.cfg.mp_bytes, data)

    def read_mp(self, gfn: int, mp: int,
                nbytes: Optional[int] = None) -> bytes:
        n = self.cfg.mp_bytes if nbytes is None else nbytes
        return self.read_at(gfn, mp * self.cfg.mp_bytes, n)

    def write_at(self, gfn: int, off: int, data: bytes) -> None:
        """Byte-granular guest write (captured-trace payload replay)."""
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        self._check_serving()
        self._maybe_break_lease(gfn)
        self.space.write(gfn, data, off=off)
        if tr is not None:
            tr.push(ST_NODE_CALL, t0, _perf_ns() - t0, TAG_WRITE)

    def read_at(self, gfn: int, off: int, nbytes: int) -> bytes:
        """Byte-granular guest read (captured-trace read-verify)."""
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        self._check_serving()
        data = self.space.read(gfn, nbytes, off=off)
        if tr is not None:
            tr.push(ST_NODE_CALL, t0, _perf_ns() - t0, TAG_READ)
        return data

    def write_many(self, items) -> None:
        """Batched guest writes over (gfn, off, data) triples: one
        serving check + one GuestSpace batch call for the whole vector
        (the fleet wrapper's per-access share was a measurable slice of
        fleet swap-in p90 vs single-box)."""
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        self._check_serving()
        if self.leased_gfns:
            for gfn, _off, _data in items:
                self._maybe_break_lease(gfn)
        self.space.write_many(items)
        if tr is not None:
            tr.push(ST_NODE_CALL, t0, _perf_ns() - t0, TAG_WRITE_MANY)

    def read_many(self, reqs) -> list:
        """Batched guest reads over (gfn, off, nbytes) triples; see
        :meth:`write_many`."""
        tr = self._tr
        if tr is not None:
            t0 = _perf_ns()
        self._check_serving()
        out = self.space.read_many(reqs)
        if tr is not None:
            tr.push(ST_NODE_CALL, t0, _perf_ns() - t0, TAG_READ_MANY)
        return out

    # --------------------------------------------------- migration (control)
    def export_ms(self, gfn: int):
        """Non-consuming MS image for migration (see TaijiSystem.export_ms).

        Control-plane path: works on a draining (mid-upgrade) node too --
        decommissioning must be able to move data off a node that is not
        taking guest traffic -- but never on a dead one.
        """
        self._check_alive()
        if gfn not in self.allocated:
            raise InvalidStateError(
                f"gfn {gfn} is not allocated on node {self.node_id}")
        return self.system.export_ms(gfn)

    def import_ms(self, rows, resident) -> int:
        """Admit one exported MS image (requires a serving node)."""
        self._check_serving()
        gfn = self.system.import_ms(rows, resident)
        self.allocated.add(gfn)
        return gfn

    def evict_ms(self, gfn: int) -> None:
        """Control-plane teardown of one MS (migration source drop).

        Bypasses the serving gate -- a draining node can still be drained
        of data -- and drops the MS's backend entries through the normal
        free path so the compression accounting returns to baseline.
        """
        self._check_alive()
        self._maybe_break_lease(gfn)
        self.system.guest_free_ms(gfn)
        self.allocated.discard(gfn)

    # ----------------------------------------------------- stepped background
    def step(self, *, reclaim: bool = True) -> int:
        """One deterministic background round.

        Draining nodes only advance their upgrade countdown (the module
        swap happens at the end of the drain); serving nodes run every
        LRU scan shard and, when the controller's stagger window allows,
        one reclaim round -- routed through the entry table so an
        upgraded module's reclaim implementation takes over seamlessly.
        """
        if not self.alive:
            return 0
        self.rounds += 1
        if self._upgrade is not None:
            self._upgrade.rounds_left -= 1
            if self._upgrade.rounds_left <= 0:
                self._finish_upgrade()
            return 0
        self.system.step_background(reclaim=False)    # LRU scan shards only
        if not reclaim:
            return 0
        self.reclaim_windows += 1
        return int(self.entry.call("reclaim_round"))

    # ----------------------------------------------------------- hot-upgrade
    def begin_upgrade(self, module_cls: Type[EngineModule],
                      drain_rounds: int = 1) -> None:
        if self._upgrade is not None:
            raise InvalidStateError(f"node {self.node_id} already upgrading")
        self._upgrade = _PendingUpgrade(module_cls, max(1, drain_rounds))

    def _finish_upgrade(self) -> None:
        assert self._upgrade is not None
        module_cls = self._upgrade.module_cls
        try:
            hot_upgrade(self.system, self.entry, module_cls(self.system))
        except (ABIMismatchError, TaijiError):
            self.upgrade_failed = True
        else:
            self.upgrade_failed = False
            self.upgrade_epoch += 1
        finally:
            self._upgrade = None

    @property
    def module_version(self) -> int:
        return self.system.module_version

    def health_probe(self) -> bool:
        """Deterministic post-upgrade self-check.

        Pushes one MS through the full data path of the (possibly new)
        module: alloc, write a marker, active swap-out through the entry
        table, fault it back in, verify bytes, free. Abort-on-regression
        for the rolling upgrade keys off this (plus the optional latency
        guard in the controller).
        """
        if self.upgrade_failed:
            return False
        if len(self.allocated) >= self.capacity_ms:
            return True                  # no room for a probe: version-only
        marker = bytes([(0x5A + self.node_id) & 0xFF]) * 32
        try:
            gfn = self.alloc_ms()
            try:
                self.write_mp(gfn, 0, marker)
                self.entry.call("swap_out_ms", gfn)
                ok = self.read_mp(gfn, 0, len(marker)) == marker
            finally:
                self.free_ms_gfn(gfn)
        except TaijiError:
            return False
        return ok and self.system.metrics.crc_failures == 0

    # -------------------------------------------------------------- snapshot
    def snapshot(self) -> Dict[str, object]:
        if not self.alive:
            # dead nodes have no system to snapshot: a minimal byte-stable
            # view keeps chaos replays comparable without try/except
            return {
                "deterministic": {
                    "node_id": self.node_id,
                    "failure_domain": self.failure_domain,
                    "alive": False,
                    "serving": False,
                    "allocated_ms": len(self.allocated),
                    "rounds": self.rounds,
                    "reclaim_windows": self.reclaim_windows,
                    "upgrade_epoch": self.upgrade_epoch,
                    "upgrade_failed": self.upgrade_failed,
                    "recoveries": self.recoveries,
                },
                "latency": {},
            }
        s = self.system.snapshot()
        s["deterministic"].update(
            node_id=self.node_id,
            failure_domain=self.failure_domain,
            alive=True,
            serving=self.serving,
            allocated_ms=len(self.allocated),
            rounds=self.rounds,
            reclaim_windows=self.reclaim_windows,
            upgrade_epoch=self.upgrade_epoch,
            upgrade_failed=self.upgrade_failed,
            recoveries=self.recoveries,
        )
        return s

    def close(self) -> None:
        if self.alive:                   # a killed node is already closed
            self.system.close()
