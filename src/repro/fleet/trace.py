"""Trace-driven workload replay (tracehm-style TSV traces).

A trace is a TSV file: one header comment carrying the parameters every
replayer needs to reproduce payloads byte-for-byte, then one op per line:

    # taiji-trace v1 seed=7 ms_bytes=16384 mps_per_ms=8 zero=0.60 comp=0.25
    0	alloc	12	0
    1	touch	0x30800	1
    2	tick	6	0
    3	touch	0x30800	0
    4	upgrade	2	0
    5	free	12	0

Columns are ``seq, op, ms/addr, is_write``:

  * ``alloc``/``free`` -- arg is a trace-level MS *token*; the replayer
    maps tokens to (node, gfn) through the fleet controller's admission
    path, so the trace itself is placement-agnostic.
  * ``touch``  -- arg is a hex address ``token*ms_bytes + mp*mp_bytes``;
    ``is_write`` selects guest write (payload derived deterministically
    from the header seed) vs. guest read (faulting swapped MPs back in).
  * ``tick``   -- arg fleet controller rounds to run (BACK phases: LRU
    aging + staggered reclaim windows).
  * ``upgrade``-- start a rolling hot-upgrade; arg is the per-node drain
    duration in rounds.
  * ``kill``   -- chaos: kill node ``arg``; ``is_write=1`` means drained
    (graceful decommission: MSs live-migrate off first), 0 a hard crash
    (contents lost; the controller re-places committed MSs on the next
    tick).
  * ``recover``-- chaos: bring node ``arg`` back, fresh and empty.
  * ``migrate``-- live-migrate MS token ``arg`` to the least-pressured
    other node (controller placement, read-verified).

Captured application workloads (ISSUE 5) add two *payload* ops with a
fifth column:

    7	wdata	0x30880	1	eJzLSM3JyQcABiwCFQ==
    8	rdata	0x30880	0	64:9c2e5a31

  * ``wdata`` -- a real guest write captured at the access layer
    (:class:`TraceRecorder` on a ``GuestSpace``); the column carries the
    actual bytes (zlib+base64), so replays rewrite the application's
    data -- real KV blocks, expert weights -- byte-identically instead
    of deriving pages from the header seed.
  * ``rdata`` -- a captured guest read; the column carries
    ``nbytes:crc32`` of what the application saw, so every replayed read
    is verified against the capture-time content.

Everything is seeded and single-threaded (round-based), so replaying the
same trace twice yields byte-identical deterministic snapshots -- the
failure schedule is part of the trace, so chaos replays deterministically
too.
"""
from __future__ import annotations

import base64
import binascii
import json
import random
import zlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from ..analysis.lock_order import named_lock
from ..core.guest import GuestObserver

TRACE_MAGIC = "taiji-trace v1"

OP_ALLOC = "alloc"
OP_FREE = "free"
OP_TOUCH = "touch"
OP_TICK = "tick"
OP_UPGRADE = "upgrade"
# chaos ops (ISSUE 4): the failure schedule is part of the trace, so two
# replays of the same trace see byte-identical failures
OP_KILL = "kill"          # arg node_id; is_write=1 -> drained (graceful)
OP_RECOVER = "recover"    # arg node_id
OP_MIGRATE = "migrate"    # arg MS token; controller picks the destination
# captured-workload payload ops (ISSUE 5): column 5 carries content
OP_WDATA = "wdata"        # arg byte addr; payload = zlib+base64 bytes
OP_RDATA = "rdata"        # arg byte addr; payload = "nbytes:crc32hex"

PAYLOAD_OPS = frozenset((OP_WDATA, OP_RDATA))
_HEX_OPS = frozenset((OP_TOUCH, OP_WDATA, OP_RDATA))

# paper Fig 15c production mix: 76.79% zero pages, 23.21% compressed at
# ~47.63% ratio. The generator defaults add an incompressible tail so the
# backend's raw branch is exercised too.
DEFAULT_ZERO_FRAC = 0.60
DEFAULT_COMP_FRAC = 0.25

K_PAGE_ZERO, K_PAGE_COMP, K_PAGE_RAND = "zero", "comp", "rand"


# --------------------------------------------------------------- payloads
def _page_hash(seed: int, token: int, mp: int) -> int:
    return zlib.crc32(f"{seed}/{token}/{mp}".encode())


def page_kind(seed: int, token: int, mp: int,
              zero_frac: float, comp_frac: float) -> str:
    """Deterministic page class for (trace, token, mp) -- no RNG state."""
    u = (_page_hash(seed, token, mp) & 0xFFFFFF) / float(1 << 24)
    if u < zero_frac:
        return K_PAGE_ZERO
    if u < zero_frac + comp_frac:
        return K_PAGE_COMP
    return K_PAGE_RAND


def page_bytes(seed: int, token: int, mp: int, mp_bytes: int,
               zero_frac: float, comp_frac: float) -> bytes:
    """The payload a ``touch`` write carries: purely a function of the
    trace header + address, so generator, replayer and verifier agree."""
    kind = page_kind(seed, token, mp, zero_frac, comp_frac)
    if kind == K_PAGE_ZERO:
        return bytes(mp_bytes)
    h = _page_hash(seed, token, mp)
    rng = np.random.default_rng(h)
    if kind == K_PAGE_COMP:
        # ~50%-compressible: structured half + incompressible half
        structured = np.full(mp_bytes // 2, h & 0xFF, np.uint8)
        noise = rng.integers(0, 256, mp_bytes - mp_bytes // 2, dtype=np.int64)
        return structured.tobytes() + noise.astype(np.uint8).tobytes()
    return rng.integers(0, 256, mp_bytes, dtype=np.int64).astype(
        np.uint8).tobytes()


def touch_addr(token: int, mp: int, ms_bytes: int, mp_bytes: int) -> int:
    return token * ms_bytes + mp * mp_bytes


# ------------------------------------------------------------------ format
class TraceHeader:
    def __init__(self, seed: int, ms_bytes: int, mps_per_ms: int,
                 zero_frac: float, comp_frac: float) -> None:
        if mps_per_ms < 1:
            raise ValueError(f"mps_per_ms must be >= 1, got {mps_per_ms}")
        if ms_bytes <= 0 or ms_bytes % mps_per_ms:
            raise ValueError(
                f"ms_bytes ({ms_bytes}) must be a positive multiple of "
                f"mps_per_ms ({mps_per_ms})")
        self.seed = seed
        self.ms_bytes = ms_bytes
        self.mps_per_ms = mps_per_ms
        self.mp_bytes = ms_bytes // mps_per_ms
        self.zero_frac = zero_frac
        self.comp_frac = comp_frac

    def line(self) -> str:
        return (f"# {TRACE_MAGIC} seed={self.seed} ms_bytes={self.ms_bytes} "
                f"mps_per_ms={self.mps_per_ms} zero={self.zero_frac:.6g} "
                f"comp={self.comp_frac:.6g}")

    @classmethod
    def parse(cls, line: str) -> "TraceHeader":
        if TRACE_MAGIC not in line:
            raise ValueError(f"not a taiji trace header: {line!r}")
        kv = dict(tok.split("=", 1) for tok in line.split() if "=" in tok)
        try:
            return cls(seed=int(kv["seed"]), ms_bytes=int(kv["ms_bytes"]),
                       mps_per_ms=int(kv["mps_per_ms"]),
                       zero_frac=float(kv["zero"]),
                       comp_frac=float(kv["comp"]))
        except KeyError as e:
            raise ValueError(
                f"trace header missing key {e.args[0]}: {line!r}") from None
        except ValueError as e:
            raise ValueError(f"malformed trace header {line!r}: {e}") from None


def format_line(seq: int, op: str, arg: int, is_write: int,
                payload: str = "") -> str:
    arg_s = f"0x{arg:x}" if op in _HEX_OPS else str(arg)
    if op in PAYLOAD_OPS:
        return f"{seq}\t{op}\t{arg_s}\t{is_write}\t{payload}"
    return f"{seq}\t{op}\t{arg_s}\t{is_write}"


def parse_line(line: str) -> Tuple[int, str, int, int, str]:
    """Parse one op line into ``(seq, op, arg, is_write, payload)``.

    ``payload`` is the fifth column of the captured-workload ops
    (``wdata``/``rdata``) and ``""`` otherwise; a fifth column on any
    other op -- or a payload op without one -- is malformed.
    """
    parts = line.rstrip("\n").split("\t")
    if len(parts) not in (4, 5):
        raise ValueError(
            f"malformed trace line (want 4 or 5 tab-separated columns, "
            f"got {len(parts)}): {line!r}")
    seq_s, op, arg_s, w_s = parts[:4]
    payload = parts[4] if len(parts) == 5 else ""
    if (len(parts) == 5) != (op in PAYLOAD_OPS) or (op in PAYLOAD_OPS
                                                    and not payload):
        raise ValueError(
            f"payload column is required for {sorted(PAYLOAD_OPS)} ops "
            f"and forbidden otherwise: {line!r}")
    try:
        seq = int(seq_s)
        arg = int(arg_s, 16 if arg_s.startswith("0x") else 10)
        w = int(w_s)
    except ValueError as e:
        raise ValueError(f"malformed trace line {line!r}: {e}") from None
    if w not in (0, 1):
        raise ValueError(f"is_write must be 0 or 1 in {line!r}")
    return seq, op, arg, w, payload


def encode_payload(data: bytes) -> str:
    """Write payload wire form: zlib+base64 (tab-free single token)."""
    return base64.b64encode(zlib.compress(bytes(data), 6)).decode("ascii")


def decode_payload(payload: str) -> bytes:
    try:
        return zlib.decompress(base64.b64decode(payload, validate=True))
    except (binascii.Error, zlib.error, ValueError) as e:
        raise ValueError(f"malformed wdata payload: {e}") from None


def encode_read_check(data: bytes) -> str:
    """Read-verify wire form: ``nbytes:crc32hex`` of the bytes read."""
    return f"{len(data)}:{zlib.crc32(data) & 0xFFFFFFFF:08x}"


def decode_read_check(payload: str) -> Tuple[int, int]:
    try:
        nbytes_s, crc_s = payload.split(":", 1)
        nbytes, crc = int(nbytes_s), int(crc_s, 16)
    except ValueError as e:
        raise ValueError(f"malformed rdata check: {e}") from None
    if nbytes < 0:
        raise ValueError(f"malformed rdata check (negative size): {payload!r}")
    return nbytes, crc


# --------------------------------------------------------------- generator
class TraceGen:
    """Synthesizes the paper's workload shapes as a seeded trace.

    Phases compose: FRONT fill (allocs + page-mix writes), BACK aging
    (ticks that age the LRU and fire staggered reclaim windows), fault
    bursts (Zipf-popular reads over the filled set, faulting swapped MPs
    back in), churn (free/realloc) and a rolling hot-upgrade marker.
    """

    def __init__(self, seed: int, ms_bytes: int, mps_per_ms: int,
                 zero_frac: float = DEFAULT_ZERO_FRAC,
                 comp_frac: float = DEFAULT_COMP_FRAC) -> None:
        self.header = TraceHeader(seed, ms_bytes, mps_per_ms,
                                  zero_frac, comp_frac)
        self._rng = random.Random(seed)
        self._ops: List[Tuple[str, int, int]] = []
        self._next_token = 0
        self._live: List[int] = []

    # ------------------------------------------------------------- phases
    def front_fill(self, n_ms: int, write_frac: float = 1.0) -> List[int]:
        """FRONT phase: allocate ``n_ms`` sections, write the page mix."""
        hdr = self.header
        tokens = []
        for _ in range(n_ms):
            token = self._next_token
            self._next_token += 1
            self._ops.append((OP_ALLOC, token, 0))
            self._live.append(token)
            tokens.append(token)
            for mp in range(hdr.mps_per_ms):
                if write_frac >= 1.0 or self._rng.random() < write_frac:
                    addr = touch_addr(token, mp, hdr.ms_bytes, hdr.mp_bytes)
                    self._ops.append((OP_TOUCH, addr, 1))
        return tokens

    def back_phase(self, n_ticks: int) -> None:
        """BACK phase: controller rounds only (aging + reclaim windows)."""
        self._ops.append((OP_TICK, n_ticks, 0))

    def fault_burst(self, n_touches: int, zipf_a: float = 1.2,
                    tick_every: int = 0) -> None:
        """Read burst with Zipf MS popularity and sequential MP locality."""
        hdr = self.header
        if not self._live:
            return
        ranks = np.arange(1, len(self._live) + 1, dtype=np.float64)
        pop = 1.0 / ranks ** zipf_a
        weights = list(pop / pop.sum())
        cursor: Dict[int, int] = {}
        for i in range(n_touches):
            token = self._rng.choices(self._live, weights=weights)[0]
            mp = cursor.get(token, 0) % hdr.mps_per_ms
            cursor[token] = mp + 1
            addr = touch_addr(token, mp, hdr.ms_bytes, hdr.mp_bytes)
            self._ops.append((OP_TOUCH, addr, 0))
            if tick_every and (i + 1) % tick_every == 0:
                self._ops.append((OP_TICK, 1, 0))

    def churn(self, n_frees: int, n_allocs: int) -> None:
        """Free a seeded sample, then re-allocate fresh sections."""
        n_frees = min(n_frees, len(self._live))
        for token in self._rng.sample(self._live, n_frees):
            self._live.remove(token)
            self._ops.append((OP_FREE, token, 0))
        self.front_fill(n_allocs)

    def rolling_upgrade(self, drain_rounds: int = 2,
                        settle_ticks: int = 8) -> None:
        """Rolling hot-upgrade marker + enough ticks to complete it."""
        self._ops.append((OP_UPGRADE, drain_rounds, 0))
        if settle_ticks:
            self._ops.append((OP_TICK, settle_ticks, 0))

    # -------------------------------------------------------- chaos phases
    def kill_node(self, node_id: int, *, drain: bool = False,
                  settle_ticks: int = 2) -> None:
        """Chaos op: kill a node (``drain`` = migrate its MSs off first);
        the settle ticks let the controller run failure recovery."""
        self._ops.append((OP_KILL, node_id, 1 if drain else 0))
        if settle_ticks:
            self._ops.append((OP_TICK, settle_ticks, 0))

    def recover_node(self, node_id: int, settle_ticks: int = 1) -> None:
        """Chaos op: bring a killed node back (fresh and empty)."""
        self._ops.append((OP_RECOVER, node_id, 0))
        if settle_ticks:
            self._ops.append((OP_TICK, settle_ticks, 0))

    def migrate(self, token: int) -> None:
        """Live-migrate one MS token (replay-side controller placement)."""
        self._ops.append((OP_MIGRATE, token, 0))

    def migrate_sample(self, n: int) -> List[int]:
        """Migrate a seeded sample of live tokens."""
        n = min(n, len(self._live))
        tokens = self._rng.sample(self._live, n)
        for token in tokens:
            self.migrate(token)
        return tokens

    # -------------------------------------------------------------- output
    def lines(self) -> List[str]:
        out = [self.header.line()]
        out.extend(format_line(i, op, arg, w)
                   for i, (op, arg, w) in enumerate(self._ops))
        return out

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write("\n".join(self.lines()) + "\n")

    @property
    def n_ops(self) -> int:
        return len(self._ops)


class _Coverage:
    """Merged, sorted, disjoint ``[start, end)`` byte intervals of one MS
    whose replay-side content is known (written during capture, or
    zero-filled by a capture-time alloc)."""

    __slots__ = ("iv",)

    def __init__(self, iv: Optional[List[Tuple[int, int]]] = None) -> None:
        self.iv: List[Tuple[int, int]] = iv or []

    def add(self, start: int, end: int) -> None:
        if end <= start:
            return
        merged: List[Tuple[int, int]] = []
        for s, e in self.iv:
            if e < start or s > end:         # disjoint (touching merges)
                merged.append((s, e))
            else:
                start, end = min(s, start), max(e, end)
        merged.append((start, end))
        merged.sort()
        self.iv = merged

    def gaps(self, start: int, end: int) -> List[Tuple[int, int]]:
        """Uncovered subranges of ``[start, end)``, in order."""
        out: List[Tuple[int, int]] = []
        cur = start
        for s, e in self.iv:
            if e <= cur:
                continue
            if s >= end:
                break
            if s > cur:
                out.append((cur, s))
            cur = max(cur, e)
            if cur >= end:
                return out
        if cur < end:
            out.append((cur, end))
        return out


class TraceRecorder(GuestObserver):
    """Capture observer: renders live guest traffic on a ``GuestSpace``
    into a replayable fleet trace (tracehm's record-at-the-access-layer
    design).

    Attach to the canonical space of the system an application drives::

        rec = space.attach(TraceRecorder.for_space(space))
        ... run the serving loop ...
        lines = rec.lines()            # replayable on any fleet

    Allocs/frees become placement-agnostic token ops; writes become
    ``wdata`` ops carrying the application's actual bytes; reads become
    ``rdata`` ops carrying a content hash, so a replay verifies every
    read against what the application really saw; zero-length residency
    hints (batched touch, step pins) become ``touch`` reads; background
    rounds become ``tick`` ops.

    Partial captures replay byte-identically: an MS allocated *before*
    capture started is registered lazily on first access with empty
    write coverage, and a read of any not-yet-covered range first emits
    a ``wdata`` re-establishing the observed bytes -- the replay cannot
    know pre-capture content any other way.  MSs allocated during
    capture start fully covered (alloc zero-fills on both sides).
    """

    def __init__(self, ms_bytes: int, mps_per_ms: int, *,
                 seed: int = 0) -> None:
        # zero/comp fracs are meaningless for captured payloads; the
        # header keeps them only so seed-derived touch writes (if any
        # are spliced in) stay well-defined
        self.header = TraceHeader(seed, ms_bytes, mps_per_ms, 0.0, 0.0)
        self._lock = named_lock("metrics")
        self._token: Dict[int, int] = {}     # live gfn -> trace token
        self._cov: Dict[int, _Coverage] = {}  # token -> known-content ranges
        self._next_token = 0
        self._ops: List[Tuple[str, int, int, str]] = []

    @classmethod
    def for_space(cls, space, *, seed: int = 0) -> "TraceRecorder":
        return cls(space.cfg.ms_bytes, space.cfg.mps_per_ms, seed=seed)

    # ------------------------------------------------------ observer hooks
    def on_alloc(self, gfn: int) -> None:
        with self._lock:
            # a capture-time alloc is zero-filled at replay too: the
            # whole MS counts as known content
            self._register(gfn, covered=True)

    def on_free(self, gfn: int) -> None:
        with self._lock:
            token = self._token.pop(gfn, None)
            if token is not None:
                self._cov.pop(token, None)
                self._ops.append((OP_FREE, token, 0, ""))

    def on_access(self, gfn: int, off: int, nbytes: int, is_write: bool,
                  data: Optional[bytes] = None) -> None:
        with self._lock:
            token = self._token_of(gfn)
            addr = token * self.header.ms_bytes + off
            if nbytes == 0:                  # residency hint (touch / pin)
                self._ops.append((OP_TOUCH, addr, 0, ""))
                return
            cov = self._cov[token]
            if is_write:
                cov.add(off, off + nbytes)
                self._ops.append((OP_WDATA, addr, 1, encode_payload(data)))
                return
            # pre-capture content (lazily-registered MS, or any range no
            # recorded write covers) must be re-established before the
            # read can verify -- emit the observed bytes as wdata first
            for gs, ge in cov.gaps(off, off + nbytes):
                self._ops.append((
                    OP_WDATA, token * self.header.ms_bytes + gs, 1,
                    encode_payload(data[gs - off:ge - off])))
            cov.add(off, off + nbytes)
            self._ops.append((OP_RDATA, addr, 0, encode_read_check(data)))

    def on_tick(self, rounds: int) -> None:
        with self._lock:
            self._ops.append((OP_TICK, rounds, 0, ""))

    def _register(self, gfn: int, *, covered: bool) -> int:
        token = self._next_token
        self._next_token += 1
        self._token[gfn] = token
        self._cov[token] = _Coverage(
            [(0, self.header.ms_bytes)] if covered else None)
        self._ops.append((OP_ALLOC, token, 0, ""))
        return token

    def _token_of(self, gfn: int) -> int:
        token = self._token.get(gfn)
        if token is None:                    # allocated before capture began
            token = self._register(gfn, covered=False)
        return token

    # -------------------------------------------------------------- output
    def lines(self) -> List[str]:
        with self._lock:
            return [self.header.line()] + [
                format_line(i, op, arg, w, payload)
                for i, (op, arg, w, payload) in enumerate(self._ops)]

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write("\n".join(self.lines()) + "\n")

    @property
    def n_ops(self) -> int:
        with self._lock:
            return len(self._ops)

    def op_counts(self) -> Dict[str, int]:
        """Recorded ops by kind (e.g. ``{"alloc": 3, "wdata": 12, ...}``)."""
        with self._lock:
            counts: Dict[str, int] = {}
            for op, _arg, _w, _payload in self._ops:
                counts[op] = counts.get(op, 0) + 1
            return counts


class TraceReplayer:
    """Deterministic seeded trace replay through a fleet controller.

    Single-threaded, round-based: trace lines are applied in order, so
    two replays of the same trace through identically-configured fleets
    produce byte-identical deterministic snapshots. Placement is decided
    live by the controller's admission path; tokens that were rejected at
    admission simply drop their later touches (counted, like a guest VM
    that was never scheduled onto the fleet).
    """

    def __init__(self, controller, lines: Iterable[str], *,
                 upgrade_module_cls=None, verify_reads: bool = True) -> None:
        from ..core.hotupgrade import EngineModuleV2
        from .controller import REJECT_NO_CAPACITY, REJECT_OVERCOMMIT
        from .node import NodeDeadError, NodeNotServingError
        self._not_serving_exc = NodeNotServingError
        self._dead_exc = NodeDeadError
        self.controller = controller
        self.upgrade_module_cls = upgrade_module_cls or EngineModuleV2
        self.verify_reads = verify_reads
        # failure recovery + drain migrations remap (node, gfn) pairs; the
        # listener keeps the token map and the written-set in sync
        controller.remap_listener = self._on_remap

        lines = [ln for ln in lines if ln.strip()]
        if not lines or not lines[0].startswith("#"):
            raise ValueError("trace must start with a header comment")
        self.header = TraceHeader.parse(lines[0])
        self._body = [ln for ln in lines[1:] if not ln.startswith("#")]

        self.placed: Dict[int, Tuple[object, int]] = {}   # token -> (node, gfn)
        self._loc: Dict[Tuple[int, int], int] = {}  # (node_id, gfn) -> token
        # token -> written MP set: keyed by token so frees, hard-kill
        # re-placements and losses forget a whole token in one pop
        self.written: Dict[int, Set[int]] = {}
        # tokens whose captured payload content died with a node (hard
        # kill re-placed them as fresh zeroed MSs): rdata content checks
        # must not expect the capture-time bytes any more
        self.payload_lost: Set[int] = set()
        self.counters: Dict[str, int] = {
            "ops": 0, "allocs": 0, "frees": 0, "reads": 0, "writes": 0,
            "ticks": 0, "upgrades": 0, "touch_unplaced": 0,
            "touch_not_serving": 0, "free_not_serving": 0,
            "verify_failures": 0,
            "payload_writes": 0, "payload_reads": 0,
            "payload_verify_skipped": 0,
            "kills": 0, "recovers": 0,
            "migrations": 0, "migrate_rejected": 0, "migrate_unplaced": 0,
            "touch_dead": 0, "free_dead": 0,
            "ms_migrated": 0, "ms_replaced": 0, "ms_lost": 0,
            "reject_" + REJECT_OVERCOMMIT: 0,
            "reject_" + REJECT_NO_CAPACITY: 0,
        }

    # --------------------------------------------------------------- replay
    def run(self) -> Dict[str, object]:
        for line in self._body:
            _seq, op, arg, is_write, payload = parse_line(line)
            self.counters["ops"] += 1
            if op == OP_ALLOC:
                self._op_alloc(arg)
            elif op == OP_FREE:
                self._op_free(arg)
            elif op == OP_TOUCH:
                self._op_touch(arg, is_write)
            elif op == OP_WDATA:
                self._op_wdata(arg, payload)
            elif op == OP_RDATA:
                self._op_rdata(arg, payload)
            elif op == OP_TICK:
                for _ in range(arg):
                    self.controller.tick()
                self.counters["ticks"] += arg
            elif op == OP_UPGRADE:
                self.controller.start_rolling_upgrade(
                    self.upgrade_module_cls, drain_rounds=arg)
                self.counters["upgrades"] += 1
            elif op == OP_KILL:
                self.controller.kill_node(arg, drain=bool(is_write))
                self.counters["kills"] += 1
            elif op == OP_RECOVER:
                self.controller.recover_node(arg)
                self.counters["recovers"] += 1
            elif op == OP_MIGRATE:
                self._op_migrate(arg)
            else:
                raise ValueError(f"unknown trace op {op!r}: {line!r}")
        return self.result()

    # -------------------------------------------------------- chaos remaps
    def _on_remap(self, src_node, old_gfn: int, dst_node,
                  new_gfn, preserved: bool) -> None:
        """Controller notification: an MS moved (migration, preserved) or
        was re-placed fresh / lost (failure recovery)."""
        token = self._loc.pop((src_node.node_id, old_gfn), None)
        if token is None:
            return                       # not a replayer-tracked MS
        if dst_node is None:             # lost with the node: no capacity
            self.placed.pop(token, None)
            self.counters["ms_lost"] += 1
            self.written.pop(token, None)
            self.payload_lost.discard(token)
            return
        self.placed[token] = (dst_node, new_gfn)
        self._loc[(dst_node.node_id, new_gfn)] = token
        if preserved:
            self.counters["ms_migrated"] += 1
        else:
            # hard-kill re-placement: a fresh zeroed MS -- prior writes
            # are gone, so read-verify (seed-derived AND captured-payload
            # content checks) must not expect them
            self.counters["ms_replaced"] += 1
            self.written.pop(token, None)
            self.payload_lost.add(token)

    def _op_migrate(self, token: int) -> None:
        placed = self.placed.get(token)
        if placed is None:
            self.counters["migrate_unplaced"] += 1
            return
        node, gfn = placed
        dst, _new_gfn, _reason = self.controller.migrate_ms(node, gfn)
        if dst is None:
            self.counters["migrate_rejected"] += 1
        else:
            self.counters["migrations"] += 1   # map updated via _on_remap

    def _op_alloc(self, token: int) -> None:
        node, gfn, reason = self.controller.admit_alloc()
        self.counters["allocs"] += 1
        if node is None:
            key = "reject_" + reason
            self.counters[key] = self.counters.get(key, 0) + 1
            return
        self.placed[token] = (node, gfn)
        self._loc[(node.node_id, gfn)] = token

    def _op_free(self, token: int) -> None:
        placed = self.placed.pop(token, None)
        if placed is None:
            return
        node, gfn = placed
        try:
            node.free_ms_gfn(gfn)
        except self._dead_exc:
            # the owner died and recovery has not settled yet: the free is
            # lost traffic; the tick-driven re-placement will remap it
            self.counters["free_dead"] += 1
            self.placed[token] = placed
            return
        except self._not_serving_exc:
            # the owner is draining: the free is lost traffic, like any
            # other op against a mid-upgrade node; its data stays live
            self.counters["free_not_serving"] += 1
            self.placed[token] = placed
            return
        self.counters["frees"] += 1
        self._loc.pop((node.node_id, gfn), None)
        self.written.pop(token, None)
        self.payload_lost.discard(token)

    def _op_touch(self, addr: int, is_write: int) -> None:
        hdr = self.header
        token = addr // hdr.ms_bytes
        mp = (addr % hdr.ms_bytes) // hdr.mp_bytes
        placed = self.placed.get(token)
        if placed is None:
            self.counters["touch_unplaced"] += 1
            return
        node, gfn = placed
        try:
            if is_write:
                node.write_mp(gfn, mp, page_bytes(
                    hdr.seed, token, mp, hdr.mp_bytes,
                    hdr.zero_frac, hdr.comp_frac))
                self.written.setdefault(token, set()).add(mp)
                self.counters["writes"] += 1
            else:
                got = node.read_mp(gfn, mp)
                self.counters["reads"] += 1
                if self.verify_reads and mp in self.written.get(token, ()):
                    want = page_bytes(hdr.seed, token, mp, hdr.mp_bytes,
                                      hdr.zero_frac, hdr.comp_frac)
                    if got != want:
                        self.counters["verify_failures"] += 1
        except self._dead_exc:
            self.counters["touch_dead"] += 1
        except self._not_serving_exc:
            self.counters["touch_not_serving"] += 1

    # ------------------------------------------------- captured payload ops
    def _locate(self, addr: int):
        """(node, gfn, byte offset) for a captured payload address, or
        ``None`` (counted like any other unplaced touch)."""
        token, off = divmod(addr, self.header.ms_bytes)
        placed = self.placed.get(token)
        if placed is None:
            self.counters["touch_unplaced"] += 1
            return None
        node, gfn = placed
        return node, gfn, off

    def _op_wdata(self, addr: int, payload: str) -> None:
        loc = self._locate(addr)
        if loc is None:
            return
        node, gfn, off = loc
        data = decode_payload(payload)
        try:
            node.write_at(gfn, off, data)
        except self._dead_exc:
            self.counters["touch_dead"] += 1
            return
        except self._not_serving_exc:
            self.counters["touch_not_serving"] += 1
            return
        self.counters["payload_writes"] += 1

    def _op_rdata(self, addr: int, payload: str) -> None:
        nbytes, crc = decode_read_check(payload)
        loc = self._locate(addr)
        if loc is None:
            return
        node, gfn, off = loc
        try:
            got = node.read_at(gfn, off, nbytes)
        except self._dead_exc:
            self.counters["touch_dead"] += 1
            return
        except self._not_serving_exc:
            self.counters["touch_not_serving"] += 1
            return
        self.counters["payload_reads"] += 1
        if not self.verify_reads:
            return
        if addr // self.header.ms_bytes in self.payload_lost:
            # the token's content died in a hard kill and was re-placed
            # zeroed: a capture-time hash cannot match, and that is the
            # correct replay outcome, not a data-integrity failure
            self.counters["payload_verify_skipped"] += 1
            return
        if zlib.crc32(got) & 0xFFFFFFFF != crc:
            self.counters["verify_failures"] += 1

    # --------------------------------------------------------------- result
    def result(self) -> Dict[str, object]:
        snap = self.controller.snapshot()
        snap["deterministic"]["replay"] = dict(sorted(self.counters.items()))
        return snap

    def deterministic_bytes(self) -> bytes:
        return json.dumps(self.result()["deterministic"],
                          sort_keys=True).encode()


class FailureSchedule:
    """Seeded chaos plan: which nodes die (drained or hard), which come
    back, and how many live MSs migrate.

    The plan is derived purely from ``(seed, n_nodes)`` and rendered into
    trace ops, so the failure schedule travels with the trace: replaying
    the same file replays the same failures at the same points, and the
    determinism contract extends over chaos by construction.
    """

    def __init__(self, seed: int, n_nodes: int, *, kills: int = 1,
                 drain_frac: float = 0.5, recover: bool = True,
                 migrations: int = 0) -> None:
        if n_nodes < 2:
            raise ValueError("a chaos schedule needs >= 2 nodes (a survivor)")
        rng = random.Random(seed)
        self.seed = seed
        self.n_nodes = n_nodes
        self.migrations = migrations
        kills = min(kills, n_nodes - 1)          # someone must survive
        victims = rng.sample(range(n_nodes), kills)
        self.kill_events: List[Tuple[int, bool]] = [
            (v, rng.random() < drain_frac) for v in victims]
        self.recover_nodes: List[int] = list(victims) if recover else []


def chaos_trace(seed: int, ms_bytes: int, mps_per_ms: int, n_nodes: int, *,
                fill_ms: int, burst: int, kills: int = 1,
                migrations: int = 2, drain_frac: float = 0.5,
                recover: bool = True,
                zero_frac: float = DEFAULT_ZERO_FRAC,
                comp_frac: float = DEFAULT_COMP_FRAC) -> TraceGen:
    """The canonical chaos scenario: fill + age (so the fleet holds a
    mixed resident/swapped population), live-migrate a seeded sample of
    MSs, fault-burst, kill nodes mid-replay (drained and hard per the
    seeded schedule), burst over the survivors, recover, and burst again
    against the rebuilt fleet."""
    gen = TraceGen(seed, ms_bytes, mps_per_ms, zero_frac, comp_frac)
    sched = FailureSchedule(seed ^ 0xC4A05, n_nodes, kills=kills,
                            drain_frac=drain_frac, recover=recover,
                            migrations=migrations)
    gen.front_fill(fill_ms)
    gen.back_phase(8)                       # age to COLD + reclaim windows
    gen.migrate_sample(sched.migrations)    # live migration under load
    gen.fault_burst(burst // 3, tick_every=48)
    for node_id, drain in sched.kill_events:
        gen.kill_node(node_id, drain=drain)
    gen.fault_burst(burst // 3, tick_every=48)
    for node_id in sched.recover_nodes:
        gen.recover_node(node_id)
    gen.back_phase(4)
    gen.fault_burst(burst - 2 * (burst // 3), tick_every=64)
    return gen


def paper_trace(seed: int, ms_bytes: int, mps_per_ms: int, *,
                fill_ms: int, burst: int, churn_frees: int = 0,
                upgrade: bool = True,
                zero_frac: float = DEFAULT_ZERO_FRAC,
                comp_frac: float = DEFAULT_COMP_FRAC) -> TraceGen:
    """The canonical scenario: fill past the fleet admission cap, age +
    reclaim, fault-burst, churn, then one rolling hot-upgrade and a
    second burst against the upgraded modules."""
    gen = TraceGen(seed, ms_bytes, mps_per_ms, zero_frac, comp_frac)
    gen.front_fill(fill_ms)
    gen.back_phase(8)                       # age to COLD + reclaim windows
    gen.fault_burst(burst, tick_every=48)   # faults vs. staggered BACK
    if churn_frees:
        gen.churn(churn_frees, churn_frees // 2)
        gen.back_phase(4)
    if upgrade:
        gen.rolling_upgrade(drain_rounds=2)
        gen.fault_burst(burst // 2, tick_every=64)
    return gen
