"""Trace-driven workload replay (tracehm-style TSV traces).

A trace is a TSV file: one header comment carrying the parameters every
replayer needs to reproduce payloads byte-for-byte, then one op per line:

    # taiji-trace v1 seed=7 ms_bytes=16384 mps_per_ms=8 zero=0.60 comp=0.25
    0	alloc	12	0
    1	touch	0x30800	1
    2	tick	6	0
    3	touch	0x30800	0
    4	upgrade	2	0
    5	free	12	0

Columns are ``seq, op, ms/addr, is_write``:

  * ``alloc``/``free`` -- arg is a trace-level MS *token*; the replayer
    maps tokens to (node, gfn) through the fleet controller's admission
    path, so the trace itself is placement-agnostic.
  * ``touch``  -- arg is a hex address ``token*ms_bytes + mp*mp_bytes``;
    ``is_write`` selects guest write (payload derived deterministically
    from the header seed) vs. guest read (faulting swapped MPs back in).
  * ``tick``   -- arg fleet controller rounds to run (BACK phases: LRU
    aging + staggered reclaim windows).
  * ``upgrade``-- start a rolling hot-upgrade; arg is the per-node drain
    duration in rounds.
  * ``kill``   -- chaos: kill node ``arg``; ``is_write=1`` means drained
    (graceful decommission: MSs live-migrate off first), 0 a hard crash
    (contents lost; the controller re-places committed MSs on the next
    tick).
  * ``recover``-- chaos: bring node ``arg`` back, fresh and empty.
  * ``migrate``-- live-migrate MS token ``arg`` to the least-pressured
    other node (controller placement, read-verified).

Everything is seeded and single-threaded (round-based), so replaying the
same trace twice yields byte-identical deterministic snapshots -- the
failure schedule is part of the trace, so chaos replays deterministically
too.
"""
from __future__ import annotations

import json
import random
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

TRACE_MAGIC = "taiji-trace v1"

OP_ALLOC = "alloc"
OP_FREE = "free"
OP_TOUCH = "touch"
OP_TICK = "tick"
OP_UPGRADE = "upgrade"
# chaos ops (ISSUE 4): the failure schedule is part of the trace, so two
# replays of the same trace see byte-identical failures
OP_KILL = "kill"          # arg node_id; is_write=1 -> drained (graceful)
OP_RECOVER = "recover"    # arg node_id
OP_MIGRATE = "migrate"    # arg MS token; controller picks the destination

# paper Fig 15c production mix: 76.79% zero pages, 23.21% compressed at
# ~47.63% ratio. The generator defaults add an incompressible tail so the
# backend's raw branch is exercised too.
DEFAULT_ZERO_FRAC = 0.60
DEFAULT_COMP_FRAC = 0.25

K_PAGE_ZERO, K_PAGE_COMP, K_PAGE_RAND = "zero", "comp", "rand"


# --------------------------------------------------------------- payloads
def _page_hash(seed: int, token: int, mp: int) -> int:
    return zlib.crc32(f"{seed}/{token}/{mp}".encode())


def page_kind(seed: int, token: int, mp: int,
              zero_frac: float, comp_frac: float) -> str:
    """Deterministic page class for (trace, token, mp) -- no RNG state."""
    u = (_page_hash(seed, token, mp) & 0xFFFFFF) / float(1 << 24)
    if u < zero_frac:
        return K_PAGE_ZERO
    if u < zero_frac + comp_frac:
        return K_PAGE_COMP
    return K_PAGE_RAND


def page_bytes(seed: int, token: int, mp: int, mp_bytes: int,
               zero_frac: float, comp_frac: float) -> bytes:
    """The payload a ``touch`` write carries: purely a function of the
    trace header + address, so generator, replayer and verifier agree."""
    kind = page_kind(seed, token, mp, zero_frac, comp_frac)
    if kind == K_PAGE_ZERO:
        return bytes(mp_bytes)
    h = _page_hash(seed, token, mp)
    rng = np.random.default_rng(h)
    if kind == K_PAGE_COMP:
        # ~50%-compressible: structured half + incompressible half
        structured = np.full(mp_bytes // 2, h & 0xFF, np.uint8)
        noise = rng.integers(0, 256, mp_bytes - mp_bytes // 2, dtype=np.int64)
        return structured.tobytes() + noise.astype(np.uint8).tobytes()
    return rng.integers(0, 256, mp_bytes, dtype=np.int64).astype(
        np.uint8).tobytes()


def touch_addr(token: int, mp: int, ms_bytes: int, mp_bytes: int) -> int:
    return token * ms_bytes + mp * mp_bytes


# ------------------------------------------------------------------ format
class TraceHeader:
    def __init__(self, seed: int, ms_bytes: int, mps_per_ms: int,
                 zero_frac: float, comp_frac: float) -> None:
        if mps_per_ms < 1:
            raise ValueError(f"mps_per_ms must be >= 1, got {mps_per_ms}")
        if ms_bytes <= 0 or ms_bytes % mps_per_ms:
            raise ValueError(
                f"ms_bytes ({ms_bytes}) must be a positive multiple of "
                f"mps_per_ms ({mps_per_ms})")
        self.seed = seed
        self.ms_bytes = ms_bytes
        self.mps_per_ms = mps_per_ms
        self.mp_bytes = ms_bytes // mps_per_ms
        self.zero_frac = zero_frac
        self.comp_frac = comp_frac

    def line(self) -> str:
        return (f"# {TRACE_MAGIC} seed={self.seed} ms_bytes={self.ms_bytes} "
                f"mps_per_ms={self.mps_per_ms} zero={self.zero_frac:.6g} "
                f"comp={self.comp_frac:.6g}")

    @classmethod
    def parse(cls, line: str) -> "TraceHeader":
        if TRACE_MAGIC not in line:
            raise ValueError(f"not a taiji trace header: {line!r}")
        kv = dict(tok.split("=", 1) for tok in line.split() if "=" in tok)
        try:
            return cls(seed=int(kv["seed"]), ms_bytes=int(kv["ms_bytes"]),
                       mps_per_ms=int(kv["mps_per_ms"]),
                       zero_frac=float(kv["zero"]),
                       comp_frac=float(kv["comp"]))
        except KeyError as e:
            raise ValueError(
                f"trace header missing key {e.args[0]}: {line!r}") from None
        except ValueError as e:
            raise ValueError(f"malformed trace header {line!r}: {e}") from None


def format_line(seq: int, op: str, arg: int, is_write: int) -> str:
    if op == OP_TOUCH:
        return f"{seq}\t{op}\t0x{arg:x}\t{is_write}"
    return f"{seq}\t{op}\t{arg}\t{is_write}"


def parse_line(line: str) -> Tuple[int, str, int, int]:
    parts = line.rstrip("\n").split("\t")
    if len(parts) != 4:
        raise ValueError(
            f"malformed trace line (want 4 tab-separated columns, "
            f"got {len(parts)}): {line!r}")
    seq_s, op, arg_s, w_s = parts
    try:
        seq = int(seq_s)
        arg = int(arg_s, 16 if arg_s.startswith("0x") else 10)
        w = int(w_s)
    except ValueError as e:
        raise ValueError(f"malformed trace line {line!r}: {e}") from None
    if w not in (0, 1):
        raise ValueError(f"is_write must be 0 or 1 in {line!r}")
    return seq, op, arg, w


# --------------------------------------------------------------- generator
class TraceGen:
    """Synthesizes the paper's workload shapes as a seeded trace.

    Phases compose: FRONT fill (allocs + page-mix writes), BACK aging
    (ticks that age the LRU and fire staggered reclaim windows), fault
    bursts (Zipf-popular reads over the filled set, faulting swapped MPs
    back in), churn (free/realloc) and a rolling hot-upgrade marker.
    """

    def __init__(self, seed: int, ms_bytes: int, mps_per_ms: int,
                 zero_frac: float = DEFAULT_ZERO_FRAC,
                 comp_frac: float = DEFAULT_COMP_FRAC) -> None:
        self.header = TraceHeader(seed, ms_bytes, mps_per_ms,
                                  zero_frac, comp_frac)
        self._rng = random.Random(seed)
        self._ops: List[Tuple[str, int, int]] = []
        self._next_token = 0
        self._live: List[int] = []

    # ------------------------------------------------------------- phases
    def front_fill(self, n_ms: int, write_frac: float = 1.0) -> List[int]:
        """FRONT phase: allocate ``n_ms`` sections, write the page mix."""
        hdr = self.header
        tokens = []
        for _ in range(n_ms):
            token = self._next_token
            self._next_token += 1
            self._ops.append((OP_ALLOC, token, 0))
            self._live.append(token)
            tokens.append(token)
            for mp in range(hdr.mps_per_ms):
                if write_frac >= 1.0 or self._rng.random() < write_frac:
                    addr = touch_addr(token, mp, hdr.ms_bytes, hdr.mp_bytes)
                    self._ops.append((OP_TOUCH, addr, 1))
        return tokens

    def back_phase(self, n_ticks: int) -> None:
        """BACK phase: controller rounds only (aging + reclaim windows)."""
        self._ops.append((OP_TICK, n_ticks, 0))

    def fault_burst(self, n_touches: int, zipf_a: float = 1.2,
                    tick_every: int = 0) -> None:
        """Read burst with Zipf MS popularity and sequential MP locality."""
        hdr = self.header
        if not self._live:
            return
        ranks = np.arange(1, len(self._live) + 1, dtype=np.float64)
        pop = 1.0 / ranks ** zipf_a
        weights = list(pop / pop.sum())
        cursor: Dict[int, int] = {}
        for i in range(n_touches):
            token = self._rng.choices(self._live, weights=weights)[0]
            mp = cursor.get(token, 0) % hdr.mps_per_ms
            cursor[token] = mp + 1
            addr = touch_addr(token, mp, hdr.ms_bytes, hdr.mp_bytes)
            self._ops.append((OP_TOUCH, addr, 0))
            if tick_every and (i + 1) % tick_every == 0:
                self._ops.append((OP_TICK, 1, 0))

    def churn(self, n_frees: int, n_allocs: int) -> None:
        """Free a seeded sample, then re-allocate fresh sections."""
        n_frees = min(n_frees, len(self._live))
        for token in self._rng.sample(self._live, n_frees):
            self._live.remove(token)
            self._ops.append((OP_FREE, token, 0))
        self.front_fill(n_allocs)

    def rolling_upgrade(self, drain_rounds: int = 2,
                        settle_ticks: int = 8) -> None:
        """Rolling hot-upgrade marker + enough ticks to complete it."""
        self._ops.append((OP_UPGRADE, drain_rounds, 0))
        if settle_ticks:
            self._ops.append((OP_TICK, settle_ticks, 0))

    # -------------------------------------------------------- chaos phases
    def kill_node(self, node_id: int, *, drain: bool = False,
                  settle_ticks: int = 2) -> None:
        """Chaos op: kill a node (``drain`` = migrate its MSs off first);
        the settle ticks let the controller run failure recovery."""
        self._ops.append((OP_KILL, node_id, 1 if drain else 0))
        if settle_ticks:
            self._ops.append((OP_TICK, settle_ticks, 0))

    def recover_node(self, node_id: int, settle_ticks: int = 1) -> None:
        """Chaos op: bring a killed node back (fresh and empty)."""
        self._ops.append((OP_RECOVER, node_id, 0))
        if settle_ticks:
            self._ops.append((OP_TICK, settle_ticks, 0))

    def migrate(self, token: int) -> None:
        """Live-migrate one MS token (replay-side controller placement)."""
        self._ops.append((OP_MIGRATE, token, 0))

    def migrate_sample(self, n: int) -> List[int]:
        """Migrate a seeded sample of live tokens."""
        n = min(n, len(self._live))
        tokens = self._rng.sample(self._live, n)
        for token in tokens:
            self.migrate(token)
        return tokens

    # -------------------------------------------------------------- output
    def lines(self) -> List[str]:
        out = [self.header.line()]
        out.extend(format_line(i, op, arg, w)
                   for i, (op, arg, w) in enumerate(self._ops))
        return out

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write("\n".join(self.lines()) + "\n")

    @property
    def n_ops(self) -> int:
        return len(self._ops)


class TraceReplayer:
    """Deterministic seeded trace replay through a fleet controller.

    Single-threaded, round-based: trace lines are applied in order, so
    two replays of the same trace through identically-configured fleets
    produce byte-identical deterministic snapshots. Placement is decided
    live by the controller's admission path; tokens that were rejected at
    admission simply drop their later touches (counted, like a guest VM
    that was never scheduled onto the fleet).
    """

    def __init__(self, controller, lines: Iterable[str], *,
                 upgrade_module_cls=None, verify_reads: bool = True) -> None:
        from ..core.hotupgrade import EngineModuleV2
        from .controller import REJECT_NO_CAPACITY, REJECT_OVERCOMMIT
        from .node import NodeDeadError, NodeNotServingError
        self._not_serving_exc = NodeNotServingError
        self._dead_exc = NodeDeadError
        self.controller = controller
        self.upgrade_module_cls = upgrade_module_cls or EngineModuleV2
        self.verify_reads = verify_reads
        # failure recovery + drain migrations remap (node, gfn) pairs; the
        # listener keeps the token map and the written-set in sync
        controller.remap_listener = self._on_remap

        lines = [ln for ln in lines if ln.strip()]
        if not lines or not lines[0].startswith("#"):
            raise ValueError("trace must start with a header comment")
        self.header = TraceHeader.parse(lines[0])
        self._body = [ln for ln in lines[1:] if not ln.startswith("#")]

        self.placed: Dict[int, Tuple[object, int]] = {}   # token -> (node, gfn)
        self._loc: Dict[Tuple[int, int], int] = {}  # (node_id, gfn) -> token
        # token -> written MP set: keyed by token so frees, hard-kill
        # re-placements and losses forget a whole token in one pop
        self.written: Dict[int, Set[int]] = {}
        self.counters: Dict[str, int] = {
            "ops": 0, "allocs": 0, "frees": 0, "reads": 0, "writes": 0,
            "ticks": 0, "upgrades": 0, "touch_unplaced": 0,
            "touch_not_serving": 0, "free_not_serving": 0,
            "verify_failures": 0,
            "kills": 0, "recovers": 0,
            "migrations": 0, "migrate_rejected": 0, "migrate_unplaced": 0,
            "touch_dead": 0, "free_dead": 0,
            "ms_migrated": 0, "ms_replaced": 0, "ms_lost": 0,
            "reject_" + REJECT_OVERCOMMIT: 0,
            "reject_" + REJECT_NO_CAPACITY: 0,
        }

    # --------------------------------------------------------------- replay
    def run(self) -> Dict[str, object]:
        for line in self._body:
            _seq, op, arg, is_write = parse_line(line)
            self.counters["ops"] += 1
            if op == OP_ALLOC:
                self._op_alloc(arg)
            elif op == OP_FREE:
                self._op_free(arg)
            elif op == OP_TOUCH:
                self._op_touch(arg, is_write)
            elif op == OP_TICK:
                for _ in range(arg):
                    self.controller.tick()
                self.counters["ticks"] += arg
            elif op == OP_UPGRADE:
                self.controller.start_rolling_upgrade(
                    self.upgrade_module_cls, drain_rounds=arg)
                self.counters["upgrades"] += 1
            elif op == OP_KILL:
                self.controller.kill_node(arg, drain=bool(is_write))
                self.counters["kills"] += 1
            elif op == OP_RECOVER:
                self.controller.recover_node(arg)
                self.counters["recovers"] += 1
            elif op == OP_MIGRATE:
                self._op_migrate(arg)
            else:
                raise ValueError(f"unknown trace op {op!r}: {line!r}")
        return self.result()

    # -------------------------------------------------------- chaos remaps
    def _on_remap(self, src_node, old_gfn: int, dst_node,
                  new_gfn, preserved: bool) -> None:
        """Controller notification: an MS moved (migration, preserved) or
        was re-placed fresh / lost (failure recovery)."""
        token = self._loc.pop((src_node.node_id, old_gfn), None)
        if token is None:
            return                       # not a replayer-tracked MS
        if dst_node is None:             # lost with the node: no capacity
            self.placed.pop(token, None)
            self.counters["ms_lost"] += 1
            self.written.pop(token, None)
            return
        self.placed[token] = (dst_node, new_gfn)
        self._loc[(dst_node.node_id, new_gfn)] = token
        if preserved:
            self.counters["ms_migrated"] += 1
        else:
            # hard-kill re-placement: a fresh zeroed MS -- prior writes
            # are gone, so read-verify must not expect them
            self.counters["ms_replaced"] += 1
            self.written.pop(token, None)

    def _op_migrate(self, token: int) -> None:
        placed = self.placed.get(token)
        if placed is None:
            self.counters["migrate_unplaced"] += 1
            return
        node, gfn = placed
        dst, _new_gfn, _reason = self.controller.migrate_ms(node, gfn)
        if dst is None:
            self.counters["migrate_rejected"] += 1
        else:
            self.counters["migrations"] += 1   # map updated via _on_remap

    def _op_alloc(self, token: int) -> None:
        node, gfn, reason = self.controller.admit_alloc()
        self.counters["allocs"] += 1
        if node is None:
            key = "reject_" + reason
            self.counters[key] = self.counters.get(key, 0) + 1
            return
        self.placed[token] = (node, gfn)
        self._loc[(node.node_id, gfn)] = token

    def _op_free(self, token: int) -> None:
        placed = self.placed.pop(token, None)
        if placed is None:
            return
        node, gfn = placed
        try:
            node.free_ms_gfn(gfn)
        except self._dead_exc:
            # the owner died and recovery has not settled yet: the free is
            # lost traffic; the tick-driven re-placement will remap it
            self.counters["free_dead"] += 1
            self.placed[token] = placed
            return
        except self._not_serving_exc:
            # the owner is draining: the free is lost traffic, like any
            # other op against a mid-upgrade node; its data stays live
            self.counters["free_not_serving"] += 1
            self.placed[token] = placed
            return
        self.counters["frees"] += 1
        self._loc.pop((node.node_id, gfn), None)
        self.written.pop(token, None)

    def _op_touch(self, addr: int, is_write: int) -> None:
        hdr = self.header
        token = addr // hdr.ms_bytes
        mp = (addr % hdr.ms_bytes) // hdr.mp_bytes
        placed = self.placed.get(token)
        if placed is None:
            self.counters["touch_unplaced"] += 1
            return
        node, gfn = placed
        try:
            if is_write:
                node.write_mp(gfn, mp, page_bytes(
                    hdr.seed, token, mp, hdr.mp_bytes,
                    hdr.zero_frac, hdr.comp_frac))
                self.written.setdefault(token, set()).add(mp)
                self.counters["writes"] += 1
            else:
                got = node.read_mp(gfn, mp)
                self.counters["reads"] += 1
                if self.verify_reads and mp in self.written.get(token, ()):
                    want = page_bytes(hdr.seed, token, mp, hdr.mp_bytes,
                                      hdr.zero_frac, hdr.comp_frac)
                    if got != want:
                        self.counters["verify_failures"] += 1
        except self._dead_exc:
            self.counters["touch_dead"] += 1
        except self._not_serving_exc:
            self.counters["touch_not_serving"] += 1

    # --------------------------------------------------------------- result
    def result(self) -> Dict[str, object]:
        snap = self.controller.snapshot()
        snap["deterministic"]["replay"] = dict(sorted(self.counters.items()))
        return snap

    def deterministic_bytes(self) -> bytes:
        return json.dumps(self.result()["deterministic"],
                          sort_keys=True).encode()


class FailureSchedule:
    """Seeded chaos plan: which nodes die (drained or hard), which come
    back, and how many live MSs migrate.

    The plan is derived purely from ``(seed, n_nodes)`` and rendered into
    trace ops, so the failure schedule travels with the trace: replaying
    the same file replays the same failures at the same points, and the
    determinism contract extends over chaos by construction.
    """

    def __init__(self, seed: int, n_nodes: int, *, kills: int = 1,
                 drain_frac: float = 0.5, recover: bool = True,
                 migrations: int = 0) -> None:
        if n_nodes < 2:
            raise ValueError("a chaos schedule needs >= 2 nodes (a survivor)")
        rng = random.Random(seed)
        self.seed = seed
        self.n_nodes = n_nodes
        self.migrations = migrations
        kills = min(kills, n_nodes - 1)          # someone must survive
        victims = rng.sample(range(n_nodes), kills)
        self.kill_events: List[Tuple[int, bool]] = [
            (v, rng.random() < drain_frac) for v in victims]
        self.recover_nodes: List[int] = list(victims) if recover else []


def chaos_trace(seed: int, ms_bytes: int, mps_per_ms: int, n_nodes: int, *,
                fill_ms: int, burst: int, kills: int = 1,
                migrations: int = 2, drain_frac: float = 0.5,
                recover: bool = True,
                zero_frac: float = DEFAULT_ZERO_FRAC,
                comp_frac: float = DEFAULT_COMP_FRAC) -> TraceGen:
    """The canonical chaos scenario: fill + age (so the fleet holds a
    mixed resident/swapped population), live-migrate a seeded sample of
    MSs, fault-burst, kill nodes mid-replay (drained and hard per the
    seeded schedule), burst over the survivors, recover, and burst again
    against the rebuilt fleet."""
    gen = TraceGen(seed, ms_bytes, mps_per_ms, zero_frac, comp_frac)
    sched = FailureSchedule(seed ^ 0xC4A05, n_nodes, kills=kills,
                            drain_frac=drain_frac, recover=recover,
                            migrations=migrations)
    gen.front_fill(fill_ms)
    gen.back_phase(8)                       # age to COLD + reclaim windows
    gen.migrate_sample(sched.migrations)    # live migration under load
    gen.fault_burst(burst // 3, tick_every=48)
    for node_id, drain in sched.kill_events:
        gen.kill_node(node_id, drain=drain)
    gen.fault_burst(burst // 3, tick_every=48)
    for node_id in sched.recover_nodes:
        gen.recover_node(node_id)
    gen.back_phase(4)
    gen.fault_burst(burst - 2 * (burst // 3), tick_every=64)
    return gen


def paper_trace(seed: int, ms_bytes: int, mps_per_ms: int, *,
                fill_ms: int, burst: int, churn_frees: int = 0,
                upgrade: bool = True,
                zero_frac: float = DEFAULT_ZERO_FRAC,
                comp_frac: float = DEFAULT_COMP_FRAC) -> TraceGen:
    """The canonical scenario: fill past the fleet admission cap, age +
    reclaim, fault-burst, churn, then one rolling hot-upgrade and a
    second burst against the upgraded modules."""
    gen = TraceGen(seed, ms_bytes, mps_per_ms, zero_frac, comp_frac)
    gen.front_fill(fill_ms)
    gen.back_phase(8)                       # age to COLD + reclaim windows
    gen.fault_burst(burst, tick_every=48)   # faults vs. staggered BACK
    if churn_frees:
        gen.churn(churn_frees, churn_frees // 2)
        gen.back_phase(4)
    if upgrade:
        gen.rolling_upgrade(drain_rounds=2)
        gen.fault_burst(burst // 2, tick_every=64)
    return gen
