"""FleetController -- multi-node elasticity orchestration (control plane).

CLUES-style cluster orchestration adapted to memory elasticity: the
controller owns fleet-wide *admission control* for elastic MS
allocations, *pressure-aware placement* onto the least-pressured serving
node, *staggered reclaim* coordination (nodes are partitioned into
stagger groups; only one group's BACK reclaim fires per fleet tick, so
the whole fleet never compresses/swaps in the same window), and *rolling
hot-upgrade* orchestration with failure-domain batching and
abort-on-regression, *failure recovery* (a dead node's committed MSs are
re-placed onto survivors under admission control on the next tick), and
*live MS migration* between nodes (export -> admit + import ->
read-verify -> drop, preserving the resident/swapped split).

Concurrency model: one deterministic event loop. ``tick()`` is a fleet
round that steps every node once; nothing runs on threads, so replaying
a seeded trace is exactly reproducible (see ``trace.TraceReplayer``).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

from ..core.hotupgrade import EngineModule
from ..core.metrics import LatencyHistogram
from ..obs.tracer import (ST_FLEET_ADMISSION, ST_FLEET_PLACEMENT,
                          ST_FLEET_RECOVERY, ST_FLEET_STEP, ST_FLEET_TICK,
                          ST_FLEET_UPGRADE)
from .node import NodeAgent

_perf_ns = time.perf_counter_ns

REJECT_OVERCOMMIT = "fleet_overcommit"
REJECT_NO_CAPACITY = "no_serving_capacity"

# migration rejection reasons: all checked *before* any mutation, so a
# rejected migration leaves both nodes untouched
REJECT_MIGRATE_BAD_SRC = "migrate_bad_src"
REJECT_MIGRATE_NO_DST = "migrate_no_dst"
REJECT_MIGRATE_VERIFY = "migrate_verify_failed"

# remap_listener(src_node, old_gfn, dst_node | None, new_gfn | None,
#                data_preserved): how the trace replayer tracks tokens
# across migrations (preserved) and failure re-placements (fresh MS)
RemapListener = Callable[[NodeAgent, int, Optional[NodeAgent],
                          Optional[int], bool], None]


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Control-plane knobs (per-node knobs stay in TaijiConfig)."""

    # fleet-wide admission cap: committed virtual MSs may not exceed this
    # multiple of the fleet's managed physical MSs. Per-node overcommit is
    # +50% (paper O3); holding the *fleet* at +25% keeps aggregate reclaim
    # pressure bounded even when placement is skewed.
    overcommit_cap: float = 1.25
    # number of reclaim stagger groups: node i belongs to group
    # (i % groups); group (tick % groups) reclaims this tick.
    reclaim_stagger_groups: int = 2
    # rolling upgrade: rounds a node drains before its module swap
    upgrade_drain_rounds: int = 2
    # optional latency guard (abort-on-regression): if set, a batch whose
    # post-upgrade fleet p90 fault latency exceeds guard * the pre-upgrade
    # baseline aborts the rollout. Timing-dependent, so it is OFF by
    # default; the deterministic health probe always runs.
    latency_guard_factor: Optional[float] = None
    latency_guard_min_samples: int = 200

    @classmethod
    def production_profile(cls) -> "FleetConfig":
        """The named production rollout profile (ROADMAP wiring item).

        Wires the optional latency guard into abort-on-regression: a
        rollout batch whose post-upgrade fleet p90 fault latency exceeds
        1.5x the pre-rollout baseline aborts, judged only on a deep
        sample window (500 faults) so one noisy probe cannot kill a
        rollout.  Reclaim stagger widens to 4 groups and drains deepen --
        production trades rollout speed for blast-radius control.
        """
        return cls(overcommit_cap=1.25,
                   reclaim_stagger_groups=4,
                   upgrade_drain_rounds=3,
                   latency_guard_factor=1.5,
                   latency_guard_min_samples=500)


class _RollingUpgrade:
    def __init__(self, module_cls: Type[EngineModule],
                 batches: List[List[NodeAgent]], drain_rounds: int,
                 baseline_p90_ns: float) -> None:
        self.module_cls = module_cls
        self.batches = batches
        self.drain_rounds = drain_rounds
        self.baseline_p90_ns = baseline_p90_ns
        self.batch_idx = 0
        self.in_flight = False
        # fleet fault histogram at batch start: the latency guard judges
        # only the samples recorded *since*, so pre-upgrade history can't
        # dilute a regression. pre_batch_epoch snapshots (kills,
        # recoveries): a fleet-membership change between capture and
        # validation invalidates the delta (the dead node's samples are in
        # the pre hist but not the post), so the guard skips that batch.
        self.pre_batch_hist: Optional[LatencyHistogram] = None
        self.pre_batch_epoch: Tuple[int, int] = (0, 0)


def _hist_delta(post: LatencyHistogram,
                pre: LatencyHistogram) -> LatencyHistogram:
    """Samples recorded between two cumulative histogram states.

    Buckets/counters are additive so they subtract cleanly; the exact
    reservoir does not, so the delta keeps no samples and ``percentile``
    falls back to bucket math.
    """
    d = LatencyHistogram()
    d.buckets = [a - b for a, b in zip(post.buckets, pre.buckets)]
    d.count = post.count - pre.count
    d.total_ns = post.total_ns - pre.total_ns
    d.max_ns = post.max_ns
    return d


class FleetController:
    def __init__(self, nodes: Sequence[NodeAgent],
                 fleet_cfg: Optional[FleetConfig] = None) -> None:
        self.nodes = list(nodes)
        if not self.nodes:
            raise ValueError("fleet needs at least one node")
        self.cfg = fleet_cfg or FleetConfig()
        if self.cfg.reclaim_stagger_groups < 1:
            raise ValueError("reclaim_stagger_groups must be >= 1")
        self.ticks = 0
        # admission counters
        self.admitted = 0
        self.rejections: Dict[str, int] = {REJECT_OVERCOMMIT: 0,
                                           REJECT_NO_CAPACITY: 0}
        self.placements: Dict[int, int] = {n.node_id: 0 for n in self.nodes}
        self.reclaimed_mps = 0
        # chaos + migration counters
        self.kills = 0
        self.recoveries = 0
        self.migrations = 0
        self.migration_mps = 0
        self.migrations_rejected: Dict[str, int] = {
            REJECT_MIGRATE_BAD_SRC: 0, REJECT_MIGRATE_NO_DST: 0,
            REJECT_MIGRATE_VERIFY: 0}
        self.ms_replaced = 0             # re-placed after a hard kill (fresh)
        self.ms_lost = 0                 # died with the node, no capacity
        self.remap_listener: Optional[RemapListener] = None
        # rolling upgrade state
        self._rolling: Optional[_RollingUpgrade] = None
        self.upgrade_batches_done = 0
        self.upgrade_aborted = False
        self.upgrade_abort_reason = ""
        # stage-attributed tracing (repro.obs): the controller gets its
        # own tracer when the fleet is traced, on a pid track one past the
        # node ids; None when any node runs untraced
        self.tracer = None
        if all(n.system.metrics.tracer is not None for n in self.nodes):
            from ..obs.tracer import SpanTracer
            obs = self.nodes[0].cfg.obs
            self.tracer = SpanTracer(cap=obs.ring_capacity,
                                     max_spans=obs.max_spans,
                                     pid=len(self.nodes))

    # ---------------------------------------------------------- fleet sums
    # dead nodes are out of the fleet: their physical MSs back nothing and
    # their committed MSs are in-flight to survivors (failure recovery)
    def fleet_managed_ms(self) -> int:
        return sum(n.managed_phys_ms for n in self.nodes if n.alive)

    def fleet_committed_ms(self) -> int:
        return sum(len(n.allocated) for n in self.nodes if n.alive)

    def fleet_free_ms(self) -> int:
        return sum(n.free_ms for n in self.nodes if n.alive)

    def node_by_id(self, node_id: int) -> NodeAgent:
        for n in self.nodes:
            if n.node_id == node_id:
                return n
        raise ValueError(f"unknown node id {node_id}")

    # ----------------------------------------------------------- admission
    def admit_alloc(self) -> Tuple[Optional[NodeAgent], Optional[int], str]:
        """Admission control + placement for one elastic MS allocation.

        Returns ``(node, gfn, "ok")`` on success, else
        ``(None, None, reason)``. Placement is pressure-aware: the
        least-pressured serving node with virtual headroom wins (node_id
        breaks ties deterministically).
        """
        tr = self.tracer
        if tr is not None:
            t0 = _perf_ns()
        cap = int(self.fleet_managed_ms() * self.cfg.overcommit_cap)
        if self.fleet_committed_ms() + 1 > cap:
            self.rejections[REJECT_OVERCOMMIT] += 1
            if tr is not None:
                tr.push(ST_FLEET_ADMISSION, t0, _perf_ns() - t0, 1)
            return None, None, REJECT_OVERCOMMIT
        if tr is not None:
            t_p = _perf_ns()
        node = self._pick_target()
        if tr is not None:
            tr.push(ST_FLEET_PLACEMENT, t_p, _perf_ns() - t_p)
        if node is None:
            self.rejections[REJECT_NO_CAPACITY] += 1
            if tr is not None:
                tr.push(ST_FLEET_ADMISSION, t0, _perf_ns() - t0, 1)
            return None, None, REJECT_NO_CAPACITY
        gfn = node.alloc_ms()
        self.admitted += 1
        self.placements[node.node_id] += 1
        if tr is not None:
            tr.push(ST_FLEET_ADMISSION, t0, _perf_ns() - t0)
        return node, gfn, "ok"

    def _pick_target(self,
                     exclude: Optional[NodeAgent] = None
                     ) -> Optional[NodeAgent]:
        """The one placement policy, shared by admission and migration:
        least-pressured serving node with virtual headroom (node_id
        breaks ties deterministically), optionally excluding a node."""
        candidates = [n for n in self.nodes
                      if n is not exclude and n.serving
                      and len(n.allocated) < n.capacity_ms]
        if not candidates:
            return None
        return min(candidates, key=lambda n: (n.pressure(), n.node_id))

    # --------------------------------------------------------- fleet round
    def reclaim_group_of(self, node_index: int) -> int:
        return node_index % self.cfg.reclaim_stagger_groups

    def tick(self) -> int:
        """One fleet round: detect dead nodes (failure recovery), step
        every surviving node, stagger reclaim windows, drive any in-flight
        rolling upgrade. Returns MPs reclaimed."""
        tr = self.tracer
        if tr is not None:
            t0 = _perf_ns()
        for node in self.nodes:
            if not node.alive and node.allocated:
                if tr is not None:
                    t_r = _perf_ns()
                self._replace_dead_ms(node)
                if tr is not None:
                    tr.push(ST_FLEET_RECOVERY, t_r, _perf_ns() - t_r)
        groups = self.cfg.reclaim_stagger_groups
        active_group = self.ticks % groups
        reclaimed = 0
        if tr is not None:
            t_s = _perf_ns()
        for i, node in enumerate(self.nodes):
            if not node.alive:
                continue
            window = node.serving and self.reclaim_group_of(i) == active_group
            reclaimed += node.step(reclaim=window)
        self.reclaimed_mps += reclaimed
        if tr is not None:
            t_u = _perf_ns()
            tr.push(ST_FLEET_STEP, t_s, t_u - t_s)
        self._drive_rolling()
        self.ticks += 1
        if tr is not None:
            tr.push(ST_FLEET_UPGRADE, t_u, _perf_ns() - t_u)
            tr.push(ST_FLEET_TICK, t0, _perf_ns() - t0)
        return reclaimed

    # ---------------------------------------------------- failure injection
    def kill_node(self, node_id: int, *, drain: bool = False) -> None:
        """Deterministic failure injection: kill one NodeAgent.

        ``drain=True`` is a graceful decommission: committed MSs are
        live-migrated to survivors first (guest-visible bytes preserved);
        whatever cannot be placed dies with the node. ``drain=False`` is
        a hard crash -- contents are lost, and the next :meth:`tick`
        detects the dead node and re-places its committed MSs as fresh
        allocations under normal admission control. Idempotent.
        """
        node = self.node_by_id(node_id)
        if not node.alive:
            return
        if drain:
            for gfn in sorted(node.allocated):
                self.migrate_ms(node, gfn)
            # whatever could not be placed dies with the node -- counted
            # lost, NOT re-placed as a fresh MS (a silent zeroed
            # replacement would mislabel data loss as recovery). Final by
            # nature: the data source disappears at the kill point, so
            # there is nothing to retry when capacity returns.
            for gfn in sorted(node.allocated):
                self.ms_lost += 1
                if self.remap_listener is not None:
                    self.remap_listener(node, gfn, None, None, False)
            node.allocated.clear()
        node.kill()
        self.kills += 1

    def recover_node(self, node_id: int) -> None:
        """Bring a killed node back (fresh, empty, serving). If the node
        was never ticked over while dead, its committed MSs are settled
        (re-placed or lost) before the identity is reused. Idempotent."""
        node = self.node_by_id(node_id)
        if node.alive:
            return
        if node.allocated:
            # the identity is being reused: pending MSs settle for good
            self._replace_dead_ms(node, final=True)
        node.recover()
        self.recoveries += 1

    def _replace_dead_ms(self, node: NodeAgent, *, final: bool = False) -> None:
        """Re-place a dead node's committed MSs onto survivors.

        The contents died with the node: each MS re-enters through the
        normal admission path as a fresh (zeroed) allocation. A placement
        shortage can be *transient* -- e.g. candidates draining
        mid-upgrade, or headroom that frees up when another node recovers
        -- so unplaced MSs stay pending on the dead node and every tick
        retries them; only a ``final`` settlement (the node's identity is
        being reused by :meth:`recover_node`) counts them lost. The remap
        listener (the trace replayer) is told about both outcomes so
        token maps and the read-verify written-set stay deterministic.
        """
        remaining: List[int] = []
        for gfn in sorted(node.allocated):
            dst, new_gfn, _reason = self.admit_alloc()
            if dst is None:
                if final:
                    self.ms_lost += 1
                    if self.remap_listener is not None:
                        self.remap_listener(node, gfn, None, None, False)
                else:
                    remaining.append(gfn)
                continue
            self.ms_replaced += 1
            if self.remap_listener is not None:
                self.remap_listener(node, gfn, dst, new_gfn, False)
        node.allocated.clear()
        node.allocated.update(remaining)

    # ------------------------------------------------------- live migration
    def migrate_ms(self, src: Union[NodeAgent, int], gfn: int,
                   dst: Optional[Union[NodeAgent, int]] = None, *,
                   verify: bool = True
                   ) -> Tuple[Optional[NodeAgent], Optional[int], str]:
        """Live MS migration: export on the source, admit + import on the
        destination, read-verify, then drop the source copy.

        Returns ``(dst_node, new_gfn, "ok")`` or ``(None, None, reason)``.
        With ``dst=None`` the least-pressured serving node (excluding the
        source) is chosen, admission-control style. All rejections happen
        before any mutation; a failed read-verify rolls the destination
        copy back and keeps the source authoritative. The resident/swapped
        split of the MS survives the move (import re-stores the swapped
        MPs through the batched store machinery).
        """
        if isinstance(src, int):
            src = self.node_by_id(src)
        if isinstance(dst, int):
            dst = self.node_by_id(dst)
        if not src.alive or gfn not in src.allocated:
            self.migrations_rejected[REJECT_MIGRATE_BAD_SRC] += 1
            return None, None, REJECT_MIGRATE_BAD_SRC
        if dst is None:
            dst = self._pick_target(exclude=src)
        elif (dst is src or not dst.serving
              or len(dst.allocated) >= dst.capacity_ms):
            dst = None
        if dst is None:
            self.migrations_rejected[REJECT_MIGRATE_NO_DST] += 1
            return None, None, REJECT_MIGRATE_NO_DST
        rows, resident = src.export_ms(gfn)      # non-consuming peek
        new_gfn = dst.import_ms(rows, resident)
        if verify:
            # read-verify without faulting: export the imported copy and
            # compare guest-visible bytes against the source image
            got, _res = dst.system.export_ms(new_gfn)
            if not np.array_equal(got, rows):
                dst.evict_ms(new_gfn)            # roll back, keep source
                self.migrations_rejected[REJECT_MIGRATE_VERIFY] += 1
                return None, None, REJECT_MIGRATE_VERIFY
        src.evict_ms(gfn)
        self.migrations += 1
        self.migration_mps += src.cfg.mps_per_ms
        if self.remap_listener is not None:
            self.remap_listener(src, gfn, dst, new_gfn, True)
        return dst, new_gfn, "ok"

    # ------------------------------------------------------ rolling upgrade
    def start_rolling_upgrade(self, module_cls: Type[EngineModule],
                              drain_rounds: Optional[int] = None) -> None:
        """Plan a fleet-wide rolling hot-upgrade.

        Nodes are batched by failure domain (one domain in flight at a
        time) so a bad module build can never take out more than one
        domain before the health probes abort the rollout.
        """
        if self._rolling is not None:
            raise RuntimeError("a rolling upgrade is already in flight")
        domains: Dict[int, List[NodeAgent]] = {}
        for n in self.nodes:
            if not n.alive:              # dead nodes are not upgraded
                continue
            domains.setdefault(n.failure_domain, []).append(n)
        if not domains:
            raise RuntimeError("no alive nodes to upgrade")
        batches = [sorted(domains[d], key=lambda n: n.node_id)
                   for d in sorted(domains)]
        self.upgrade_aborted = False
        self.upgrade_abort_reason = ""
        self.upgrade_batches_done = 0
        self._rolling = _RollingUpgrade(
            module_cls, batches,
            drain_rounds if drain_rounds is not None
            else self.cfg.upgrade_drain_rounds,
            baseline_p90_ns=self._fleet_fault_hist().percentile(0.90))

    @property
    def upgrade_in_progress(self) -> bool:
        return self._rolling is not None

    def _abort_rolling(self, reason: str) -> None:
        self.upgrade_aborted = True
        self.upgrade_abort_reason = reason
        self._rolling = None

    def _drive_rolling(self) -> None:
        ru = self._rolling
        if ru is None:
            return
        if ru.in_flight:
            batch = ru.batches[ru.batch_idx]
            dead = [n for n in batch if not n.alive]
            if dead:
                # a batch member died mid-drain/swap: abort the rollout
                # cleanly. Surviving batch members finish their drain via
                # step() and return to serving -- nothing stays stuck.
                self._abort_rolling(
                    f"node {dead[0].node_id} died mid-upgrade batch")
                return
            if any(not n.serving for n in batch):
                return                   # still draining/swapping
            ru.in_flight = False
            if not self._validate_batch(batch, ru):
                self.upgrade_aborted = True
                self._rolling = None
                return
            self.upgrade_batches_done += 1
            ru.batch_idx += 1
        if ru.batch_idx >= len(ru.batches):
            self._rolling = None         # rollout complete
            return
        batch = ru.batches[ru.batch_idx]
        dead = [n for n in batch if not n.alive]
        if dead:
            self._abort_rolling(
                f"node {dead[0].node_id} died before its upgrade batch")
            return
        if self.cfg.latency_guard_factor is not None:
            ru.pre_batch_hist = self._fleet_fault_hist()
            ru.pre_batch_epoch = (self.kills, self.recoveries)
        for n in batch:
            n.begin_upgrade(ru.module_cls, ru.drain_rounds)
        ru.in_flight = True

    def _validate_batch(self, batch: List[NodeAgent],
                        ru: _RollingUpgrade) -> bool:
        """Abort-on-regression gate after each failure-domain batch."""
        target = ru.module_cls.VERSION
        for n in batch:
            if n.upgrade_failed or n.module_version != target:
                self.upgrade_abort_reason = (
                    f"node {n.node_id}: module swap failed "
                    f"(version {n.module_version} != {target})")
                return False
            if not n.health_probe():
                self.upgrade_abort_reason = (
                    f"node {n.node_id}: post-upgrade health probe failed")
                return False
        guard = self.cfg.latency_guard_factor
        if (guard is not None and ru.baseline_p90_ns > 0
                and ru.pre_batch_hist is not None
                and (self.kills, self.recoveries) == ru.pre_batch_epoch):
            since = _hist_delta(self._fleet_fault_hist(), ru.pre_batch_hist)
            if (since.count >= self.cfg.latency_guard_min_samples
                    and since.percentile(0.90) > guard * ru.baseline_p90_ns):
                self.upgrade_abort_reason = (
                    f"fleet p90 fault latency regressed past "
                    f"{guard:.1f}x baseline")
                return False
        return True

    # ------------------------------------------------------------ snapshots
    def _fleet_fault_hist(self) -> LatencyHistogram:
        agg = LatencyHistogram()
        for n in self.nodes:
            if not n.alive:
                continue
            # the fault_latency property folds pending ring samples itself
            agg.merge(n.system.metrics.fault_latency)
        return agg

    def latency_snapshot(self) -> Dict[str, object]:
        """Fleet-wide latency aggregation (timing-dependent)."""
        out: Dict[str, object] = {}
        fault_agg: Optional[LatencyHistogram] = None
        for name, pick in (("fault", lambda m: m.fault_latency),
                           ("swap_out", lambda m: m.swap_out_latency),
                           ("swap_in", lambda m: m.swap_in_latency)):
            agg = LatencyHistogram()
            for n in self.nodes:
                if not n.alive:
                    continue
                agg.merge(pick(n.system.metrics))
            out[name] = agg.snapshot()
            if name == "fault":
                fault_agg = agg
        # the paper's 10us claim is for passive swap-in (fault path)
        out["frac_fault_under_10us"] = fault_agg.fraction_below(10_000)
        return out

    def snapshot(self) -> Dict[str, object]:
        return {
            "deterministic": {
                "ticks": self.ticks,
                "admitted": self.admitted,
                "rejections": dict(self.rejections),
                "placements": {str(k): v
                               for k, v in sorted(self.placements.items())},
                "reclaimed_mps": self.reclaimed_mps,
                "fleet_committed_ms": self.fleet_committed_ms(),
                "fleet_free_ms": self.fleet_free_ms(),
                "alive_nodes": sum(1 for n in self.nodes if n.alive),
                "kills": self.kills,
                "recoveries": self.recoveries,
                "migrations": self.migrations,
                "migration_mps": self.migration_mps,
                "migrations_rejected": dict(self.migrations_rejected),
                "ms_replaced": self.ms_replaced,
                "ms_lost": self.ms_lost,
                "upgrade_in_progress": self.upgrade_in_progress,
                "upgrade_batches_done": self.upgrade_batches_done,
                "upgrade_aborted": self.upgrade_aborted,
                "upgrade_abort_reason": self.upgrade_abort_reason,
                "nodes": [n.snapshot()["deterministic"]
                          for n in self.nodes],
            },
            "latency": self.latency_snapshot(),
        }

    def deterministic_bytes(self) -> bytes:
        """Canonical serialization of the deterministic snapshot: two
        replays of the same seeded trace must produce identical bytes."""
        return json.dumps(self.snapshot()["deterministic"],
                          sort_keys=True).encode()

    def close(self) -> None:
        for n in self.nodes:
            n.close()
