"""FleetController -- multi-node elasticity orchestration (control plane).

CLUES-style cluster orchestration adapted to memory elasticity: the
controller owns fleet-wide *admission control* for elastic MS
allocations, *pressure-aware placement* onto the least-pressured serving
node, *staggered reclaim* coordination (nodes are partitioned into
stagger groups; only one group's BACK reclaim fires per fleet tick, so
the whole fleet never compresses/swaps in the same window), and *rolling
hot-upgrade* orchestration with failure-domain batching and
abort-on-regression.

Concurrency model: one deterministic event loop. ``tick()`` is a fleet
round that steps every node once; nothing runs on threads, so replaying
a seeded trace is exactly reproducible (see ``trace.TraceReplayer``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple, Type

from ..core.hotupgrade import EngineModule
from ..core.metrics import LatencyHistogram
from .node import NodeAgent

REJECT_OVERCOMMIT = "fleet_overcommit"
REJECT_NO_CAPACITY = "no_serving_capacity"


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Control-plane knobs (per-node knobs stay in TaijiConfig)."""

    # fleet-wide admission cap: committed virtual MSs may not exceed this
    # multiple of the fleet's managed physical MSs. Per-node overcommit is
    # +50% (paper O3); holding the *fleet* at +25% keeps aggregate reclaim
    # pressure bounded even when placement is skewed.
    overcommit_cap: float = 1.25
    # number of reclaim stagger groups: node i belongs to group
    # (i % groups); group (tick % groups) reclaims this tick.
    reclaim_stagger_groups: int = 2
    # rolling upgrade: rounds a node drains before its module swap
    upgrade_drain_rounds: int = 2
    # optional latency guard (abort-on-regression): if set, a batch whose
    # post-upgrade fleet p90 fault latency exceeds guard * the pre-upgrade
    # baseline aborts the rollout. Timing-dependent, so it is OFF by
    # default; the deterministic health probe always runs.
    latency_guard_factor: Optional[float] = None
    latency_guard_min_samples: int = 200


class _RollingUpgrade:
    def __init__(self, module_cls: Type[EngineModule],
                 batches: List[List[NodeAgent]], drain_rounds: int,
                 baseline_p90_ns: float) -> None:
        self.module_cls = module_cls
        self.batches = batches
        self.drain_rounds = drain_rounds
        self.baseline_p90_ns = baseline_p90_ns
        self.batch_idx = 0
        self.in_flight = False
        # fleet fault histogram at batch start: the latency guard judges
        # only the samples recorded *since*, so pre-upgrade history can't
        # dilute a regression
        self.pre_batch_hist: Optional[LatencyHistogram] = None


def _hist_delta(post: LatencyHistogram,
                pre: LatencyHistogram) -> LatencyHistogram:
    """Samples recorded between two cumulative histogram states.

    Buckets/counters are additive so they subtract cleanly; the exact
    reservoir does not, so the delta keeps no samples and ``percentile``
    falls back to bucket math.
    """
    d = LatencyHistogram()
    d.buckets = [a - b for a, b in zip(post.buckets, pre.buckets)]
    d.count = post.count - pre.count
    d.total_ns = post.total_ns - pre.total_ns
    d.max_ns = post.max_ns
    return d


class FleetController:
    def __init__(self, nodes: Sequence[NodeAgent],
                 fleet_cfg: Optional[FleetConfig] = None) -> None:
        self.nodes = list(nodes)
        if not self.nodes:
            raise ValueError("fleet needs at least one node")
        self.cfg = fleet_cfg or FleetConfig()
        if self.cfg.reclaim_stagger_groups < 1:
            raise ValueError("reclaim_stagger_groups must be >= 1")
        self.ticks = 0
        # admission counters
        self.admitted = 0
        self.rejections: Dict[str, int] = {REJECT_OVERCOMMIT: 0,
                                           REJECT_NO_CAPACITY: 0}
        self.placements: Dict[int, int] = {n.node_id: 0 for n in self.nodes}
        self.reclaimed_mps = 0
        # rolling upgrade state
        self._rolling: Optional[_RollingUpgrade] = None
        self.upgrade_batches_done = 0
        self.upgrade_aborted = False
        self.upgrade_abort_reason = ""

    # ---------------------------------------------------------- fleet sums
    def fleet_managed_ms(self) -> int:
        return sum(n.managed_phys_ms for n in self.nodes)

    def fleet_committed_ms(self) -> int:
        return sum(len(n.allocated) for n in self.nodes)

    def fleet_free_ms(self) -> int:
        return sum(n.free_ms for n in self.nodes)

    # ----------------------------------------------------------- admission
    def admit_alloc(self) -> Tuple[Optional[NodeAgent], Optional[int], str]:
        """Admission control + placement for one elastic MS allocation.

        Returns ``(node, gfn, "ok")`` on success, else
        ``(None, None, reason)``. Placement is pressure-aware: the
        least-pressured serving node with virtual headroom wins (node_id
        breaks ties deterministically).
        """
        cap = int(self.fleet_managed_ms() * self.cfg.overcommit_cap)
        if self.fleet_committed_ms() + 1 > cap:
            self.rejections[REJECT_OVERCOMMIT] += 1
            return None, None, REJECT_OVERCOMMIT
        candidates = [n for n in self.nodes
                      if n.serving and len(n.allocated) < n.capacity_ms]
        if not candidates:
            self.rejections[REJECT_NO_CAPACITY] += 1
            return None, None, REJECT_NO_CAPACITY
        node = min(candidates, key=lambda n: (n.pressure(), n.node_id))
        gfn = node.alloc_ms()
        self.admitted += 1
        self.placements[node.node_id] += 1
        return node, gfn, "ok"

    # --------------------------------------------------------- fleet round
    def reclaim_group_of(self, node_index: int) -> int:
        return node_index % self.cfg.reclaim_stagger_groups

    def tick(self) -> int:
        """One fleet round: step every node, stagger reclaim windows,
        drive any in-flight rolling upgrade. Returns MPs reclaimed."""
        groups = self.cfg.reclaim_stagger_groups
        active_group = self.ticks % groups
        reclaimed = 0
        for i, node in enumerate(self.nodes):
            window = node.serving and self.reclaim_group_of(i) == active_group
            reclaimed += node.step(reclaim=window)
        self.reclaimed_mps += reclaimed
        self._drive_rolling()
        self.ticks += 1
        return reclaimed

    # ------------------------------------------------------ rolling upgrade
    def start_rolling_upgrade(self, module_cls: Type[EngineModule],
                              drain_rounds: Optional[int] = None) -> None:
        """Plan a fleet-wide rolling hot-upgrade.

        Nodes are batched by failure domain (one domain in flight at a
        time) so a bad module build can never take out more than one
        domain before the health probes abort the rollout.
        """
        if self._rolling is not None:
            raise RuntimeError("a rolling upgrade is already in flight")
        domains: Dict[int, List[NodeAgent]] = {}
        for n in self.nodes:
            domains.setdefault(n.failure_domain, []).append(n)
        batches = [sorted(domains[d], key=lambda n: n.node_id)
                   for d in sorted(domains)]
        self.upgrade_aborted = False
        self.upgrade_abort_reason = ""
        self.upgrade_batches_done = 0
        self._rolling = _RollingUpgrade(
            module_cls, batches,
            drain_rounds if drain_rounds is not None
            else self.cfg.upgrade_drain_rounds,
            baseline_p90_ns=self._fleet_fault_hist().percentile(0.90))

    @property
    def upgrade_in_progress(self) -> bool:
        return self._rolling is not None

    def _drive_rolling(self) -> None:
        ru = self._rolling
        if ru is None:
            return
        if ru.in_flight:
            batch = ru.batches[ru.batch_idx]
            if any(not n.serving for n in batch):
                return                   # still draining/swapping
            ru.in_flight = False
            if not self._validate_batch(batch, ru):
                self.upgrade_aborted = True
                self._rolling = None
                return
            self.upgrade_batches_done += 1
            ru.batch_idx += 1
        if ru.batch_idx >= len(ru.batches):
            self._rolling = None         # rollout complete
            return
        if self.cfg.latency_guard_factor is not None:
            ru.pre_batch_hist = self._fleet_fault_hist()
        for n in ru.batches[ru.batch_idx]:
            n.begin_upgrade(ru.module_cls, ru.drain_rounds)
        ru.in_flight = True

    def _validate_batch(self, batch: List[NodeAgent],
                        ru: _RollingUpgrade) -> bool:
        """Abort-on-regression gate after each failure-domain batch."""
        target = ru.module_cls.VERSION
        for n in batch:
            if n.upgrade_failed or n.module_version != target:
                self.upgrade_abort_reason = (
                    f"node {n.node_id}: module swap failed "
                    f"(version {n.module_version} != {target})")
                return False
            if not n.health_probe():
                self.upgrade_abort_reason = (
                    f"node {n.node_id}: post-upgrade health probe failed")
                return False
        guard = self.cfg.latency_guard_factor
        if (guard is not None and ru.baseline_p90_ns > 0
                and ru.pre_batch_hist is not None):
            since = _hist_delta(self._fleet_fault_hist(), ru.pre_batch_hist)
            if (since.count >= self.cfg.latency_guard_min_samples
                    and since.percentile(0.90) > guard * ru.baseline_p90_ns):
                self.upgrade_abort_reason = (
                    f"fleet p90 fault latency regressed past "
                    f"{guard:.1f}x baseline")
                return False
        return True

    # ------------------------------------------------------------ snapshots
    def _fleet_fault_hist(self) -> LatencyHistogram:
        agg = LatencyHistogram()
        for n in self.nodes:
            # the fault_latency property folds pending ring samples itself
            agg.merge(n.system.metrics.fault_latency)
        return agg

    def latency_snapshot(self) -> Dict[str, object]:
        """Fleet-wide latency aggregation (timing-dependent)."""
        out: Dict[str, object] = {}
        fault_agg: Optional[LatencyHistogram] = None
        for name, pick in (("fault", lambda m: m.fault_latency),
                           ("swap_out", lambda m: m.swap_out_latency),
                           ("swap_in", lambda m: m.swap_in_latency)):
            agg = LatencyHistogram()
            for n in self.nodes:
                agg.merge(pick(n.system.metrics))
            out[name] = agg.snapshot()
            if name == "fault":
                fault_agg = agg
        # the paper's 10us claim is for passive swap-in (fault path)
        out["frac_fault_under_10us"] = fault_agg.fraction_below(10_000)
        return out

    def snapshot(self) -> Dict[str, object]:
        return {
            "deterministic": {
                "ticks": self.ticks,
                "admitted": self.admitted,
                "rejections": dict(self.rejections),
                "placements": {str(k): v
                               for k, v in sorted(self.placements.items())},
                "reclaimed_mps": self.reclaimed_mps,
                "fleet_committed_ms": self.fleet_committed_ms(),
                "fleet_free_ms": self.fleet_free_ms(),
                "upgrade_in_progress": self.upgrade_in_progress,
                "upgrade_batches_done": self.upgrade_batches_done,
                "upgrade_aborted": self.upgrade_aborted,
                "upgrade_abort_reason": self.upgrade_abort_reason,
                "nodes": [n.snapshot()["deterministic"]
                          for n in self.nodes],
            },
            "latency": self.latency_snapshot(),
        }

    def deterministic_bytes(self) -> bytes:
        """Canonical serialization of the deterministic snapshot: two
        replays of the same seeded trace must produce identical bytes."""
        return json.dumps(self.snapshot()["deterministic"],
                          sort_keys=True).encode()

    def close(self) -> None:
        for n in self.nodes:
            n.close()
