"""FleetController -- multi-node elasticity orchestration (control plane).

CLUES-style cluster orchestration adapted to memory elasticity: the
controller owns fleet-wide *admission control* for elastic MS
allocations, *pressure-aware placement* onto the least-pressured serving
node, *staggered reclaim* coordination (nodes are partitioned into
stagger groups; only one group's BACK reclaim fires per fleet tick, so
the whole fleet never compresses/swaps in the same window), and *rolling
hot-upgrade* orchestration with failure-domain batching and
abort-on-regression, *failure recovery* (a dead node's committed MSs are
re-placed onto survivors under admission control on the next tick), and
*live MS migration* between nodes (export -> admit + import ->
read-verify -> drop, preserving the resident/swapped split).

Concurrency model: one deterministic event loop. ``tick()`` is a fleet
round that steps every node once; nothing runs on threads, so replaying
a seeded trace is exactly reproducible (see ``trace.TraceReplayer``).
"""
from __future__ import annotations

import dataclasses
import json
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

from ..core.hotupgrade import EngineModule
from ..core.metrics import LatencyHistogram
from ..obs.tracer import (ST_FLEET_ADMISSION, ST_FLEET_PLACEMENT,
                          ST_FLEET_RECOVERY, ST_FLEET_STEP, ST_FLEET_TICK,
                          ST_FLEET_UPGRADE)
from .node import NodeAgent

_perf_ns = time.perf_counter_ns

REJECT_OVERCOMMIT = "fleet_overcommit"
REJECT_NO_CAPACITY = "no_serving_capacity"

# migration rejection reasons: all checked *before* any mutation, so a
# rejected migration leaves both nodes untouched
REJECT_MIGRATE_BAD_SRC = "migrate_bad_src"
REJECT_MIGRATE_NO_DST = "migrate_no_dst"
REJECT_MIGRATE_VERIFY = "migrate_verify_failed"

# remap_listener(src_node, old_gfn, dst_node | None, new_gfn | None,
#                data_preserved): how the trace replayer tracks tokens
# across migrations (preserved) and failure re-placements (fresh MS)
RemapListener = Callable[[NodeAgent, int, Optional[NodeAgent],
                          Optional[int], bool], None]


# ------------------------------------------------ remote-tier MS images
# A replica blob is the owner's full export image (guest-visible rows +
# resident/swapped split) compressed as one zlib stream, so the peer can
# hold -- and hand back -- bytes it cannot interpret, and a recovered MS
# re-lands with the elasticity state it left with.
def _encode_ms_image(rows: np.ndarray,
                     resident: np.ndarray) -> Tuple[bytes, int]:
    raw = (np.asarray(resident, dtype=np.uint8).tobytes()
           + np.ascontiguousarray(rows, dtype=np.uint8).tobytes())
    blob = zlib.compress(raw, 1)
    # CRC covers the *stored* bytes: the peer's remote_get re-checksums
    # the blob as held, so rot anywhere between put and get is caught
    # without the peer having to understand (or decompress) the image
    return blob, zlib.crc32(blob)


def _decode_ms_image(blob: bytes, mps_per_ms: int,
                     mp_bytes: int) -> Tuple[np.ndarray, np.ndarray]:
    raw = zlib.decompress(blob)
    resident = np.frombuffer(raw[:mps_per_ms], dtype=np.uint8).astype(bool)
    rows = np.frombuffer(raw[mps_per_ms:], dtype=np.uint8).reshape(
        mps_per_ms, mp_bytes)
    return rows, resident


def _remote_tier(node: NodeAgent) -> int:
    hp = getattr(node.cfg.swap, "hot_path", None)
    return int(getattr(hp, "remote_tier", 0) or 0)


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Control-plane knobs (per-node knobs stay in TaijiConfig)."""

    # fleet-wide admission cap: committed virtual MSs may not exceed this
    # multiple of the fleet's managed physical MSs. Per-node overcommit is
    # +50% (paper O3); holding the *fleet* at +25% keeps aggregate reclaim
    # pressure bounded even when placement is skewed.
    overcommit_cap: float = 1.25
    # number of reclaim stagger groups: node i belongs to group
    # (i % groups); group (tick % groups) reclaims this tick.
    reclaim_stagger_groups: int = 2
    # rolling upgrade: rounds a node drains before its module swap
    upgrade_drain_rounds: int = 2
    # optional latency guard (abort-on-regression): if set, a batch whose
    # post-upgrade fleet p90 fault latency exceeds guard * the pre-upgrade
    # baseline aborts the rollout. Timing-dependent, so it is OFF by
    # default; the deterministic health probe always runs.
    latency_guard_factor: Optional[float] = None
    latency_guard_min_samples: int = 200

    @classmethod
    def production_profile(cls) -> "FleetConfig":
        """The named production rollout profile (ROADMAP wiring item).

        Wires the optional latency guard into abort-on-regression: a
        rollout batch whose post-upgrade fleet p90 fault latency exceeds
        1.5x the pre-rollout baseline aborts, judged only on a deep
        sample window (500 faults) so one noisy probe cannot kill a
        rollout.  Reclaim stagger widens to 4 groups and drains deepen --
        production trades rollout speed for blast-radius control.
        """
        return cls(overcommit_cap=1.25,
                   reclaim_stagger_groups=4,
                   upgrade_drain_rounds=3,
                   latency_guard_factor=1.5,
                   latency_guard_min_samples=500)


class _RollingUpgrade:
    def __init__(self, module_cls: Type[EngineModule],
                 batches: List[List[NodeAgent]], drain_rounds: int,
                 baseline_p90_ns: float) -> None:
        self.module_cls = module_cls
        self.batches = batches
        self.drain_rounds = drain_rounds
        self.baseline_p90_ns = baseline_p90_ns
        self.batch_idx = 0
        self.in_flight = False
        # fleet fault histogram at batch start: the latency guard judges
        # only the samples recorded *since*, so pre-upgrade history can't
        # dilute a regression. pre_batch_epoch snapshots (kills,
        # recoveries): a fleet-membership change between capture and
        # validation invalidates the delta (the dead node's samples are in
        # the pre hist but not the post), so the guard skips that batch.
        self.pre_batch_hist: Optional[LatencyHistogram] = None
        self.pre_batch_epoch: Tuple[int, int] = (0, 0)


def _hist_delta(post: LatencyHistogram,
                pre: LatencyHistogram) -> LatencyHistogram:
    """Samples recorded between two cumulative histogram states.

    Buckets/counters are additive so they subtract cleanly; the exact
    reservoir does not, so the delta keeps no samples and ``percentile``
    falls back to bucket math.
    """
    d = LatencyHistogram()
    d.buckets = [a - b for a, b in zip(post.buckets, pre.buckets)]
    d.count = post.count - pre.count
    d.total_ns = post.total_ns - pre.total_ns
    d.max_ns = post.max_ns
    return d


class FleetController:
    def __init__(self, nodes: Sequence[NodeAgent],
                 fleet_cfg: Optional[FleetConfig] = None) -> None:
        self.nodes = list(nodes)
        if not self.nodes:
            raise ValueError("fleet needs at least one node")
        self.cfg = fleet_cfg or FleetConfig()
        if self.cfg.reclaim_stagger_groups < 1:
            raise ValueError("reclaim_stagger_groups must be >= 1")
        self.ticks = 0
        # admission counters
        self.admitted = 0
        self.rejections: Dict[str, int] = {REJECT_OVERCOMMIT: 0,
                                           REJECT_NO_CAPACITY: 0}
        self.placements: Dict[int, int] = {n.node_id: 0 for n in self.nodes}
        self.reclaimed_mps = 0
        # chaos + migration counters
        self.kills = 0
        self.recoveries = 0
        self.migrations = 0
        self.migration_mps = 0
        self.migrations_rejected: Dict[str, int] = {
            REJECT_MIGRATE_BAD_SRC: 0, REJECT_MIGRATE_NO_DST: 0,
            REJECT_MIGRATE_VERIFY: 0}
        self.ms_replaced = 0             # re-placed after a hard kill (fresh)
        self.ms_lost = 0                 # died with the node, no capacity
        self.remap_listener: Optional[RemapListener] = None
        # remote-peer swap tier (ISSUE 9): controller-brokered leases.
        # (owner_id, gfn) -> (peer_id, peer_epoch): the peer holds a
        # replica of the owner's fully-swapped MS in its BackendStore;
        # peer_epoch (= peer.recoveries at grant) invalidates leases that
        # survive a peer's death + rebirth, whose replica bytes did not.
        self.leases: Dict[Tuple[int, int], Tuple[int, int]] = {}
        # drain-kill leftovers whose only surviving copy is their replica:
        # these recover preserved or count lost -- never fresh-replaced
        self._drain_pending: set = set()
        self.remote_puts = 0             # replicas placed (lease grants)
        self.remote_recovered = 0        # dead-owner MSs rebuilt from peers
        self.remote_rereplicated = 0     # replicas re-placed off a dead peer
        self.remote_dropped = 0          # leases broken (write/free/loss)
        self.remote_evicted = 0          # peer hit its watermark: evict back
        for n in self.nodes:
            n._lease_break = self._on_lease_break
        # rolling upgrade state
        self._rolling: Optional[_RollingUpgrade] = None
        self.upgrade_batches_done = 0
        self.upgrade_aborted = False
        self.upgrade_abort_reason = ""
        # stage-attributed tracing (repro.obs): the controller gets its
        # own tracer when the fleet is traced, on a pid track one past the
        # node ids; None when any node runs untraced
        self.tracer = None
        if all(n.system.metrics.tracer is not None for n in self.nodes):
            from ..obs.tracer import SpanTracer
            obs = self.nodes[0].cfg.obs
            self.tracer = SpanTracer(cap=obs.ring_capacity,
                                     max_spans=obs.max_spans,
                                     pid=len(self.nodes))

    # ---------------------------------------------------------- fleet sums
    # dead nodes are out of the fleet: their physical MSs back nothing and
    # their committed MSs are in-flight to survivors (failure recovery)
    def fleet_managed_ms(self) -> int:
        return sum(n.managed_phys_ms for n in self.nodes if n.alive)

    def fleet_committed_ms(self) -> int:
        return sum(len(n.allocated) for n in self.nodes if n.alive)

    def fleet_free_ms(self) -> int:
        return sum(n.free_ms for n in self.nodes if n.alive)

    def node_by_id(self, node_id: int) -> NodeAgent:
        for n in self.nodes:
            if n.node_id == node_id:
                return n
        raise ValueError(f"unknown node id {node_id}")

    # ----------------------------------------------------------- admission
    def admit_alloc(self) -> Tuple[Optional[NodeAgent], Optional[int], str]:
        """Admission control + placement for one elastic MS allocation.

        Returns ``(node, gfn, "ok")`` on success, else
        ``(None, None, reason)``. Placement is pressure-aware: the
        least-pressured serving node with virtual headroom wins (node_id
        breaks ties deterministically).
        """
        tr = self.tracer
        if tr is not None:
            t0 = _perf_ns()
        cap = int(self.fleet_managed_ms() * self.cfg.overcommit_cap)
        if self.fleet_committed_ms() + 1 > cap:
            self.rejections[REJECT_OVERCOMMIT] += 1
            if tr is not None:
                tr.push(ST_FLEET_ADMISSION, t0, _perf_ns() - t0, 1)
            return None, None, REJECT_OVERCOMMIT
        if tr is not None:
            t_p = _perf_ns()
        node = self._pick_target()
        if tr is not None:
            tr.push(ST_FLEET_PLACEMENT, t_p, _perf_ns() - t_p)
        if node is None:
            self.rejections[REJECT_NO_CAPACITY] += 1
            if tr is not None:
                tr.push(ST_FLEET_ADMISSION, t0, _perf_ns() - t0, 1)
            return None, None, REJECT_NO_CAPACITY
        gfn = node.alloc_ms()
        self.admitted += 1
        self.placements[node.node_id] += 1
        if tr is not None:
            tr.push(ST_FLEET_ADMISSION, t0, _perf_ns() - t0)
        return node, gfn, "ok"

    def _pick_target(self,
                     exclude: Optional[NodeAgent] = None
                     ) -> Optional[NodeAgent]:
        """The one placement policy, shared by admission and migration:
        least-pressured serving node with virtual headroom (node_id
        breaks ties deterministically), optionally excluding a node."""
        candidates = [n for n in self.nodes
                      if n is not exclude and n.serving
                      and len(n.allocated) < n.capacity_ms]
        if not candidates:
            return None
        return min(candidates, key=lambda n: (n.pressure(), n.node_id))

    # --------------------------------------------------------- fleet round
    def reclaim_group_of(self, node_index: int) -> int:
        return node_index % self.cfg.reclaim_stagger_groups

    def tick(self) -> int:
        """One fleet round: detect dead nodes (failure recovery), step
        every surviving node, stagger reclaim windows, drive any in-flight
        rolling upgrade. Returns MPs reclaimed."""
        tr = self.tracer
        if tr is not None:
            t0 = _perf_ns()
        for node in self.nodes:
            if not node.alive and node.allocated:
                if tr is not None:
                    t_r = _perf_ns()
                self._replace_dead_ms(node)
                if tr is not None:
                    tr.push(ST_FLEET_RECOVERY, t_r, _perf_ns() - t_r)
        # settle leases whose *peer* died (or was reborn): re-replicate
        # from the still-alive owner, exactly once per lease
        if self.leases:
            self._settle_dead_peers()
        groups = self.cfg.reclaim_stagger_groups
        active_group = self.ticks % groups
        reclaimed = 0
        if tr is not None:
            t_s = _perf_ns()
        for i, node in enumerate(self.nodes):
            if not node.alive:
                continue
            window = node.serving and self.reclaim_group_of(i) == active_group
            reclaimed += node.step(reclaim=window)
        self.reclaimed_mps += reclaimed
        if tr is not None:
            t_u = _perf_ns()
            tr.push(ST_FLEET_STEP, t_s, t_u - t_s)
        self._drive_rolling()
        # remote-peer tier passes run after stepping: reclaim is what
        # creates the fully-swapped population worth replicating
        self._replicate_pass()
        self._evict_pass()
        self.ticks += 1
        if tr is not None:
            tr.push(ST_FLEET_UPGRADE, t_u, _perf_ns() - t_u)
            tr.push(ST_FLEET_TICK, t0, _perf_ns() - t0)
        return reclaimed

    # ---------------------------------------------------- failure injection
    def kill_node(self, node_id: int, *, drain: bool = False) -> None:
        """Deterministic failure injection: kill one NodeAgent.

        ``drain=True`` is a graceful decommission: committed MSs are
        live-migrated to survivors first (guest-visible bytes preserved);
        whatever cannot be placed dies with the node. ``drain=False`` is
        a hard crash -- contents are lost, and the next :meth:`tick`
        detects the dead node and re-places its committed MSs as fresh
        allocations under normal admission control. Idempotent.
        """
        node = self.node_by_id(node_id)
        if not node.alive:
            return
        if drain:
            for gfn in sorted(node.allocated):
                self.migrate_ms(node, gfn)
            # whatever could not be placed dies with the node -- counted
            # lost, NOT re-placed as a fresh MS (a silent zeroed
            # replacement would mislabel data loss as recovery). Final
            # for *unleased* MSs: their only data source disappears at
            # the kill point, so there is nothing to retry when capacity
            # returns. A leased MS is different (ISSUE 9): its replica
            # outlives the node, so it stays pending on the dead
            # identity and every tick retries lease-driven recovery --
            # the exact scenario the remote tier exists for.
            pending: List[int] = []
            for gfn in sorted(node.allocated):
                if (node.node_id, gfn) in self.leases:
                    pending.append(gfn)
                    self._drain_pending.add((node.node_id, gfn))
                    continue
                self.ms_lost += 1
                if self.remap_listener is not None:
                    self.remap_listener(node, gfn, None, None, False)
            node.allocated.clear()
            node.allocated.update(pending)
        node.kill()
        self.kills += 1

    def recover_node(self, node_id: int) -> None:
        """Bring a killed node back (fresh, empty, serving). If the node
        was never ticked over while dead, its committed MSs are settled
        (re-placed or lost) before the identity is reused. Idempotent."""
        node = self.node_by_id(node_id)
        if node.alive:
            return
        if node.allocated:
            # the identity is being reused: pending MSs settle for good
            self._replace_dead_ms(node, final=True)
        node.recover()
        self.recoveries += 1

    def _replace_dead_ms(self, node: NodeAgent, *, final: bool = False) -> None:
        """Re-place a dead node's committed MSs onto survivors.

        The contents died with the node: each MS re-enters through the
        normal admission path as a fresh (zeroed) allocation. A placement
        shortage can be *transient* -- e.g. candidates draining
        mid-upgrade, or headroom that frees up when another node recovers
        -- so unplaced MSs stay pending on the dead node and every tick
        retries them; only a ``final`` settlement (the node's identity is
        being reused by :meth:`recover_node`) counts them lost. The remap
        listener (the trace replayer) is told about both outcomes so
        token maps and the read-verify written-set stay deterministic.
        """
        remaining: List[int] = []
        for gfn in sorted(node.allocated):
            # remote-peer tier first (ISSUE 9): a valid lease means a live
            # peer holds this MS's full content -- recover it *preserved*
            # instead of re-placing a fresh zeroed MS. Placement bypasses
            # the overcommit admission counter exactly like live
            # migration does (the MS is already committed; this is the
            # same data changing hosts, not a new allocation).
            key = (node.node_id, gfn)
            outcome = self._recover_from_lease(node, gfn)
            if outcome == "recovered":
                self._drain_pending.discard(key)
                continue
            if outcome == "retry" and not final:
                remaining.append(gfn)    # lease valid, no capacity yet
                continue
            if key in self._drain_pending:
                # drain leftover: its bytes only survived on the replica.
                # With the lease unusable (or the settlement final), it
                # is honestly lost -- a fresh zeroed replacement would
                # mislabel data loss as recovery.
                self._drain_pending.discard(key)
                if self._drop_lease(node.node_id, gfn):
                    self.remote_dropped += 1
                self.ms_lost += 1
                if self.remap_listener is not None:
                    self.remap_listener(node, gfn, None, None, False)
                continue
            dst, new_gfn, _reason = self.admit_alloc()
            if dst is None:
                if final:
                    if self._drop_lease(node.node_id, gfn):
                        self.remote_dropped += 1
                    self.ms_lost += 1
                    if self.remap_listener is not None:
                        self.remap_listener(node, gfn, None, None, False)
                else:
                    remaining.append(gfn)
                continue
            if self._drop_lease(node.node_id, gfn):
                self.remote_dropped += 1
            self.ms_replaced += 1
            if self.remap_listener is not None:
                self.remap_listener(node, gfn, dst, new_gfn, False)
        node.allocated.clear()
        node.allocated.update(remaining)

    # ------------------------------------------------- remote-peer tier
    # Zero -> compressed -> remote-peer (ISSUE 9): each serving node with
    # ``hot_path.remote_tier > 0`` gets its fully-swapped MSs replicated
    # onto the least-pressured peer under a controller-brokered lease.
    # The lease registry is the single source of truth; nodes carry only
    # a mirror set (``leased_gfns``) so their write path can break a
    # lease in O(1). Every lease settles exactly once: recovery, owner
    # write/free, peer death (re-replicate or drop), or peer watermark
    # eviction.
    def _replicate_pass(self) -> None:
        """Place replicas for every unleased fully-swapped MS of every
        remote-tier-enabled serving owner. Runs once per tick, after the
        step loop (reclaim is what creates the fully-swapped population)."""
        for owner in self.nodes:
            if not owner.serving or _remote_tier(owner) <= 0:
                continue
            engine = owner.system.engine
            for gfn in sorted(owner.allocated):
                if (owner.node_id, gfn) in self.leases:
                    continue
                if not engine.ms_fully_swapped(gfn):
                    continue
                self._replicate_one(owner, gfn)

    def _replicate_one(self, owner: NodeAgent, gfn: int) -> bool:
        """Export one fully-swapped MS and lease its replica to a peer.

        Peer choice is the shared pressure-aware placement policy
        (:meth:`_pick_target`); a peer already in its critical watermark
        zone is refused -- replicating onto a node in fault-path reclaim
        would trade durability for latency where it hurts most.
        """
        peer = self._pick_target(exclude=owner)
        if peer is None:
            return False
        if peer.system.watermark.zone(peer.free_ms) == "critical":
            return False
        rows, resident = owner.export_ms(gfn)
        blob, crc = _encode_ms_image(rows, resident)
        peer.system.backend.remote_put(owner.node_id, gfn, blob, crc)
        self.leases[(owner.node_id, gfn)] = (peer.node_id, peer.recoveries)
        owner.leased_gfns.add(gfn)
        self.remote_puts += 1
        return True

    def _drop_lease(self, owner_id: int, gfn: int) -> bool:
        """Remove one lease and its replica (if the peer still has it).
        Returns whether a lease existed; the caller attributes the drop
        to the right counter."""
        lease = self.leases.pop((owner_id, gfn), None)
        self.node_by_id(owner_id).leased_gfns.discard(gfn)
        if lease is None:
            return False
        peer = self.node_by_id(lease[0])
        if peer.alive and peer.recoveries == lease[1]:
            peer.system.backend.remote_drop(owner_id, gfn)
        return True

    def _on_lease_break(self, owner: NodeAgent, gfn: int) -> None:
        """Node write-path callback: the owner is about to mutate (or
        free) a leased MS, so the replica is stale the moment the op
        lands. Installed on every NodeAgent at controller construction."""
        if self._drop_lease(owner.node_id, gfn):
            self.remote_dropped += 1

    def _recover_from_lease(self, owner: NodeAgent, gfn: int) -> str:
        """Try to rebuild a dead owner's MS from its peer replica.

        Returns ``"recovered"`` (content-preserving import done),
        ``"retry"`` (lease valid but no placement capacity this tick --
        the replica outlives the owner, so waiting is safe), or
        ``"none"`` (no usable lease: fall through to the legacy
        fresh-replacement path).
        """
        key = (owner.node_id, gfn)
        lease = self.leases.get(key)
        if lease is None:
            return "none"
        peer = self.node_by_id(lease[0])
        if not peer.alive or peer.recoveries != lease[1]:
            del self.leases[key]         # replica died with the peer
            self.remote_dropped += 1
            return "none"
        blob = peer.system.backend.remote_get(owner.node_id, gfn)
        if blob is None:                 # missing or failed its CRC
            del self.leases[key]
            self.remote_dropped += 1
            return "none"
        dst = self._pick_target()        # dead owner is not serving
        if dst is None:
            return "retry"
        rows, resident = _decode_ms_image(blob, owner.cfg.mps_per_ms,
                                          owner.cfg.mp_bytes)
        new_gfn = dst.import_ms(rows, resident)
        self._drop_lease(owner.node_id, gfn)
        self.remote_recovered += 1
        if self.remap_listener is not None:
            self.remap_listener(owner, gfn, dst, new_gfn, True)
        return "recovered"

    def _settle_dead_peers(self) -> None:
        """Settle every lease whose peer died or was reborn (stale
        epoch): re-replicate from the still-alive owner when the MS is
        still eligible, else drop. Exactly once per lease -- the lease
        leaves the registry before any counter moves."""
        for key in sorted(self.leases):
            peer_id, epoch = self.leases[key]
            peer = self.node_by_id(peer_id)
            if peer.alive and peer.recoveries == epoch:
                continue
            owner_id, gfn = key
            del self.leases[key]
            owner = self.node_by_id(owner_id)
            owner.leased_gfns.discard(gfn)
            if (owner.serving and gfn in owner.allocated
                    and owner.system.engine.ms_fully_swapped(gfn)
                    and self._replicate_one(owner, gfn)):
                self.remote_rereplicated += 1
            else:
                self.remote_dropped += 1

    def _evict_pass(self) -> None:
        """Release replicas held by peers that hit their critical
        watermark: the peer's own guests outrank replica hosting, and
        the owner still has the authoritative copy. The next replicate
        pass re-places the MS on a healthier peer if one exists. A dead
        owner's replica is exempt -- it is the *only* surviving copy, so
        the peer keeps carrying it until recovery settles the lease."""
        if not self.leases:
            return
        for key in sorted(self.leases):
            peer_id, epoch = self.leases[key]
            peer = self.node_by_id(peer_id)
            if not peer.alive or peer.recoveries != epoch:
                continue                 # _settle_dead_peers owns these
            if not self.node_by_id(key[0]).alive:
                continue                 # sole copy of a dead owner's MS
            if peer.system.watermark.zone(peer.free_ms) != "critical":
                continue
            peer.system.backend.remote_drop(key[0], key[1])
            del self.leases[key]
            self.node_by_id(key[0]).leased_gfns.discard(key[1])
            self.remote_evicted += 1

    # ------------------------------------------------------- live migration
    def migrate_ms(self, src: Union[NodeAgent, int], gfn: int,
                   dst: Optional[Union[NodeAgent, int]] = None, *,
                   verify: bool = True
                   ) -> Tuple[Optional[NodeAgent], Optional[int], str]:
        """Live MS migration: export on the source, admit + import on the
        destination, read-verify, then drop the source copy.

        Returns ``(dst_node, new_gfn, "ok")`` or ``(None, None, reason)``.
        With ``dst=None`` the least-pressured serving node (excluding the
        source) is chosen, admission-control style. All rejections happen
        before any mutation; a failed read-verify rolls the destination
        copy back and keeps the source authoritative. The resident/swapped
        split of the MS survives the move (import re-stores the swapped
        MPs through the batched store machinery).
        """
        if isinstance(src, int):
            src = self.node_by_id(src)
        if isinstance(dst, int):
            dst = self.node_by_id(dst)
        if not src.alive or gfn not in src.allocated:
            self.migrations_rejected[REJECT_MIGRATE_BAD_SRC] += 1
            return None, None, REJECT_MIGRATE_BAD_SRC
        if dst is None:
            dst = self._pick_target(exclude=src)
        elif (dst is src or not dst.serving
              or len(dst.allocated) >= dst.capacity_ms):
            dst = None
        if dst is None:
            self.migrations_rejected[REJECT_MIGRATE_NO_DST] += 1
            return None, None, REJECT_MIGRATE_NO_DST
        rows, resident = src.export_ms(gfn)      # non-consuming peek
        new_gfn = dst.import_ms(rows, resident)
        if verify:
            # read-verify without faulting: export the imported copy and
            # compare guest-visible bytes against the source image
            got, _res = dst.system.export_ms(new_gfn)
            if not np.array_equal(got, rows):
                dst.evict_ms(new_gfn)            # roll back, keep source
                self.migrations_rejected[REJECT_MIGRATE_VERIFY] += 1
                return None, None, REJECT_MIGRATE_VERIFY
        src.evict_ms(gfn)
        self.migrations += 1
        self.migration_mps += src.cfg.mps_per_ms
        if self.remap_listener is not None:
            self.remap_listener(src, gfn, dst, new_gfn, True)
        return dst, new_gfn, "ok"

    # ------------------------------------------------------ rolling upgrade
    def start_rolling_upgrade(self, module_cls: Type[EngineModule],
                              drain_rounds: Optional[int] = None) -> None:
        """Plan a fleet-wide rolling hot-upgrade.

        Nodes are batched by failure domain (one domain in flight at a
        time) so a bad module build can never take out more than one
        domain before the health probes abort the rollout.
        """
        if self._rolling is not None:
            raise RuntimeError("a rolling upgrade is already in flight")
        domains: Dict[int, List[NodeAgent]] = {}
        for n in self.nodes:
            if not n.alive:              # dead nodes are not upgraded
                continue
            domains.setdefault(n.failure_domain, []).append(n)
        if not domains:
            raise RuntimeError("no alive nodes to upgrade")
        batches = [sorted(domains[d], key=lambda n: n.node_id)
                   for d in sorted(domains)]
        self.upgrade_aborted = False
        self.upgrade_abort_reason = ""
        self.upgrade_batches_done = 0
        self._rolling = _RollingUpgrade(
            module_cls, batches,
            drain_rounds if drain_rounds is not None
            else self.cfg.upgrade_drain_rounds,
            baseline_p90_ns=self._fleet_fault_hist().percentile(0.90))

    @property
    def upgrade_in_progress(self) -> bool:
        return self._rolling is not None

    def _abort_rolling(self, reason: str) -> None:
        self.upgrade_aborted = True
        self.upgrade_abort_reason = reason
        self._rolling = None

    def _drive_rolling(self) -> None:
        ru = self._rolling
        if ru is None:
            return
        if ru.in_flight:
            batch = ru.batches[ru.batch_idx]
            dead = [n for n in batch if not n.alive]
            if dead:
                # a batch member died mid-drain/swap: abort the rollout
                # cleanly. Surviving batch members finish their drain via
                # step() and return to serving -- nothing stays stuck.
                self._abort_rolling(
                    f"node {dead[0].node_id} died mid-upgrade batch")
                return
            if any(not n.serving for n in batch):
                return                   # still draining/swapping
            ru.in_flight = False
            if not self._validate_batch(batch, ru):
                self.upgrade_aborted = True
                self._rolling = None
                return
            self.upgrade_batches_done += 1
            ru.batch_idx += 1
        if ru.batch_idx >= len(ru.batches):
            self._rolling = None         # rollout complete
            return
        batch = ru.batches[ru.batch_idx]
        dead = [n for n in batch if not n.alive]
        if dead:
            self._abort_rolling(
                f"node {dead[0].node_id} died before its upgrade batch")
            return
        if self.cfg.latency_guard_factor is not None:
            ru.pre_batch_hist = self._fleet_fault_hist()
            ru.pre_batch_epoch = (self.kills, self.recoveries)
        for n in batch:
            n.begin_upgrade(ru.module_cls, ru.drain_rounds)
        ru.in_flight = True

    def _validate_batch(self, batch: List[NodeAgent],
                        ru: _RollingUpgrade) -> bool:
        """Abort-on-regression gate after each failure-domain batch."""
        target = ru.module_cls.VERSION
        for n in batch:
            if n.upgrade_failed or n.module_version != target:
                self.upgrade_abort_reason = (
                    f"node {n.node_id}: module swap failed "
                    f"(version {n.module_version} != {target})")
                return False
            if not n.health_probe():
                self.upgrade_abort_reason = (
                    f"node {n.node_id}: post-upgrade health probe failed")
                return False
        guard = self.cfg.latency_guard_factor
        if (guard is not None and ru.baseline_p90_ns > 0
                and ru.pre_batch_hist is not None
                and (self.kills, self.recoveries) == ru.pre_batch_epoch):
            since = _hist_delta(self._fleet_fault_hist(), ru.pre_batch_hist)
            if (since.count >= self.cfg.latency_guard_min_samples
                    and since.percentile(0.90) > guard * ru.baseline_p90_ns):
                self.upgrade_abort_reason = (
                    f"fleet p90 fault latency regressed past "
                    f"{guard:.1f}x baseline")
                return False
        return True

    # ------------------------------------------------------------ snapshots
    def _fleet_fault_hist(self) -> LatencyHistogram:
        agg = LatencyHistogram()
        for n in self.nodes:
            if not n.alive:
                continue
            # the fault_latency property folds pending ring samples itself
            agg.merge(n.system.metrics.fault_latency)
        return agg

    def latency_snapshot(self) -> Dict[str, object]:
        """Fleet-wide latency aggregation (timing-dependent)."""
        out: Dict[str, object] = {}
        fault_agg: Optional[LatencyHistogram] = None
        for name, pick in (("fault", lambda m: m.fault_latency),
                           ("swap_out", lambda m: m.swap_out_latency),
                           ("swap_in", lambda m: m.swap_in_latency)):
            agg = LatencyHistogram()
            for n in self.nodes:
                if not n.alive:
                    continue
                agg.merge(pick(n.system.metrics))
            out[name] = agg.snapshot()
            if name == "fault":
                fault_agg = agg
        # the paper's 10us claim is for passive swap-in (fault path)
        out["frac_fault_under_10us"] = fault_agg.fraction_below(10_000)
        return out

    def snapshot(self) -> Dict[str, object]:
        return {
            "deterministic": {
                "ticks": self.ticks,
                "admitted": self.admitted,
                "rejections": dict(self.rejections),
                "placements": {str(k): v
                               for k, v in sorted(self.placements.items())},
                "reclaimed_mps": self.reclaimed_mps,
                "fleet_committed_ms": self.fleet_committed_ms(),
                "fleet_free_ms": self.fleet_free_ms(),
                "alive_nodes": sum(1 for n in self.nodes if n.alive),
                "kills": self.kills,
                "recoveries": self.recoveries,
                "migrations": self.migrations,
                "migration_mps": self.migration_mps,
                "migrations_rejected": dict(self.migrations_rejected),
                "ms_replaced": self.ms_replaced,
                "ms_lost": self.ms_lost,
                "remote_leases": len(self.leases),
                "remote_puts": self.remote_puts,
                "remote_recovered": self.remote_recovered,
                "remote_rereplicated": self.remote_rereplicated,
                "remote_dropped": self.remote_dropped,
                "remote_evicted": self.remote_evicted,
                "remote_held": sum(n.system.backend.remote_held()
                                   for n in self.nodes if n.alive),
                "remote_modeled_ns": sum(n.system.backend.remote_modeled_ns
                                         for n in self.nodes if n.alive),
                "upgrade_in_progress": self.upgrade_in_progress,
                "upgrade_batches_done": self.upgrade_batches_done,
                "upgrade_aborted": self.upgrade_aborted,
                "upgrade_abort_reason": self.upgrade_abort_reason,
                "nodes": [n.snapshot()["deterministic"]
                          for n in self.nodes],
            },
            "latency": self.latency_snapshot(),
        }

    def deterministic_bytes(self) -> bytes:
        """Canonical serialization of the deterministic snapshot: two
        replays of the same seeded trace must produce identical bytes."""
        return json.dumps(self.snapshot()["deterministic"],
                          sort_keys=True).encode()

    def close(self) -> None:
        for n in self.nodes:
            n.close()
