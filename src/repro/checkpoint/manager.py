"""Versioned checkpointing with atomic commits (fault-tolerance substrate).

Design requirements from DESIGN.md §5:
  * atomic: a checkpoint directory is staged under ``.tmp-<step>`` and
    renamed into place -- a crash mid-save never corrupts the latest
    checkpoint (restart-safe on preemption);
  * complete: params + optimizer state + step + data-pipeline cursor +
    elastic-manager metadata snapshot travel together, so a restart
    resumes the exact stream position;
  * elastic: tensors are stored unsharded (gathered host-side), so a
    restore may use a different mesh/data-axis size than the save
    (elastic scaling across restarts);
  * ABI-tagged: the manifest carries ``abi_version`` (the paper's
    hot-upgrade metadata-compatibility contract, §4.4) and restore
    refuses incompatible layouts.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.config import ABI_VERSION

_MANIFEST = "manifest.json"


def _flatten(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any,
             pipeline_snapshot: Optional[Dict] = None,
             extra: Optional[Dict] = None) -> Path:
        stage = self.dir / f".tmp-{step}"
        final = self.dir / f"step_{step:010d}"
        if stage.exists():
            shutil.rmtree(stage)
        stage.mkdir(parents=True)

        arrays = dict(_flatten(state))
        np.savez(stage / "state.npz", **arrays)
        manifest = {
            "step": step,
            "abi_version": ABI_VERSION,
            "time": time.time(),
            "n_arrays": len(arrays),
            "pipeline": pipeline_snapshot or {},
            "extra": extra or {},
        }
        (stage / _MANIFEST).write_text(json.dumps(manifest, indent=2))
        os.replace(stage, final)               # atomic commit
        self._gc()
        return final

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                       if p.name.startswith("step_"))
        return steps[-1] if steps else None

    def restore(self, state_template: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        """Restore into the structure of ``state_template``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        manifest = json.loads((path / _MANIFEST).read_text())
        if manifest["abi_version"] != ABI_VERSION:
            raise ValueError(
                f"checkpoint ABI {manifest['abi_version']} != {ABI_VERSION}")
        data = np.load(path / "state.npz")
        keys = [k for k, _ in _flatten(state_template)]
        if set(keys) != set(data.files):
            missing = set(keys) - set(data.files)
            extra = set(data.files) - set(keys)
            raise ValueError(f"state layout mismatch: missing={missing} "
                             f"unexpected={extra}")
        leaves = [data[k] for k in keys]
        treedef = jax.tree_util.tree_structure(state_template)
        template_leaves = jax.tree_util.tree_leaves(state_template)
        cast = [np.asarray(l).astype(np.asarray(t).dtype)
                for l, t in zip(leaves, template_leaves)]
        return jax.tree_util.tree_unflatten(treedef, cast), manifest

    # --------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.iterdir()
                       if p.name.startswith("step_"))
        for p in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(p)
