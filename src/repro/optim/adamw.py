"""AdamW with dtype-configurable state + global-norm clipping + schedule.

Self-contained (no optax in this offline container). Moment dtype is
per-arch configurable (``opt_dtype``): the 235B/398B configs use bf16
moments so fully sharded optimizer state fits 16 GB/chip HBM (see
DESIGN.md §5 and the dry-run memory analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def update(grads, state: AdamWState, params, step: jnp.ndarray,
           cfg: AdamWConfig) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(step, cfg)
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1 - cfg.b2)
        upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (upd + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v), {"grad_norm": gnorm, "lr": lr}
