"""Paged decode attention kernel -- block-table indirection inside attn.

The device-side analogue of Taiji's EPT walk on the I/O path: every KV
read during decode goes through the block table, so swapped/compacted
blocks never require relayout of the pool. One grid step = one
(sequence, context-block) pair; the block index comes from the
scalar-prefetched block table; online-softmax state (m, l, acc) lives in
VMEM scratch across the context-block dimension.

Grid: (B, mbs). BlockSpecs: q (1, H, hd) resident per sequence; pool
block (1, bt, 2, KV, hd) selected by ``block_table[b, j]``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(table_ref, kvlen_ref, q_ref, pool_ref, out_ref,
                       m_ref, l_ref, acc_ref, *, bt: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    mbs = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[b]
    block_start = j * bt

    @pl.when(block_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (H, hd)
        kv = pool_ref[0]                                # (bt, 2, KV, hd)
        k = kv[:, 0].astype(jnp.float32)                # (bt, KV, hd)
        v = kv[:, 1].astype(jnp.float32)
        H, hd = q.shape
        KV = k.shape[1]
        g = H // KV
        qg = q.reshape(KV, g, hd)
        s = jnp.einsum("kgd,tkd->kgt", qg, k)           # (KV, g, bt)
        pos = block_start + jnp.arange(bt)
        s = jnp.where(pos[None, None, :] < kv_len, s, NEG_INF)

        m_prev = m_ref[...]                             # (KV, g)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[..., None]
                        + jnp.einsum("kgt,tkd->kgd", p, v))
        m_ref[...] = m_new

    @pl.when(j == mbs - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = acc_ref[...] / l[..., None]               # (KV, g, hd)
        KV, g, hd = out.shape
        out_ref[0] = out.reshape(KV * g, hd).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jnp.ndarray, kv_pool: jnp.ndarray,
                           block_table: jnp.ndarray, kv_len: jnp.ndarray,
                           *, interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, hd); kv_pool: (n_blocks, bt, 2, KV, hd);
    block_table: (B, mbs) i32; kv_len: (B,) i32 -> (B, H, hd).

    NOTE on head layout: grouped heads are laid out KV-major, i.e.
    q[b].reshape(KV, g, hd) -- matching ref.paged_decode_attention.
    """
    B, H, hd = q.shape
    n_blocks, bt, two, KV, _ = kv_pool.shape
    assert two == 2 and H % KV == 0
    mbs = block_table.shape[1]
    g = H // KV
    scale = hd ** -0.5

    kern = functools.partial(_paged_attn_kernel, bt=bt, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # block_table, kv_len
        grid=(B, mbs),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, tbl, kvl: (b, 0, 0)),
            pl.BlockSpec((1, bt, 2, KV, hd),
                         lambda b, j, tbl, kvl: (tbl[b, j], 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, tbl, kvl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, g), jnp.float32),          # running max
            pltpu.VMEM((KV, g), jnp.float32),          # running denom
            pltpu.VMEM((KV, g, hd), jnp.float32),      # accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_table, kv_len, q, kv_pool)
