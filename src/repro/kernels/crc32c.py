"""Swap-verification checksum kernel (paper §7.1).

HARDWARE ADAPTATION NOTE (DESIGN.md §2): CRC32 is a bit-serial polynomial
division -- its GF(2) shift-register structure maps to CPU lookup tables
or dedicated CRC instructions, neither of which exists on the TPU VPU
(8x128 vector lanes, no per-lane byte tables). Rather than force a
degenerate port (a 256-entry gather per byte), we implement the
*equivalent guarantee* -- detecting corrupted swap round-trips -- with a
weighted Fletcher checksum: two modular reductions (sum(x), sum(i*x)),
fully vectorizable, detecting all 1- and 2-byte errors and bursts up to
the weight period like Fletcher-32/Adler-32. The host control plane keeps
zlib.crc32 (paper-faithful); the device path uses this kernel; both are
exercised by the corruption-injection tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_P = 65521  # largest prime < 2^16


def _fletcher_kernel(x_ref, out_ref, *, tile: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.uint32) % _P
    base = (j * tile) % _P
    w = (jnp.arange(x.shape[-1], dtype=jnp.uint32) + 1 + base) % _P
    s1 = jnp.sum(x % _P, axis=-1) % _P
    s2 = jnp.sum((x * w) % _P, axis=-1) % _P
    packed = (s1 | (s2 << jnp.uint32(16))).astype(jnp.uint32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # combine tiles: modular add of the two 16-bit halves
    prev = out_ref[...]
    p1 = prev & jnp.uint32(0xFFFF)
    p2 = prev >> jnp.uint32(16)
    n1 = (p1 + (packed & jnp.uint32(0xFFFF))) % _P
    n2 = (p2 + (packed >> jnp.uint32(16))) % _P
    out_ref[...] = (n1 | (n2 << jnp.uint32(16))).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("tile_elems", "interpret"))
def fletcher_checksum(blocks: jnp.ndarray, *, tile_elems: int = 4096,
                      interpret: bool = True) -> jnp.ndarray:
    """blocks: (n, elems) int -> (n,) uint32 checksums.

    Grid (n, elems // tile); each VMEM tile contributes a partial
    (s1, s2) pair combined modularly across tiles.
    """
    n, elems = blocks.shape
    tile = min(tile_elems, elems)
    assert elems % tile == 0
    kern = functools.partial(_fletcher_kernel, tile=tile)
    return pl.pallas_call(
        kern,
        grid=(n, elems // tile),
        in_specs=[pl.BlockSpec((1, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(blocks)
