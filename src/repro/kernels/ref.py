"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# Fletcher-style weighted checksum parameters (see crc32c.py for why CRC's
# bit-serial structure does not transfer to the TPU VPU)
_CHK_P = jnp.uint32(65521)          # largest prime < 2^16 (Adler/Fletcher)


def zero_detect(blocks: jnp.ndarray) -> jnp.ndarray:
    """blocks: (n, elems) -> (n,) bool, True where the block is all zero."""
    return jnp.all(blocks == 0, axis=-1)


def block_quantize(blocks: jnp.ndarray, mps_per_block: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-MP symmetric int8 quantization.

    blocks: (n, elems) float -> (q (n, elems) int8, scales (n, mps) f32).
    Each block is split into ``mps_per_block`` equal MPs with independent
    absmax scales (the lossy KV-cache backend; beyond-paper).
    """
    n, elems = blocks.shape
    mp = elems // mps_per_block
    x = blocks.reshape(n, mps_per_block, mp).astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(n, elems), scale


def block_dequantize(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of block_quantize -> (n, elems) f32."""
    n, elems = q.shape
    mps = scales.shape[-1]
    x = q.reshape(n, mps, elems // mps).astype(jnp.float32)
    return (x * scales[..., None]).reshape(n, elems)


def fletcher_checksum(blocks: jnp.ndarray) -> jnp.ndarray:
    """Weighted Fletcher-style checksum per block.

    blocks: (n, elems) uint8-valued (any int dtype) ->
    (n,) uint32 = (sum(x) mod p) | ((sum((i+1) * x) mod p) << 16).
    Vectorizable (two reductions) with burst-error detection comparable to
    CRC for the swap-verification use case (paper §7.1).
    """
    x = blocks.astype(jnp.uint32) % _CHK_P
    n, elems = x.shape
    w = (jnp.arange(elems, dtype=jnp.uint32) + 1) % _CHK_P
    # chunked reduction: each term < p (~2^16); uint32 safely sums 2^16
    # terms per chunk before the modular fold
    chunk = 4096
    pad = (-elems) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, (0, pad))
    xc = x.reshape(n, -1, chunk)
    wc = w.reshape(-1, chunk)
    s1 = jnp.sum(jnp.sum(xc, axis=-1) % _CHK_P, axis=-1) % _CHK_P
    s2 = jnp.sum(jnp.sum((xc * wc[None]) % _CHK_P, axis=-1) % _CHK_P,
                 axis=-1) % _CHK_P
    return (s1 | (s2 << jnp.uint32(16))).astype(jnp.uint32)


def gather_blocks(pool: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """Swap data path: out[i] = pool[indices[i]] (block gather)."""
    return pool[indices]


def scatter_blocks(pool: jnp.ndarray, indices: jnp.ndarray,
                   blocks: jnp.ndarray) -> jnp.ndarray:
    """Swap-in data path: pool[indices[i]] = blocks[i]."""
    return pool.at[indices].set(blocks)


def paged_decode_attention(q: jnp.ndarray, kv_pool: jnp.ndarray,
                           block_table: jnp.ndarray, kv_len: jnp.ndarray,
                           ) -> jnp.ndarray:
    """Decode attention through a block table (the EPT walk on the I/O path).

    q: (B, H, hd); kv_pool: (n_blocks, bt, 2, KV, hd);
    block_table: (B, mbs) int32; kv_len: (B,) int32. Returns (B, H, hd).
    """
    B, H, hd = q.shape
    _, bt, _, KV, _ = kv_pool.shape
    mbs = block_table.shape[1]
    gathered = kv_pool[block_table]                # (B, mbs, bt, 2, KV, hd)
    seq = gathered.reshape(B, mbs * bt, 2, KV, hd)
    k, v = seq[:, :, 0], seq[:, :, 1]
    g = H // KV
    qg = q.reshape(B, KV, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(mbs * bt)[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
