"""Pallas TPU kernels for the perf-critical layers (DESIGN.md §2).

Each kernel module uses pl.pallas_call with explicit BlockSpec VMEM
tiling; ops.py exposes jit'd wrappers (interpret=True off-TPU) and ref.py
holds the pure-jnp oracles the tests sweep against.

  zero_detect      -- zero-block detection (backend fast path, Fig 15c)
  compress         -- per-MP int8 quantize/dequantize (device swap backend)
  crc32c           -- Fletcher checksum (swap verification, §7.1; see the
                      hardware-adaptation note for why not bit-serial CRC)
  swap_copy        -- batched block gather/scatter via scalar-prefetched
                      indirection (swap/compaction data path)
  paged_attention  -- decode attention walking the block table in-kernel
                      (the EPT walk on the I/O path)
"""
from . import ops, ref  # noqa: F401
