"""Batched block gather/scatter through an indirection table.

This is the swap engine's data path on device: moving a batch of MS-sized
blocks between pool slots according to the block table (swap-in
placement, compaction/defragmentation, prefetch). The block indices are
scalar-prefetched (``PrefetchScalarGridSpec``) so the DMA engine knows the
source block before the grid step runs -- the Pallas analogue of walking
the EPT before issuing the copy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_blocks(pool: jnp.ndarray, indices: jnp.ndarray,
                  *, interpret: bool = True) -> jnp.ndarray:
    """out[i] = pool[indices[i]].

    pool: (n_pool, elems); indices: (n_out,) int32 -> (n_out, elems).
    The pool BlockSpec's index_map reads the prefetched indices.
    """
    n_out = indices.shape[0]
    elems = pool.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out,),
        in_specs=[pl.BlockSpec((1, elems), lambda i, idx: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, elems), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, elems), pool.dtype),
        interpret=interpret,
    )(indices, pool)


def _scatter_kernel(idx_ref, pool_in_ref, blocks_ref, pool_ref):
    del pool_in_ref                       # aliased with pool_ref
    pool_ref[...] = blocks_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_blocks(pool: jnp.ndarray, indices: jnp.ndarray,
                   blocks: jnp.ndarray, *, interpret: bool = True
                   ) -> jnp.ndarray:
    """pool[indices[i]] = blocks[i]; returns the updated pool (donated).

    Uses input/output aliasing so untouched pool slots keep their data.
    """
    n_out, elems = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_out,),
        in_specs=[pl.BlockSpec((1, elems), lambda i, idx: (idx[i], 0)),
                  pl.BlockSpec((1, elems), lambda i, idx: (i, 0))],
        out_specs=pl.BlockSpec((1, elems), lambda i, idx: (idx[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},      # pool input (after prefetch) -> out
        interpret=interpret,
    )(indices, pool, blocks)
