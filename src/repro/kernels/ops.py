"""Jit'd public wrappers for all Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates the
kernel bodies in interpret mode); on a TPU backend the same calls compile
to Mosaic. The reference oracles live in ref.py; tests sweep
shapes/dtypes asserting allclose between the two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .compress import block_dequantize, block_quantize
from .crc32c import fletcher_checksum
from .paged_attention import paged_decode_attention
from .swap_copy import gather_blocks, scatter_blocks
from .zero_detect import zero_detect


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


def _pick_tile(elems: int, cap: int = 4096) -> int:
    """Largest divisor of ``elems`` not exceeding ``cap`` (VMEM tile)."""
    t = min(cap, elems)
    while elems % t:
        t -= 1
    return t


def batch_zero_detect(blocks: np.ndarray) -> np.ndarray:
    """(n, elems) uint8 host batch -> (n,) bool via the Pallas kernel.

    Device entry point for the backend's batched zero-page scan; on CPU
    containers the kernel runs in interpret mode, so the numpy fallback in
    BackendStore stays the default (cfg.swap.use_pallas_kernels).
    """
    out = zero_detect(jnp.asarray(blocks), tile_elems=_pick_tile(blocks.shape[1]),
                      interpret=default_interpret())
    return np.asarray(out)


def batch_checksum(blocks: np.ndarray) -> np.ndarray:
    """(n, elems) uint8 host batch -> (n,) uint32 Fletcher checksums.

    The device-path integrity tag for batched swaps (DESIGN.md §2); the
    host CRC stored in MS records remains zlib.crc32 so records are
    byte-compatible between scalar and batched paths.
    """
    out = fletcher_checksum(jnp.asarray(blocks),
                            tile_elems=_pick_tile(blocks.shape[1]),
                            interpret=default_interpret())
    return np.asarray(out)


def batch_gather(pool: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """Host entry for the swap-copy gather: ``out[i] = pool[indices[i]]``.

    ``pool`` is an (n_pool, elems) uint8 view of a physical MS frame; the
    indices are scalar-prefetched so the DMA engine knows each source
    block before its grid step (the device analogue of the EPT-walked
    batched swap-out copy).
    """
    out = gather_blocks(jnp.asarray(pool), jnp.asarray(indices, jnp.int32),
                        interpret=default_interpret())
    return np.asarray(out)


def batch_scatter(pool: np.ndarray, indices: np.ndarray,
                  blocks: np.ndarray) -> np.ndarray:
    """Host entry for the swap-copy scatter: ``pool[indices[i]] = blocks[i]``.

    Returns the updated pool as a host array (the device aliases the pool
    buffer in place; the host wrapper materializes the result for the
    caller to store back into the frame).
    """
    out = scatter_blocks(jnp.asarray(pool), jnp.asarray(indices, jnp.int32),
                         jnp.asarray(blocks), interpret=default_interpret())
    return np.asarray(out)


__all__ = [
    "zero_detect", "block_quantize", "block_dequantize",
    "fletcher_checksum", "gather_blocks", "scatter_blocks",
    "paged_decode_attention", "on_tpu", "default_interpret",
    "batch_zero_detect", "batch_checksum", "batch_gather", "batch_scatter",
]
