"""Jit'd public wrappers for all Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates the
kernel bodies in interpret mode); on a TPU backend the same calls compile
to Mosaic. The reference oracles live in ref.py; tests sweep
shapes/dtypes asserting allclose between the two.
"""
from __future__ import annotations

import jax

from .compress import block_dequantize, block_quantize
from .crc32c import fletcher_checksum
from .paged_attention import paged_decode_attention
from .swap_copy import gather_blocks, scatter_blocks
from .zero_detect import zero_detect


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


__all__ = [
    "zero_detect", "block_quantize", "block_dequantize",
    "fletcher_checksum", "gather_blocks", "scatter_blocks",
    "paged_decode_attention", "on_tpu", "default_interpret",
]
