"""Zero-block detection kernel (backend fast path, paper §4.2.2).

76.79% of swapped pages in production are zero pages (paper Fig 15c);
detecting them before compression is the hottest backend operation. The
kernel reduces each block tile-by-tile in VMEM; grid is over blocks, the
element dimension is tiled at ``tile_elems`` so arbitrarily large blocks
(2 MiB MSs) never exceed VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _zero_detect_kernel(x_ref, out_ref):
    j = pl.program_id(1)
    tile_nonzero = jnp.any(x_ref[...] != 0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.ones_like(out_ref)

    @pl.when(tile_nonzero)
    def _mark():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("tile_elems", "interpret"))
def zero_detect(blocks: jnp.ndarray, *, tile_elems: int = 4096,
                interpret: bool = True) -> jnp.ndarray:
    """blocks: (n, elems) -> (n,) bool (True == all zero).

    BlockSpec: (1, tile_elems) VMEM tiles; grid (n, elems // tile_elems).
    """
    n, elems = blocks.shape
    tile = min(tile_elems, elems)
    assert elems % tile == 0, (elems, tile)
    grid = (n, elems // tile)
    out = pl.pallas_call(
        _zero_detect_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(blocks)
    return out
