"""Block quantize/dequantize kernels -- the device-side swap backend.

The paper's zswap backend compresses losslessly on host CPUs. A TPU has
no byte-granular entropy coder, so the TPU-native adaptation (DESIGN.md
§2, beyond-paper) is per-MP symmetric int8 quantization: 2x (bf16) / 4x
(f32) space saving with bounded error, acceptable for KV-cache blocks and
verified against the lossless host path in tests. Each grid step loads
one (1, mp_elems) MP tile into VMEM, computes its absmax scale on the
VPU, and writes the packed int8 tile -- compression at memory bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    scale_ref[...] = jnp.full_like(scale_ref, scale)


@functools.partial(jax.jit, static_argnames=("mps_per_block", "interpret"))
def block_quantize(blocks: jnp.ndarray, mps_per_block: int = 8,
                   *, interpret: bool = True):
    """blocks: (n, elems) float -> (q int8 (n, elems), scales (n, mps) f32).

    Grid: (n, mps_per_block); BlockSpec tiles one MP per step.
    """
    n, elems = blocks.shape
    assert elems % mps_per_block == 0
    mp = elems // mps_per_block
    q, scales = pl.pallas_call(
        _quant_kernel,
        grid=(n, mps_per_block),
        in_specs=[pl.BlockSpec((1, mp), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((1, mp), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((n, elems), jnp.int8),
                   jax.ShapeDtypeStruct((n, mps_per_block), jnp.float32)],
        interpret=interpret,
    )(blocks)
    return q, scales


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32)
                  * scale_ref[0, 0]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def block_dequantize(q: jnp.ndarray, scales: jnp.ndarray,
                     out_dtype=jnp.float32, *, interpret: bool = True):
    """Inverse kernel: (q (n, elems), scales (n, mps)) -> (n, elems)."""
    n, elems = q.shape
    mps = scales.shape[-1]
    mp = elems // mps
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n, mps),
        in_specs=[pl.BlockSpec((1, mp), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, mp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, elems), out_dtype),
        interpret=interpret,
    )(q, scales)
