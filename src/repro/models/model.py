"""Model assembly: parameter init + train/prefill/decode for all families.

Layer stacks are ``lax.scan``-ed over stacked parameters (leading layer
dim) with ``jax.checkpoint`` on the body -- one compiled body per arch
regardless of depth, activation remat by default. The jamba hybrid scans
over groups of ``hybrid_group`` layers (7 mamba + 1 attention, FFN
alternating dense/MoE), keeping heterogeneity inside the scanned body.

Decode uses a paged KV cache: per attention layer a block pool
``(n_blocks, block_tokens, 2, kv_heads, head_dim)`` addressed through a
``(B, max_blocks)`` block table -- the device-side analogue of Taiji's
block-table (EPT) indirection, and the structure the elastic KV manager
swaps at MS granularity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import shard_ctx

from .config import ArchConfig
from .layers import (apply_rope, attention_block,
                     decode_attention, mrope_cos_sin, rms_norm, rope_angles,
                     swiglu)
from .moe import moe_ffn
from .ssm import mamba_block, mamba_decode_step

Params = Dict[str, Any]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ============================================================== param init
def _init_attn(key, cfg: ArchConfig, n: int = 1) -> Params:
    """Attention params, optionally stacked over ``n`` layers."""
    D, hd = cfg.d_model, cfg.head_dim_
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg.param_dtype)
    shape = lambda *s: (n, *s) if n > 1 else s
    std = 0.02
    p = {
        "wq": jax.random.normal(ks[0], shape(D, H * hd), dt) * std,
        "wk": jax.random.normal(ks[1], shape(D, KV * hd), dt) * std,
        "wv": jax.random.normal(ks[2], shape(D, KV * hd), dt) * std,
        "wo": jax.random.normal(ks[3], shape(H * hd, D), dt) * (std / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(shape(H * hd), dt)
        p["bk"] = jnp.zeros(shape(KV * hd), dt)
        p["bv"] = jnp.zeros(shape(KV * hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones(shape(hd), dt)
        p["k_norm"] = jnp.ones(shape(hd), dt)
    return p


def _init_mlp(key, cfg: ArchConfig, d_ff: int, n: int = 1) -> Params:
    D = cfg.d_model
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    shape = lambda *s: (n, *s) if n > 1 else s
    std = 0.02
    return {
        "w_gate": jax.random.normal(ks[0], shape(D, d_ff), dt) * std,
        "w_up": jax.random.normal(ks[1], shape(D, d_ff), dt) * std,
        "w_down": jax.random.normal(ks[2], shape(d_ff, D), dt) * (std / math.sqrt(2 * cfg.n_layers)),
    }


def _init_moe(key, cfg: ArchConfig, n: int = 1) -> Params:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_routed, m.d_ff_expert
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    shape = lambda *s: (n, *s) if n > 1 else s
    std = 0.02
    p = {
        "router": jax.random.normal(ks[0], shape(D, E), dt) * std,
        "w_gate": jax.random.normal(ks[1], shape(E, D, F), dt) * std,
        "w_up": jax.random.normal(ks[2], shape(E, D, F), dt) * std,
        "w_down": jax.random.normal(ks[3], shape(E, F, D), dt) * (std / math.sqrt(2 * cfg.n_layers)),
    }
    if m.n_shared:
        Fs = m.n_shared * F
        p["shared_gate"] = jax.random.normal(ks[4], shape(D, Fs), dt) * std
        p["shared_up"] = jax.random.normal(ks[5], shape(D, Fs), dt) * std
        p["shared_down"] = jax.random.normal(ks[6], shape(Fs, D), dt) * (std / math.sqrt(2 * cfg.n_layers))
    return p


def _init_mamba(key, cfg: ArchConfig, n: int = 1) -> Params:
    mc = cfg.mamba
    D, DI, DS = cfg.d_model, cfg.d_inner, mc.d_state
    dtr = cfg.dt_rank_
    dt = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    shape = lambda *s: (n, *s) if n > 1 else s
    std = 0.02
    # S4-style A init: -(1..d_state) per channel
    A = jnp.tile(jnp.arange(1, DS + 1, dtype=jnp.float32)[None, :], (DI, 1))
    A_log = jnp.log(A).astype(dt)
    if n > 1:
        A_log = jnp.tile(A_log[None], (n, 1, 1))
    return {
        "in_proj": jax.random.normal(ks[0], shape(D, 2 * DI), dt) * std,
        "conv_w": jax.random.normal(ks[1], shape(mc.d_conv, DI), dt) * std,
        "conv_b": jnp.zeros(shape(DI), dt),
        "x_proj": jax.random.normal(ks[2], shape(DI, dtr + 2 * DS), dt) * std,
        "dt_proj": jax.random.normal(ks[3], shape(dtr, DI), dt) * (dtr ** -0.5),
        "dt_bias": jnp.full(shape(DI), math.log(math.e - 1), dt),  # softplus^-1(1)
        "A_log": A_log,
        "D": jnp.ones(shape(DI), dt),
        "out_proj": jax.random.normal(ks[4], shape(DI, D), dt) * (std / math.sqrt(2 * cfg.n_layers)),
    }


def init_params(rng: jax.Array, cfg: ArchConfig) -> Params:
    cfg.validate()
    dt = _dtype(cfg.param_dtype)
    keys = jax.random.split(rng, 16)
    D, V = cfg.d_model, cfg.vocab
    params: Params = {
        "embed": jax.random.normal(keys[0], (V, D), dt) * 0.02,
        "final_norm": jnp.ones((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (D, V), dt) * 0.02
    if cfg.frontend_dim:
        params["frontend_proj"] = jax.random.normal(
            keys[2], (cfg.frontend_dim, D), dt) * 0.02

    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.hybrid_group
        g = cfg.hybrid_group
        n_mamba = g - 1                # mamba layers per group
        n_moe = g // 2                 # MoE FFNs per group (every other)
        n_mlp = g - n_moe              # dense FFNs per group
        sub = jax.random.split(keys[3], 8)
        layers = {
            "ln_mix": jnp.ones((G, g, D), dt),
            "ln_ffn": jnp.ones((G, g, D), dt),
            "attn": _stack_over_groups(lambda k: _init_attn(k, cfg), sub[1], G),
            "mamba": _stack_over_groups(
                lambda k: _init_mamba(k, cfg, n=n_mamba), sub[2], G),
            "moe": _stack_over_groups(
                lambda k: _init_moe(k, cfg, n=n_moe), sub[3], G),
            "mlp": _stack_over_groups(
                lambda k: _init_mlp(k, cfg, cfg.d_ff, n=n_mlp), sub[4], G),
        }
        params["layers"] = layers
        return params

    if cfg.family == "ssm":
        L = cfg.n_layers
        params["layers"] = {
            "ln1": jnp.ones((L, D), dt),
            "mamba": _init_mamba(keys[3], cfg, n=L),
        }
        return params

    # dense / moe / audio / vlm: homogeneous decoder or encoder stack
    m = cfg.moe
    first_dense = m is not None and m.first > 0
    L = cfg.n_layers - (1 if first_dense else 0)
    layers: Params = {
        "ln1": jnp.ones((L, D), dt),
        "ln2": jnp.ones((L, D), dt),
        "attn": _init_attn(keys[3], cfg, n=L),
    }
    if m is not None:
        layers["moe"] = _init_moe(keys[4], cfg, n=L)
    else:
        layers["mlp"] = _init_mlp(keys[4], cfg, cfg.d_ff, n=L)
    params["layers"] = layers
    if first_dense:
        params["layer0"] = {
            "ln1": jnp.ones((D,), dt),
            "ln2": jnp.ones((D,), dt),
            "attn": _init_attn(keys[5], cfg),
            "mlp": _init_mlp(keys[6], cfg, cfg.d_ff),
        }
    return params


def _stack_over_groups(fn, key, G: int) -> Params:
    """Initialize ``fn`` per group and stack leaves -> leading dim G."""
    trees = [fn(k) for k in jax.random.split(key, G)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def param_shapes(cfg: ArchConfig) -> Params:
    """Shape/dtype tree without allocating (dry-run input)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ================================================================= forward
def _ffn_dispatch(x, layer_p, cfg: ArchConfig, is_moe: bool):
    if is_moe:
        return moe_ffn(x, layer_p, cfg)
    return swiglu(x, layer_p["w_gate"], layer_p["w_up"], layer_p["w_down"]), 0.0


def _cast(p, dtype):
    return jax.tree.map(lambda w: w.astype(dtype), p)


def _dense_layer_body(cfg: ArchConfig, cos, sin, causal: bool):
    """Per-layer body for the homogeneous stacks (dense/moe/audio/vlm)."""
    cdt = _dtype(cfg.compute_dtype)
    has_moe = cfg.moe is not None

    def body(carry, layer_p):
        x, aux = carry
        layer_p = _cast(layer_p, cdt)
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        h = attention_block(h, layer_p["attn"], cfg, cos, sin, causal=causal)
        x = x + h
        h = rms_norm(x, layer_p["ln2"], cfg.norm_eps)
        if has_moe:
            h, a = moe_ffn(h, layer_p["moe"], cfg)
        else:
            h, a = _ffn_dispatch(h, layer_p["mlp"], cfg, False)
        return (shard_ctx.act(x + h), aux + a), None

    return body


def _hybrid_group_body(cfg: ArchConfig, cos, sin):
    """jamba: one scanned step = hybrid_group layers."""
    cdt = _dtype(cfg.compute_dtype)
    g = cfg.hybrid_group

    def body(carry, group_p):
        x, aux = carry
        group_p = _cast(group_p, cdt)
        mi = 0
        for j in range(g):
            h = rms_norm(x, group_p["ln_mix"][j], cfg.norm_eps)
            if j == cfg.attn_index:
                h = attention_block(h, group_p["attn"], cfg, cos, sin,
                                    causal=True)
            else:
                mp = jax.tree.map(lambda w: w[mi], group_p["mamba"])
                h = mamba_block(h, mp, cfg)
                mi += 1
            x = x + h
            h = rms_norm(x, group_p["ln_ffn"][j], cfg.norm_eps)
            if j % 2 == 1:                      # MoE every other layer
                mo = jax.tree.map(lambda w: w[j // 2], group_p["moe"])
                h, a = moe_ffn(h, mo, cfg)
            else:
                ml = jax.tree.map(lambda w: w[j // 2], group_p["mlp"])
                h, a = _ffn_dispatch(h, ml, cfg, False)
            x = shard_ctx.act(x + h)
            aux = aux + a
        return (x, aux), None

    return body


def _ssm_layer_body(cfg: ArchConfig):
    cdt = _dtype(cfg.compute_dtype)

    def body(carry, layer_p):
        x, aux = carry
        layer_p = _cast(layer_p, cdt)
        h = rms_norm(x, layer_p["ln1"], cfg.norm_eps)
        h = mamba_block(h, layer_p["mamba"], cfg)
        return (shard_ctx.act(x + h), aux), None

    return body


def _embed_inputs(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    cdt = _dtype(cfg.compute_dtype)
    if cfg.family == "audio":
        x = batch["features"].astype(cdt) @ params["frontend_proj"].astype(cdt)
        return x
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(cdt)
    if cfg.family == "vlm":
        nv = batch["vision_embeds"].shape[1]
        x = lax.dynamic_update_slice(
            x, batch["vision_embeds"].astype(cdt), (0, 0, 0))
        del nv
    return x


def _positions_cos_sin(cfg: ArchConfig, batch: Dict[str, jnp.ndarray], S: int):
    hd = cfg.head_dim_
    if cfg.mrope_sections is not None:
        pos_ids = batch["mrope_pos"]                 # (3, B, S)
        return mrope_cos_sin(pos_ids, hd, cfg.rope_theta, cfg.mrope_sections)
    pos = jnp.arange(S)
    return rope_angles(pos, hd, cfg.rope_theta)


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            *, remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward -> (hidden (B,S,D) fp-compute, aux_loss)."""
    x = shard_ctx.act(_embed_inputs(params, cfg, batch))
    B, S, D = x.shape
    aux = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        body = _ssm_layer_body(cfg)
    elif cfg.family == "hybrid":
        cos, sin = _positions_cos_sin(cfg, batch, S)
        body = _hybrid_group_body(cfg, cos, sin)
    else:
        cos, sin = _positions_cos_sin(cfg, batch, S)
        body = _dense_layer_body(cfg, cos, sin, causal=cfg.causal)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    m = cfg.moe
    if m is not None and m.first > 0 and "layer0" in params:
        p0 = _cast(params["layer0"], _dtype(cfg.compute_dtype))
        h = rms_norm(x, p0["ln1"], cfg.norm_eps)
        h = attention_block(h, p0["attn"], cfg, cos, sin, causal=cfg.causal)
        x = x + h
        h = rms_norm(x, p0["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p0["mlp"]["w_gate"], p0["mlp"]["w_up"],
                       p0["mlp"]["w_down"])

    (x, aux), _ = lax.scan(body, (x, aux), params["layers"])
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    return x, aux


def logits_from_hidden(params: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return shard_ctx.logits(jnp.einsum("...d,dv->...v", x, head.astype(x.dtype)))


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token (decoder) or frame-label (encoder) cross entropy."""
    hidden, aux = forward(params, cfg, batch)
    logits = logits_from_hidden(params, cfg, hidden)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ================================================================== decode
@dataclasses.dataclass
class CacheSpec:
    """Geometry of the paged decode cache for one arch/shape."""
    batch: int
    max_seq: int
    n_attn_layers: int
    n_mamba_layers: int

    def n_blocks(self, cfg: ArchConfig) -> int:
        return self.batch * (self.max_seq // cfg.kv_block_tokens)

    def max_blocks_per_seq(self, cfg: ArchConfig) -> int:
        return self.max_seq // cfg.kv_block_tokens


def attn_layer_count(cfg: ArchConfig) -> int:
    return sum(cfg.is_attn_layer(l) for l in range(cfg.n_layers)
               ) if cfg.n_heads else 0


def mamba_layer_count(cfg: ArchConfig) -> int:
    if cfg.mamba is None:
        return 0
    return sum(not cfg.is_attn_layer(l) for l in range(cfg.n_layers))


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Allocate an empty paged decode cache."""
    spec = CacheSpec(batch, max_seq, attn_layer_count(cfg),
                     mamba_layer_count(cfg))
    bt = cfg.kv_block_tokens
    cache: Dict[str, jnp.ndarray] = {
        "kv_len": jnp.zeros((batch,), jnp.int32),
    }
    if spec.n_attn_layers:
        nb = spec.n_blocks(cfg)
        mbs = spec.max_blocks_per_seq(cfg)
        if cfg.kv_pool_layout == "per_seq":
            # pool factored per sequence: the block table indexes within a
            # sequence's own partition, so gathers stay batch-aligned and
            # shard-local (per-host pools on TPU serving)
            cache["kv_pool"] = jnp.zeros(
                (spec.n_attn_layers, batch, mbs, bt, 2, cfg.n_kv_heads,
                 cfg.head_dim_), dtype)
            cache["block_table"] = jnp.tile(
                jnp.arange(mbs, dtype=jnp.int32)[None, :], (batch, 1))
        else:
            cache["kv_pool"] = jnp.zeros(
                (spec.n_attn_layers, nb, bt, 2, cfg.n_kv_heads, cfg.head_dim_),
                dtype)
            # sequence i owns pool rows [i*mbs, (i+1)*mbs)
            cache["block_table"] = (jnp.arange(batch)[:, None] * mbs
                                    + jnp.arange(mbs)[None, :]).astype(jnp.int32)
    if spec.n_mamba_layers:
        mc = cfg.mamba
        cache["conv_state"] = jnp.zeros(
            (spec.n_mamba_layers, batch, mc.d_conv - 1, cfg.d_inner), jnp.float32)
        cache["ssm_state"] = jnp.zeros(
            (spec.n_mamba_layers, batch, cfg.d_inner, mc.d_state), jnp.float32)
    return cache


def _paged_kv_write(pool_l: jnp.ndarray, block_table: jnp.ndarray,
                    pos: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    bt: int) -> jnp.ndarray:
    """Write one token's K/V into the paged pool.

    pool_l: (n_blocks, bt, 2, KV, hd) [global layout] or
    (B, mbs, bt, 2, KV, hd) [per_seq layout]; pos: (B,) absolute
    positions; k/v: (B, KV, hd).
    """
    B = pos.shape[0]
    blk = jnp.take_along_axis(block_table, (pos // bt)[:, None], axis=1)[:, 0]
    slot = pos % bt
    kv = jnp.stack([k, v], axis=1).astype(pool_l.dtype)      # (B, 2, KV, hd)
    if pool_l.ndim == 6:                     # per_seq layout
        return pool_l.at[jnp.arange(B), blk, slot].set(kv)
    return pool_l.at[blk, slot].set(kv)


def _paged_kv_read(pool_l: jnp.ndarray, block_table: jnp.ndarray,
                   bt: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather a sequence-major KV view: (B, S_max, KV, hd) x2."""
    if pool_l.ndim == 6:                     # per_seq: batch-aligned gather
        B, mbs = block_table.shape
        idx = block_table.reshape(B, mbs, 1, 1, 1, 1)
        gathered = jnp.take_along_axis(pool_l, idx, axis=1)
    else:
        gathered = pool_l[block_table]       # (B, mbs, bt, 2, KV, hd)
    B, mbs, _, _, KV, hd = gathered.shape
    seq = gathered.reshape(B, mbs * bt, 2, KV, hd)
    return seq[:, :, 0], seq[:, :, 1]


def decode_step(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                cache: Dict[str, jnp.ndarray],
                mrope_pos: Optional[jnp.ndarray] = None,
                input_embeds: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step: tokens (B,) -> (logits (B,V), cache').

    ``input_embeds`` (B, D), if given, overrides the token embedding --
    used when replaying a multimodal prefix (vision patches) through the
    decode path.
    """
    cdt = _dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    hd = cfg.head_dim_
    bt = cfg.kv_block_tokens
    pos = cache["kv_len"]                                    # (B,)

    if input_embeds is not None:
        x = input_embeds.astype(cdt)
    else:
        x = params["embed"][tokens].astype(cdt)              # (B, D)

    # rope angles at the current position
    if cfg.mrope_sections is not None:
        p3 = (mrope_pos if mrope_pos is not None
              else jnp.tile(pos[None, :, None], (3, 1, 1)))  # (3, B, 1)
        cos, sin = mrope_cos_sin(p3, hd, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.n_heads:
        cos, sin = rope_angles(pos[:, None], hd, cfg.rope_theta)  # (B,1,half)
    else:
        cos = sin = None

    def attn_decode(h2, layer_p, pool_l):
        q = h2 @ layer_p["wq"]
        k = h2 @ layer_p["wk"]
        v = h2 @ layer_p["wv"]
        if cfg.qkv_bias:
            q, k, v = q + layer_p["bq"], k + layer_p["bk"], v + layer_p["bv"]
        # decode attention is pure-DP over the batch: heads stay replicated
        # per device so the (KV, group) factorization never reshards the
        # batch-local KV pool (EXPERIMENTS.md §Perf cell A)
        q = shard_ctx.act(q.reshape(B, 1, cfg.n_heads, hd))
        k = shard_ctx.act(k.reshape(B, 1, cfg.n_kv_heads, hd))
        v = shard_ctx.act(v.reshape(B, 1, cfg.n_kv_heads, hd))
        if cfg.qk_norm:
            q = rms_norm(q, layer_p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, layer_p["k_norm"], cfg.norm_eps)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        pool_l = shard_ctx.act(_paged_kv_write(
            pool_l, cache["block_table"], pos, k[:, 0], v[:, 0], bt))
        ks, vs = _paged_kv_read(pool_l, cache["block_table"], bt)
        o = decode_attention(q, ks.astype(cdt), vs.astype(cdt),
                             kv_len=pos + 1)
        o = o.reshape(B, cfg.n_heads * hd)
        return o @ layer_p["wo"], pool_l

    new_cache = dict(cache)

    if cfg.family == "hybrid":
        g = cfg.hybrid_group
        G = cfg.n_layers // g

        def group_step(x1, xs):
            group_p, pool_l, conv_g, ssm_g = xs
            group_p = _cast(group_p, cdt)
            mi = 0
            conv_out, ssm_out = [], []
            for j in range(g):
                h = rms_norm(x1, group_p["ln_mix"][j], cfg.norm_eps)
                if j == cfg.attn_index:
                    h, pool_l = attn_decode(h, group_p["attn"], pool_l)
                else:
                    mp = jax.tree.map(lambda w: w[mi], group_p["mamba"])
                    h, cs, ss = mamba_decode_step(
                        h, mp, cfg, conv_g[mi], ssm_g[mi])
                    conv_out.append(cs)
                    ssm_out.append(ss)
                    mi += 1
                x1 = x1 + h
                h = rms_norm(x1, group_p["ln_ffn"][j], cfg.norm_eps)
                if j % 2 == 1:
                    mo = jax.tree.map(lambda w: w[j // 2], group_p["moe"])
                    h, _ = moe_ffn(h[:, None, :], mo, cfg)
                    h = h[:, 0]
                else:
                    ml = jax.tree.map(lambda w: w[j // 2], group_p["mlp"])
                    h = swiglu(h, ml["w_gate"], ml["w_up"], ml["w_down"])
                x1 = x1 + h
            return x1, (pool_l, jnp.stack(conv_out), jnp.stack(ssm_out))

        x, (pools, convs, ssms) = lax.scan(
            group_step, x,
            (params["layers"], cache["kv_pool"],
             cache["conv_state"].reshape(G, g - 1, B, cfg.mamba.d_conv - 1,
                                         cfg.d_inner),
             cache["ssm_state"].reshape(G, g - 1, B, cfg.d_inner,
                                        cfg.mamba.d_state)))
        new_cache["kv_pool"] = pools
        new_cache["conv_state"] = convs.reshape(cache["conv_state"].shape)
        new_cache["ssm_state"] = ssms.reshape(cache["ssm_state"].shape)

    elif cfg.family == "ssm":
        def layer_step(x1, xs):
            layer_p, conv_s, ssm_s = xs
            layer_p = _cast(layer_p, cdt)
            h = rms_norm(x1, layer_p["ln1"], cfg.norm_eps)
            h, cs, ss = mamba_decode_step(h, layer_p["mamba"], cfg,
                                          conv_s, ssm_s)
            return x1 + h, (cs, ss)

        x, (convs, ssms) = lax.scan(
            layer_step, x,
            (params["layers"], cache["conv_state"], cache["ssm_state"]))
        new_cache["conv_state"] = convs
        new_cache["ssm_state"] = ssms

    else:
        m = cfg.moe
        has_layer0 = m is not None and m.first > 0 and "layer0" in params
        pool = cache["kv_pool"]
        pool_rest = pool[1:] if has_layer0 else pool
        if has_layer0:
            p0 = _cast(params["layer0"], cdt)
            h = rms_norm(x, p0["ln1"], cfg.norm_eps)
            h, pool0 = attn_decode(h, p0["attn"], pool[0])
            x = x + h
            h = rms_norm(x, p0["ln2"], cfg.norm_eps)
            x = x + swiglu(h, p0["mlp"]["w_gate"], p0["mlp"]["w_up"],
                           p0["mlp"]["w_down"])

        def layer_step(x1, xs):
            layer_p, pool_l = xs
            layer_p = _cast(layer_p, cdt)
            h = rms_norm(x1, layer_p["ln1"], cfg.norm_eps)
            h, pool_l = attn_decode(h, layer_p["attn"], pool_l)
            x1 = x1 + h
            h = rms_norm(x1, layer_p["ln2"], cfg.norm_eps)
            if m is not None:
                h, _ = moe_ffn(h[:, None, :], layer_p["moe"], cfg)
                h = h[:, 0]
            else:
                ml = layer_p["mlp"]
                h = swiglu(h, ml["w_gate"], ml["w_up"], ml["w_down"])
            return x1 + h, pool_l

        x, pools = lax.scan(layer_step, x, (params["layers"], pool_rest))
        new_cache["kv_pool"] = (jnp.concatenate([pool0[None], pools], axis=0)
                                if has_layer0 else pools)

    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)
    new_cache["kv_len"] = pos + 1
    return logits, new_cache


# ================================================================= prefill
def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Prefill forward: returns last-position logits (B, V) and aux.

    (The 32k-prefill dry-run shape measures the forward data path; cache
    materialization for serving reuses forward's per-layer K/V -- see
    launch/serve.py for the full pipeline.)
    """
    hidden, aux = forward(params, cfg, batch, remat=False)
    last = hidden[:, -1, :]
    return logits_from_hidden(params, cfg, last), aux
