"""Mamba-1 selective SSM block (falcon-mamba, jamba mamba layers).

TPU adaptation: the recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
a chunked scan -- ``lax.scan`` over chunks of length ``chunk`` carrying the
(B, d_inner, d_state) boundary state, with a ``lax.associative_scan``
inside each chunk. This bounds the materialized (B, chunk, d_inner,
d_state) tensor (VMEM/HBM-friendly) while keeping O(log chunk) depth,
instead of a length-S sequential loop or an all-S associative scan.

Decode is the exact single-step recurrence over carried (conv, ssm) state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


from .config import ArchConfig


def _ssm_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def selective_scan_chunked(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                           chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + b_t, returning all h_t and the final state.

    a, b: (B, S, d_inner, d_state) fp32; h0: (B, d_inner, d_state).
    (Reference path -- kernels/ref oracles; the model uses the fused
    per-chunk variant below which never materializes (B,S,DI,DS).)
    """
    B, S, DI, DS = a.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)          # identity transition
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (S + pad) // c
    a_c = a.reshape(B, n, c, DI, DS).transpose(1, 0, 2, 3, 4)
    b_c = b.reshape(B, n, c, DI, DS).transpose(1, 0, 2, 3, 4)

    def step(h, ab):
        ac, bc = ab                               # (B, c, DI, DS)
        pa, pb = lax.associative_scan(_ssm_combine, (ac, bc), axis=1)
        h_all = pa * h[:, None] + pb              # states at every position
        return h_all[:, -1], h_all

    h_final, hs = lax.scan(step, h0, (a_c, b_c))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, n * c, DI, DS)
    return hs[:, :S], h_final


def mamba_scan_fused(xc: jnp.ndarray, dt: jnp.ndarray, Bssm: jnp.ndarray,
                     Cssm: jnp.ndarray, A: jnp.ndarray, D: jnp.ndarray,
                     chunk: int) -> jnp.ndarray:
    """Fused chunked selective scan: y from per-chunk state expansion.

    The (B, chunk, DI, DS) transition/state tensors are built *inside* the
    chunk loop (checkpointed body), so the full (B, S, DI, DS) expansion
    never hits HBM -- forward or backward. This is the TPU-native shape of
    the Mamba recurrence (chunk working set sized for VMEM).

    xc/dt: (B, S, DI) fp32; Bssm/Cssm: (B, S, DS) fp32; A: (DI, DS);
    D: (DI,). Returns y: (B, S, DI) fp32.
    """
    B, S, DI = xc.shape
    DS = A.shape[-1]
    c = min(chunk, S)
    pad = (-S) % c

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2)) \
            if pad else x

    n = (S + pad) // c
    xs = tuple(
        v.reshape(B, n, c, -1).transpose(1, 0, 2, 3)
        for v in (pad_t(xc), pad_t(dt), pad_t(Bssm), pad_t(Cssm)))

    def step(h, chunk_xs):
        xc_c, dt_c, B_c, C_c = chunk_xs           # (B, c, DI|DS)
        a = jnp.exp(dt_c[..., None] * A[None, None])          # (B,c,DI,DS)
        bx = (dt_c * xc_c)[..., None] * B_c[:, :, None, :]
        pa, pb = lax.associative_scan(_ssm_combine, (a, bx), axis=1)
        h_all = pa * h[:, None] + pb
        y_c = jnp.sum(h_all * C_c[:, :, None, :], axis=-1)
        y_c = y_c + xc_c * D[None, None, :]
        return h_all[:, -1], y_c

    h0 = jnp.zeros((B, DI, DS), jnp.float32)
    _, ys = lax.scan(jax.checkpoint(step, prevent_cse=False), h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, n * c, DI)
    return y[:, :S]


def mamba_block(x: jnp.ndarray, p: dict, cfg: ArchConfig,
                ) -> jnp.ndarray:
    """Training/prefill forward. x: (B, S, D) -> (B, S, D)."""
    mc = cfg.mamba
    B, S, D = x.shape
    DI, DS = cfg.d_inner, mc.d_state
    dtr = cfg.dt_rank_

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])   # (B, S, 2*DI)
    xp, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time (kernel d_conv)
    w = p["conv_w"]                                   # (d_conv, DI)
    xp_pad = jnp.pad(xp, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
    xc = sum(xp_pad[:, i : i + S, :] * w[i][None, None, :]
             for i in range(mc.d_conv)) + p["conv_b"]
    xc = jax.nn.silu(xc)

    # input-dependent SSM parameters
    proj = jnp.einsum("bsi,ir->bsr", xc, p["x_proj"])  # (B,S,dtr+2*DS)
    dt_low, Bssm, Cssm = jnp.split(proj, [dtr, dtr + DS], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_low, p["dt_proj"])
                         + p["dt_bias"]).astype(jnp.float32)  # (B,S,DI)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (DI, DS)

    y = mamba_scan_fused(xc.astype(jnp.float32), dt,
                         Bssm.astype(jnp.float32), Cssm.astype(jnp.float32),
                         A, p["D"].astype(jnp.float32), mc.chunk)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def mamba_decode_step(x: jnp.ndarray, p: dict, cfg: ArchConfig,
                      conv_state: jnp.ndarray, ssm_state: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (B, D); conv_state: (B, d_conv-1, DI);
    ssm_state: (B, DI, DS). Returns (out (B, D), conv_state', ssm_state')."""
    mc = cfg.mamba
    B, D = x.shape
    DI, DS = cfg.d_inner, mc.d_state
    dtr = cfg.dt_rank_

    xz = jnp.einsum("bd,de->be", x, p["in_proj"])
    xp, z = jnp.split(xz, 2, axis=-1)                  # (B, DI)

    # conv over the carried window
    w = p["conv_w"]                                    # (d_conv, DI)
    window = jnp.concatenate([conv_state, xp[:, None, :]], axis=1)  # (B,dc,DI)
    xc = jnp.einsum("bci,ci->bi", window, w) + p["conv_b"]
    xc = jax.nn.silu(xc)
    new_conv_state = window[:, 1:, :]

    proj = jnp.einsum("bi,ir->br", xc, p["x_proj"])
    dt_low, Bssm, Cssm = jnp.split(proj, [dtr, dtr + DS], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("br,ri->bi", dt_low, p["dt_proj"])
                         + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A[None])               # (B,DI,DS)
    bx = (dt * xc.astype(jnp.float32))[..., None] * \
        Bssm.astype(jnp.float32)[:, None, :]
    h = a * ssm_state + bx
    y = jnp.sum(h * Cssm.astype(jnp.float32)[:, None, :], axis=-1)
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bi,id->bd", y, p["out_proj"]), new_conv_state, h
