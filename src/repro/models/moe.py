"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Fine-grained MoE in the DeepSeekMoE style: ``n_shared`` always-on experts
plus ``n_routed`` routed experts with top-k gating. Dispatch is the
sort-based (dropping-above-capacity) formulation:

  1. top-k expert ids per token -> (T*k) assignments;
  2. stable-sort assignments by expert id;
  3. position-within-expert via searchsorted run starts;
  4. scatter token ids into an (E, C) slot table (overflow drops);
  5. grouped GEMM via einsum over the (E, C, D) gathered activations;
  6. combine: gather each assignment's output and weighted-sum over k.

Under pjit the sort/gather/scatter become XLA collectives when tokens are
data-sharded and experts are model-sharded (expert parallelism); the
roofline table attributes those bytes to the collective term.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import shard_ctx

from .config import ArchConfig, MoEConfig


def router_topk(x: jnp.ndarray, w_router: jnp.ndarray, top_k: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (T, D) -> (gates (T,k), expert_idx (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    E = w_router.shape[-1]
    me = jnp.mean(probs, axis=0)                       # mean router prob
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _dispatch_tokens(xt: jnp.ndarray, p: dict, cfg: ArchConfig,
                     constrain: bool = True
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based dispatch + grouped GEMM over a flat token set.

    xt: (T, D) -> (out (T, D) fp32, aux scalar).
    """
    m: MoEConfig = cfg.moe
    T, D = xt.shape
    E, k = m.n_routed, m.top_k
    # capacity with a dropless floor for small token counts (decode steps
    # are exact; large training/prefill batches use capacity-factor drops)
    C = min(max(int(T * k / E * m.capacity_factor), 64), T)

    gates, idx, aux = router_topk(xt, p["router"], k)

    # ---- sort assignments by expert ------------------------------------
    flat_e = idx.reshape(-1)                          # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within each expert's run
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < C

    # ---- scatter into the (E, C) slot table ----------------------------
    slot = jnp.where(keep, se * C + pos, E * C)       # drops -> scratch slot
    token_for_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        st.astype(jnp.int32), mode="drop")[: E * C]
    # gather activations; token id T -> zero row
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    c_or_id = shard_ctx.moe_dispatch if constrain else (lambda t: t)
    xe = c_or_id(xt_pad[token_for_slot].reshape(E, C, D))

    # ---- grouped expert GEMMs ------------------------------------------
    h = c_or_id(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    u = c_or_id(jnp.einsum("ecd,edf->ecf", xe, p["w_up"]))
    h = jax.nn.silu(h) * u
    ye = c_or_id(jnp.einsum("ecf,efd->ecd", h, p["w_down"]))   # (E, C, D)

    # ---- combine back to tokens ----------------------------------------
    ye_flat = jnp.concatenate(
        [ye.reshape(E * C, D), jnp.zeros((1, D), ye.dtype)], axis=0)
    # for each sorted assignment: its slot output (dropped -> zero row).
    # Combine in the compute dtype: an fp32 accumulator here upcasts the
    # whole dispatch exchange (fwd + bwd) to fp32 -- measured as 2x the EP
    # all-to-all bytes on qwen3-moe train (EXPERIMENTS.md §Perf cell B).
    # Each token sums exactly top_k contributions, safe in bf16.
    contrib = ye_flat[jnp.where(keep, se * C + pos, E * C)]
    out = jnp.zeros((T + 1, D), xt.dtype).at[st].add(
        (contrib.astype(jnp.float32) * sg[:, None]).astype(xt.dtype),
        mode="drop")[:T]
    return out, aux


def moe_ffn(x: jnp.ndarray, p: dict, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss).

    p: router (D, E); w_gate/w_up (E, D, F); w_down (E, F, D);
       optional shared_{gate,up,down} for the shared experts.

    Two dispatch modes (EXPERIMENTS.md §Perf cell B):
      * global: one sort over all B*S tokens (baseline). Correctness-
        simple, but under pjit the global argsort/scatter of data-sharded
        tokens compiles to cross-device collective chains per layer.
      * grouped: vmap the same dispatch over per-sample groups (B groups
        of S tokens). Sorts become shard-local; the remaining collective
        is the unavoidable expert-parallel (group -> expert) exchange.
    """
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    grouped = m.grouped_dispatch and B > 1 and S >= m.min_group_tokens

    if grouped:
        # no in-group constraints: the vmapped group dim carries the data
        # sharding; constraining (E, C, D) inside vmap would shard C over
        # the batch axis and replicate groups (measured regression)
        outs, auxs = jax.vmap(
            lambda xg: _dispatch_tokens(xg, p, cfg, constrain=False))(x)
        out = outs.reshape(B * S, D)
        aux = jnp.mean(auxs)
    else:
        out, aux = _dispatch_tokens(x.reshape(B * S, D), p, cfg)

    # ---- shared experts (dense, always on) ------------------------------
    xt = x.reshape(B * S, D)
    if m.n_shared:
        g = jnp.einsum("td,df->tf", xt, p["shared_gate"])
        u2 = jnp.einsum("td,df->tf", xt, p["shared_up"])
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u2,
                               p["shared_down"]).astype(out.dtype)

    return out.reshape(B, S, D).astype(x.dtype), aux * m.router_aux_weight
