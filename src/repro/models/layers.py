"""Core transformer layers: RMSNorm, RoPE / M-RoPE, GQA attention with
chunked (flash-semantics) computation, SwiGLU MLP.

Attention never materializes the full S x S score matrix: an outer
``lax.scan`` over query chunks carries nothing, and an inner scan over KV
chunks carries running (max, denominator, accumulator) -- the standard
online-softmax formulation, which is what makes the 32k prefill and 4k x
256 training shapes fit per-device HBM.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import shard_ctx

from .config import ArchConfig

NEG_INF = -1e30


# --------------------------------------------------------------------- norm
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------- rope
def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int -> cos/sin of shape (..., S, dim//2)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def mrope_cos_sin(pos_ids: jnp.ndarray, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """M-RoPE (qwen2-vl): pos_ids (3, B, S) for (t, h, w) axes.

    Each rotary pair belongs to one of the three sections; its angle uses
    that axis's position id. Returns cos/sin (B, S, head_dim//2).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # section id per rotary pair: [0]*s0 + [1]*s1 + [2]*s2
    sec_id = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    # pick the position for each pair from the matching (t/h/w) axis:
    # (half, B, S) -> (B, S, half)
    pos = pos_ids.astype(jnp.float32)[sec_id, :, :].transpose(1, 2, 0)
    # angle = pos * freq per pair
    ang = pos * freqs[None, None, :]
    return jnp.cos(ang), jnp.sin(ang)


# ----------------------------------------------------- chunked attention
#
# Flash-semantics attention with a CUSTOM VJP. Plain autodiff through the
# online-softmax scans makes XLA save the per-tile probability tensors for
# the backward pass -- O(S^2) memory again, measured at ~15 GB/device/layer
# on the train_4k cells. The custom backward recomputes each tile's
# probabilities from the saved logsumexp (the FlashAttention-2 recipe),
# so both passes stay O(S * chunk) in memory.

class _FlashCfg(NamedTuple):
    causal: bool
    cq: int
    ckv: int
    scale: float
    q_offset: int
    nq: int
    nkv: int
    skv: int                     # valid kv length (for padding mask)


def _tile_bias(cfg: _FlashCfg, qi, kj) -> jnp.ndarray:
    """2-D (cq, ckv) additive bias for tile (qi, kj): padding + causality.

    Kept 2-D (no B/H dims) so XLA cannot hoist a 5-D mask buffer out of
    the chunk loops (measured 37 GB/device before this change).
    """
    kpos = kj * cfg.ckv + jnp.arange(cfg.ckv)
    bias = jnp.where(kpos < cfg.skv, 0.0, NEG_INF)[None, :]
    if cfg.causal:
        qpos = cfg.q_offset + qi * cfg.cq + jnp.arange(cfg.cq)
        bias = bias + jnp.where(qpos[:, None] >= kpos[None, :], 0.0, NEG_INF)
    return bias.astype(jnp.float32)


def _flash_fwd_pass(cfg: _FlashCfg, qs, ks, vs):
    """qs: (nq, B, cq, H, hd) pre-scaled; ks/vs: (nkv, B, ckv, H, hd).

    Returns out (nq, B, cq, H, hd) and lse (nq, B, H, cq).
    """
    nq, B, cq, H, hd = qs.shape

    def q_step(_, qi_q):
        qi, qc = qi_q
        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        o0 = jnp.zeros((B, cq, H, hd), jnp.float32)

        def kv_step(carry, kj_kv):
            m, l, o = carry
            kj, kc, vc = kj_kv
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32)
            s = s + _tile_bias(cfg, qi, kj)[None, None]
            mc = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, mc)
            p = jnp.exp(s - m_new[..., None])
            a = jnp.exp(m - m_new)
            l_new = l * a + jnp.sum(p, axis=-1)
            oc = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vc.dtype), vc)
            o_new = o * a.transpose(0, 2, 1)[..., None] + oc.astype(jnp.float32)
            return (m_new, l_new, o_new), None

        (m, l, o), _ = lax.scan(kv_step, (m0, l0, o0),
                                (jnp.arange(cfg.nkv), ks, vs))
        l = jnp.maximum(l, 1e-30)
        out = (o / l.transpose(0, 2, 1)[..., None]).astype(vs.dtype)
        lse = m + jnp.log(l)
        return None, (out, lse)

    _, (outs, lses) = lax.scan(q_step, None, (jnp.arange(nq), qs))
    return outs, lses


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(cfg: _FlashCfg, qs, ks, vs):
    out, _ = _flash_fwd_pass(cfg, qs, ks, vs)
    return out


def _flash_fwd(cfg: _FlashCfg, qs, ks, vs):
    out, lse = _flash_fwd_pass(cfg, qs, ks, vs)
    return out, (qs, ks, vs, out, lse)


def _flash_bwd(cfg: _FlashCfg, res, do):
    qs, ks, vs, out, lse = res
    nq, B, cq, H, hd = qs.shape
    # delta_i = sum_d do_id * o_id  -> (nq, B, H, cq)
    delta = jnp.einsum("nbqhd,nbqhd->nbhq", do.astype(jnp.float32),
                       out.astype(jnp.float32))

    def p_tile(qi, kj, qc, kc, lse_c):
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32)
        s = s + _tile_bias(cfg, qi, kj)[None, None]
        return jnp.exp(s - lse_c[..., None])          # (B,H,cq,ckv)

    # ---- dq: outer scan over q chunks, inner over kv chunks
    def dq_step(_, xs):
        qi, qc, do_c, lse_c, delta_c = xs

        def kv_step(dq_acc, kj_kv):
            kj, kc, vc = kj_kv
            p = p_tile(qi, kj, qc, kc, lse_c)
            dp = jnp.einsum("bqhd,bkhd->bhqk", do_c.astype(jnp.float32),
                            vc.astype(jnp.float32))
            ds = p * (dp - delta_c[..., None])
            return dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                       kc.astype(jnp.float32)), None

        dq0 = jnp.zeros((B, cq, H, hd), jnp.float32)
        dq_c, _ = lax.scan(kv_step, dq0, (jnp.arange(cfg.nkv), ks, vs))
        return None, dq_c

    _, dqs = lax.scan(dq_step, None,
                      (jnp.arange(nq), qs, do, lse, delta))

    # ---- dk/dv: outer scan over kv chunks, inner over q chunks
    ckv = ks.shape[2]

    def dkv_step(_, xs):
        kj, kc, vc = xs

        def q_step(acc, qx):
            dk_acc, dv_acc = acc
            qi, qc, do_c, lse_c, delta_c = qx
            p = p_tile(qi, kj, qc, kc, lse_c)
            dv_acc = dv_acc + jnp.einsum("bhqk,bqhd->bkhd", p,
                                         do_c.astype(jnp.float32))
            dp = jnp.einsum("bqhd,bkhd->bhqk", do_c.astype(jnp.float32),
                            vc.astype(jnp.float32))
            ds = p * (dp - delta_c[..., None])
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds,
                                         qc.astype(jnp.float32))
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, ckv, H, hd), jnp.float32)
        (dk_c, dv_c), _ = lax.scan(q_step, (z, z),
                                   (jnp.arange(nq), qs, do, lse, delta))
        return None, (dk_c, dv_c)

    _, (dks, dvs) = lax.scan(dkv_step, None, (jnp.arange(cfg.nkv), ks, vs))
    return dqs.astype(qs.dtype), dks.astype(ks.dtype), dvs.astype(vs.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, causal: bool, chunk_q: int, chunk_kv: int,
                      scale: Optional[float] = None,
                      q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention with flash custom VJP.

    q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) with Hq % Hkv == 0 (GQA:
    K/V are repeated to Hq -- the repeat's own VJP reduces the grads back
    over the head groups). Returns (B, Sq, Hq, hd).
    ``q_offset``: absolute position of q[0] (decode: Skv - 1).
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    if Hkv != Hq:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else hd ** -0.5
    q = (q.astype(jnp.float32) * scale).astype(q.dtype)

    cq = min(chunk_q, Sq)
    ckv = min(chunk_kv, Skv)
    pad_q = (-Sq) % cq
    pad_kv = (-Skv) % ckv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq = (Sq + pad_q) // cq
    nkv = (Skv + pad_kv) // ckv

    qs = q.reshape(B, nq, cq, Hq, hd).transpose(1, 0, 2, 3, 4)
    ks = k.reshape(B, nkv, ckv, Hq, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nkv, ckv, Hq, hd).transpose(1, 0, 2, 3, 4)

    cfg = _FlashCfg(causal=causal, cq=cq, ckv=ckv, scale=scale,
                    q_offset=q_offset, nq=nq, nkv=nkv, skv=Skv)
    outs = _flash(cfg, qs, ks, vs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, Hq, hd)
    return out[:, :Sq]


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: Optional[jnp.ndarray] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token decode attention.

    q: (B, 1, Hq, hd); k/v: (B, S, Hkv, hd); kv_len: (B,) valid lengths.
    """
    B, _, Hq, hd = q.shape
    _, S, Hkv, _ = k.shape
    scale = scale if scale is not None else hd ** -0.5
    g = Hq // Hkv
    qg = (q.astype(jnp.float32) * scale).astype(q.dtype).reshape(B, Hkv, g, hd)
    # keep k/v in their storage dtype: upcasting them here made XLA hoist
    # a full-pool fp32 convert + gather out of the layer scan (77 GB/step
    # measured on decode_32k -- see EXPERIMENTS.md §Perf cell A)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                   preferred_element_type=jnp.float32)
    if kv_len is not None:
        mask = jnp.arange(S)[None, None, None, :] < kv_len[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------- mlp
def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = shard_ctx.ffn_hidden(jnp.einsum("...d,df->...f", x, w_gate))
    u = shard_ctx.ffn_hidden(jnp.einsum("...d,df->...f", x, w_up))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


# ------------------------------------------------------------ attention op
def attention_block(x: jnp.ndarray, p: dict, cfg: ArchConfig,
                    cos: jnp.ndarray, sin: jnp.ndarray,
                    *, causal: bool) -> jnp.ndarray:
    """Full attention sub-layer (projections + rope + chunked attn)."""
    B, S, D = x.shape
    hd = cfg.head_dim_
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = shard_ctx.heads(q.reshape(B, S, cfg.n_heads, hd))
    k = shard_ctx.heads(k.reshape(B, S, cfg.n_kv_heads, hd), kv=True)
    v = shard_ctx.heads(v.reshape(B, S, cfg.n_kv_heads, hd), kv=True)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = chunked_attention(q, k, v, causal=causal,
                          chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    o = o.reshape(B, S, cfg.n_heads * hd)
    return shard_ctx.act(jnp.einsum("bse,ed->bsd", o, p["wo"]))
