"""Architecture configuration schema for the model zoo.

One dataclass covers all ten assigned architectures; family-specific
features (GQA geometry, qk-norm, QKV bias, MoE, Mamba, M-RoPE, encoder vs
decoder) are flags/sub-configs. Exact per-arch values live in
``src/repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int                 # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0             # shared (always-on) experts
    # which layers are MoE: every `freq`-th layer, starting at `first`
    freq: int = 1
    first: int = 0                # deepseek-moe: layer 0 stays dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # grouped dispatch (§Perf cell B): sort/scatter within per-sample
    # groups (vmapped over batch) instead of one global token sort, so
    # dispatch collectives reduce to the expert-parallel all-to-all
    grouped_dispatch: bool = False
    min_group_tokens: int = 256   # fall back to global sort below this


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    chunk: int = 128              # chunked selective-scan length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | hybrid | audio | vlm | ssm
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int                  # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 0                 # dense FFN hidden (0 for pure-MoE FFNs)
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True           # False -> encoder-only (hubert)
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # hybrid interleave: within each group of `hybrid_group` layers, the
    # layer at index `attn_index` is attention, the rest are mamba
    # (jamba: 1 attention per 8 layers)
    hybrid_group: int = 0
    attn_index: int = 0
    # M-RoPE (qwen2-vl): per-axis (t, h, w) rotary sections over head_dim/2
    mrope_sections: Optional[Tuple[int, int, int]] = None
    # modality frontend stub: input embeddings dimensionality (audio/vlm)
    frontend_dim: int = 0
    max_vision_tokens: int = 0    # vlm: image patch embeddings per sample
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"
    # attention chunking (flash-semantics) for long sequences
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    # serving geometry
    kv_block_tokens: int = 64
    # paged-KV pool layout: "global" (one flat block pool, vLLM-style,
    # baseline) or "per_seq" (pool factored (B, blocks_per_seq, ...) so the
    # block-table gather is batch-aligned and shard-local -- the per-host
    # pool layout used on TPU serving; see EXPERIMENTS.md §Perf cell A)
    kv_pool_layout: str = "global"

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def dt_rank_(self) -> int:
        if self.mamba is None:
            return 0
        return self.mamba.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return 0 if self.mamba is None else self.mamba.expand * self.d_model

    def is_attn_layer(self, layer: int) -> bool:
        if self.family == "ssm":
            return False
        if self.hybrid_group:
            return layer % self.hybrid_group == self.attn_index
        return True

    def is_moe_layer(self, layer: int) -> bool:
        if self.moe is None:
            return False
        m = self.moe
        return layer >= m.first and (layer - m.first) % m.freq == 0

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "hybrid", "audio", "vlm", "ssm")
        if self.family == "ssm":
            assert self.mamba is not None and self.n_heads == 0
        if self.family == "hybrid":
            assert self.hybrid_group > 0 and self.mamba is not None
        if self.family in ("dense", "moe", "audio", "vlm"):
            assert self.n_heads > 0
        if self.n_heads:
            assert self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0

    # parameter count (for 6ND model-FLOPs in the roofline)
    def param_count(self) -> int:
        D, V = self.d_model, self.vocab
        hd = self.head_dim_
        n = V * D                              # embedding
        if not self.tie_embeddings:
            n += D * V                         # lm head
        for l in range(self.n_layers):
            n += 2 * D                         # norms
            if self.is_attn_layer(l) and self.n_heads:
                q = D * self.n_heads * hd
                kv = 2 * D * self.n_kv_heads * hd
                o = self.n_heads * hd * D
                n += q + kv + o
                if self.qkv_bias:
                    n += (self.n_heads + 2 * self.n_kv_heads) * hd
                if self.qk_norm:
                    n += 2 * hd
            elif self.mamba is not None:
                di, s = self.d_inner, self.mamba.d_state
                dtr = self.dt_rank_
                n += D * 2 * di                # in_proj
                n += self.mamba.d_conv * di + di   # conv + bias
                n += di * (dtr + 2 * s)        # x_proj
                n += dtr * di + di             # dt_proj + bias
                n += di * s + di               # A_log + D
                n += di * D                    # out_proj
            if self.is_moe_layer(l):
                m = self.moe
                n += D * m.n_routed            # router
                n += m.n_routed * 3 * D * m.d_ff_expert
                n += m.n_shared * 3 * D * m.d_ff_expert
            elif self.d_ff:
                n += 3 * D * self.d_ff         # swiglu mlp
        if self.frontend_dim:
            n += self.frontend_dim * D         # frontend projection stub
        return n

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        n_moe_layers = sum(self.is_moe_layer(l) for l in range(self.n_layers))
        inactive = n_moe_layers * (m.n_routed - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return total - inactive
