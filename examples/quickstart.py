"""Quickstart: train a ~100M-parameter decoder end to end.

    PYTHONPATH=src python examples/quickstart.py --steps 300

builds a ~100M qwen3-style model (exact configs for the ten assigned
architectures live in src/repro/configs/), streams synthetic data,
checkpoints every 50 steps, and survives restarts (rerun the command --
it resumes from the latest checkpoint). ``--tiny`` shrinks the model for
a <1 minute smoke run on CPU.
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticPipeline
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.train import steps


def config_100m() -> ArchConfig:
    return ArchConfig(
        name="quickstart-100m", family="dense", vocab=32768,
        d_model=640, n_layers=10, n_heads=10, n_kv_heads=2, head_dim=64,
        d_ff=1792, qk_norm=True, attn_chunk_q=128, attn_chunk_kv=256,
    )


def config_tiny() -> ArchConfig:
    return dataclasses.replace(config_100m(), vocab=2048, d_model=128,
                               n_layers=4, n_heads=4, n_kv_heads=2,
                               head_dim=32, d_ff=384)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    args = ap.parse_args()

    cfg = config_tiny() if args.tiny else config_100m()
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.n_layers}L d{cfg.d_model})")

    opt_cfg = adamw.AdamWConfig(lr=6e-4, total_steps=args.steps,
                                warmup_steps=max(1, args.steps // 20))
    state = steps.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    pipe = SyntheticPipeline(cfg, args.batch, args.seq, seed=0)
    ckpt = CheckpointManager(args.ckpt_dir)

    start = 0
    if ckpt.latest_step() is not None:
        state, manifest = ckpt.restore(state)
        pipe.restore(manifest["pipeline"])
        start = manifest["step"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(lambda s, b: steps.train_step(s, b, cfg, opt_cfg),
                      donate_argnums=(0,))
    t0 = time.time()
    first_loss = None
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % 10 == 0 or i == start:
            loss = float(metrics["loss"])
            first_loss = first_loss if first_loss is not None else loss
            rate = (i + 1 - start) / (time.time() - t0)
            print(f"step {i+1:4d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {rate:.2f} it/s")
            assert np.isfinite(loss)
        if (i + 1) % 50 == 0:
            ckpt.save(i + 1, state, pipe.snapshot())
    ckpt.save(args.steps, state, pipe.snapshot())
    print(f"done; loss {first_loss:.3f} -> {float(metrics['loss']):.3f}")


if __name__ == "__main__":
    main()
