"""Elastic MoE expert cache during training (reduced deepseek-moe).

    PYTHONPATH=src python examples/elastic_moe_training.py

Trains the reduced deepseek-moe config while mirroring its routed-expert
weights in a Taiji ElasticExpertCache sized for only a fraction of the
experts: the router's empirical distribution keeps hot experts resident
while cold ones live compressed, exactly the paper's "reserved for peak,
cold in practice" memory -- and every step verifies the faulted-back
weights match training state bit-for-bit (CRC-guarded round trip).
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduce import reduced_config
from repro.core.config import LRUConfig
from repro.core.elastic_params import ElasticExpertCache, make_expert_taiji_config
from repro.core.system import TaijiSystem
from repro.data.pipeline import SyntheticPipeline
from repro.models.moe import router_topk
from repro.optim import adamw
from repro.train import steps


def main() -> None:
    cfg = reduced_config("deepseek-moe-16b")
    m = cfg.moe
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=40)
    state = steps.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    pipe = SyntheticPipeline(cfg, 4, 64, seed=0)
    step_fn = jax.jit(lambda s, b: steps.train_step(s, b, cfg, opt_cfg))

    # expert cache: physical room for only 1/2 of the routed experts
    e_shape = (cfg.d_model, m.d_ff_expert)
    e_bytes = int(np.prod(e_shape)) * 4
    tcfg = make_expert_taiji_config(
        e_bytes * 3 + 64, m.n_routed // 2, m.n_routed,
        lru=LRUConfig(scan_interval_s=0.002, workers=1, stabilize_scans=1))
    system = TaijiSystem(tcfg)
    # the GuestSpace is the sanctioned surface: every expert read/write
    # below goes through typed MS views on it (attach a TraceRecorder
    # here to capture the churn as a replayable fleet trace)
    cache = ElasticExpertCache(system.guest, m.n_routed,
                               (3, *e_shape), dtype=np.float32)

    def expert_weights(params, eid):
        moe = params["layers"]["moe"]
        return np.stack([np.asarray(moe["w_gate"][0, eid]),
                         np.asarray(moe["w_up"][0, eid]),
                         np.asarray(moe["w_down"][0, eid].T)])

    for eid in range(m.n_routed):
        cache.put_expert(eid, expert_weights(state.params, eid))

    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        # which experts does the router activate for this batch?
        x = state.params["embed"][batch["tokens"]].reshape(-1, cfg.d_model)
        _, idx, _ = router_topk(x, state.params["layers"]["moe"]["router"][0],
                                m.top_k)
        active = sorted(set(np.asarray(idx).reshape(-1).tolist()))
        cache.note_routing(active)
        with cache.prepare_dispatch(active):     # swap in + pin for the step
            state, metrics = step_fn(state, batch)
        # push updated weights back to the elastic store
        for eid in active:
            cache.put_expert(eid, expert_weights(state.params, eid))
        for _ in range(2):
            system.lru.scan_shard(0, 1)
        system.engine.reclaim_round()
        if (step + 1) % 10 == 0:
            res = cache.residency()
            print(f"step {step+1:3d} loss={float(metrics['loss']):.4f} "
                  f"experts resident={res['resident_experts']} "
                  f"swapped={res['swapped_experts']}")

    # verify every expert (faulting cold ones back in) matches train state
    for eid in range(m.n_routed):
        np.testing.assert_array_equal(cache.get_expert(eid),
                                      expert_weights(state.params, eid))
    print("all expert weights verified through the elastic store")
    st = system.stats()["metrics"]
    print(f"expert swaps: out={st['ms_swapped_out']} faults={st['faults']}")
    system.close()


if __name__ == "__main__":
    main()
