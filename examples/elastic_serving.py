"""Elastic serving: more live sequences than physical KV capacity.

    PYTHONPATH=src python examples/elastic_serving.py

Runs the full Taiji stack under a multi-turn serving workload (reduced
qwen3-4b): idle sequences cool down in the multi-level LRU, the watermark
policy swaps their KV blocks to the zero/compressed backend, and each
scheduled batch pins + faults its blocks back in before decoding (the DMA
contract). Halfway through, the swap engine is HOT-UPGRADED v1 -> v2
under load -- serving never stops (paper §4.4).

All guest memory flows through the system's GuestSpace (the sanctioned
surface); pass ``--capture trace.tsv`` to attach a TraceRecorder and
write the serving workload as a replayable fleet trace.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs.reduce import reduced_config
from repro.core import EngineModule, EngineModuleV2, EntryOps, install_module, hot_upgrade
from repro.core.config import LRUConfig, SchedulerConfig
from repro.core.elastic_kv import ElasticKVCache, KVGeometry, make_kv_taiji_config
from repro.core.system import TaijiSystem
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--capture", metavar="PATH", default=None,
                    help="record the serving workload as a replayable "
                         "fleet trace (TSV) at PATH")
    args = ap.parse_args()
    cfg = reduced_config("qwen3-4b")
    geom = KVGeometry(n_layers=M.attn_layer_count(cfg),
                      kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                      block_tokens=cfg.kv_block_tokens)
    n_seqs, phys_blocks, turns, batch = 24, 48, 40, 4
    prompt, gen = 24, 8
    worst = n_seqs * (-(-(prompt + turns * gen) // geom.block_tokens))
    tcfg = make_kv_taiji_config(
        geom, phys_blocks, overcommit=worst / phys_blocks,
        lru=LRUConfig(scan_interval_s=0.002, workers=2, stabilize_scans=1),
        scheduler=SchedulerConfig(cycle_ms=2.0, shards=2))
    system = TaijiSystem(tcfg)
    space = system.guest                     # the one guest-memory surface
    recorder = None
    if args.capture:
        from repro.fleet.trace import TraceRecorder
        recorder = space.attach(TraceRecorder.for_space(space))
    system.start_background()
    cache = ElasticKVCache(geom, space)

    entry = EntryOps()
    install_module(system, entry, EngineModule(system))

    rng = np.random.default_rng(0)
    for sid in range(n_seqs):
        cache.create_sequence(sid)
        for _ in range(prompt):
            cache.append_kv(sid, rng.standard_normal(
                (geom.n_layers, 2, geom.kv_heads, geom.head_dim)
            ).astype(np.float16))

    # a scheduled batch's pinned working set must fit physical memory (the
    # DMA contract): finished conversations are recycled at max_ctx tokens
    max_ctx = (phys_blocks // (2 * batch)) * geom.block_tokens

    for turn in range(turns):
        if turn == turns // 2:
            print(">>> hot-upgrading swap engine v1 -> v2 under load...")
            hot_upgrade(system, entry, EngineModuleV2(system))
            print(f">>> running module version: {entry.call('version')}")
        for sid in range(n_seqs):
            if cache.seq_len(sid) + gen > max_ctx:   # conversation finished
                cache.drop_sequence(sid)
                cache.create_sequence(sid)
                for _ in range(prompt):
                    cache.append_kv(sid, rng.standard_normal(
                        (geom.n_layers, 2, geom.kv_heads, geom.head_dim)
                    ).astype(np.float16))
        ids = rng.choice(n_seqs, size=batch, replace=False)
        nxt = rng.choice(n_seqs, size=batch, replace=False)
        prefetch = cache.prefetch_async(nxt)     # overlap next batch's swap-ins
        with cache.prepare_step(ids):            # pin working set (DMA rule)
            for _ in range(gen):
                for sid in ids:
                    cache.append_kv(int(sid), rng.standard_normal(
                        (geom.n_layers, 2, geom.kv_heads, geom.head_dim)
                    ).astype(np.float16))
        prefetch.join(timeout=1)
        if (turn + 1) % 10 == 0:
            res = cache.residency()
            print(f"turn {turn+1:3d}: {res['resident_blocks']} resident / "
                  f"{res['swapped_blocks']} swapped blocks, "
                  f"free={system.phys.free_count} MS")

    st = system.stats()["metrics"]
    print("\nfault latency:", st["fault_latency"])
    print(f"swapped out {st['ms_swapped_out']} MSes; compression ratio "
          f"{st['compression_ratio']:.3f}; module v{entry.call('version')}")
    if recorder is not None:
        recorder.write(args.capture)
        print(f"captured {recorder.n_ops} trace ops -> {args.capture} "
              f"(replay with repro.fleet.harness.replay)")
    system.close()


if __name__ == "__main__":
    main()
