"""Multi-level LRU (paper §4.2.1, Fig 7): transitions, smoothing, order."""
import random

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - CI pins hypothesis
    HAVE_HYPOTHESIS = False

from repro.core.config import small_test_config
from repro.core.lru import (ACTIVE, COLD, HOT, HOT_INT, INACTIVE,
                            MultiLevelLRU)


class Bits:
    def __init__(self):
        self.accessed = set()

    def probe(self, gfn):
        hit = gfn in self.accessed
        self.accessed.discard(gfn)
        return hit


def make(stabilize=1):
    cfg = small_test_config(lru=small_test_config().lru.__class__(
        scan_interval_s=0.001, stabilize_scans=stabilize, workers=1,
        scan_cache_size=4))
    bits = Bits()
    return MultiLevelLRU(cfg, bits.probe), bits


def test_access_moves_toward_hot_one_level_per_scan():
    lru, bits = make()
    lru.track(1)                       # starts ACTIVE
    assert lru.level_of(1) == ACTIVE
    bits.accessed.add(1)
    lru.scan_shard(0, 1)
    assert lru.level_of(1) == HOT_INT  # one level only (smoothing)
    bits.accessed.add(1)
    lru.scan_shard(0, 1)
    assert lru.level_of(1) == HOT


def test_idle_drifts_toward_cold_with_stabilization():
    lru, bits = make(stabilize=2)
    lru.track(7)
    lru.scan_shard(0, 1)               # 1 idle scan: no move yet
    assert lru.level_of(7) == ACTIVE
    lru.scan_shard(0, 1)               # 2nd idle scan: move one level
    assert lru.level_of(7) == INACTIVE
    for _ in range(4):
        lru.scan_shard(0, 1)
    assert lru.level_of(7) == COLD


def test_transient_access_does_not_jump_to_hot():
    """A single access inside a huge page must not look permanently hot."""
    lru, bits = make(stabilize=1)
    lru.track(3)
    bits.accessed.add(3)
    lru.scan_shard(0, 1)
    assert lru.level_of(3) == HOT_INT
    for _ in range(6):                 # goes cold again when idle
        lru.scan_shard(0, 1)
    assert lru.level_of(3) == COLD


def test_pick_cold_orders_coldest_first():
    lru, bits = make(stabilize=1)
    for g in (10, 11, 12):
        lru.track(g)
    for _ in range(5):
        lru.scan_shard(0, 1)
    assert lru.level_of(10) == COLD
    picked = lru.pick_cold(2)
    assert picked == [10, 11]          # arrival order = coldest first


def test_swapin_joins_hot_set():
    lru, bits = make()
    lru.track(5)
    lru.note_swapped_out(5)
    assert lru.level_of(5) is None
    lru.note_swapped_in(5)
    assert lru.level_of(5) == HOT


def _run_traffic(ops):
    """Shared property body: hypothesis and the seeded fallback drive it."""
    lru, bits = make()
    tracked = set()
    for gfn, access in ops:
        if gfn not in tracked:
            lru.track(gfn)
            tracked.add(gfn)
        if access:
            bits.accessed.add(gfn)
        lru.scan_shard(0, 1)
        lru.check_invariants()
    assert lru.tracked() == len(tracked)
    counts = lru.counts()
    assert sum(counts.values()) == len(tracked)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(0, 15), st.booleans()),
                    max_size=120))
    @settings(max_examples=40, deadline=None)
    def test_invariants_under_random_traffic(ops):
        _run_traffic(ops)


def test_invariants_under_seeded_random_traffic():
    """Seeded-``random`` fallback fuzz: randomized coverage without
    hypothesis (not installed in the local container; CI keeps the
    hypothesis path above)."""
    rng = random.Random(0x7A111)
    for _case in range(40):
        n_ops = rng.randrange(0, 121)
        ops = [(rng.randrange(0, 16), rng.random() < 0.5)
               for _ in range(n_ops)]
        _run_traffic(ops)
