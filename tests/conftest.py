import os
import sys

import pytest

# kernels + models run on the single host CPU device in tests; the 512-
# device override belongs ONLY to the dry-run (see launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _lockdep_env_on() -> bool:
    return os.environ.get("TAIJI_LOCKDEP", "") not in ("", "0")


@pytest.fixture(autouse=True)
def _lockdep_latch():
    """In the lockdep CI lane, fail any test whose run latched a lock-order
    violation even if the raising thread swallowed it (scheduler workers
    log task exceptions instead of propagating). Tests that provoke
    violations on purpose drain the latch via ``witness.clear_violations``
    before returning."""
    if not _lockdep_env_on():
        yield
        return
    from repro.analysis import witness
    before = len(witness.violations)
    yield
    fresh = witness.violations[before:]
    assert not fresh, f"lock-order violations latched during test: {fresh}"


def pytest_sessionfinish(session, exitstatus):
    path = os.environ.get("TAIJI_LOCKDEP_GRAPH")
    if path and _lockdep_env_on():
        from repro.analysis import witness
        witness.dump_graph_to(path)
