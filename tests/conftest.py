import os
import sys

# kernels + models run on the single host CPU device in tests; the 512-
# device override belongs ONLY to the dry-run (see launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
