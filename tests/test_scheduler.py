"""hv_sched (paper §4.3, Fig 9 / Fig 14b): shares, penalties, hotplug."""
import time

from repro.core.config import SchedulerConfig, small_test_config
from repro.core.scheduler import BACK, FRONT, HvScheduler


def spin_task(duration):
    def fn(quantum):
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < min(quantum, duration):
            pass
        return True
    return fn


def make(front=0.7, back=0.2, fcpu=0.05, idle=0.05, shards=1):
    cfg = small_test_config(scheduler=SchedulerConfig(
        cycle_ms=5.0, share_front=front, share_fcpu=fcpu, share_back=back,
        share_idle=idle, shards=shards))
    return HvScheduler(cfg)


def test_front_share_protected_under_back_flood():
    """BACK elasticity tasks must not starve the data plane (O1)."""
    sched = make()
    sched.add_task(0, "vcpu", FRONT, spin_task(1.0))
    for i in range(4):
        sched.add_task(0, f"swap{i}", BACK, spin_task(1.0))
    sched.start()
    time.sleep(0.5)
    sched.stop()
    rt = sched.class_runtime()
    total = rt["FRONT"] + rt["BACK"]
    assert rt["FRONT"] / total > 0.6, rt    # ~0.78 expected for 0.7/0.2


def test_unused_front_slices_flow_to_back():
    sched = make()
    # no FRONT tasks at all: BACK may exceed its static share
    sched.add_task(0, "swap", BACK, spin_task(1.0))
    sched.start()
    time.sleep(0.3)
    sched.stop()
    rt = sched.class_runtime()
    wall = 0.3
    assert rt["BACK"] > wall * 0.4, rt      # >> its 20% static share


def test_overrun_penalty_applied():
    sched = make()

    calls = []

    def hog(quantum):
        calls.append(quantum)
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < quantum * 3:   # always overruns
            pass
        return True

    t = sched.add_task(0, "hog", BACK, hog)
    sched.start()
    time.sleep(0.2)
    sched.stop()
    assert t.overruns >= 1
    assert any(q < max(calls) * 0.9 for q in calls[1:]), \
        "penalty should shrink later quanta"


def test_one_shot_task_removed():
    sched = make()
    ran = []
    sched.add_task(0, "once", BACK, lambda q: (ran.append(1), False)[1])
    sched.start()
    time.sleep(0.1)
    sched.stop()
    assert len(ran) == 1


def test_hotplug_vcpu_gets_time():
    """CPU elasticity (§7.4): a hot-plugged VCPU runs under FCPU."""
    sched = make(front=0.5, fcpu=0.2, back=0.2, idle=0.1)
    sched.add_task(0, "vcpu0", FRONT, spin_task(1.0))
    t = sched.hotplug_vcpu(0, "vcpu1", spin_task(1.0))
    sched.start()
    time.sleep(0.3)
    sched.stop()
    assert t.runtime_s > 0.02, sched.class_runtime()


def test_back_disabled_shard_gives_time_to_front():
    sched = make(shards=1)
    sched.add_task(0, "vcpu", FRONT, spin_task(1.0))
    sched.add_task(0, "swap", BACK, spin_task(1.0))
    sched.set_back_enabled(0, False)
    sched.start()
    time.sleep(0.25)
    sched.stop()
    rt = sched.class_runtime()
    assert rt["BACK"] < 0.02, rt
