"""Fleet control plane: admission, placement, staggered reclaim, rolling
hot-upgrade, and deterministic trace replay (ISSUE 2 acceptance)."""
import json

import pytest

from repro.core.config import ABI_VERSION, small_test_config
from repro.core.hotupgrade import EngineModule, EngineModuleV2
from repro.fleet import (REJECT_NO_CAPACITY, REJECT_OVERCOMMIT, FleetConfig,
                         NodeNotServingError, TraceGen, TraceHeader,
                         TraceReplayer, page_bytes, paper_trace, parse_line)
from repro.fleet.harness import build_fleet, replay_twice


def make_fleet(n_nodes=4, domains=2, fleet_cfg=None, **cfg_overrides):
    return build_fleet(n_nodes, domains, small_test_config(**cfg_overrides),
                       fleet_cfg)


# ------------------------------------------------------------- trace format
def test_trace_tsv_roundtrip(tmp_path):
    cfg = small_test_config()
    gen = TraceGen(11, cfg.ms_bytes, cfg.mps_per_ms)
    gen.front_fill(3)
    gen.back_phase(2)
    gen.fault_burst(5)
    path = tmp_path / "t.tsv"
    gen.write(str(path))
    lines = path.read_text().splitlines()
    hdr = TraceHeader.parse(lines[0])
    assert hdr.seed == 11 and hdr.ms_bytes == cfg.ms_bytes
    seqs = []
    for ln in lines[1:]:
        seq, op, arg, w, payload = parse_line(ln)
        seqs.append(seq)
        assert op in ("alloc", "free", "touch", "tick", "upgrade")
        assert w in (0, 1)
        assert payload == ""             # seed-derived traces carry none
    assert seqs == list(range(len(seqs)))    # dense sequence numbers


def test_page_bytes_deterministic_and_mixed():
    kinds = set()
    for mp in range(64):
        a = page_bytes(5, 0, mp, 512, 0.4, 0.3)
        b = page_bytes(5, 0, mp, 512, 0.4, 0.3)
        assert a == b
        kinds.add("zero" if a == bytes(512) else "data")
    assert kinds == {"zero", "data"}         # the mix actually mixes
    # different seed -> different stream
    assert any(page_bytes(5, 0, m, 512, 0.4, 0.3)
               != page_bytes(6, 0, m, 512, 0.4, 0.3) for m in range(64))


# -------------------------------------------------- admission + placement
def test_admission_rejects_past_fleet_overcommit_cap():
    fleet = make_fleet(n_nodes=2, fleet_cfg=FleetConfig(overcommit_cap=1.0))
    cap = fleet.fleet_managed_ms()           # 1.0x: physical only
    placed = 0
    rejected = 0
    for _ in range(cap + 5):
        node, gfn, reason = fleet.admit_alloc()
        if node is None:
            assert reason == REJECT_OVERCOMMIT
            rejected += 1
        else:
            placed += 1
    assert placed == cap and rejected == 5
    assert fleet.rejections[REJECT_OVERCOMMIT] == 5
    fleet.close()


def test_placement_prefers_least_pressured_node():
    fleet = make_fleet(n_nodes=3)
    # preload node 0 well past its watermark band
    n0 = fleet.nodes[0]
    for _ in range(n0.managed_phys_ms - 2):
        n0.alloc_ms()
    assert n0.pressure() > fleet.nodes[1].pressure()
    node, _gfn, reason = fleet.admit_alloc()
    assert reason == "ok" and node.node_id != 0
    fleet.close()


def test_no_capacity_rejection_when_all_nodes_drain():
    fleet = make_fleet(n_nodes=2, domains=1)  # one failure domain = both drain
    fleet.start_rolling_upgrade(EngineModuleV2, drain_rounds=3)
    fleet.tick()                              # batch begins: both nodes drain
    assert all(not n.serving for n in fleet.nodes)
    node, _gfn, reason = fleet.admit_alloc()
    assert node is None and reason == REJECT_NO_CAPACITY
    fleet.close()


# ------------------------------------------------------- staggered reclaim
def test_reclaim_windows_are_staggered_across_groups():
    fleet = make_fleet(n_nodes=4, fleet_cfg=FleetConfig(
        reclaim_stagger_groups=2))
    for _ in range(6):
        fleet.tick()
    # group 0 = nodes 0,2 ; group 1 = nodes 1,3 ; alternate ticks
    assert [n.reclaim_windows for n in fleet.nodes] == [3, 3, 3, 3]
    assert all(n.rounds == 6 for n in fleet.nodes)
    # never both groups in one tick: per-tick window count == n_nodes/groups
    fleet2 = make_fleet(n_nodes=4, fleet_cfg=FleetConfig(
        reclaim_stagger_groups=4))
    fleet2.tick()
    assert sum(n.reclaim_windows for n in fleet2.nodes) == 1
    fleet.close()
    fleet2.close()


def test_staggered_reclaim_actually_swaps_out_under_pressure():
    fleet = make_fleet(n_nodes=2)
    # fill both nodes past the low watermark so reclaim has real work
    for _ in range(int(fleet.fleet_managed_ms() * 1.2)):
        node, gfn, reason = fleet.admit_alloc()
        if node is not None:
            node.write_mp(gfn, 0, b"\xAB" * node.cfg.mp_bytes)
    reclaimed = sum(fleet.tick() for _ in range(10))
    assert reclaimed > 0
    assert fleet.reclaimed_mps == reclaimed
    fleet.close()


# -------------------------------------------------------- rolling upgrade
def test_rolling_upgrade_no_node_serves_traffic_mid_upgrade():
    fleet = make_fleet(n_nodes=4, domains=2)
    allocs = {}
    for n in fleet.nodes:
        allocs[n.node_id] = n.alloc_ms()
    fleet.start_rolling_upgrade(EngineModuleV2, drain_rounds=2)
    fleet.tick()                              # domain-0 batch starts draining
    draining = [n for n in fleet.nodes if not n.serving]
    untouched = [n for n in fleet.nodes if n.serving]
    assert {n.failure_domain for n in draining} == {0}
    assert {n.failure_domain for n in untouched} == {1}
    for n in draining:                        # mid-upgrade: traffic refused
        with pytest.raises(NodeNotServingError):
            n.read_mp(allocs[n.node_id], 0, 16)
        with pytest.raises(NodeNotServingError):
            n.alloc_ms()
        assert n.module_version == 1          # swap happens after the drain
    for n in untouched:                       # the other domain still serves
        n.read_mp(allocs[n.node_id], 0, 16)
    while fleet.upgrade_in_progress:
        fleet.tick()
    assert not fleet.upgrade_aborted
    assert fleet.upgrade_batches_done == 2
    for n in fleet.nodes:
        assert n.serving and n.module_version == 2 and n.upgrade_epoch == 1
        n.read_mp(allocs[n.node_id], 0, 16)   # serving again post-upgrade
    fleet.close()


def test_production_profile_rollout_completes_with_guard_armed():
    """The named production profile (ROADMAP wiring item): the latency
    guard is live on every batch -- pre-batch histograms are captured and
    validated -- and a healthy module still rolls out to completion."""
    prof = FleetConfig.production_profile()
    assert prof.latency_guard_factor is not None
    fleet = make_fleet(n_nodes=4, domains=2, fleet_cfg=prof)
    allocs = [fleet.admit_alloc() for _ in range(8)]
    for node, gfn, ok in allocs:
        assert ok == "ok"
        node.write_mp(gfn, 0, b"\x3C" * node.cfg.mp_bytes)
        node.system.engine.swap_out_ms(gfn)
        node.read_mp(gfn, 0)                  # fault: guard baseline samples
    fleet.start_rolling_upgrade(EngineModuleV2)
    assert fleet._rolling.baseline_p90_ns > 0  # guard baseline captured
    for _ in range(40):
        if not fleet.upgrade_in_progress:
            break
        fleet.tick()
        if fleet._rolling is not None and fleet._rolling.in_flight:
            # guard pre-batch capture ran because the factor is wired
            assert fleet._rolling.pre_batch_hist is not None
    assert not fleet.upgrade_aborted, fleet.upgrade_abort_reason
    assert fleet.upgrade_batches_done == 2
    assert all(n.module_version == 2 for n in fleet.nodes)
    # profile knobs actually shape the fleet round: 4 stagger groups
    assert fleet.cfg.reclaim_stagger_groups == 4
    fleet.close()


class BadABIModule(EngineModule):
    VERSION = 9
    ABI = ABI_VERSION + 1                     # refuses to attach


def test_rolling_upgrade_aborts_on_regression_and_spares_other_domains():
    fleet = make_fleet(n_nodes=4, domains=2)
    fleet.start_rolling_upgrade(BadABIModule, drain_rounds=1)
    for _ in range(10):
        if not fleet.upgrade_in_progress:
            break
        fleet.tick()
    assert fleet.upgrade_aborted
    assert "module swap failed" in fleet.upgrade_abort_reason
    assert fleet.upgrade_batches_done == 0
    # failure-domain batching contained the blast radius: domain-1 nodes
    # never began draining, and every node still serves v1 traffic
    for n in fleet.nodes:
        assert n.serving and n.module_version == 1
        if n.failure_domain == 1:
            assert n.upgrade_failed is False and n.rounds > 0
    fleet.close()


# ------------------------------------------------ deterministic trace replay
def test_seeded_trace_replay_is_byte_identical_across_runs():
    """Acceptance: a seeded 4-node, >=2k-op replay is deterministic and
    exercises admission rejection + staggered reclaim + a full rolling
    hot-upgrade, while reporting fleet-wide swap-in percentiles."""
    cfg = small_test_config()
    gen = paper_trace(7, cfg.ms_bytes, cfg.mps_per_ms,
                      fill_ms=120, burst=600, churn_frees=20)
    assert gen.n_ops >= 2000

    eq = replay_twice(gen.lines(), n_nodes=4, domains=2, cfg=cfg)
    assert eq.identical, eq.report()          # byte-identical snapshots
    lat1 = eq.runs[0].result["latency"]
    lat2 = eq.runs[1].result["latency"]

    det = json.loads(eq.runs[0].bytes.decode())
    assert det["rejections"][REJECT_OVERCOMMIT] > 0      # admission exercised
    assert det["reclaimed_mps"] > 0                      # reclaim exercised
    assert det["upgrade_batches_done"] == 2              # full rolling upgrade
    assert not det["upgrade_aborted"]
    assert all(n["module_version"] == 2 for n in det["nodes"])
    assert det["replay"]["verify_failures"] == 0         # data integrity
    # fleet-wide swap-in latency aggregation is populated (timing-dependent
    # values live outside the deterministic snapshot)
    assert lat1["fault"]["count"] > 0 and lat1["fault"]["p90_us"] > 0
    assert lat1["fault"]["count"] == lat2["fault"]["count"]


def test_trace_replay_from_file_roundtrip(tmp_path):
    cfg = small_test_config()
    gen = TraceGen(3, cfg.ms_bytes, cfg.mps_per_ms)
    gen.front_fill(12)
    gen.back_phase(6)
    gen.fault_burst(60)
    path = tmp_path / "fleet.tsv"
    gen.write(str(path))

    fleet = make_fleet(n_nodes=2)
    rep = TraceReplayer(fleet, path.read_text().splitlines())
    res = rep.run()
    assert res["deterministic"]["replay"]["ops"] == gen.n_ops
    assert res["deterministic"]["replay"]["verify_failures"] == 0
    fleet.close()
