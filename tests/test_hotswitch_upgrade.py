"""Hot-switch (paper §4.1.2, Fig 6) + hot-upgrade (§4.4, Fig 10)."""
import threading
import time

import pytest

from repro.core import (EngineModule, EngineModuleV2, EntryOps,
                        PlainMemorySystem, hot_switch, hot_upgrade,
                        install_module, small_test_config)
from repro.core.errors import ABIMismatchError

pytestmark = pytest.mark.slow      # excluded from the default CI lane


class Service(threading.Thread):
    """A running workload: continuous read/write through the accessor."""

    def __init__(self, plain, pcpu, pfns):
        super().__init__(daemon=True)
        self.plain = plain
        self.pcpu = pcpu
        self.pfns = pfns
        self.ops = 0
        self.errors = []
        self.stop_flag = threading.Event()

    def run(self):
        ms = self.plain.cfg.ms_bytes
        off = 64 + 32 * self.pcpu         # disjoint region per service
        i = 0
        while not self.stop_flag.is_set():
            p = self.pfns[i % len(self.pfns)]
            payload = (self.ops % 251).to_bytes(1, "little") * 16
            try:
                self.plain.write(self.pcpu, p * ms + off, payload)
                got = self.plain.read(self.pcpu, p * ms + off, 16)
                assert got == payload, (got, payload)
                self.ops += 1
            except Exception as e:      # pragma: no cover
                self.errors.append(e)
                break
            i += 1


def test_hot_switch_is_transparent_to_running_services():
    plain = PlainMemorySystem(small_test_config())
    pfns = [plain.alloc_ms() for _ in range(6)]
    for i, p in enumerate(pfns):
        plain.write(0, p * plain.cfg.ms_bytes, bytes([i + 1]) * 128)

    services = [Service(plain, pcpu, pfns) for pcpu in range(2)]
    for sv in services:
        sv.start()
    time.sleep(0.05)

    stages = []
    system = hot_switch(plain, on_stage=lambda c, s: stages.append((c, s)))
    time.sleep(0.1)

    for sv in services:
        sv.stop_flag.set()
    for sv in services:
        sv.join(2)

    assert all(not sv.errors for sv in services)
    assert all(sv.ops > 0 for sv in services)
    # two-stage switch ran per PCPU
    assert stages.count((0, "stage1")) == 1 and stages.count((0, "stage2")) == 1
    # original contents preserved (services overwrote offset 64 only)
    for i, p in enumerate(pfns):
        assert plain.read(0, p * plain.cfg.ms_bytes, 16) == bytes([i + 1]) * 16
    # and the memory is now swappable -- the point of the switch
    assert system.engine.swap_out_ms(pfns[0]) == system.cfg.mps_per_ms
    assert plain.read(0, pfns[0] * plain.cfg.ms_bytes, 16) == bytes([1]) * 16
    system.close()


def test_hot_upgrade_under_load_carries_state():
    plain = PlainMemorySystem(small_test_config())
    pfns = [plain.alloc_ms() for _ in range(6)]
    system = hot_switch(plain)
    entry = EntryOps()
    install_module(system, entry, EngineModule(system))
    assert entry.call("version") == 1

    # swap some memory out under v1 so there is real metadata to inherit
    data = bytes(range(256)) * (system.cfg.ms_bytes // 256)
    system.guest.write(pfns[1], data)
    entry.call("swap_out_ms", pfns[1])

    sv = Service(plain, 0, pfns[2:])
    sv.start()
    time.sleep(0.02)

    hot_upgrade(system, entry, EngineModuleV2(system))

    sv.stop_flag.set()
    sv.join(2)
    assert not sv.errors and sv.ops > 0
    assert entry.call("version") == 2
    assert system.module_version == 2
    # v1's swapped-out metadata is directly usable by v2 (no conversion)
    assert system.guest.read(pfns[1], len(data)) == data
    system.close()


def test_incompatible_abi_refused():
    plain = PlainMemorySystem(small_test_config())
    system = hot_switch(plain)
    entry = EntryOps()
    install_module(system, entry, EngineModule(system))

    class BadModule(EngineModule):
        VERSION = 99
        ABI = 999                      # incompatible metadata layout

    with pytest.raises(ABIMismatchError):
        hot_upgrade(system, entry, BadModule(system))
    assert entry.call("version") == 1  # old module still serving
    system.close()


def test_entry_ops_drain_before_swap():
    entry = EntryOps()
    release = threading.Event()
    entered = threading.Event()

    def slow_op():
        entered.set()
        release.wait(2)
        return "old"

    entry.register("op", slow_op)
    results = []
    t = threading.Thread(target=lambda: results.append(entry.call("op")))
    t.start()
    entered.wait(2)

    swapped = threading.Event()

    def do_swap():
        entry.swap_all({"op": lambda: "new"})
        swapped.set()

    t2 = threading.Thread(target=do_swap)
    t2.start()
    time.sleep(0.05)
    assert not swapped.is_set()        # waits for the in-flight call
    release.set()
    t.join(2)
    t2.join(2)
    assert results == ["old"]
    assert entry.call("op") == "new"
