"""Property tests for the req red-black tree (paper Fig 8 (1.1-1.3))."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.rbtree import RBTree


@given(st.lists(st.integers(0, 10_000), unique=True, max_size=200))
@settings(max_examples=60, deadline=None)
def test_insert_find_invariants(keys):
    t = RBTree()
    for k in keys:
        t.insert(k, k * 2)
    t.check_invariants()
    assert len(t) == len(keys)
    for k in keys:
        assert t.find(k) == k * 2
    assert [k for k, _ in t.items()] == sorted(keys)


@given(st.lists(st.integers(0, 1000), unique=True, min_size=1, max_size=120),
       st.data())
@settings(max_examples=60, deadline=None)
def test_delete_keeps_invariants(keys, data):
    t = RBTree()
    for k in keys:
        t.insert(k, str(k))
    to_del = data.draw(st.lists(st.sampled_from(keys), unique=True))
    for k in to_del:
        assert t.delete(k) == str(k)
        t.check_invariants()
    remaining = sorted(set(keys) - set(to_del))
    assert [k for k, _ in t.items()] == remaining
    for k in to_del:
        assert t.find(k) is None


@given(st.lists(st.integers(0, 1000), unique=True, min_size=1, max_size=80),
       st.integers(0, 1001))
@settings(max_examples=60, deadline=None)
def test_floor_lookup(keys, probe):
    t = RBTree()
    for k in keys:
        t.insert(k, k)
    expect = max((k for k in keys if k <= probe), default=None)
    assert t.floor(probe) == expect
