"""Property tests for the req red-black tree (paper Fig 8 (1.1-1.3))."""
import random

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - CI pins hypothesis
    HAVE_HYPOTHESIS = False

from repro.core.rbtree import RBTree


def _check_insert_find(keys):
    t = RBTree()
    for k in keys:
        t.insert(k, k * 2)
    t.check_invariants()
    assert len(t) == len(keys)
    for k in keys:
        assert t.find(k) == k * 2
    assert [k for k, _ in t.items()] == sorted(keys)


def _check_delete(keys, to_del):
    t = RBTree()
    for k in keys:
        t.insert(k, str(k))
    for k in to_del:
        assert t.delete(k) == str(k)
        t.check_invariants()
    remaining = sorted(set(keys) - set(to_del))
    assert [k for k, _ in t.items()] == remaining
    for k in to_del:
        assert t.find(k) is None


def _check_floor(keys, probe):
    t = RBTree()
    for k in keys:
        t.insert(k, k)
    expect = max((k for k in keys if k <= probe), default=None)
    assert t.floor(probe) == expect


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(0, 10_000), unique=True, max_size=200))
    @settings(max_examples=60, deadline=None)
    def test_insert_find_invariants(keys):
        _check_insert_find(keys)

    @given(st.lists(st.integers(0, 1000), unique=True, min_size=1,
                    max_size=120),
           st.data())
    @settings(max_examples=60, deadline=None)
    def test_delete_keeps_invariants(keys, data):
        to_del = data.draw(st.lists(st.sampled_from(keys), unique=True))
        _check_delete(keys, to_del)

    @given(st.lists(st.integers(0, 1000), unique=True, min_size=1,
                    max_size=80),
           st.integers(0, 1001))
    @settings(max_examples=60, deadline=None)
    def test_floor_lookup(keys, probe):
        _check_floor(keys, probe)


def _sample_keys(rng, lo, hi, max_size, min_size=0):
    n = rng.randrange(min_size, max_size + 1)
    return rng.sample(range(lo, hi + 1), n)


def test_fuzz_insert_find_invariants_seeded():
    """Seeded-``random`` fallback fuzz: randomized coverage without
    hypothesis (not installed in the local container; CI keeps the
    hypothesis path above)."""
    rng = random.Random(0xB17EE)
    for _case in range(60):
        _check_insert_find(_sample_keys(rng, 0, 10_000, 200))


def test_fuzz_delete_keeps_invariants_seeded():
    rng = random.Random(0xDE1E7E)
    for _case in range(60):
        keys = _sample_keys(rng, 0, 1000, 120, min_size=1)
        to_del = rng.sample(keys, rng.randrange(0, len(keys) + 1))
        _check_delete(keys, to_del)


def test_fuzz_floor_lookup_seeded():
    rng = random.Random(0xF100E)
    for _case in range(60):
        keys = _sample_keys(rng, 0, 1000, 80, min_size=1)
        _check_floor(keys, rng.randrange(0, 1002))
