"""Live MS migration (ISSUE 4): export/import preserves guest-visible
bytes across every page kind (resident / zero / standalone-compressed /
extent), the resident/swapped split survives the move, source accounting
drains back to baseline, and admission rejects without mutating either
node."""
import numpy as np

from repro.core.config import small_test_config
from repro.fleet import (REJECT_MIGRATE_BAD_SRC, REJECT_MIGRATE_NO_DST,
                         FleetConfig, FleetController, NodeAgent)


def make_fleet(n_nodes=2, **overrides):
    cfg = small_test_config(**overrides)
    nodes = [NodeAgent(i, cfg) for i in range(n_nodes)]
    return FleetController(nodes, FleetConfig()), nodes, cfg


def _mixed_ms(node, cfg):
    """One MS holding every page kind the backend can produce: resident
    (random + patterned), zero, extent rows ("x"), a standalone zlib blob
    ("z") and a verbatim incompressible row ("v")."""
    gfn = node.alloc_ms()
    mp = cfg.mp_bytes
    rng = np.random.default_rng(0xA11CE)
    rand = lambda: bytes(  # noqa: E731 - local helper
        rng.integers(0, 256, mp, dtype=np.int64).astype(np.uint8))
    pages = {
        0: rand(),            # resident, incompressible
        1: b"\x11" * mp,      # resident, patterned
        2: bytes(mp),         # -> K_ZERO (batched)
        3: bytes(mp),         # -> K_ZERO (scalar)
        4: b"\x22" * mp,      # -> extent row ("x")
        5: b"\x33" * mp,      # -> extent row ("x")
        6: b"\x44" * mp,      # -> standalone zlib blob ("z")
        7: rand(),            # -> stored verbatim ("v")
    }
    for i, data in pages.items():
        node.write_mp(gfn, i, data)
    eng = node.system.engine
    eng.swap_out_mps(gfn, [2, 4, 5], batched=True)    # zero + joint extent
    eng.swap_out_mps(gfn, [3, 6, 7], batched=False)   # zero + "z" + "v"
    # white-box: all three compressed shapes really are on the backend
    tags = {e[0] for k, e in node.system.backend._compressed.items()
            if k[0] == gfn}
    assert tags == {"z", "v", "x"}
    return gfn, pages


def test_migrate_mixed_kinds_preserves_bytes_and_split():
    fleet, (n0, n1), cfg = make_fleet()
    gfn, pages = _mixed_ms(n0, cfg)
    dst, new_gfn, reason = fleet.migrate_ms(n0, gfn, n1)
    assert reason == "ok" and dst is n1

    # the resident/swapped split survived the move: 6 MPs re-stored on
    # the destination through the batched store machinery
    req = n1.system.reqs.lookup(new_gfn)
    assert req is not None and req.record.swapped_out_count() == 6

    # post-migration guest-visible bytes equal pre-migration for every MP
    # (reads fault the swapped MPs back in: all four kinds round-trip)
    for i, data in pages.items():
        assert n1.read_mp(new_gfn, i) == data, f"mp {i} bytes changed"

    # source is fully dropped and its accounting is back to baseline
    assert gfn not in n0.allocated
    assert n0.system.backend.stored_bytes() == 0
    m = n0.system.metrics
    assert m.backend_raw_bytes == 0 and m.backend_stored_bytes == 0
    assert m.crc_failures == 0 and n1.system.metrics.crc_failures == 0
    assert fleet.migrations == 1
    assert fleet.migration_mps == cfg.mps_per_ms
    fleet.close()


def test_migrate_full_node_rejected_without_mutation():
    fleet, (n0, n1), cfg = make_fleet()
    gfn, _pages = _mixed_ms(n0, cfg)
    while len(n1.allocated) < n1.capacity_ms:     # fill dst's virtual space
        n1.alloc_ms()

    src_stored = n0.system.backend.stored_bytes()
    src_swapped = n0.system.reqs.lookup(gfn).record.swapped_out_count()
    dst_allocated = len(n1.allocated)

    dst, new_gfn, reason = fleet.migrate_ms(n0, gfn, n1)
    assert dst is None and new_gfn is None
    assert reason == REJECT_MIGRATE_NO_DST
    assert fleet.migrations_rejected[REJECT_MIGRATE_NO_DST] == 1

    # neither node mutated: source keeps the MS and its backend state,
    # destination allocation count unchanged
    assert gfn in n0.allocated
    assert n0.system.backend.stored_bytes() == src_stored
    assert n0.system.reqs.lookup(gfn).record.swapped_out_count() == src_swapped
    assert len(n1.allocated) == dst_allocated
    assert fleet.migrations == 0
    fleet.close()


def test_migrate_unknown_gfn_rejected():
    fleet, (n0, n1), _cfg = make_fleet()
    dst, _g, reason = fleet.migrate_ms(n0, 999, n1)
    assert dst is None and reason == REJECT_MIGRATE_BAD_SRC
    # self-migration is a no-dst rejection, also without mutation
    gfn = n0.alloc_ms()
    dst, _g, reason = fleet.migrate_ms(n0, gfn, n0)
    assert dst is None and reason == REJECT_MIGRATE_NO_DST
    assert gfn in n0.allocated
    fleet.close()


def test_migrate_auto_dst_picks_least_pressured():
    fleet, nodes, cfg = make_fleet(n_nodes=3)
    n0, n1, n2 = nodes
    gfn = n0.alloc_ms()
    n0.write_mp(gfn, 0, b"\xAB" * cfg.mp_bytes)
    for _ in range(n1.managed_phys_ms - 2):       # make n1 pressured
        n1.alloc_ms()
    dst, new_gfn, reason = fleet.migrate_ms(n0, gfn)
    assert reason == "ok" and dst is n2
    assert n2.read_mp(new_gfn, 0) == b"\xAB" * cfg.mp_bytes
    fleet.close()


def test_migrate_fully_resident_ms():
    """An MS that never swapped (no req record) migrates resident."""
    fleet, (n0, n1), cfg = make_fleet()
    gfn = n0.alloc_ms()
    payload = b"\x77" * cfg.mp_bytes
    n0.write_mp(gfn, 1, payload)
    assert n0.system.reqs.lookup(gfn) is None     # no swap history
    dst, new_gfn, reason = fleet.migrate_ms(n0, gfn, n1)
    assert reason == "ok"
    assert n1.system.reqs.lookup(new_gfn) is None  # still fully resident
    assert n1.read_mp(new_gfn, 1) == payload
    assert n1.read_mp(new_gfn, 0) == bytes(cfg.mp_bytes)
    fleet.close()


def test_migrate_fully_swapped_ms():
    """A fully-swapped MS (pfn == NO_PFN on the source) migrates too."""
    fleet, (n0, n1), cfg = make_fleet()
    gfn = n0.alloc_ms()
    payload = b"\x55" * cfg.mp_bytes
    n0.write_mp(gfn, 3, payload)
    n0.system.engine.swap_out_ms(gfn)
    rec = n0.system.reqs.lookup(gfn).record
    assert rec.swapped_out_count() == cfg.mps_per_ms and rec.pfn == -1

    dst, new_gfn, reason = fleet.migrate_ms(n0, gfn, n1)
    assert reason == "ok"
    req = n1.system.reqs.lookup(new_gfn)
    assert req.record.swapped_out_count() == cfg.mps_per_ms
    assert n1.read_mp(new_gfn, 3) == payload
    assert n1.system.metrics.crc_failures == 0
    fleet.close()


def test_export_is_non_consuming():
    """Two exports of the same MS agree and leave the backend intact --
    the read-verify pass must not perturb what it verifies."""
    fleet, (n0, _n1), cfg = make_fleet()
    gfn, pages = _mixed_ms(n0, cfg)
    stored_before = n0.system.backend.stored_bytes()
    rows1, res1 = n0.export_ms(gfn)
    rows2, res2 = n0.export_ms(gfn)
    assert np.array_equal(rows1, rows2) and np.array_equal(res1, res2)
    assert n0.system.backend.stored_bytes() == stored_before
    for i, data in pages.items():
        assert rows1[i].tobytes() == data
    assert res1.tolist() == [True, True] + [False] * 6
    fleet.close()
