"""Hybrid virtualization layer (paper §4.1): translation + contracts."""
import pytest

from repro.core.config import small_test_config
from repro.core.errors import InvalidStateError
from repro.core.system import TaijiSystem
from repro.core.virt import EPTFault


def test_gpa_hpa_identity_for_mpool():
    s = TaijiSystem(small_test_config())
    cfg = s.cfg
    for gfn in range(cfg.mpool_reserve_ms):
        assert int(s.virt.table.pfn[gfn]) == gfn
        assert s.virt.table.is_pinned(gfn)
        s.virt.root_access(gfn * cfg.ms_bytes)   # must not raise


def test_root_access_rejects_non_identity():
    s = TaijiSystem(small_test_config())
    g = s.guest_alloc_ms()
    with pytest.raises(InvalidStateError):
        s.virt.root_access(g * s.cfg.ms_bytes)


def test_guest_rw_roundtrip_and_access_bit():
    s = TaijiSystem(small_test_config())
    g = s.guest_alloc_ms()
    off = 2 * s.cfg.mp_bytes + 10
    s.guest.write(g, b"taiji", off=off)
    assert s.guest.read(g, 5, off=off) == b"taiji"
    assert s.virt.table.test_and_clear_accessed(g)
    assert not s.virt.table.test_and_clear_accessed(g)


def test_access_crossing_mp_boundary():
    s = TaijiSystem(small_test_config())
    g = s.guest_alloc_ms()
    off = s.cfg.mp_bytes - 3
    s.guest.write(g, b"abcdef", off=off)    # spans MP0 -> MP1
    assert s.guest.read(g, 6, off=off) == b"abcdef"


def test_fault_raised_without_handler():
    s = TaijiSystem(small_test_config())
    g = s.guest_alloc_ms()
    s.guest.write(g, b"x" * 16)
    s.engine.swap_out_ms(g)
    s.virt.fault_handler = None        # detach engine
    with pytest.raises(EPTFault):
        s.virt.guest_read(s.guest.addr_of(g), 1)


def test_fault_handler_resolves_transparently():
    s = TaijiSystem(small_test_config())
    g = s.guest_alloc_ms()
    s.guest.write(g, bytes(range(64)))
    assert s.engine.swap_out_ms(g) == s.cfg.mps_per_ms
    assert s.guest.read(g, 64) == bytes(range(64))
    assert s.metrics.faults > 0
