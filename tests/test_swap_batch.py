"""Batched swap data path: equivalence with the scalar reference path.

The batched pipeline (store_batch/load_batch, index-vector chunks) must be
observationally identical to the scalar per-MP path: same bytes back, same
MS record state transitions, same CRC protection, same cancellation
semantics for a racing fault.
"""
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core.config import SwapConfig, small_test_config
from repro.core.errors import CorruptionError
from repro.core.ms import (K_COMPRESSED, K_NONE, K_ZERO, MS_PARTIAL,
                           MS_RESIDENT, MS_SWAPPED, bitmap_indices, iter_set,
                           popcount_words, set_bits)
from repro.core.system import TaijiSystem


def fresh(**kw):
    return TaijiSystem(small_test_config(**kw))


def mixed_ms(cfg, seed):
    """Zero / compressible / incompressible MP mix in one MS."""
    rng = np.random.default_rng(seed)
    rows = []
    for mp in range(cfg.mps_per_ms):
        r = mp % 3
        if r == 0:
            rows.append(np.zeros(cfg.mp_bytes, np.uint8))
        elif r == 1:
            rows.append(np.full(cfg.mp_bytes, mp & 0xFF, np.uint8))
        else:
            rows.append(rng.integers(0, 256, cfg.mp_bytes).astype(np.uint8))
    return np.concatenate(rows).tobytes()


def record_view(s, g):
    rec = s.reqs.lookup(g).record
    return {
        "state": rec.state,
        "present": rec.present_count,
        "bm_out": rec.bm_out.copy(),
        "bm_in": rec.bm_in.copy(),
        "kinds": rec.kinds.copy(),
        "crc": rec.crc.copy(),
    }


# ---------------------------------------------------------- bitmap helpers
def test_bitmap_helpers_match_scalar_bit_ops():
    rng = np.random.default_rng(3)
    bm = rng.integers(0, 2**63, 4, dtype=np.uint64)
    n = 200
    want = [i for i in range(n) if (int(bm[i >> 6]) >> (i & 63)) & 1]
    assert bitmap_indices(bm, n).tolist() == want
    assert list(iter_set(bm, n)) == want
    assert popcount_words(bm) == sum(int(w).bit_count() for w in bm)

    bm2 = np.zeros(4, dtype=np.uint64)
    idxs = np.array(want[:17])
    set_bits(bm2, idxs, True)
    assert bitmap_indices(bm2, n).tolist() == sorted(idxs.tolist())
    set_bits(bm2, idxs[:5], False)
    assert bitmap_indices(bm2, n).tolist() == sorted(idxs[5:].tolist())


# ------------------------------------------------------- state equivalence
def test_swap_out_state_identical_to_scalar():
    data = None
    views = {}
    for batched in (False, True):
        s = fresh()
        g = s.guest_alloc_ms()
        data = data or mixed_ms(s.cfg, 11)
        s.guest.write(g, data)
        assert s.engine.swap_out_ms(g, batched=batched) == s.cfg.mps_per_ms
        views[batched] = record_view(s, g)
        s.close()
    a, b = views[False], views[True]
    assert a["state"] == b["state"] == MS_SWAPPED
    assert a["present"] == b["present"] == 0
    assert np.array_equal(a["bm_out"], b["bm_out"])
    assert np.array_equal(a["bm_in"], b["bm_in"])
    assert np.array_equal(a["kinds"], b["kinds"])
    assert np.array_equal(a["crc"], b["crc"])      # zlib CRCs byte-identical


def test_roundtrip_bytes_identical_all_path_combinations():
    for out_b in (False, True):
        for in_b in (False, True):
            s = fresh()
            g = s.guest_alloc_ms()
            data = mixed_ms(s.cfg, 7)
            s.guest.write(g, data)
            s.engine.swap_out_ms(g, batched=out_b)
            s.engine.swap_in_ms(g, batched=in_b)
            rec = s.reqs.lookup(g).record
            assert rec.state == MS_RESIDENT
            assert rec.present_count == s.cfg.mps_per_ms
            assert np.all(rec.kinds == K_NONE)
            assert s.guest.read(g, s.cfg.ms_bytes) == data, (out_b, in_b)
            s.close()


def test_batched_swap_out_then_scalar_faults():
    """A fault must read back an MP stored by the batched path (extents)."""
    s = fresh()
    g = s.guest_alloc_ms()
    data = mixed_ms(s.cfg, 5)
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    # touch MPs one at a time through the guest read path
    for mp in range(s.cfg.mps_per_ms):
        off = mp * s.cfg.mp_bytes
        assert s.guest.read(g, s.cfg.mp_bytes, off=off) == \
            data[off:off + s.cfg.mp_bytes]
    assert s.reqs.lookup(g).record.state == MS_RESIDENT
    s.close()


def test_partial_batched_swap_in_leaves_partial_state():
    s = fresh(swap=SwapConfig(batch_enabled=True, batch_mps=3))
    g = s.guest_alloc_ms()
    data = mixed_ms(s.cfg, 9)
    s.guest.write(g, data)
    s.engine.swap_out_ms(g)
    # fault one MP first so the batched prefetch starts from PARTIAL
    assert s.guest.read(g, s.cfg.mp_bytes) == data[:s.cfg.mp_bytes]
    rec = s.reqs.lookup(g).record
    assert rec.state == MS_PARTIAL
    assert s.engine.swap_in_ms(g, batched=True) == s.cfg.mps_per_ms - 1
    assert rec.state == MS_RESIDENT
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    s.close()


# ----------------------------------------------------------- backend tiers
def test_zero_ms_stores_no_backend_bytes():
    s = fresh()
    g = s.guest_alloc_ms()                 # zero-filled
    s.engine.swap_out_ms(g, batched=True)
    rec = s.reqs.lookup(g).record
    assert np.all(rec.kinds == K_ZERO)
    assert s.backend.stored_bytes() == 0
    assert s.metrics.backend_zero_mps == s.cfg.mps_per_ms
    s.engine.swap_in_ms(g, batched=True)
    assert s.guest.read(g, 64) == b"\x00" * 64
    s.close()


def test_compressible_ms_uses_extent_and_compresses():
    s = fresh()
    g = s.guest_alloc_ms()
    data = bytes(np.full(s.cfg.ms_bytes, 0xAB, np.uint8))
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    rec = s.reqs.lookup(g).record
    assert np.all(rec.kinds == K_COMPRESSED)
    assert len(s.backend._extents) == 1    # one extent per batch
    assert s.backend.stored_bytes() < s.cfg.ms_bytes // 4
    s.engine.swap_in_ms(g, batched=True)
    assert not s.backend._extents          # fully consumed
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    s.close()


def test_store_batch_crcs_match_scalar_zlib():
    s = fresh()
    cfg = s.cfg
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, (5, cfg.mp_bytes)).astype(np.uint8)
    data[2] = 0
    mps = np.array([0, 1, 2, 5, 7])
    kinds, crcs = s.backend.store_batch(100, mps, data)
    for i in range(5):
        assert int(crcs[i]) == zlib.crc32(data[i])
    assert kinds[2] == K_ZERO
    s.close()


def test_crc_mismatch_injection_batched_swap_in():
    s = fresh()
    g = s.guest_alloc_ms()
    data = bytes(np.full(s.cfg.ms_bytes, 0x5C, np.uint8))
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    # corrupt the extent payload (cache it raw first: a corrupted zlib
    # stream would fail in inflate, which is not the check under test)
    key = next(iter(s.backend._extents))
    ext = s.backend._extents[key]
    raw = bytearray(ext.payload if ext.is_raw else zlib.decompress(ext.payload))
    raw[len(raw) // 2] ^= 0x01
    ext.payload = bytes(raw)
    ext.is_raw = True
    with pytest.raises(CorruptionError):
        s.engine.swap_in_ms(g, batched=True)
    assert s.metrics.crc_failures >= 1
    # all-or-nothing: the failed chunk consumed nothing, so good rows are
    # still individually faultable and the bad row keeps failing
    bad_row = (len(raw) // 2) // s.cfg.mp_bytes
    good_row = 0 if bad_row != 0 else 1
    off = good_row * s.cfg.mp_bytes
    assert s.guest.read(g, s.cfg.mp_bytes, off=off) == \
        data[off:off + s.cfg.mp_bytes]
    with pytest.raises(CorruptionError):
        s.guest.read(g, s.cfg.mp_bytes, off=bad_row * s.cfg.mp_bytes)
    s.close()


def test_crc_mismatch_injection_scalar_fault_on_batched_store():
    s = fresh()
    g = s.guest_alloc_ms()
    data = bytes(np.full(s.cfg.ms_bytes, 0x5C, np.uint8))
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    key = next(iter(s.backend._extents))
    ext = s.backend._extents[key]
    raw = bytearray(ext.payload if ext.is_raw else zlib.decompress(ext.payload))
    raw[0] ^= 0xFF
    ext.payload = bytes(raw)
    ext.is_raw = True
    with pytest.raises(CorruptionError):
        s.guest.read(g, s.cfg.ms_bytes)
    assert s.metrics.crc_failures >= 1
    s.close()


def test_disk_tier_kind_selection_matches_scalar(tmp_path):
    """With a disk tier configured the batch path must keep scalar kind
    selection (incompressible rows spill to disk, no resident extent)."""
    from repro.core.config import BackendConfig

    views = {}
    data = None
    for batched in (False, True):
        s = fresh(backend=BackendConfig(
            disk_fallback_path=str(tmp_path / f"tier-{batched}.bin")))
        g = s.guest_alloc_ms()
        data = data or mixed_ms(s.cfg, 13)
        s.guest.write(g, data)
        s.engine.swap_out_ms(g, batched=batched)
        views[batched] = record_view(s, g)
        assert not s.backend._extents
        s.engine.swap_in_ms(g, batched=batched)
        assert s.guest.read(g, s.cfg.ms_bytes) == data
        s.close()
    assert np.array_equal(views[False]["kinds"], views[True]["kinds"])
    assert np.array_equal(views[False]["crc"], views[True]["crc"])


def test_stored_bytes_stable_after_partial_extent_fault():
    """A fault decompressing an extent must not inflate accounting.

    Probes the scalar slice-only reference path, so extent readahead is
    disabled (with it on, the first fault legitimately consumes the whole
    extent)."""
    s = fresh(swap=SwapConfig(readahead_enabled=False))
    g = s.guest_alloc_ms()
    data = bytes(np.full(s.cfg.ms_bytes, 0x3A, np.uint8))
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    before = s.backend.stored_bytes()
    # fault one MP: the load peeks + caches the extent raw
    assert s.guest.read(g, s.cfg.mp_bytes) == data[:s.cfg.mp_bytes]
    assert s.backend.stored_bytes() == before
    s.close()


# ------------------------------------------------------------- concurrency
def test_racing_fault_cancels_batched_swap_out():
    """A fault during a batched swap-out waits at most one chunk, cancels
    the writer, and reads consistent data."""
    s = fresh(swap=SwapConfig(batch_enabled=True, batch_mps=2))
    g = s.guest_alloc_ms()
    data = mixed_ms(s.cfg, 21)
    s.guest.write(g, data)

    orig = s.backend.store_batch
    started = threading.Event()

    def slow_store_batch(gfn, mps, d):
        started.set()
        time.sleep(0.002)                  # one chunk takes ~2ms
        return orig(gfn, mps, d)

    s.backend.store_batch = slow_store_batch
    done = threading.Event()
    result = {}

    def writer():
        result["n"] = s.engine.swap_out_ms(g, batched=True)
        done.set()

    w = threading.Thread(target=writer)
    w.start()
    started.wait(5)
    time.sleep(0.003)                      # land mid-flight
    got = s.guest.read(g, s.cfg.ms_bytes)   # reader bumps the writer
    assert got == data
    w.join(5)
    assert done.is_set()
    # either the reader arrived in time to cancel, or the writer had
    # already finished every chunk -- both leave a consistent MS
    assert s.metrics.writer_cancels >= 1 or result["n"] == s.cfg.mps_per_ms
    rec = s.reqs.lookup(g).record
    assert rec.present_count == s.cfg.mps_per_ms
    assert rec.state == MS_RESIDENT
    assert np.all(rec.bm_in == 0)
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    s.close()


def test_concurrent_faults_after_batched_swap_out_exactly_once():
    s = fresh()
    g = s.guest_alloc_ms()
    data = mixed_ms(s.cfg, 31)
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    errs = []

    def reader(mp):
        try:
            off = mp * s.cfg.mp_bytes
            got = s.guest.read(g, s.cfg.mp_bytes, off=off)
            assert got == data[off:off + s.cfg.mp_bytes]
        except Exception as e:             # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=reader, args=(mp % s.cfg.mps_per_ms,))
               for mp in range(4 * s.cfg.mps_per_ms)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert s.metrics.mp_swapped_in == s.cfg.mps_per_ms   # exactly once
    assert s.reqs.lookup(g).record.state == MS_RESIDENT
    s.close()
