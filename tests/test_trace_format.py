"""Trace format round-trips + malformed-line rejection (ISSUE 4).

Hypothesis drives the property bodies in CI (pinned in requirements);
the local container has no hypothesis, so a seeded-``random`` fallback
runs the same bodies, matching the test_lru.py pattern."""
import random

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - CI pins hypothesis
    HAVE_HYPOTHESIS = False

from repro.fleet.trace import (OP_ALLOC, OP_FREE, OP_KILL, OP_MIGRATE,
                               OP_RDATA, OP_RECOVER, OP_TICK, OP_TOUCH,
                               OP_UPGRADE, OP_WDATA, TraceHeader,
                               decode_payload, decode_read_check,
                               encode_payload, encode_read_check,
                               format_line, parse_line)

OPS = (OP_ALLOC, OP_FREE, OP_TOUCH, OP_TICK, OP_UPGRADE,
       OP_KILL, OP_RECOVER, OP_MIGRATE)


# ------------------------------------------------------- property bodies
def _roundtrip_line(seq, op, arg, w):
    line = format_line(seq, op, arg, w)
    assert "\n" not in line
    assert parse_line(line) == (seq, op, arg, w, "")
    assert parse_line(line + "\n") == (seq, op, arg, w, "")   # file form


def _roundtrip_payload_line(seq, arg, data):
    wline = format_line(seq, OP_WDATA, arg, 1, encode_payload(data))
    assert "\n" not in wline
    pseq, pop, parg, pw, payload = parse_line(wline)
    assert (pseq, pop, parg, pw) == (seq, OP_WDATA, arg, 1)
    assert decode_payload(payload) == data
    rline = format_line(seq, OP_RDATA, arg, 0, encode_read_check(data))
    _, _, _, _, check = parse_line(rline)
    assert decode_read_check(check) == (len(data), __import__(
        "zlib").crc32(data) & 0xFFFFFFFF)


def _roundtrip_header(seed, ms_bytes, mps_per_ms, zero, comp):
    hdr = TraceHeader(seed, ms_bytes, mps_per_ms, zero, comp)
    parsed = TraceHeader.parse(hdr.line())
    assert (parsed.seed, parsed.ms_bytes, parsed.mps_per_ms) == \
        (seed, ms_bytes, mps_per_ms)
    assert parsed.mp_bytes == ms_bytes // mps_per_ms
    # %.6g is the canonical float form: reformatting is a fixed point
    assert TraceHeader.parse(parsed.line()).line() == parsed.line()


# ------------------------------------------------------- hypothesis path
if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10**9), st.sampled_from(OPS),
           st.integers(0, 2**48), st.integers(0, 1))
    @settings(max_examples=80, deadline=None)
    def test_line_roundtrip_random(seq, op, arg, w):
        _roundtrip_line(seq, op, arg, w)

    @given(st.integers(0, 10**9), st.integers(0, 2**48),
           st.binary(min_size=0, max_size=512))
    @settings(max_examples=60, deadline=None)
    def test_payload_line_roundtrip_random(seq, arg, data):
        _roundtrip_payload_line(seq, arg, data)

    @given(st.integers(0, 2**31),
           st.integers(1, 64).map(lambda k: 512 * k),
           st.sampled_from([1, 2, 4, 8, 16]),
           st.floats(0, 1).map(lambda f: round(f, 4)),
           st.floats(0, 1).map(lambda f: round(f, 4)))
    @settings(max_examples=40, deadline=None)
    def test_header_roundtrip_random(seed, ms_bytes, mps, zero, comp):
        _roundtrip_header(seed, ms_bytes, mps, zero, comp)


# --------------------------------------------------- seeded fallback path
def test_line_roundtrip_seeded_fallback():
    """Seeded-``random`` fallback fuzz: randomized coverage without
    hypothesis (not installed locally; CI keeps the path above)."""
    rng = random.Random(0xC4A05)
    for _ in range(400):
        _roundtrip_line(rng.randrange(0, 10**9), rng.choice(OPS),
                        rng.randrange(0, 2**48), rng.randrange(0, 2))


def test_header_roundtrip_seeded_fallback():
    rng = random.Random(0x7A171)
    for _ in range(120):
        _roundtrip_header(rng.randrange(0, 2**31),
                          512 * rng.randrange(1, 65),
                          rng.choice([1, 2, 4, 8, 16]),
                          round(rng.random(), 4), round(rng.random(), 4))


def test_payload_line_roundtrip_seeded_fallback():
    rng = random.Random(0x9DA7A)
    for _ in range(120):
        _roundtrip_payload_line(rng.randrange(0, 10**9),
                                rng.randrange(0, 2**48),
                                rng.randbytes(rng.randrange(0, 512)))


# ------------------------------------------------------ malformed inputs
@pytest.mark.parametrize("line", [
    "",                                  # empty
    "1\talloc\t3",                       # missing column
    "1\talloc\t3\t0\textra",             # payload column on a payload-free op
    "1\twdata\t0x40\t1",                 # payload op without its payload
    "1\twdata\t0x40\t1\t",               # payload op with an empty payload
    "1\trdata\t0x40\t0",                 # ditto for the read-check op
    "1\twdata\t0x40\t1\ta\tb",           # too many columns
    "x\talloc\t3\t0",                    # non-int seq
    "1\talloc\tzz\t0",                   # non-int arg
    "1\ttouch\t0xgg\t0",                 # bad hex arg
    "1\talloc\t3\t7",                    # is_write out of range
    "1\talloc\t3\tx",                    # non-int is_write
    "1 alloc 3 0",                       # wrong separator
])
def test_malformed_lines_rejected(line):
    with pytest.raises(ValueError):
        parse_line(line)


@pytest.mark.parametrize("payload", [
    "not-base64!",                       # bad alphabet
    "aGVsbG8=",                          # valid base64, not zlib
])
def test_malformed_payloads_rejected(payload):
    with pytest.raises(ValueError):
        decode_payload(payload)


@pytest.mark.parametrize("check", ["", "64", "x:abcd", "64:zz", "-1:00000000"])
def test_malformed_read_checks_rejected(check):
    with pytest.raises(ValueError):
        decode_read_check(check)


@pytest.mark.parametrize("line", [
    "# not-a-taiji-trace seed=1",                                  # magic
    "# taiji-trace v1 ms_bytes=512 mps_per_ms=8 zero=.5 comp=.2",  # no seed
    "# taiji-trace v1 seed=x ms_bytes=512 mps_per_ms=8 zero=.5 comp=.2",
    "# taiji-trace v1 seed=1 ms_bytes=500 mps_per_ms=8 zero=.5 comp=.2",
    "# taiji-trace v1 seed=1 ms_bytes=512 mps_per_ms=0 zero=.5 comp=.2",
    "# taiji-trace v1 seed=1 ms_bytes=-512 mps_per_ms=8 zero=.5 comp=.2",
])
def test_malformed_headers_rejected(line):
    with pytest.raises(ValueError):
        TraceHeader.parse(line)
