"""Known-bad concurrency patterns for the AST lint's fixture suite.

NOT a test module (and not importable into the engine): every construct
below is a violation the lint must flag with a file:line finding. Kept
under tests/fixtures/ so neither pytest nor `lint src/` picks it up.
"""
import threading
import time
import zlib


class BadReclaim:
    """Each method is one seeded violation class."""

    def __init__(self):
        self.spare = threading.Lock()          # TJL003: bare construction

    def tree_then_mutex(self, reqs, req):
        # the exact drift req.py:232 documents: the mutex bounce nested
        # under the tree lock (declared anti-edge req.tree -> req.mp_mutex)
        with reqs._lock:                       # lock: req.tree
            req.mp_mutex.acquire()             # TJL001: anti-edge
            req.mp_mutex.release()

    def rank_inversion(self, backend, req):
        with backend._ext_lock:
            with req.mp_mutex:                 # TJL001: 52 -> 20 inversion
                pass

    def blocking_under_mutex(self, req, other):
        with req.mp_cond:
            time.sleep(0.001)                  # TJL002: sleep under mutex
            zlib.compress(b"x" * 64)           # TJL002: compress under mutex
            other.mp_cond.wait()               # TJL002: foreign condvar wait

    def blocking_writer_under_mutex(self, req, victim):
        with req.mp_mutex:
            # PR 3's bailout uses blocking=False here; the blocking form
            # is a rank inversion (rwlock ranks below the mutex)
            victim.rwlock.acquire_write()      # TJL001: 20 -> 10 blocking

    def deprecated_shims(self, system, gfn):
        addr = system.ms_addr(gfn, mp=1)       # TJL004
        system.write(addr, b"zz")              # TJL004
        return system.read(addr, 2)            # TJL004
