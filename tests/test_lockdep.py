"""Lock hierarchy enforcement (ISSUE 10): the runtime witness's rank /
anti-edge / cycle detection, the PR 3 bailout false-positive guard, the
off-mode zero-overhead contract, and the AST lint fixture suite."""
import contextlib
import os
import subprocess
import sys
import threading

import pytest

from repro.analysis import lint, lock_order, witness
from repro.analysis.lock_order import LockOrderViolation, named_lock

REPO = os.path.join(os.path.dirname(__file__), "..")
FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "lockdep_bad")
SRC = os.path.join(REPO, "src")


@contextlib.contextmanager
def lockdep_on():
    """Enable the witness for locks constructed inside the block; drain
    any latched violations on exit (so the autouse lane check and later
    tests see a clean slate) and restore the previous switch state."""
    prev = lock_order.STATE.on
    lock_order.STATE.on = True
    try:
        yield
    finally:
        witness.clear_violations()
        lock_order.STATE.on = prev


# ---------------------------------------------------------------- witness
def test_rank_inversion_raises():
    with lockdep_on():
        lru = named_lock("lru")
        mutex = named_lock("req.mp_mutex", group=1)
        with pytest.raises(LockOrderViolation, match="rank inversion"):
            with lru:
                mutex.acquire()
        assert not lru.locked()  # the with-block unwound


def test_ascending_ranks_are_legal():
    with lockdep_on():
        mutex = named_lock("req.mp_mutex", group=2)
        slot = named_lock("slot")
        metrics = named_lock("metrics")
        with mutex:
            with slot:
                with metrics:
                    assert witness.held_classes() == [
                        "req.mp_mutex", "slot", "metrics"]
        assert witness.held_classes() == []


def test_anti_edge_tree_then_mutex_raises():
    """Regression for the req.py:232 contract (satellite 3): the mutex
    bounce must not nest under the tree lock. The declared anti-edge
    fires even though plain rank order would already reject it -- with
    the documented message, so the report names the invariant."""
    with lockdep_on():
        tree = named_lock("req.tree")
        mutex = named_lock("req.mp_mutex", group=3)
        with pytest.raises(LockOrderViolation, match="anti-edge"):
            with tree:
                mutex.acquire()  # the quiesce bounce, nested wrongly
        drained = witness.clear_violations()
        assert any("quiesce" in v for v in drained)


def test_mutex_then_tree_is_legal():
    """The real direction: critical-zone reclaim takes the tree lock
    while holding a req mutex (get_or_create under _alloc_slot_critical).
    The declared order (tree above mp_mutex) must allow it."""
    with lockdep_on():
        mutex = named_lock("req.mp_mutex", group=4)
        tree = named_lock("req.tree")
        with mutex:
            with tree:
                pass
        assert witness.clear_violations() == []


def test_trylock_is_exempt_but_still_held():
    with lockdep_on():
        lru = named_lock("lru")
        mutex = named_lock("req.mp_mutex", group=5)
        metrics = named_lock("metrics")
        with lru:
            assert mutex.acquire(blocking=False)  # inversion, but trylock
            # ...and the trylocked mutex still participates as a held
            # lock: a leaf above it is fine
            with metrics:
                assert witness.held_classes() == [
                    "lru", "req.mp_mutex", "metrics"]
            mutex.release()
        assert witness.clear_violations() == []


def test_gate_allows_pr3_bailout_nesting():
    """The critical-zone bailout (PR 3): while holding req A's mutex, the
    reclaimer trylocks victim B's write grant and only then takes B's
    mutex. Same-rank mutex nesting is legal iff that grant is held."""
    with lockdep_on():
        mutex_a = named_lock("req.mp_mutex", group=10)
        mutex_b = named_lock("req.mp_mutex", group=11)
        with mutex_a:
            # trylocked write grant on req B (what acquire_write(
            # blocking=False) records on success)
            witness.push_virtual(witness.RWLOCK_CLASS, 11, iid=0xB,
                                 write=True, trylock=True)
            try:
                with mutex_b:  # gated: B's write grant is held
                    assert witness.held_classes()[-1] == "req.mp_mutex"
            finally:
                witness.pop_virtual(0xB)
        assert witness.clear_violations() == []


def test_mutex_nesting_without_grant_raises():
    with lockdep_on():
        mutex_a = named_lock("req.mp_mutex", group=12)
        mutex_b = named_lock("req.mp_mutex", group=13)
        with pytest.raises(LockOrderViolation, match="same-rank"):
            with mutex_a:
                mutex_b.acquire()  # no write grant for req 13: ABBA risk


def test_cross_thread_cycle_detected():
    """T1 takes A then B (legal: 'app' is a multi class). T2 then taking
    B before A must raise at the acquisition that closes the cycle, even
    though T2's own stack never inverts a rank."""
    with lockdep_on():
        a = named_lock("app")
        b = named_lock("app")

        def t1():
            with a:
                with b:
                    pass

        t = threading.Thread(target=t1)
        t.start()
        t.join()

        with pytest.raises(LockOrderViolation, match="cycle"):
            with b:
                a.acquire()


def test_condition_wait_keeps_stack_accurate():
    """Condition.wait releases/reacquires through the witness wrapper,
    so locks taken after a wait still see an accurate held stack."""
    with lockdep_on():
        mutex = named_lock("req.mp_mutex", group=6)
        cond = threading.Condition(mutex)
        metrics = named_lock("metrics")
        with cond:
            cond.wait(timeout=0.01)
            with metrics:
                assert witness.held_classes() == ["req.mp_mutex", "metrics"]
        assert witness.held_classes() == []
        assert witness.clear_violations() == []


def test_edge_graph_records_observed_edges():
    with lockdep_on():
        witness.reset()
        mutex = named_lock("req.mp_mutex", group=7)
        slot = named_lock("slot")
        with mutex:
            with slot:
                pass
        graph = witness.dump_graph()
        assert {"src": "req.mp_mutex", "dst": "slot", "tag": "ok",
                "count": 1} in graph["edges"]
        assert graph["violations"] == []


# ------------------------------------------------- engine false positives
def test_engine_under_pressure_is_clean():
    """False-positive guard at engine level: a system pushed into the
    critical zone (reclaim-under-fault, the gated bailout nesting) must
    produce zero witness violations."""
    from repro.core.config import small_test_config
    from repro.core.system import TaijiSystem

    with lockdep_on():
        witness.reset()
        sys_ = TaijiSystem(small_test_config())
        space = sys_.guest
        cfg = sys_.cfg
        n = cfg.n_virt_ms - cfg.mpool_reserve_ms - 2  # well past physical
        gfns = [space.alloc_ms() for _ in range(n)]
        pat = b"\xa5" * 256
        for g in gfns:
            space.write(g, pat)
            sys_.step_background()
        for g in gfns:  # fault the cold tail back in
            assert space.read(g, len(pat)) == pat
        assert witness.clear_violations() == []
        graph = witness.dump_graph()
        # the run actually exercised nesting under the mutex
        assert any(e["src"] == "req.mp_mutex" for e in graph["edges"])


# ------------------------------------------------------------- off mode
def test_off_mode_returns_raw_lock():
    """With the witness off, named_lock must hand back a plain
    threading.Lock -- not a wrapper -- so the fault fast path pays
    literally nothing."""
    prev = lock_order.STATE.on
    lock_order.STATE.on = False
    try:
        lk = named_lock("req.mp_mutex", group=1)
        assert type(lk) is type(threading.Lock())
    finally:
        lock_order.STATE.on = prev


def test_rwlock_hooks_are_one_truthiness_check_when_off():
    from repro.core.req import RWLockWriterCancel
    prev = lock_order.STATE.on
    lock_order.STATE.on = False
    try:
        rw = RWLockWriterCancel(group=1)
        rw.acquire_read()
        rw.release_read()
        grant = rw.acquire_write()
        rw.release_write(grant)
        # off mode must leave no witness state behind
        assert witness.held_classes() == []
    finally:
        lock_order.STATE.on = prev


# ------------------------------------------------------------ AST lint
def test_lint_clean_on_src():
    assert lint.lint_paths([SRC]) == []


def test_lint_fixture_findings():
    findings = lint.lint_paths([FIXTURE])
    codes = {f.code for f in findings}
    assert codes == {"TJL001", "TJL002", "TJL003", "TJL004"}
    by_code = {}
    for f in findings:
        assert f.path.endswith("bad_nesting.py") and f.line > 0
        by_code.setdefault(f.code, []).append(f)
    assert len(by_code["TJL001"]) == 3   # anti-edge, inversion, rwlock
    assert len(by_code["TJL002"]) == 3   # sleep, compress, foreign wait
    assert len(by_code["TJL003"]) == 1   # bare Lock()
    assert len(by_code["TJL004"]) == 3   # ms_addr, write, read
    anti = [f for f in by_code["TJL001"] if "anti-edge" in f.message]
    assert anti and "quiesce" in anti[0].message


@pytest.mark.parametrize("target,expected", [("src/", 0),
                                             ("tests/fixtures/lockdep_bad/", 1)])
def test_lint_cli_exit_codes(target, expected):
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", target],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert proc.returncode == expected, proc.stdout + proc.stderr
    if expected:
        assert "TJL001" in proc.stdout and ":" in proc.stdout.split()[0]
