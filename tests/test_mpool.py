"""mpool: pinned metadata arena (paper §4.1.1, Fig 13a)."""
import numpy as np
import pytest

from repro.core.errors import MpoolExhaustedError
from repro.core.mpool import Mpool


def make_pool(pages=8, page_bytes=1024):
    return Mpool(np.zeros(pages * page_bytes, dtype=np.uint8), page_bytes)


def test_page_alloc_free_cycle():
    p = make_pool()
    pages = [p.alloc_page() for _ in range(8)]
    with pytest.raises(MpoolExhaustedError):
        p.alloc_page()
    offsets = {h.offset for h in pages}
    assert len(offsets) == 8
    for h in pages:
        p.free_page(h)
    assert p.stats()["used_bytes"] == 0
    p.alloc_page()  # reusable


def test_slab_size_classes_and_reuse():
    p = make_pool()
    a = p.slab_alloc(40)       # -> 64B class
    b = p.slab_alloc(64)
    assert a.nbytes == 64 and b.nbytes == 64
    # same class shares a page
    assert a.offset // 1024 == b.offset // 1024
    c = p.slab_alloc(100)      # -> 128B class, different page
    assert c.offset // 1024 != a.offset // 1024
    p.slab_free(a)
    d = p.slab_alloc(33)
    assert d.offset == a.offset      # slot reused
    stats = p.stats()
    assert stats["slab_bytes"] == 64 * 2 + 128


def test_views_are_arena_backed_and_zeroed():
    p = make_pool()
    h = p.slab_alloc(64)
    v = h.view(np.uint32)
    assert v.sum() == 0
    v[:] = 0xDEAD
    # re-attached view sees the same bytes (hot-upgrade inheritance)
    from repro.core.mpool import Handle
    h2 = Handle(p, h.offset, h.nbytes)
    assert (h2.view(np.uint32) == 0xDEAD).all()


def test_accounting_split():
    p = make_pool(pages=16)
    p.alloc_page()
    p.alloc_page()
    for _ in range(5):
        p.slab_alloc(200)
    s = p.stats()
    assert s["full_page_bytes"] == 2048
    assert s["slab_bytes"] == 5 * 256
    assert 0 < s["utilization"] < 1
    assert abs(s["full_page_fraction"] + s["slab_fraction"] - 1.0) < 1e-9
