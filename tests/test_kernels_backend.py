"""``use_pallas_kernels=True`` wired through the backend/swap data path.

ISSUE 6 revives the flag: the batched data path routes its zero-detect
scan, per-extent-row Fletcher integrity tags and swap gather/scatter
copies through the Pallas kernels (interpret mode on CPU, so this runs
in default-lane CI). The per-MP zlib CRCs stored in MS records are
unchanged -- records stay byte-compatible with the host path -- and the
lossless zlib compression itself stays host-side (kernels/compress.py
is the lossy int8 KV tier and never feeds the exact backend).
"""
import numpy as np
import pytest

from repro.core.config import (BackendConfig, HotPathConfig, SwapConfig,
                               small_test_config)
from repro.core.errors import CorruptionError
from repro.core.system import TaijiSystem


def _kernel_cfg(**overrides):
    base = dict(
        ms_bytes=32 * 1024, mps_per_ms=32,
        backend=BackendConfig(extent_max_rows=8),
        swap=SwapConfig(hot_path=HotPathConfig(pallas_kernels=True)))
    base.update(overrides)
    return small_test_config(**base)


def _compressible_ms(rng, ms_bytes, mps, zero_every=4):
    """Paper-like mix: some zero MPs, the rest compressible non-zero."""
    mp = ms_bytes // mps
    rows = []
    for i in range(mps):
        if i % zero_every == 0:
            rows.append(bytes(mp))
        else:
            rows.append(
                rng.integers(1, 256, 64, dtype=np.uint8).tobytes()
                * (mp // 64))
    return b"".join(rows)


@pytest.fixture
def system():
    s = TaijiSystem(_kernel_cfg())
    yield s
    s.close()


def test_kernel_flag_wires_backend_and_engine(system):
    assert system.cfg.swap.use_pallas_kernels is True
    assert system.backend._kernel_zero_detect is not None
    assert system.backend._kernel_checksum is not None
    assert system.engine._kernel_gather is not None
    assert system.engine._kernel_scatter is not None


def test_swap_roundtrip_with_kernels(system):
    rng = np.random.default_rng(21)
    cfg = system.cfg
    gfns = [system.guest.alloc_ms() for _ in range(3)]
    data = {g: _compressible_ms(rng, cfg.ms_bytes, cfg.mps_per_ms)
            for g in gfns}
    for g in gfns:
        system.guest.write(g, data[g])
    for g in gfns:
        assert system.engine.swap_out_ms(g) == cfg.mps_per_ms
    for g in gfns:
        system.engine.swap_in_ms(g)
        assert system.guest.read(g) == data[g]
    assert system.metrics.crc_failures == 0
    assert system.metrics.backend_batch_stores > 0      # batched path ran
    assert system.metrics.backend_batch_loads > 0


def test_kernel_and_host_paths_swap_interchangeably():
    """Kind/CRC selection is identical on both paths: an MS swapped out
    under kernels reads back on a host-path system image and vice versa
    (the MS record ABI is shared; only in-memory extras differ)."""
    rng = np.random.default_rng(22)
    results = {}
    for kernels in (False, True):
        s = TaijiSystem(_kernel_cfg(
            swap=SwapConfig(hot_path=HotPathConfig(pallas_kernels=kernels))))
        try:
            g = s.guest.alloc_ms()
            data = _compressible_ms(rng, s.cfg.ms_bytes, s.cfg.mps_per_ms)
            s.guest.write(g, data)
            s.engine.swap_out_ms(g)
            rec = s.reqs.lookup(g).record
            results[kernels] = (rec.kinds.tolist(), rec.crc.tolist())
            assert s.guest.read(g) == data
        finally:
            s.close()
        rng = np.random.default_rng(22)                  # same data again
    assert results[False] == results[True]


def test_store_load_batch_with_extent_tags(system):
    """Direct backend unit: store_batch attaches per-row Fletcher tags to
    extents; load_batch verifies them and round-trips the bytes."""
    be = system.backend
    cfg = system.cfg
    rng = np.random.default_rng(23)
    k = 16
    mps = np.arange(k)
    data = np.frombuffer(
        b"".join(rng.integers(1, 256, 64, dtype=np.uint8).tobytes()
                 * (cfg.mp_bytes // 64) for _ in range(k)),
        np.uint8).reshape(k, cfg.mp_bytes).copy()
    gfn = 997                                           # synthetic key space
    kinds, crcs = be.store_batch(gfn, mps, data)
    exts = [ext for (g, _), ext in be._extents.items() if g == gfn]
    assert exts and all(ext.tags is not None for ext in exts)
    out = np.zeros_like(data)
    be.load_batch(gfn, mps, kinds, crcs, out)
    np.testing.assert_array_equal(out, data)
    assert system.metrics.crc_failures == 0


def test_corrupted_extent_tag_detected(system):
    be = system.backend
    cfg = system.cfg
    rng = np.random.default_rng(24)
    k = 8
    mps = np.arange(k)
    data = np.frombuffer(
        b"".join(rng.integers(1, 256, 64, dtype=np.uint8).tobytes()
                 * (cfg.mp_bytes // 64) for _ in range(k)),
        np.uint8).reshape(k, cfg.mp_bytes).copy()
    gfn = 998
    kinds, crcs = be.store_batch(gfn, mps, data)
    # flip one stored tag: the device-side integrity check must fire
    # before any row is consumed (all-or-nothing load_batch)
    (key, ext) = next((kv for kv in be._extents.items() if kv[0][0] == gfn))
    ext.tags[0] ^= 0x1
    out = np.zeros_like(data)
    with pytest.raises(CorruptionError, match="extent tag mismatch"):
        be.load_batch(gfn, mps, kinds, crcs, out)
    assert system.metrics.crc_failures == 1
    # nothing was consumed: restore the tag and the load succeeds
    ext.tags[0] ^= 0x1
    be.load_batch(gfn, mps, kinds, crcs, out)
    np.testing.assert_array_equal(out, data)


def test_zero_detect_kernel_matches_host_scan(system):
    be = system.backend
    cfg = system.cfg
    rng = np.random.default_rng(25)
    data = rng.integers(0, 256, (12, cfg.mp_bytes), dtype=np.uint8)
    data[::3] = 0
    got = np.asarray(be._kernel_zero_detect(data))
    np.testing.assert_array_equal(got.astype(bool), ~data.any(axis=1))


def test_fault_path_under_kernels(system):
    """Passive faults (guest read of a swapped MS) still resolve with
    kernels on: zero MPs via the fast path, extent rows via tag-verified
    readahead."""
    rng = np.random.default_rng(26)
    cfg = system.cfg
    g = system.guest.alloc_ms()
    data = _compressible_ms(rng, cfg.ms_bytes, cfg.mps_per_ms)
    system.guest.write(g, data)
    system.engine.swap_out_ms(g)
    # fault back one MP at a time through the guest path
    for mp in range(cfg.mps_per_ms):
        off = mp * cfg.mp_bytes
        assert system.guest.read(g, cfg.mp_bytes, off=off) == \
            data[off:off + cfg.mp_bytes]
    assert system.metrics.faults > 0
    assert system.metrics.crc_failures == 0
