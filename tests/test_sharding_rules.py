"""Sharding rules: divisibility guards, FSDP/TP assignment, batch fitting."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.sharding import ShardingRules, abstract_mesh
from repro.launch import specs as SP
from repro.models import model as M


def mesh16x16():
    return abstract_mesh((16, 16), ("data", "model"))


def mesh_pod():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def pspec_of(tree, *path):
    node = tree
    for p in path:
        node = node[p]
    return node


def test_gqa_heads_sharded_when_divisible():
    cfg = get_config("qwen3-4b")                 # 32 heads, kv 8
    rules = ShardingRules(cfg, mesh16x16())
    shapes = M.param_shapes(cfg)
    specs = rules.param_pspecs(shapes)
    assert pspec_of(specs, "layers", "attn", "wq") == P(None, "data", "model")
    # kv heads 8 < 16: kv projections stay unsharded on model
    assert pspec_of(specs, "layers", "attn", "wk") == P(None, "data", None)


def test_nondivisible_heads_left_unsharded():
    cfg = get_config("qwen2-0.5b")               # 14 heads
    rules = ShardingRules(cfg, mesh16x16())
    specs = rules.param_pspecs(M.param_shapes(cfg))
    assert pspec_of(specs, "layers", "attn", "wq") == P(None, "data", None)
    # but the MLP hidden (4864 = 16*304) is TP-sharded
    assert pspec_of(specs, "layers", "mlp", "w_gate") == P(None, "data", "model")


def test_moe_experts_sharded_over_model():
    cfg = get_config("qwen3-moe-235b-a22b")      # 128 experts
    rules = ShardingRules(cfg, mesh16x16())
    specs = rules.param_pspecs(M.param_shapes(cfg))
    assert pspec_of(specs, "layers", "moe", "w_gate")[1] == "model"
    assert pspec_of(specs, "layers", "moe", "w_down")[1] == "model"


def test_mamba_d_inner_sharded():
    cfg = get_config("falcon-mamba-7b")
    rules = ShardingRules(cfg, mesh16x16())
    specs = rules.param_pspecs(M.param_shapes(cfg))
    assert pspec_of(specs, "layers", "mamba", "in_proj") == P(None, "data", "model")
    assert pspec_of(specs, "layers", "mamba", "out_proj") == P(None, "model", "data")


def test_batch_specs_fit_small_batches():
    cfg = get_config("jamba-1.5-large-398b")
    rules = ShardingRules(cfg, mesh_pod(), pod_axis="pod")
    from repro.configs import SHAPES
    # long_500k decode: B=1 cannot shard over (pod, data)
    sds = SP.input_specs(cfg, SHAPES["long_500k"])
    specs = rules.batch_pspecs(sds)
    assert specs["tokens"] == P(None)
    # train batch 256 shards over (pod, data)
    sds = SP.input_specs(cfg, SHAPES["train_4k"])
    specs = rules.batch_pspecs(sds)
    assert specs["tokens"][0] == ("pod", "data")


def test_cache_specs_shard_pool_blocks():
    cfg = get_config("qwen3-4b")
    rules = ShardingRules(cfg, mesh16x16())
    cache_sds = SP.cache_specs(cfg, 128, 32768)
    specs = rules.cache_pspecs(cache_sds, 128)
    assert specs["kv_pool"][1] == "data"
    assert specs["block_table"] == P("data", None)


def test_state_specs_cover_opt_state():
    cfg = get_config("qwen2-0.5b")
    rules = ShardingRules(cfg, mesh16x16())
    st = SP.state_specs(cfg)
    sp = rules.state_pspecs(st)
    assert sp.step == P()
    assert jax.tree.structure(sp.opt.mu) == jax.tree.structure(sp.params)


def test_axis_ctx_flags():
    cfg = get_config("qwen2-0.5b")
    rules = ShardingRules(cfg, mesh16x16())
    ctx = rules.make_axis_ctx(batch=256)
    assert not ctx.heads_ok          # 14 heads
    assert ctx.vocab_ok              # 151936 % 16 == 0
    assert ctx.ffn_ok                # 4864 % 16 == 0
    ctx1 = rules.make_axis_ctx(batch=1)
    assert ctx1.batch is None        # B=1 unshardable
