"""Microsecond fault path (ISSUE 3): O(1) descriptors, zero-page fast
path, extent readahead, latency ring, backend accounting.

The fast path must be *observationally identical* to the locked scalar
reference path (``SwapConfig(fast_fault_enabled=False,
readahead_enabled=False)``): same bytes, same record state, same
exactly-once guarantees under racing writers.
"""
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core.config import SwapConfig, small_test_config
from repro.core.errors import CorruptionError
from repro.core.metrics import (FK_COMPRESSED, FK_FAST, FK_ZERO,
                                LatencyHistogram, Metrics)
from repro.core.ms import (K_COMPRESSED, K_NONE, K_ZERO,
                           MS_RESIDENT, MS_SWAPPED)
from repro.core.system import TaijiSystem

SCALAR = SwapConfig(fast_fault_enabled=False, readahead_enabled=False)


def fresh(**kw):
    return TaijiSystem(small_test_config(**kw))


def mixed_ms(cfg, seed):
    """Zero / compressible / incompressible MP mix in one MS."""
    rng = np.random.default_rng(seed)
    rows = []
    for mp in range(cfg.mps_per_ms):
        r = mp % 3
        if r == 0:
            rows.append(np.zeros(cfg.mp_bytes, np.uint8))
        elif r == 1:
            rows.append(np.full(cfg.mp_bytes, mp & 0xFF, np.uint8))
        else:
            rows.append(rng.integers(0, 256, cfg.mp_bytes).astype(np.uint8))
    return np.concatenate(rows).tobytes()


# ------------------------------------------------------- descriptor table
def test_descriptor_table_registered_and_consistent():
    s = fresh()
    g = s.guest_alloc_ms()
    s.guest.write(g, mixed_ms(s.cfg, 1))
    s.engine.swap_out_ms(g)
    ft = s.reqs.table
    req = s.reqs.lookup(g)
    assert ft.reqs[g] is req
    assert req.fdesc is not None
    hdr, bmo, bmi, kio, cro = req.fdesc
    # descriptor loads must agree with the MSRecord views
    rec = req.record
    assert int(ft.i64[hdr + 4]) == rec.state
    assert int(ft.i64[hdr + 2]) == rec.pfn
    assert int(ft.u64[bmo]) == int(rec.bm_out[0])
    assert int(ft.u64[bmi]) == int(rec.bm_in[0])
    assert int(ft.a8[kio]) == int(rec.kinds[0])
    assert int(ft.u32[cro]) == int(rec.crc[0])
    s.reqs.check_invariants()
    s.close()


def test_descriptor_unregistered_on_free():
    s = fresh()
    g = s.guest_alloc_ms()
    s.guest.write(g, mixed_ms(s.cfg, 2))
    s.engine.swap_out_ms(g)
    s.guest.read(g, s.cfg.ms_bytes)           # fault everything back
    s.guest_free_ms(g)
    assert s.reqs.table.reqs[g] is None
    assert int(s.reqs.table.hdr[g]) == -1
    s.close()


# ------------------------------------------------------ zero-page fast path
def test_zero_fast_path_resolves_and_counts():
    s = fresh()
    g = s.guest_alloc_ms()                          # zero-filled
    s.engine.swap_out_ms(g)
    assert s.guest.read(g, s.cfg.ms_bytes) == bytes(s.cfg.ms_bytes)
    s.metrics.sync()
    assert s.metrics.fault_fast_path == s.cfg.mps_per_ms
    assert s.metrics.fault_zero_pages == s.cfg.mps_per_ms
    rec = s.reqs.lookup(g).record
    assert rec.state == MS_RESIDENT
    assert rec.present_count == s.cfg.mps_per_ms
    assert np.all(rec.kinds == K_NONE)
    s.close()


def test_fast_path_first_in_allocates_exactly_once():
    """Concurrent first faults into a fully swapped MS: one slot alloc."""
    s = fresh()
    g = s.guest_alloc_ms()
    s.engine.swap_out_ms(g)
    assert s.reqs.lookup(g).record.state == MS_SWAPPED
    free_before = s.phys.free_count
    errs = []

    def reader(mp):
        try:
            got = s.guest.read(g, s.cfg.mp_bytes, off=mp * s.cfg.mp_bytes)
            assert got == bytes(s.cfg.mp_bytes)
        except Exception as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=reader, args=(mp % s.cfg.mps_per_ms,))
               for mp in range(3 * s.cfg.mps_per_ms)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert free_before - s.phys.free_count == 1     # exactly-once alloc
    assert s.metrics.ms_swapped_in == 1
    s.metrics.sync()
    assert s.metrics.mp_swapped_in == s.cfg.mps_per_ms
    assert s.reqs.lookup(g).record.state == MS_RESIDENT
    s.close()


def test_fast_vs_scalar_reference_equivalence():
    """Byte- and state-identical MS after faulting through either path."""
    data = None
    finals = {}
    for swap_cfg in (None, SCALAR):
        s = fresh(**({} if swap_cfg is None else {"swap": swap_cfg}))
        g = s.guest_alloc_ms()
        data = data or mixed_ms(s.cfg, 11)
        s.guest.write(g, data)
        s.engine.swap_out_ms(g)
        # touch MPs one at a time through the guest read path
        got = b"".join(
            s.guest.read(g, s.cfg.mp_bytes, off=mp * s.cfg.mp_bytes)
            for mp in range(s.cfg.mps_per_ms))
        rec = s.reqs.lookup(g).record
        finals[swap_cfg is None] = (got, rec.state, rec.present_count,
                                    rec.kinds.copy(), rec.bm_out.copy())
        s.close()
    fast, scalar = finals[True], finals[False]
    assert fast[0] == scalar[0] == data
    assert fast[1] == scalar[1] == MS_RESIDENT
    assert fast[2] == scalar[2]
    assert np.array_equal(fast[3], scalar[3])
    assert np.array_equal(fast[4], scalar[4])


def test_fast_path_detects_crc_corruption():
    s = fresh()
    g = s.guest_alloc_ms()
    s.engine.swap_out_ms(g)
    rec = s.reqs.lookup(g).record
    rec.crc[3] = 0xDEADBEEF                         # corrupt the record CRC
    with pytest.raises(CorruptionError):
        s.guest.read(g, 16, off=3 * s.cfg.mp_bytes)
    assert s.metrics.crc_failures >= 1
    s.close()


def test_fault_vs_swap_out_race_on_descriptor_table():
    """Racing zero faults against a slow batched writer: the MS converges
    to a consistent state and every byte reads back."""
    s = fresh(swap=SwapConfig(batch_enabled=True, batch_mps=2))
    g = s.guest_alloc_ms()
    data = mixed_ms(s.cfg, 21)
    s.guest.write(g, data)

    orig = s.backend.store_batch
    started = threading.Event()

    def slow_store_batch(gfn, mps, d):
        started.set()
        time.sleep(0.002)
        return orig(gfn, mps, d)

    s.backend.store_batch = slow_store_batch
    done = threading.Event()

    def writer():
        s.engine.swap_out_ms(g, batched=True)
        done.set()

    w = threading.Thread(target=writer)
    w.start()
    started.wait(5)
    # faults land mid-swap-out: zero MPs take the descriptor fast path,
    # compressed MPs cancel the writer through the locked path
    for mp in range(s.cfg.mps_per_ms):
        off = mp * s.cfg.mp_bytes
        assert s.guest.read(g, s.cfg.mp_bytes, off=off) == \
            data[off:off + s.cfg.mp_bytes]
    w.join(5)
    assert done.is_set()
    rec = s.reqs.lookup(g).record
    assert np.all(rec.bm_in == 0)
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    assert rec.state == MS_RESIDENT
    assert rec.present_count == s.cfg.mps_per_ms
    s.reqs.check_invariants()
    s.close()


def test_fast_faults_during_swap_out_do_not_merge_prematurely():
    """present_count transiently double-counts a writer's in-flight chunk;
    fast faults re-resolving published zero MPs must not merge the MS
    while chunk MPs are still latched."""
    s = fresh(swap=SwapConfig(batch_enabled=True, batch_mps=2))
    g = s.guest_alloc_ms()                          # all-zero MS
    orig = s.backend.store_batch

    def racing_store_batch(gfn, mps, data):
        kinds, crcs = orig(gfn, mps, data)
        rec = s.reqs.lookup(g).record
        in_chunk = {int(x) for x in mps}
        # a racing guest fast-faults every already-published zero MP
        # while this chunk is still latched (bm_in set, present_count
        # not yet decremented)
        for mp in range(s.cfg.mps_per_ms):
            if mp not in in_chunk and rec.is_swapped_out(mp) \
                    and not rec.is_swapping_in(mp):
                s.engine.fault_in(g, mp)
        return kinds, crcs

    s.backend.store_batch = racing_store_batch
    s.engine.swap_out_ms(g, batched=True)
    rec = s.reqs.lookup(g).record
    # never RESIDENT while record bits still say swapped/latched
    assert not (rec.state == MS_RESIDENT
                and (rec.bm_out.any() or rec.bm_in.any()))
    assert np.all(rec.bm_in == 0)
    # the remaining MPs fault back in cleanly and the MS converges
    assert s.guest.read(g, s.cfg.ms_bytes) == bytes(s.cfg.ms_bytes)
    assert rec.state == MS_RESIDENT
    assert rec.present_count == s.cfg.mps_per_ms
    assert not rec.bm_out.any()
    s.reqs.check_invariants()
    s.close()


def test_quiesce_diverts_fast_path_to_locked_path():
    """After the teardown barrier, faults must take the slow path (which
    serializes on the freeer's write lock) instead of the lock-light exit."""
    s = fresh()
    g = s.guest_alloc_ms()                          # zero-filled
    s.engine.swap_out_ms(g)
    s.reqs.quiesce_fast_faults(g)
    assert s.guest.read(g, s.cfg.ms_bytes) == bytes(s.cfg.ms_bytes)
    s.metrics.sync()
    assert s.metrics.fault_fast_path == 0           # all via the locked path
    assert s.metrics.fault_zero_pages == s.cfg.mps_per_ms
    s.close()


def test_fast_fault_during_batched_prefetch_chunks():
    """A zero fast fault resolving an MP between prefetch chunks must not
    make the batched swap-in reload it (stale todo list)."""
    s = fresh(swap=SwapConfig(batch_enabled=True, batch_mps=2))
    g = s.guest_alloc_ms()
    data = mixed_ms(s.cfg, 41)
    s.guest.write(g, data)
    s.engine.swap_out_ms(g)
    rec = s.reqs.lookup(g).record
    # a zero MP that lands in a later chunk than the first
    zero_mp = max(mp for mp in range(s.cfg.mps_per_ms)
                  if rec.kinds[mp] == K_ZERO)
    orig = s.backend.load_batch
    fired = []

    def load_batch_with_racing_fault(gfn, mps, kinds, crcs, out):
        if not fired and zero_mp not in [int(x) for x in mps]:
            fired.append(True)
            # simulate a concurrent guest fault winning between chunks
            s.engine.fault_in(g, zero_mp)
        return orig(gfn, mps, kinds, crcs, out)

    s.backend.load_batch = load_batch_with_racing_fault
    s.engine.swap_in_ms(g, batched=True)      # must not raise
    assert fired
    s.metrics.sync()
    assert rec.state == MS_RESIDENT
    assert rec.present_count == s.cfg.mps_per_ms
    assert s.metrics.mp_swapped_in == s.cfg.mps_per_ms   # exactly once
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    s.close()


# ----------------------------------------------------------- extent readahead
def test_readahead_materializes_whole_extent():
    s = fresh()
    g = s.guest_alloc_ms()
    data = bytes(np.full(s.cfg.ms_bytes, 0xAB, np.uint8))   # all compressible
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    faults_before = s.metrics.faults
    # one fault into the extent materializes every sibling row
    assert s.guest.read(g, s.cfg.mp_bytes, off=2 * s.cfg.mp_bytes) == \
        data[2 * s.cfg.mp_bytes:3 * s.cfg.mp_bytes]
    assert s.metrics.faults == faults_before + 1
    assert s.metrics.readahead_extents == 1
    assert s.metrics.fault_readahead_mps == s.cfg.mps_per_ms - 1
    rec = s.reqs.lookup(g).record
    assert rec.state == MS_RESIDENT
    assert rec.present_count == s.cfg.mps_per_ms
    assert not s.backend._extents                    # fully consumed
    # no further faults: everything is already resident
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    assert s.metrics.faults == faults_before + 1
    s.close()


def test_readahead_respects_in_flight_and_resident_sibling():
    """A sibling already resident must not be re-materialized."""
    s = fresh(swap=SwapConfig(readahead_enabled=False))
    g = s.guest_alloc_ms()
    data = bytes(np.full(s.cfg.ms_bytes, 0x3C, np.uint8))
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    # scalar-fault one row first (readahead off), then re-enable
    assert s.guest.read(g, s.cfg.mp_bytes) == \
        data[:s.cfg.mp_bytes]
    s.engine._readahead = True
    overwrite = b"\x55" * 8
    s.guest.write(g, overwrite)           # dirty the resident MP
    assert s.guest.read(g, s.cfg.mp_bytes, off=3 * s.cfg.mp_bytes) == \
        data[3 * s.cfg.mp_bytes:4 * s.cfg.mp_bytes]
    # readahead materialized the swapped rows but left MP 0's new bytes
    assert s.guest.read(g, 8) == overwrite
    assert s.guest.read(g, s.cfg.ms_bytes) == \
        overwrite + data[8:]
    s.close()


def test_readahead_bytes_identical_vs_scalar_path():
    data = None
    got = {}
    for readahead in (False, True):
        s = fresh(swap=SwapConfig(fast_fault_enabled=True,
                                  readahead_enabled=readahead))
        g = s.guest_alloc_ms()
        data = data or mixed_ms(s.cfg, 31)
        s.guest.write(g, data)
        s.engine.swap_out_ms(g, batched=True)
        # drive through single-MP faults in a scattered order
        order = [5, 1, 7, 3, 0, 6, 2, 4][:s.cfg.mps_per_ms]
        for mp in order:
            s.guest.read(g, 8, off=mp * s.cfg.mp_bytes)
        got[readahead] = s.guest.read(g, s.cfg.ms_bytes)
        rec = s.reqs.lookup(g).record
        assert rec.state == MS_RESIDENT
        assert np.all(rec.kinds == K_NONE)
        s.close()
    assert got[False] == got[True] == data


def test_readahead_corrupt_sibling_does_not_poison_fault():
    """A corrupt sibling row stays swapped out and keeps failing; the
    triggering fault itself succeeds."""
    s = fresh()
    g = s.guest_alloc_ms()
    data = bytes(np.full(s.cfg.ms_bytes, 0x5C, np.uint8))
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=True)
    rec = s.reqs.lookup(g).record
    bad_mp = 4
    rec.crc[bad_mp] = 0xDEADBEEF            # sibling's record CRC corrupted
    # force the per-row salvage path: whole-extent CRC must fail too
    key = next(iter(s.backend._extents))
    s.backend._extents[key].crc ^= 1
    good_mp = 1
    assert s.guest.read(g, s.cfg.mp_bytes, off=good_mp * s.cfg.mp_bytes) == \
        data[good_mp * s.cfg.mp_bytes:(good_mp + 1) * s.cfg.mp_bytes]
    assert s.metrics.crc_failures >= 1
    assert rec.is_swapped_out(bad_mp)       # left swapped, still detectable
    with pytest.raises(CorruptionError):
        s.guest.read(g, 8, off=bad_mp * s.cfg.mp_bytes)
    s.close()


def test_corrupt_mp_keeps_failing_on_retry():
    """load() verifies before consuming: a corrupt MP raises
    CorruptionError on every attempt instead of KeyError on the second."""
    s = fresh(swap=SCALAR)
    g = s.guest_alloc_ms()
    rng = np.random.default_rng(17)
    data = rng.integers(0, 256, s.cfg.ms_bytes).astype(np.uint8).tobytes()
    s.guest.write(g, data)
    s.engine.swap_out_ms(g, batched=False)    # standalone per-MP blobs
    key, entry = next((k, e) for k, e in s.backend._compressed.items()
                      if e[0] == "v")
    blob = bytearray(entry[1])
    blob[0] ^= 0xFF
    s.backend._compressed[key] = ("v", bytes(blob))
    mp = key[1]
    for _attempt in range(2):
        with pytest.raises(CorruptionError):
            s.guest.read(g, 8, off=mp * s.cfg.mp_bytes)
    assert s.metrics.crc_failures >= 2
    s.close()


# --------------------------------------------------------- backend satellites
def test_drop_decrements_backend_accounting():
    s = fresh()
    cfg = s.cfg
    b = s.backend
    rng = np.random.default_rng(3)
    k = 6
    data = np.zeros((k, cfg.mp_bytes), np.uint8)
    data[0] = 0                                       # zero row
    data[1] = 0x77                                    # compressible
    data[2] = 0x77
    data[3] = rng.integers(0, 256, cfg.mp_bytes)      # incompressible rows
    data[4] = rng.integers(0, 256, cfg.mp_bytes)
    data[5] = 0x77
    mps = np.arange(k)
    kinds, _ = b.store_batch(500, mps, data)
    assert s.metrics.backend_raw_bytes > 0
    assert s.metrics.backend_stored_bytes > 0
    for i in range(k):
        b.drop(500, int(mps[i]), int(kinds[i]))
    assert s.metrics.backend_raw_bytes == 0
    assert s.metrics.backend_stored_bytes == 0
    assert b.stored_bytes() == 0
    assert not b._extents
    # scalar-store entries account symmetrically
    kind, _crc = b.store(501, 0, data[3])
    assert kind == K_COMPRESSED                       # stored verbatim
    b.drop(501, 0, kind)
    assert s.metrics.backend_raw_bytes == 0
    assert s.metrics.backend_stored_bytes == 0
    s.close()


def test_backend_entries_tagged_explicitly():
    """No more ``len(blob)`` sniffing: every entry carries its subcode."""
    s = fresh()
    cfg = s.cfg
    rng = np.random.default_rng(9)
    b = s.backend
    compressible = np.full(cfg.mp_bytes, 0x11, np.uint8)
    incompressible = rng.integers(0, 256, cfg.mp_bytes).astype(np.uint8)
    b.store(600, 0, compressible)
    b.store(600, 1, incompressible)
    assert b._compressed[(600, 0)][0] == "z"
    assert b._compressed[(600, 1)][0] == "v"
    # round-trips are exact for both representations
    out = np.empty(cfg.mp_bytes, np.uint8)
    b.load(600, 0, K_COMPRESSED, zlib.crc32(compressible), out)
    assert bytes(out) == compressible.tobytes()
    b.load(600, 1, K_COMPRESSED, zlib.crc32(incompressible), out)
    assert bytes(out) == incompressible.tobytes()
    # batch extents are tagged references
    data = np.full((4, cfg.mp_bytes), 0x22, np.uint8)
    b.store_batch(601, np.arange(4), data)
    assert all(b._compressed[(601, mp)][0] == "x" for mp in range(4))
    s.close()


# --------------------------------------------------------------- latency ring
def test_latency_ring_matches_scalar_record():
    rng = np.random.default_rng(5)
    ns = rng.integers(100, 50_000_000, 3000)
    ref = LatencyHistogram()
    for v in ns:
        ref.record(int(v))
    m = Metrics()
    for v in ns:
        m.fault_ring.push(int(v), FK_ZERO)
    m.sync()
    h = m.fault_latency
    assert h.count == ref.count
    assert h.buckets == ref.buckets
    assert h.total_ns == ref.total_ns
    assert h.max_ns == ref.max_ns
    assert h.samples == ref.samples
    assert h.percentile(0.9) == ref.percentile(0.9)


def test_latency_ring_kind_split_and_deferred_counters():
    m = Metrics()
    for _ in range(10):
        m.fault_ring.push(5_000, FK_ZERO | FK_FAST)
    for _ in range(4):
        m.fault_ring.push(200_000, FK_COMPRESSED)
    m.sync()
    assert m.fault_latency.count == 14
    assert m.fault_latency_by_kind["zero"].count == 10
    assert m.fault_latency_by_kind["compressed"].count == 4
    # deferred fast-path counters settle at flush
    assert m.fault_fast_path == 10
    assert m.fault_zero_pages == 10
    assert m.crc_checks == 10


def test_latency_ring_flushes_when_full():
    m = Metrics()
    cap = m.fault_ring._cap
    for _ in range(cap + 10):
        m.fault_ring.push(1_000, FK_ZERO)
    # the overflow flush folded the first `cap` samples already
    assert m.fault_latency.count >= cap
    m.sync()
    assert m.fault_latency.count == cap + 10
