"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n,elems,tile", [(4, 1024, 512), (8, 4096, 4096),
                                          (3, 512, 128), (16, 256, 256)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16, np.int8])
def test_zero_detect_sweep(n, elems, tile, dtype):
    x = RNG.standard_normal((n, elems)).astype(dtype)
    x[::3] = 0
    got = ops.zero_detect(jnp.asarray(x), tile_elems=tile)
    want = ref.zero_detect(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,elems,mps", [(2, 512, 4), (4, 1024, 8),
                                         (1, 2048, 16), (6, 768, 3)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_quantize_roundtrip_sweep(n, elems, mps, dtype):
    x = (RNG.standard_normal((n, elems)) * 4).astype(dtype)
    x[0, :elems // mps] = 0                       # a zero MP
    q, s = ops.block_quantize(jnp.asarray(x), mps)
    qr, sr = ref.block_quantize(jnp.asarray(x), mps)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    d = ops.block_dequantize(q, s)
    # bounded quantization error (beyond-paper lossy KV backend contract)
    assert np.abs(np.asarray(d) - x.astype(np.float32)).max() <= \
        np.abs(x).max() / 127.0 + 1e-6


@pytest.mark.parametrize("n,elems,tile", [(4, 4096, 1024), (2, 512, 512),
                                          (8, 2048, 256)])
def test_fletcher_sweep_and_sensitivity(n, elems, tile):
    b = RNG.integers(0, 256, (n, elems)).astype(np.uint8)
    got = ops.fletcher_checksum(jnp.asarray(b), tile_elems=tile)
    want = ref.fletcher_checksum(jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # single-byte corruption always detected; swap of two adjacent bytes too
    b2 = b.copy()
    b2[0, 7] ^= 1
    assert np.asarray(ops.fletcher_checksum(jnp.asarray(b2)))[0] != \
        np.asarray(got)[0]
    b3 = b.copy()
    if b3[1, 10] != b3[1, 11]:
        b3[1, 10], b3[1, 11] = b[1, 11], b[1, 10]
        assert np.asarray(ops.fletcher_checksum(jnp.asarray(b3)))[1] != \
            np.asarray(got)[1]


@pytest.mark.parametrize("n_pool,elems,n_out", [(16, 512, 4), (8, 256, 8),
                                                (32, 1024, 1)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_gather_scatter_sweep(n_pool, elems, n_out, dtype):
    pool = RNG.standard_normal((n_pool, elems)).astype(dtype)
    idx = RNG.choice(n_pool, size=n_out, replace=False).astype(np.int32)
    got = ops.gather_blocks(jnp.asarray(pool), jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.gather_blocks(pool, idx)))
    blocks = RNG.standard_normal((n_out, elems)).astype(dtype)
    got2 = ops.scatter_blocks(jnp.asarray(pool.copy()), jnp.asarray(idx),
                              jnp.asarray(blocks))
    want2 = ref.scatter_blocks(jnp.asarray(pool), jnp.asarray(idx),
                               jnp.asarray(blocks))
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want2))


@pytest.mark.parametrize("B,H,KV,hd,bt,mbs", [
    (2, 8, 2, 32, 8, 4),
    (1, 4, 4, 64, 16, 2),      # MHA
    (3, 16, 1, 32, 8, 3),      # MQA
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_paged_attention_sweep(B, H, KV, hd, bt, mbs, dtype):
    q = RNG.standard_normal((B, H, hd)).astype(dtype)
    pool = RNG.standard_normal((B * mbs + 2, bt, 2, KV, hd)).astype(dtype)
    # non-trivial block table: blocks assigned in random pool order
    perm = RNG.permutation(B * mbs).astype(np.int32) + 2
    table = perm.reshape(B, mbs)
    kvlen = RNG.integers(1, mbs * bt + 1, (B,)).astype(np.int32)
    got = ops.paged_decode_attention(jnp.asarray(q), jnp.asarray(pool),
                                     jnp.asarray(table), jnp.asarray(kvlen))
    want = ref.paged_decode_attention(jnp.asarray(q), jnp.asarray(pool),
                                      jnp.asarray(table), jnp.asarray(kvlen))
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
