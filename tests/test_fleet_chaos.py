"""Fleet chaos (ISSUE 4): deterministic node-failure injection, failure
recovery re-placement, drained decommissions, kill-during-upgrade abort,
and the replay-equivalence harness."""
import json

import pytest

from repro.core.config import small_test_config
from repro.core.hotupgrade import EngineModuleV2
from repro.fleet import (NodeDeadError, TraceGen, chaos_trace)
from repro.fleet.harness import (assert_deterministic, build_fleet,
                                 first_divergence,
                                 snapshot_diff)


# ----------------------------------------------------------- hard failure
def test_hard_kill_replaces_committed_ms_on_survivors():
    fleet = build_fleet(n_nodes=3, domains=3)
    remaps = []
    fleet.remap_listener = (
        lambda src, g, dst, ng, preserved: remaps.append(
            (src.node_id, g, None if dst is None else dst.node_id,
             preserved)))
    for _ in range(6):
        node, gfn, reason = fleet.admit_alloc()
        assert reason == "ok"
    on_victim = len(fleet.nodes[0].allocated)
    assert on_victim > 0
    committed_before = fleet.fleet_committed_ms()

    fleet.kill_node(0)
    assert not fleet.nodes[0].alive and not fleet.nodes[0].serving
    fleet.tick()                          # controller detects + re-places

    assert fleet.ms_replaced == on_victim and fleet.ms_lost == 0
    assert len(remaps) == on_victim
    assert all(dst in (1, 2) and not preserved
               for _src, _g, dst, preserved in remaps)
    # surviving nodes serve all live MSs; fleet accounting is consistent
    assert fleet.fleet_committed_ms() == committed_before
    assert len(fleet.nodes[0].allocated) == 0
    assert all(n.serving for n in fleet.nodes if n.alive)
    fleet.close()


def test_dead_node_refuses_traffic_and_admission_skips_it():
    fleet = build_fleet(n_nodes=2, domains=2)
    n0 = fleet.nodes[0]
    gfn = n0.alloc_ms()
    fleet.kill_node(0)
    with pytest.raises(NodeDeadError):
        n0.read_mp(gfn, 0, 8)
    with pytest.raises(NodeDeadError):
        n0.alloc_ms()
    node, _gfn, reason = fleet.admit_alloc()
    assert reason == "ok" and node is fleet.nodes[1]
    # kill is idempotent; fleet sums only count the living
    fleet.kill_node(0)
    assert fleet.kills == 1
    assert fleet.fleet_managed_ms() == fleet.nodes[1].managed_phys_ms
    fleet.close()


# ------------------------------------------------------- drained failure
def test_drained_kill_preserves_bytes_via_migration():
    fleet = build_fleet(n_nodes=2, domains=2)
    cfg = fleet.nodes[0].cfg
    n0, n1 = fleet.nodes
    gfn = n0.alloc_ms()
    payload = b"\xAB" * cfg.mp_bytes
    n0.write_mp(gfn, 0, payload)

    fleet.kill_node(0, drain=True)
    assert not n0.alive
    assert fleet.migrations == 1 and fleet.ms_lost == 0
    assert len(n1.allocated) == 1
    new_gfn = next(iter(n1.allocated))
    assert n1.read_mp(new_gfn, 0) == payload
    fleet.tick()                          # nothing left to re-place
    assert fleet.ms_replaced == 0
    fleet.close()


def test_drained_kill_with_no_capacity_counts_loss_not_replacement():
    """A graceful decommission that cannot place an MS must report the
    data as LOST -- never silently re-place it as a fresh zeroed MS."""
    fleet = build_fleet(n_nodes=2, domains=2)
    n0, n1 = fleet.nodes
    gfn = n0.alloc_ms()
    n0.write_mp(gfn, 0, b"\xCD" * n0.cfg.mp_bytes)
    while len(n1.allocated) < n1.capacity_ms:   # survivor has no headroom
        n1.alloc_ms()
    remaps = []
    fleet.remap_listener = (
        lambda src, g, dst, ng, preserved: remaps.append((dst, preserved)))

    fleet.kill_node(0, drain=True)
    assert fleet.migrations == 0 and fleet.ms_lost == 1
    assert remaps == [(None, False)]            # token dropped, not remapped
    fleet.tick()                                # nothing left to re-place
    assert fleet.ms_replaced == 0
    fleet.close()


def test_transient_placement_shortage_retries_instead_of_losing():
    """A hard-killed node's MSs must not be written off while the
    shortage is transient: they stay pending and re-place as soon as a
    survivor has headroom again. Only recovery (identity reuse) settles
    the remainder as lost."""
    fleet = build_fleet(n_nodes=2, domains=2)
    n0, n1 = fleet.nodes
    n0.alloc_ms()
    # fill the survivor exactly to the post-kill fleet overcommit cap
    cap_after_kill = int(n1.managed_phys_ms * fleet.cfg.overcommit_cap)
    fillers = [n1.alloc_ms() for _ in range(cap_after_kill)]
    fleet.kill_node(0)
    fleet.tick()                          # n1 full: nothing placeable yet
    assert fleet.ms_lost == 0 and fleet.ms_replaced == 0
    assert len(n0.allocated) == 1         # pending on the dead node

    n1.free_ms_gfn(fillers[0])            # headroom returns
    fleet.tick()
    assert fleet.ms_replaced == 1 and fleet.ms_lost == 0
    assert len(n0.allocated) == 0
    fleet.close()


def test_recover_settles_unplaceable_ms_as_lost():
    fleet = build_fleet(n_nodes=2, domains=2)
    n0, n1 = fleet.nodes
    n0.alloc_ms()
    while len(n1.allocated) < n1.capacity_ms:
        n1.alloc_ms()
    fleet.kill_node(0)
    fleet.tick()                          # pending, not lost
    assert fleet.ms_lost == 0
    fleet.recover_node(0)                 # identity reused: settle for good
    assert fleet.ms_lost == 1 and len(n0.allocated) == 0
    assert n0.alive and n0.serving
    fleet.close()


# ------------------------------------------------------------- recovery
def test_recover_rejoins_empty_and_takes_placements():
    fleet = build_fleet(n_nodes=2, domains=2)
    n0 = fleet.nodes[0]
    n0.alloc_ms()
    fleet.kill_node(0)
    fleet.recover_node(0)                 # settles (re-places) then reboots
    assert n0.alive and n0.serving and len(n0.allocated) == 0
    assert n0.recoveries == 1 and fleet.recoveries == 1
    assert fleet.ms_replaced == 1         # the committed MS moved to n1
    # the recovered (empty) node is now the least-pressured target
    node, _gfn, reason = fleet.admit_alloc()
    assert reason == "ok" and node is n0
    # recover is idempotent
    fleet.recover_node(0)
    assert fleet.recoveries == 1
    fleet.close()


# ------------------------------------------------ kill during an upgrade
def test_kill_mid_upgrade_aborts_batch_cleanly():
    fleet = build_fleet(n_nodes=4, domains=2)
    fleet.start_rolling_upgrade(EngineModuleV2, drain_rounds=3)
    fleet.tick()                          # domain-0 batch starts draining
    draining = [n for n in fleet.nodes if not n.serving]
    assert draining
    fleet.kill_node(draining[0].node_id)
    for _ in range(8):
        fleet.tick()
    assert fleet.upgrade_aborted
    assert "died" in fleet.upgrade_abort_reason
    assert not fleet.upgrade_in_progress
    # no node stuck not-serving: every survivor drains out and serves
    assert all(n.serving for n in fleet.nodes if n.alive)
    fleet.close()


def test_kill_before_later_batch_aborts_rollout():
    fleet = build_fleet(n_nodes=4, domains=2)
    fleet.start_rolling_upgrade(EngineModuleV2, drain_rounds=1)
    fleet.tick()                          # batch 0 (domain 0) in flight
    victim = next(n for n in fleet.nodes if n.failure_domain == 1)
    fleet.kill_node(victim.node_id)
    for _ in range(8):
        fleet.tick()
    assert fleet.upgrade_aborted
    assert "died before" in fleet.upgrade_abort_reason
    assert all(n.serving for n in fleet.nodes if n.alive)
    fleet.close()


# --------------------------------------- snapshots/close with dead nodes
def test_snapshot_and_close_tolerate_dead_nodes():
    fleet = build_fleet(n_nodes=3, domains=3)
    fleet.nodes[1].alloc_ms()
    fleet.kill_node(1)                    # dead *with* unsettled MSs
    snap = fleet.snapshot()               # must not raise
    det = snap["deterministic"]
    assert det["alive_nodes"] == 2
    assert det["nodes"][1]["alive"] is False
    assert det["nodes"][1]["serving"] is False
    assert fleet.deterministic_bytes() == fleet.deterministic_bytes()
    assert "fault" in snap["latency"]     # latency agg skips the dead node
    fleet.close()                         # must not raise
    fleet.close()                         # idempotent


# --------------------------------------------- seeded chaos trace replay
def test_chaos_trace_replay_is_byte_identical():
    """Acceptance: a seeded chaos trace with kills, recoveries and live
    migrations replays byte-identically, zero verify failures, and the
    surviving nodes serve every live MS."""
    cfg = small_test_config()
    gen = chaos_trace(21, cfg.ms_bytes, cfg.mps_per_ms, 4,
                      fill_ms=60, burst=240, kills=2, migrations=3)
    eq = assert_deterministic(gen.lines(), n_nodes=4, domains=2, cfg=cfg)
    det = eq.runs[0].deterministic
    c = det["replay"]
    assert c["kills"] >= 1 and det["kills"] == c["kills"]
    assert det["migrations"] >= 1         # >= 1 live migration executed
    assert c["verify_failures"] == 0      # guest-visible bytes intact
    assert c["ms_migrated"] + c["ms_replaced"] + c["ms_lost"] > 0
    # after recovery, every node is back and serving what it holds
    assert det["alive_nodes"] == 4
    assert all(n["serving"] for n in det["nodes"])
    assert det["fleet_committed_ms"] == sum(
        n["allocated_ms"] for n in det["nodes"])


def test_chaos_without_recovery_leaves_dead_node_settled():
    cfg = small_test_config()
    gen = chaos_trace(22, cfg.ms_bytes, cfg.mps_per_ms, 3,
                      fill_ms=24, burst=120, kills=1, migrations=1,
                      drain_frac=0.0, recover=False)
    eq = assert_deterministic(gen.lines(), n_nodes=3, domains=3, cfg=cfg)
    det = eq.runs[0].deterministic
    assert det["alive_nodes"] == 2 and det["kills"] == 1
    dead = [n for n in det["nodes"] if not n["alive"]]
    assert len(dead) == 1 and dead[0]["allocated_ms"] == 0  # all settled
    c = det["replay"]
    assert c["ms_replaced"] + c["ms_lost"] > 0
    assert c["verify_failures"] == 0


def test_kill_during_rolling_upgrade_trace_is_deterministic():
    cfg = small_test_config()
    gen = TraceGen(5, cfg.ms_bytes, cfg.mps_per_ms)
    gen.front_fill(12)
    gen.rolling_upgrade(drain_rounds=3, settle_ticks=1)  # batch 0 drains
    gen.kill_node(0, settle_ticks=2)      # node 0 is in domain 0 = batch 0
    gen.back_phase(6)
    eq = assert_deterministic(gen.lines(), n_nodes=4, domains=2, cfg=cfg)
    det = eq.runs[0].deterministic
    assert det["upgrade_aborted"]
    assert det["alive_nodes"] == 3
    alive = [n for n in det["nodes"] if n["alive"]]
    assert all(n["serving"] for n in alive)


# ------------------------------------------------------- harness itself
def test_first_divergence_reports_json_path():
    a = json.dumps({"x": {"y": 1, "z": [1, 2]}}, sort_keys=True).encode()
    b = json.dumps({"x": {"y": 2, "z": [1, 3]}}, sort_keys=True).encode()
    assert first_divergence(a, a) is None
    rep = first_divergence(a, b)
    assert "$.x.y: 1 != 2" in rep
    assert "$.x.z[1]: 2 != 3" in rep


def test_snapshot_diff_limit_and_shapes():
    a = {"k": [1, 2, 3], "m": {"a": 1}}
    b = {"k": [1, 9], "m": {"b": 1}}
    diffs = snapshot_diff(a, b)
    assert any("length" in d for d in diffs)
    assert any("missing" in d for d in diffs)
    many_a = {str(i): i for i in range(50)}
    many_b = {str(i): i + 1 for i in range(50)}
    assert len(snapshot_diff(many_a, many_b, limit=8)) == 8


def test_replay_twice_detects_real_divergence():
    """Feed the harness two *different* traces via a stateful factory:
    it must flag the divergence and name a concrete path."""
    cfg = small_test_config()
    g1 = TraceGen(1, cfg.ms_bytes, cfg.mps_per_ms)
    g1.front_fill(4)
    g2 = TraceGen(1, cfg.ms_bytes, cfg.mps_per_ms)
    g2.front_fill(5)
    from repro.fleet.harness import replay
    r1 = replay(g1.lines(), n_nodes=2, cfg=cfg)
    r2 = replay(g2.lines(), n_nodes=2, cfg=cfg)
    div = first_divergence(r1.bytes, r2.bytes)
    assert div is not None and "admitted" in div
