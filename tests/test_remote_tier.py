"""Remote-peer swap tier (ISSUE 9): lease-brokered replication of fully
swapped-out MSs onto peer nodes, preserved recovery after owner death,
exactly-once settlement after peer death, and the ``remote_tier=0``
legacy-equivalence guarantee."""
import dataclasses

from repro.core.config import HotPathConfig, small_test_config
from repro.fleet import chaos_trace
from repro.fleet.harness import build_fleet, replay_twice


def _cfg(remote_tier: int = 1, **hp_overrides):
    cfg = small_test_config()
    hp = dataclasses.replace(cfg.swap.hot_path, remote_tier=remote_tier,
                             **hp_overrides)
    return dataclasses.replace(
        cfg, swap=dataclasses.replace(cfg.swap, hot_path=hp))


def _swap_out(node, gfn):
    node.system.engine.swap_out_ms(gfn)
    assert node.system.engine.ms_fully_swapped(gfn)


def _leased_setup(n_nodes=2):
    """A fleet where node 0 owns one written, fully swapped, leased MS."""
    fleet = build_fleet(n_nodes=n_nodes, domains=2, cfg=_cfg())
    n0 = fleet.nodes[0]
    gfn = n0.alloc_ms()
    payload = bytes(range(256)) * (n0.cfg.mp_bytes // 256)
    n0.write_mp(gfn, 0, payload)
    n0.write_mp(gfn, 1, payload[::-1])
    _swap_out(n0, gfn)
    fleet.tick()                          # replicate pass grants the lease
    assert (0, gfn) in fleet.leases
    return fleet, n0, gfn, payload


# ------------------------------------------------------------ replication
def test_replicate_pass_leases_fully_swapped_ms():
    fleet, n0, gfn, _ = _leased_setup()
    peer_id, epoch = fleet.leases[(0, gfn)]
    peer = fleet.node_by_id(peer_id)
    assert peer is not n0 and epoch == peer.recoveries
    assert gfn in n0.leased_gfns
    assert peer.system.backend.remote_held() == 1
    assert fleet.remote_puts == 1
    # the replica blob round-trips its own integrity check
    assert peer.system.backend.remote_get(0, gfn) is not None
    # idempotent: the next tick does not re-place an already-leased MS
    fleet.tick()
    assert fleet.remote_puts == 1
    fleet.close()


def test_partially_resident_ms_is_not_replicated():
    fleet = build_fleet(n_nodes=2, domains=2, cfg=_cfg())
    n0 = fleet.nodes[0]
    gfn = n0.alloc_ms()
    n0.write_mp(gfn, 0, b"\x5A" * n0.cfg.mp_bytes)   # resident MP
    fleet.tick()
    assert fleet.remote_puts == 0 and not fleet.leases
    fleet.close()


def test_remote_tier_zero_never_replicates():
    fleet = build_fleet(n_nodes=2, domains=2, cfg=_cfg(remote_tier=0))
    n0 = fleet.nodes[0]
    gfn = n0.alloc_ms()
    n0.write_mp(gfn, 0, b"\x77" * n0.cfg.mp_bytes)
    _swap_out(n0, gfn)
    for _ in range(3):
        fleet.tick()
    assert fleet.remote_puts == 0 and not fleet.leases
    assert all(n.system.backend.remote_held() == 0 for n in fleet.nodes)
    fleet.close()


# ------------------------------------------------------ preserved recovery
def test_owner_hard_kill_recovers_byte_identical_payload():
    fleet, n0, gfn, payload = _leased_setup()
    remaps = []
    fleet.remap_listener = (
        lambda src, g, dst, ng, preserved: remaps.append(
            (src.node_id, g, None if dst is None else dst.node_id,
             ng, preserved)))
    fleet.kill_node(0)                    # hard crash, no drain
    fleet.tick()                          # recovery from the peer replica

    assert fleet.remote_recovered == 1 and fleet.ms_lost == 0
    assert fleet.ms_replaced == 0         # preserved, not zero-filled
    assert remaps and remaps[0][4] is True
    dst = fleet.node_by_id(remaps[0][2])
    new_gfn = remaps[0][3]
    assert dst.read_mp(new_gfn, 0) == payload
    assert dst.read_mp(new_gfn, 1) == payload[::-1]
    # the lease settled exactly once: registry and replica both gone
    assert not fleet.leases
    assert dst.system.backend.remote_held() == 0
    fleet.close()


def test_drained_kill_keeps_leased_ms_pending_until_capacity():
    """A drain that cannot place a leased MS must not count it lost: the
    replica outlives the node, so it stays pending and recovers
    *preserved* once a survivor has headroom."""
    fleet, n0, gfn, payload = _leased_setup()
    n1 = fleet.nodes[1]
    fillers = [n1.alloc_ms() for _ in
               range(n1.capacity_ms - len(n1.allocated))]
    remaps = []
    fleet.remap_listener = (
        lambda src, g, dst, ng, preserved: remaps.append((ng, preserved)))
    fleet.kill_node(0, drain=True)        # no headroom: migration refused
    assert fleet.ms_lost == 0 and gfn in n0.allocated   # pending, leased
    fleet.tick()
    assert fleet.ms_lost == 0 and fleet.remote_recovered == 0

    for f in fillers[:2]:
        n1.free_ms_gfn(f)
    fleet.tick()
    assert fleet.remote_recovered == 1 and fleet.ms_lost == 0
    assert remaps and remaps[-1][1] is True
    assert n1.read_mp(remaps[-1][0], 0) == payload
    fleet.close()


def test_recover_settles_leased_pending_as_lost_without_capacity():
    """Identity reuse with the fleet still full is an honest loss -- the
    replica exists but there is nowhere to put it back."""
    fleet, n0, gfn, _ = _leased_setup()
    n1 = fleet.nodes[1]
    while len(n1.allocated) < n1.capacity_ms:
        n1.alloc_ms()
    fleet.kill_node(0, drain=True)
    assert fleet.ms_lost == 0
    fleet.recover_node(0)
    assert fleet.ms_lost == 1 and fleet.remote_recovered == 0
    assert not fleet.leases               # dropped with the settlement
    fleet.close()


# ----------------------------------------------------- lease invalidation
def test_owner_write_breaks_lease_and_drops_replica():
    fleet, n0, gfn, _ = _leased_setup()
    peer = fleet.node_by_id(fleet.leases[(0, gfn)][0])
    n0.write_mp(gfn, 0, b"\x11" * n0.cfg.mp_bytes)
    assert (0, gfn) not in fleet.leases
    assert gfn not in n0.leased_gfns
    assert peer.system.backend.remote_held() == 0
    assert fleet.remote_dropped == 1
    fleet.close()


def test_owner_free_breaks_lease():
    fleet, n0, gfn, _ = _leased_setup()
    n0.free_ms_gfn(gfn)
    assert not fleet.leases and fleet.remote_dropped == 1
    fleet.close()


def test_peer_watermark_eviction_releases_replica():
    fleet, n0, gfn, _ = _leased_setup(n_nodes=3)
    peer = fleet.node_by_id(fleet.leases[(0, gfn)][0])
    peer.system.watermark.zone = lambda free: "critical"
    fleet.tick()                          # evict pass releases the replica
    assert fleet.remote_evicted == 1 and not fleet.leases
    assert peer.system.backend.remote_held() == 0
    fleet.close()


# -------------------------------------------------- peer-death settlement
def test_peer_death_settles_every_lease_exactly_once():
    """Kill the node *holding* replicas: every lease it backed must
    settle exactly once -- re-replicated onto a live peer (still backed
    by a real blob) or dropped, with the two outcomes summing to the
    pre-kill count."""
    fleet = build_fleet(n_nodes=3, domains=3, cfg=_cfg())
    n0 = fleet.nodes[0]
    gfns = []
    for _ in range(4):
        g = n0.alloc_ms()
        n0.write_mp(g, 0, bytes([g % 251]) * n0.cfg.mp_bytes)
        _swap_out(n0, g)
        gfns.append(g)
    fleet.tick()
    assert len(fleet.leases) == 4
    by_peer = {}
    for key, (peer_id, _e) in fleet.leases.items():
        by_peer.setdefault(peer_id, []).append(key)
    victim_id, victim_keys = max(by_peer.items(), key=lambda kv: len(kv[1]))
    pre = len(victim_keys)
    dropped_before = fleet.remote_dropped

    fleet.kill_node(victim_id)
    fleet.tick()                          # settle + re-replicate pass

    settled = fleet.remote_rereplicated + (fleet.remote_dropped
                                           - dropped_before)
    assert settled == pre                 # exactly once, nothing twice
    # every surviving lease points at a live peer and a real blob
    for (owner_id, g), (peer_id, epoch) in fleet.leases.items():
        peer = fleet.node_by_id(peer_id)
        assert peer.alive and peer.recoveries == epoch
        assert peer.system.backend.remote_get(owner_id, g) is not None
    fleet.close()


def test_reborn_peer_epoch_invalidates_stale_lease():
    """kill+recover of the peer between controller ticks: the lease's
    epoch no longer matches, so settlement must treat the replica as
    gone (the reborn node came back empty) instead of trusting it."""
    fleet, n0, gfn, _ = _leased_setup(n_nodes=3)
    peer_id, _epoch = fleet.leases[(0, gfn)]
    fleet.kill_node(peer_id)
    fleet.recover_node(peer_id)           # fresh epoch, empty backend
    fleet.tick()
    # settled exactly once: re-replicated onto the remaining peer (or the
    # reborn one), never recovered from the dead epoch
    assert fleet.remote_rereplicated == 1
    (new_peer_id, epoch), = [v for k, v in fleet.leases.items()
                             if k == (0, gfn)]
    peer = fleet.node_by_id(new_peer_id)
    assert peer.recoveries == epoch
    assert peer.system.backend.remote_get(0, gfn) is not None
    fleet.close()


# --------------------------------------------------- legacy equivalence
def _chaos_lines(n_nodes, cfg):
    managed = n_nodes * (cfg.n_phys_ms - cfg.mpool_reserve_ms)
    return chaos_trace(13, cfg.ms_bytes, cfg.mps_per_ms, n_nodes,
                       fill_ms=int(managed * 1.1), burst=200,
                       kills=2, migrations=3).lines()


def test_remote_tier_off_is_legacy_bit_for_bit():
    """``remote_tier=0`` (including the forced-legacy scalar plugin)
    must replay byte-identically and never touch a remote counter."""
    base = small_test_config()
    legacy = dataclasses.replace(
        base, swap=dataclasses.replace(
            base.swap, hot_path=HotPathConfig.legacy_scalar()))
    for cfg in (_cfg(remote_tier=0), legacy):
        eq = replay_twice(_chaos_lines(4, cfg), n_nodes=4, domains=2,
                          cfg=cfg)
        assert eq.identical, eq.divergence
        det = eq.runs[0].deterministic
        assert det["remote_puts"] == 0
        assert det["remote_recovered"] == 0
        assert det["remote_rereplicated"] == 0
        assert det["remote_dropped"] == 0
        assert det["remote_evicted"] == 0
        assert det["remote_leases"] == 0
        assert det["remote_held"] == 0
        assert det["remote_modeled_ns"] == 0


def test_remote_tier_strictly_reduces_chaos_loss():
    """The bugfix payoff, pinned as an inequality on the same trace:
    with the remote tier on, node death loses strictly fewer MSs, and
    at least one dead-owner MS is recovered from a peer replica."""
    cfg_on, cfg_off = _cfg(remote_tier=1), _cfg(remote_tier=0)
    eq_on = replay_twice(_chaos_lines(4, cfg_on), n_nodes=4, domains=2,
                         cfg=cfg_on)
    eq_off = replay_twice(_chaos_lines(4, cfg_off), n_nodes=4, domains=2,
                          cfg=cfg_off)
    assert eq_on.identical and eq_off.identical
    det_on = eq_on.runs[0].deterministic
    det_off = eq_off.runs[0].deterministic
    assert det_on["remote_recovered"] >= 1
    assert det_on["ms_lost"] < det_off["ms_lost"]
    # determinism bit survives the remote tier wholesale
    assert eq_on.runs[0].counters["verify_failures"] == 0
