"""Stage-attributed tracing (repro.obs): span tracer mechanics, stage
tree rollup, Chrome-trace export schema, Prometheus rendering, and -- the
contract that makes tracing deployable -- deterministic snapshots stay
byte-identical with tracing on (single box, fleet replay, and chaos).
"""
import json

import pytest

from repro.core.config import ObsConfig, small_test_config
from repro.core.metrics import FK_COMPRESSED, FK_NAMES, FK_ZERO, Metrics
from repro.core.system import TaijiSystem
from repro.fleet import chaos_trace, paper_trace
from repro.fleet.harness import build_fleet, replay_twice
from repro.obs import (STAGE_NAMES, SpanTracer, export_chrome, render_prom,
                       stage_tree)
from repro.obs.tracer import (ST_FAULT_MUTEX, ST_FAULT_TOTAL,
                              ST_GUEST_ACCESS, TAG_READ)


def traced_cfg(**overrides):
    return small_test_config(obs=ObsConfig(enabled=True), **overrides)


def zero_fault_workload(system):
    """Alloc one MS, swap every (zero) MP out, fault each back with one
    read. Returns (gfn, n_reads)."""
    cfg = system.cfg
    space = system.guest
    g = space.alloc_ms()
    assert system.engine.swap_out_ms(g) == cfg.mps_per_ms
    for mp in range(cfg.mps_per_ms):
        assert space.read(g, 16, off=mp * cfg.mp_bytes) == bytes(16)
    return g, cfg.mps_per_ms


# ---------------------------------------------------------- tracer unit
def test_push_flush_aggregates():
    tr = SpanTracer(cap=64)
    for i in range(10):
        tr.push(ST_FAULT_TOTAL, 1000 + i, 100 + i, FK_ZERO)
    tr.flush()
    t = tr.totals()["fault_total"]
    assert t["count"] == 10
    assert t["total_ns"] == sum(100 + i for i in range(10))
    assert t["max_ns"] == 109
    assert t["by_tag"][FK_ZERO]["count"] == 10


def test_ring_overflow_auto_flushes():
    tr = SpanTracer(cap=8)
    for i in range(100):
        tr.push(ST_GUEST_ACCESS, i, 5, TAG_READ)
    assert tr.span_count == 100          # nothing lost: push flushes at cap


def test_max_spans_bounds_retained_not_aggregates():
    tr = SpanTracer(cap=64, max_spans=5)
    for i in range(12):
        tr.push(ST_GUEST_ACCESS, i, 7)
    tr.flush()
    assert tr.span_count == 12           # aggregates never drop
    assert len(list(tr.spans())) == 5    # retained store is bounded
    assert tr.dropped_spans == 7


def test_zero_duration_span_survives_flush():
    # enc uses dur+1 so a 0ns span is not mistaken for an empty slot
    tr = SpanTracer(cap=8)
    tr.push(ST_FAULT_MUTEX, 123, 0)
    tr.flush()
    t = tr.totals()["fault_mutex"]
    assert t["count"] == 1 and t["total_ns"] == 0


def test_stage_tree_self_time_rollup():
    tr = SpanTracer(cap=64)
    tr.push(ST_FAULT_TOTAL, 0, 100_000)
    tr.push(ST_FAULT_MUTEX, 0, 30_000)
    tree = stage_tree([tr])
    assert tree["fault_total"]["self_ns"] == 70_000
    assert tree["fault_mutex"]["self_ns"] == 30_000
    assert tree["fault_mutex"]["parent"] == "fault_total"


def test_stage_tree_self_time_clamps_at_zero():
    tr = SpanTracer(cap=64)
    tr.push(ST_FAULT_TOTAL, 0, 10_000)
    tr.push(ST_FAULT_MUTEX, 0, 40_000)   # child exceeds parent (fan-out)
    assert stage_tree([tr])["fault_total"]["self_ns"] == 0


def test_stage_tree_aggregates_across_tracers():
    a, b = SpanTracer(cap=8), SpanTracer(cap=8)
    a.push(ST_FAULT_TOTAL, 0, 100)
    b.push(ST_FAULT_TOTAL, 0, 300)
    t = stage_tree([a, b])["fault_total"]
    assert t["count"] == 2 and t["total_ns"] == 400 and t["max_ns"] == 300


# ----------------------------------------------------- system integration
def test_tracer_disabled_by_default():
    s = TaijiSystem(small_test_config())
    try:
        assert s.tracer is None
        assert s.metrics.tracer is None
    finally:
        s.close()


def test_span_counts_match_call_counts():
    s = TaijiSystem(traced_cfg())
    try:
        _, n_reads = zero_fault_workload(s)
        tr = s.tracer
        # every read is one guest_access span; every swapped MP is one
        # fault_total span with the same interval the fault ring records
        assert tr.stage_count("guest_access") == n_reads
        assert tr.stage_count("fault_total") == n_reads
        assert s.metrics.faults == n_reads
        assert tr.stage_count("swap_out") == 1
    finally:
        s.close()


def test_fault_subtree_telescopes_to_fault_total():
    """The fault_total span shares the fault ring's interval, so the
    fault subtree's self-times must sum exactly to fault_total's total --
    the invariant behind the fleet_swapin_stage_* BENCH rows."""
    s = TaijiSystem(traced_cfg())
    try:
        space = s.guest
        g = space.alloc_ms()
        pat = bytes(range(256)) * (s.cfg.mp_bytes // 256)
        for mp in range(s.cfg.mps_per_ms):
            space.write(g, pat, off=mp * s.cfg.mp_bytes)
        s.engine.swap_out_ms(g)
        for mp in range(s.cfg.mps_per_ms):
            space.read(g, 16, off=mp * s.cfg.mp_bytes)
        tree = stage_tree([s.tracer])
        subtree = ("fault_total", "fault_mutex", "fault_desc", "fault_alloc",
                   "fault_copy", "fault_backend", "fault_readahead",
                   "readahead_decode")
        self_sum = sum(tree[n]["self_ns"] for n in subtree if n in tree)
        assert self_sum == tree["fault_total"]["total_ns"]
    finally:
        s.close()


# --------------------------------------------------------- chrome export
def test_chrome_export_schema(tmp_path):
    s = TaijiSystem(traced_cfg())
    try:
        zero_fault_workload(s)
        path = tmp_path / "trace.json"
        n = s.tracer.export_chrome(str(path))
        assert n > 0
        doc = json.loads(path.read_text())
        assert doc["displayTimeUnit"] == "ns"
        events = doc["traceEvents"]
        assert len(events) == n
        last_ts = 0.0
        for ev in events:
            assert set(ev) >= {"name", "cat", "ph", "ts", "dur",
                               "pid", "tid"}
            assert ev["ph"] == "X"
            assert ev["name"] in STAGE_NAMES
            assert ev["ts"] >= 0 and ev["dur"] >= 0
            assert ev["ts"] >= last_ts   # sorted by timestamp
            last_ts = ev["ts"]
            assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
    finally:
        s.close()


def test_chrome_export_merges_tracers_with_pids(tmp_path):
    a, b = SpanTracer(cap=8, pid=0), SpanTracer(cap=8, pid=3)
    a.push(ST_FAULT_TOTAL, 100, 10)
    b.push(ST_FAULT_TOTAL, 200, 10)
    path = tmp_path / "t.json"
    assert export_chrome(str(path), [a, b]) == 2
    pids = {ev["pid"] for ev in json.loads(path.read_text())["traceEvents"]}
    assert pids == {0, 3}


# ------------------------------------------------------------ prometheus
def test_render_prom_counters_and_histograms():
    s = TaijiSystem(traced_cfg())
    try:
        zero_fault_workload(s)
        text = s.metrics.render_prom()
        assert text.endswith("\n")
        assert f"taiji_faults_total {s.metrics.faults}" in text
        assert "taiji_fault_latency_seconds_count" in text
        assert 'le="+Inf"' in text
        assert "taiji_compression_ratio" in text
        # tracer stages render when tracing is on
        assert 'taiji_stage_spans_total{stage="fault_total"}' in text
        # per-kind labeled series
        assert 'kind="zero"' in text
    finally:
        s.close()


def test_render_prom_without_tracer():
    m = Metrics()
    m.faults = 3
    text = render_prom(m)
    assert "taiji_faults_total 3" in text
    assert "stage_spans_total" not in text


# ---------------------------------------------- per-kind histogram identity
def test_fault_kind_histograms_distinct_after_flush():
    """Regression: the per-kind histograms behind fault_zero_p90_us /
    fault_readahead_p90_us / fault_latency_p99 are distinct objects fed
    distinct samples -- equal reported percentiles are order statistics
    landing on the same sample, not aliased state."""
    m = Metrics()
    m.fault_ring.push(1000, FK_ZERO)
    m.fault_ring.push(5000, FK_COMPRESSED)
    m.sync()
    kinds = m.fault_latency_by_kind
    objs = [kinds[name] for name in FK_NAMES]
    assert len({id(h) for h in objs}) == len(objs)
    assert id(m.fault_latency) not in {id(h) for h in objs}
    assert kinds["zero"].count == 1 and kinds["compressed"].count == 1
    assert kinds["zero"].total_ns == 1000
    assert kinds["compressed"].total_ns == 5000
    # reset rebuilds fresh objects; captured references keep their samples
    captured = dict(kinds)
    m.reset_fault_latency()
    fresh = m.fault_latency_by_kind
    for name in FK_NAMES:
        assert fresh[name] is not captured[name]
        assert fresh[name].count == 0
    assert captured["zero"].count == 1   # window-frozen, not cleared


# ----------------------------------------------------------- determinism
def test_deterministic_snapshot_identical_traced_vs_untraced():
    snaps = []
    for cfg in (small_test_config(), traced_cfg()):
        s = TaijiSystem(cfg)
        try:
            zero_fault_workload(s)
            snaps.append(json.dumps(s.metrics.deterministic_snapshot(),
                                    sort_keys=True))
        finally:
            s.close()
    assert snaps[0] == snaps[1]


def test_fleet_replay_deterministic_with_tracing():
    cfg = traced_cfg()
    gen = paper_trace(7, cfg.ms_bytes, cfg.mps_per_ms, fill_ms=40,
                      burst=120, churn_frees=6)
    fleets = []

    def make_fleet():
        fleet = build_fleet(4, 2, cfg)
        fleets.append(fleet)
        return fleet

    eq = replay_twice(gen.lines(), make_fleet=make_fleet)
    assert eq.identical, eq.report()
    # tracers recorded real spans and survive the harness's fleet.close()
    tracers = [n.system.metrics.tracer for n in fleets[0].nodes]
    assert all(tr is not None for tr in tracers)
    assert sum(tr.span_count for tr in tracers) > 0
    assert fleets[0].tracer is not None
    assert fleets[0].tracer.stage_count("fleet_tick") > 0


def test_fleet_traced_bytes_equal_untraced_bytes():
    """Tracing must not leak into the deterministic snapshot: the same
    seeded trace replayed traced and untraced serializes identically."""
    runs = {}
    for name, cfg in (("off", small_test_config()), ("on", traced_cfg())):
        gen = paper_trace(7, cfg.ms_bytes, cfg.mps_per_ms, fill_ms=30,
                          burst=80, churn_frees=4)
        eq = replay_twice(gen.lines(), n_nodes=2, domains=2, cfg=cfg)
        assert eq.identical, eq.report()
        runs[name] = eq.runs[0].bytes
    assert runs["on"] == runs["off"]


@pytest.mark.slow
def test_fleet_chaos_deterministic_with_tracing():
    cfg = traced_cfg()
    managed = 4 * (cfg.n_phys_ms - cfg.mpool_reserve_ms)
    gen = chaos_trace(13, cfg.ms_bytes, cfg.mps_per_ms, 4,
                      fill_ms=int(managed * 1.1), burst=200,
                      kills=2, migrations=3)
    eq = replay_twice(gen.lines(), n_nodes=4, domains=2, cfg=cfg)
    assert eq.identical, eq.report()
