"""Substrate: data pipeline, optimizer, checkpoint, metrics, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.reduce import reduced_config
from repro.core.metrics import LatencyHistogram
from repro.data.pipeline import SyntheticPipeline
from repro.launch import hlo_analysis as HA
from repro.optim import adamw
from repro.train import steps


# ------------------------------------------------------------ data pipeline
def test_pipeline_deterministic_and_resumable():
    cfg = reduced_config("qwen3-4b")
    p1 = SyntheticPipeline(cfg, 2, 16, seed=7)
    b1 = [p1.next_batch() for _ in range(3)]
    p2 = SyntheticPipeline(cfg, 2, 16, seed=7)
    p2.restore({"seed": 7, "step": 2})
    b2 = p2.next_batch()
    np.testing.assert_array_equal(b1[2]["tokens"], b2["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = reduced_config("qwen3-4b")
    p = SyntheticPipeline(cfg, 2, 16, seed=0)
    b = p.next_batch()
    assert b["tokens"].shape == b["labels"].shape == (2, 16)


def test_pipeline_vlm_masks_vision_prefix():
    cfg = reduced_config("qwen2-vl-2b")
    p = SyntheticPipeline(cfg, 2, 24, seed=0)
    b = p.next_batch()
    nv = cfg.max_vision_tokens
    assert (b["loss_mask"][:, :nv] == 0).all()
    assert (b["loss_mask"][:, nv:] == 1).all()
    assert b["mrope_pos"].shape == (3, 2, 24)
    # h/w axes differ across the vision grid (M-RoPE is really 3D)
    assert not np.array_equal(b["mrope_pos"][1, 0, :nv],
                              b["mrope_pos"][2, 0, :nv])


# ---------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params, cfg)
    for step in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, info = adamw.update(grads, state, params,
                                           jnp.asarray(step), cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_clips_gradient():
    cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    _, _, info = adamw.update({"w": jnp.full(3, 100.0)}, state, params,
                              jnp.asarray(0), cfg)
    assert float(info["grad_norm"]) > 100


def test_adamw_bf16_state_dtype():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.zeros(4, jnp.float32)}
    state = adamw.init(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = reduced_config("qwen2-0.5b")
    opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_dtype)
    state = steps.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    pipe = SyntheticPipeline(cfg, 2, 8, seed=3)
    pipe.next_batch()

    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(5, state, pipe.snapshot())
    mgr.save(10, state, pipe.snapshot())
    mgr.save(15, state, pipe.snapshot())
    assert mgr.latest_step() == 15
    # keep=2 garbage-collects the oldest
    assert not (tmp_path / "step_0000000005").exists()

    restored, manifest = mgr.restore(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["pipeline"]["step"] == 1


def test_checkpoint_layout_mismatch_refused(tmp_path):
    cfg = reduced_config("qwen2-0.5b")
    opt_cfg = adamw.AdamWConfig()
    state = steps.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, state)
    other = reduced_config("qwen3-4b")
    state2 = steps.init_train_state(jax.random.PRNGKey(0), other,
                                    adamw.AdamWConfig())
    with pytest.raises(ValueError):
        mgr.restore(state2)


# ------------------------------------------------------------------ metrics
def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for ns in [500] * 90 + [100_000] * 10:
        h.record(ns)
    assert h.percentile(0.5) <= 1024
    assert h.percentile(0.95) >= 65536
    assert 0.89 <= h.fraction_below(10_000) <= 0.91


# ------------------------------------------------------------- HLO analyzer
_TOY_HLO = """
HloModule toy

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_hlo_analyzer_multiplies_loop_trips():
    cost = HA.analyze(_TOY_HLO)
    # dot: 2*8*8*8 = 1024 flops, x10 trips
    assert cost.flops == pytest.approx(10 * 1024)
    # all-reduce result: 8*8*4 bytes, x10
    assert cost.collective_bytes == pytest.approx(10 * 256)
    assert cost.collective_by_type["all-reduce"] == pytest.approx(2560)


def test_roofline_terms_shape():
    cost = HA.analyze(_TOY_HLO)
    t = HA.roofline_terms(cost)
    assert set(t) >= {"compute_s", "memory_s", "collective_s", "dominant",
                      "roofline_fraction"}
    assert t["dominant"] in ("compute_s", "memory_s", "collective_s")
