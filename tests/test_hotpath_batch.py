"""Batched guest hot path (ISSUE 6).

Pins the contracts the batch primitives were built on:

  * ``read_many``/``write_many``/``gather``/``scatter`` are
    byte-equivalent to the scalar loops they replace, across mixed MS
    states (resident, swapped, split, zero, never-written);
  * observers see the same event stream from a batch call as from the
    equivalent scalar sequence (``on_access_batch`` default fallback),
    so a TraceRecorder capture is identical either way;
  * parallel extent compression stores byte-identical backend state for
    any worker count (ordered merge over fixed chunk boundaries);
  * ``HotPathConfig`` consolidates the scalar flags: legacy aliases
    still construct/read correctly and old pickles migrate.

Fuzzing uses hypothesis when available and falls back to a seeded
numpy sweep (the container does not ship hypothesis).
"""
import dataclasses
import pickle

import numpy as np
import pytest

from repro.core.config import (BackendConfig, HotPathConfig, SwapConfig,
                               small_test_config)
from repro.core.guest import GuestObserver
from repro.core.system import TaijiSystem
from repro.fleet.trace import TraceRecorder

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # container has no hypothesis
    HAVE_HYPOTHESIS = False

FUZZ_SEEDS = list(range(8))


def _mixed_system(seed: int):
    """A system whose MSs cover every state the fast path branches on:
    resident random, resident compressible, swapped-out, split (one MP
    faulted back), explicit zeros, and never-written. Returns the
    system, the gfn list, and a shadow dict of expected contents."""
    s = TaijiSystem(small_test_config())
    rng = np.random.default_rng(seed)
    ms = s.cfg.ms_bytes
    gfns = [s.guest.alloc_ms() for _ in range(6)]
    shadow = {}
    shadow[gfns[0]] = rng.integers(0, 256, ms, dtype=np.uint8).tobytes()
    shadow[gfns[1]] = bytes([7]) * ms                    # compressible
    shadow[gfns[2]] = rng.integers(0, 256, ms, dtype=np.uint8).tobytes()
    shadow[gfns[3]] = bytes(ms)                          # explicit zeros
    shadow[gfns[4]] = bytes(ms)                          # never written
    shadow[gfns[5]] = rng.integers(0, 256, ms, dtype=np.uint8).tobytes()
    for g in (gfns[0], gfns[1], gfns[2], gfns[3], gfns[5]):
        s.guest.write(g, shadow[g])
    s.engine.swap_out_ms(gfns[1])                        # fully swapped
    s.engine.swap_out_ms(gfns[2])
    s.guest.read(gfns[2], 8)                             # -> split MS
    return s, gfns, shadow


def _random_reqs(rng, gfns, ms_bytes, n=40):
    reqs = []
    for _ in range(n):
        g = gfns[int(rng.integers(len(gfns)))]
        off = int(rng.integers(ms_bytes))
        nbytes = int(rng.integers(ms_bytes - off + 1))
        reqs.append((g, off, nbytes))
    return reqs


def _check_read_equivalence(seed: int) -> None:
    s, gfns, shadow = _mixed_system(seed)
    try:
        rng = np.random.default_rng(seed + 1000)
        reqs = _random_reqs(rng, gfns, s.cfg.ms_bytes)
        batched = s.guest.read_many(reqs)
        assert len(batched) == len(reqs)
        for (g, off, n), got in zip(reqs, batched):
            assert got == shadow[g][off:off + n]
            assert got == s.guest.read(g, n, off=off)    # scalar agrees
    finally:
        s.close()


def _check_write_equivalence(seed: int) -> None:
    sa, gfns_a, _ = _mixed_system(seed)
    sb, gfns_b, _ = _mixed_system(seed)
    try:
        rng = np.random.default_rng(seed + 2000)
        items = [(g, off, bytes(rng.integers(0, 256, n, dtype=np.uint8)))
                 for g, off, n in _random_reqs(rng, gfns_a, sa.cfg.ms_bytes)]
        sa.guest.write_many(items)
        for g, off, data in items:                        # scalar reference
            sb.guest.write(g, data, off=off)
        for ga, gb in zip(gfns_a, gfns_b):
            assert sa.guest.read(ga) == sb.guest.read(gb)
    finally:
        sa.close()
        sb.close()


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_read_many_matches_scalar(seed):
        _check_read_equivalence(seed)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_write_many_matches_scalar(seed):
        _check_write_equivalence(seed)
else:
    @pytest.mark.parametrize("seed", FUZZ_SEEDS)
    def test_read_many_matches_scalar(seed):
        _check_read_equivalence(seed)

    @pytest.mark.parametrize("seed", FUZZ_SEEDS)
    def test_write_many_matches_scalar(seed):
        _check_write_equivalence(seed)


def test_gather_matches_view_loads():
    s, gfns, shadow = _mixed_system(3)
    try:
        got = s.guest.gather(gfns)                       # whole MSs, uint8
        assert got.shape == (len(gfns), s.cfg.ms_bytes)
        for i, g in enumerate(gfns):
            assert got[i].tobytes() == shadow[g]
        # typed window: float16 rows at an offset
        shape = (16,)
        off = 64
        typed = s.guest.gather(gfns, np.float16, shape, off=off)
        for i, g in enumerate(gfns):
            ref = s.guest.view(g, np.float16, shape, off=off).load()
            np.testing.assert_array_equal(typed[i], ref)
    finally:
        s.close()


def test_scatter_matches_view_stores():
    sa, gfns_a, _ = _mixed_system(4)
    sb, gfns_b, _ = _mixed_system(4)
    try:
        rng = np.random.default_rng(4)
        arr = rng.integers(0, 256, (len(gfns_a), 128), dtype=np.uint8)
        sa.guest.scatter(gfns_a, arr, off=32)
        for i, g in enumerate(gfns_b):
            sb.guest.view(g, np.uint8, (128,), off=32).store(arr[i])
        for ga, gb in zip(gfns_a, gfns_b):
            assert sa.guest.read(ga) == sb.guest.read(gb)
    finally:
        sa.close()
        sb.close()


def test_batch_bounds_and_shape_errors():
    s = TaijiSystem(small_test_config())
    try:
        g = s.guest.alloc_ms()
        ms = s.cfg.ms_bytes
        with pytest.raises(ValueError):
            s.guest.read_many([(g, 0, 8), (g, ms - 4, 8)])
        with pytest.raises(ValueError):
            s.guest.read_many([(g, -1, 4)])
        with pytest.raises(ValueError):
            s.guest.write_many([(g, ms, b"")])           # off must be in-MS
        with pytest.raises(ValueError):
            s.guest.gather([g], np.uint8, (ms + 1,))
        with pytest.raises(ValueError):
            s.guest.scatter([g, g], np.zeros((1, 8), np.uint8))
        assert s.guest.read_many([]) == []
        s.guest.write_many([])                           # no-op, no error
    finally:
        s.close()


# ---------------------------------------------------------------- observers
class _BatchLog(GuestObserver):
    """Observer with the batch hook: records one entry per batch call."""

    def __init__(self):
        self.batches = []
        self.scalar_events = []

    def on_access(self, gfn, off, nbytes, is_write, data=None):
        self.scalar_events.append((gfn, off, nbytes, is_write, data))

    def on_access_batch(self, events):
        self.batches.append(list(events))


class _ScalarOnlyLog(GuestObserver):
    """Observer without the batch hook: exercises the default fallback."""

    def __init__(self):
        self.events = []

    def on_access(self, gfn, off, nbytes, is_write, data=None):
        self.events.append((gfn, off, nbytes, is_write, data))


def test_batch_observer_gets_one_call_per_batch():
    s, gfns, shadow = _mixed_system(5)
    try:
        log = s.guest.attach(_BatchLog())
        reqs = [(gfns[0], 0, 8), (gfns[1], 16, 4), (gfns[4], 0, 2)]
        out = s.guest.read_many(reqs)
        assert len(log.batches) == 1
        assert log.batches[0] == [
            (g, off, n, False, out[i])
            for i, (g, off, n) in enumerate(reqs)]
        assert log.scalar_events == []                   # batch hook won
    finally:
        s.close()


def test_scalar_only_observer_sees_equivalent_event_stream():
    """The default on_access_batch fallback replays scalar on_access in
    batch order -- a scalar-hook-only observer cannot tell a batch call
    from the equivalent scalar loop."""
    sa, gfns_a, _ = _mixed_system(6)
    sb, gfns_b, _ = _mixed_system(6)
    try:
        la = sa.guest.attach(_ScalarOnlyLog())
        lb = sb.guest.attach(_ScalarOnlyLog())
        reqs = [(gfns_a[0], 0, 8), (gfns_a[2], 32, 16), (gfns_a[3], 0, 4)]
        sa.guest.read_many(reqs)
        for g, off, n in reqs:
            sb.guest.read(g, n, off=off)
        assert la.events == lb.events
    finally:
        sa.close()
        sb.close()


def test_trace_recorder_capture_identical_batch_vs_scalar():
    """TraceRecorder (scalar hooks only) captures byte-identical trace
    lines whether the workload used batch primitives or scalar calls."""
    sa, gfns_a, _ = _mixed_system(7)
    sb, gfns_b, _ = _mixed_system(7)
    try:
        ra = sa.guest.attach(TraceRecorder.for_space(sa.guest))
        rb = sb.guest.attach(TraceRecorder.for_space(sb.guest))
        payload = bytes(range(64))
        # batch workload on A
        sa.guest.write_many([(gfns_a[0], 0, payload),
                             (gfns_a[1], 128, payload)])
        sa.guest.read_many([(gfns_a[0], 0, 64), (gfns_a[1], 128, 64)])
        # scalar workload on B
        sb.guest.write(gfns_b[0], payload)
        sb.guest.write(gfns_b[1], payload, off=128)
        sb.guest.read(gfns_b[0], 64)
        sb.guest.read(gfns_b[1], 64, off=128)
        assert ra.lines()[1:] == rb.lines()[1:]
    finally:
        sa.close()
        sb.close()


# ------------------------------------------- parallel compression determinism
def _backend_image(s, gfn):
    """Byte-stable image of one MS's backend state: standalone entries
    plus extent payloads/row maps in eid order."""
    be = s.backend
    standalone = sorted(
        (mp, entry) for (g, mp), entry in be._compressed.items()
        if g == gfn and entry[0] != "x")
    refs = sorted(
        (mp, entry[1], entry[2]) for (g, mp), entry in be._compressed.items()
        if g == gfn and entry[0] == "x")
    extents = sorted(
        (eid, ext.payload, tuple(ext.mps), ext.crc)
        for (g, eid), ext in be._extents.items() if g == gfn)
    return standalone, refs, extents


def _swap_out_image(workers: int):
    """Fill one MS with seeded random bytes, swap it out under the given
    compress_workers, and return (backend image, roundtrip-read, data)."""
    cfg = small_test_config(
        ms_bytes=32 * 1024, mps_per_ms=32,
        backend=BackendConfig(extent_max_rows=4),
        swap=SwapConfig(hot_path=HotPathConfig(compress_workers=workers)))
    s = TaijiSystem(cfg)
    try:
        rng = np.random.default_rng(11)
        g = s.guest.alloc_ms()
        # compressible non-zero rows (pure random would store verbatim and
        # never form extents): short random motifs repeated per MP
        mp = cfg.mp_bytes
        data = b"".join(
            rng.integers(1, 256, 32, dtype=np.uint8).tobytes() * (mp // 32)
            for _ in range(cfg.mps_per_ms))
        s.guest.write(g, data)
        s.engine.swap_out_ms(g)                # 32 rows -> 8 extents
        image = _backend_image(s, g)
        back = s.guest.read(g)
        assert s.metrics.crc_failures == 0
        return image, back, data
    finally:
        s.close()


@pytest.mark.parametrize("workers", [2, 4])
def test_parallel_compression_stores_identical_bytes(workers):
    """Chunk boundaries are fixed by extent_max_rows and the pool merges
    in submission order, so the stored backend state is byte-identical
    for any compress_workers value (0 = serial reference)."""
    ref_image, ref_back, data = _swap_out_image(0)
    assert len(ref_image[2]) > 1               # really multi-extent
    assert ref_back == data                    # serial path round-trips
    image, back, _ = _swap_out_image(workers)
    assert image == ref_image
    assert back == data


# ------------------------------------------------------------- HotPathConfig
def test_hot_path_defaults_and_legacy_scalar():
    hp = HotPathConfig()
    assert hp.fast_fault and hp.readahead
    assert not hp.pallas_kernels
    assert hp.compress_workers > 1
    ref = HotPathConfig.legacy_scalar()
    assert not (ref.fast_fault or ref.readahead or ref.pallas_kernels)
    assert ref.compress_workers == 0


def test_swap_config_legacy_aliases_mirror_hot_path():
    sc = SwapConfig(fast_fault_enabled=False, readahead_enabled=False)
    assert sc.hot_path.fast_fault is False
    assert sc.hot_path.readahead is False
    assert sc.fast_fault_enabled is False and sc.readahead_enabled is False
    # hot_path passed directly: aliases read back from it
    sc2 = SwapConfig(hot_path=HotPathConfig.legacy_scalar())
    assert sc2.use_pallas_kernels is False
    assert sc2.fast_fault_enabled is False
    # dataclasses.replace with a legacy flag (how call sites toggle):
    # the explicit legacy value wins over the carried-along hot_path
    sc3 = dataclasses.replace(sc2, fast_fault_enabled=True)
    assert sc3.hot_path.fast_fault is True
    assert sc3.hot_path.readahead is False               # rest untouched


def test_swap_config_pickle_roundtrip_and_legacy_state():
    sc = SwapConfig(fast_fault_enabled=False)
    back = pickle.loads(pickle.dumps(sc))
    assert back == sc
    assert back.hot_path.fast_fault is False
    # a state dict from before hot_path existed (old pickle layout):
    # __setstate__ must synthesize the HotPathConfig from the scalars
    old = SwapConfig.__new__(SwapConfig)
    old.__setstate__({"batch_enabled": True, "batch_mps": 32,
                      "fast_fault_enabled": False,
                      "readahead_enabled": True,
                      "use_pallas_kernels": False})
    assert old.hot_path == HotPathConfig(fast_fault=False)
    assert old.batch_mps == 32
