"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cell_skip_reason
from repro.configs.reduce import reduced_config
from repro.data.pipeline import SyntheticPipeline
from repro.models import model as M
from repro.optim import adamw
from repro.train import steps

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32):
    pipe = SyntheticPipeline(cfg, B, S, seed=1)
    return {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=10,
                                state_dtype=cfg.opt_dtype)
    state = steps.init_train_state(RNG, cfg, opt_cfg)
    batch = make_batch(cfg)
    state2, metrics = jax.jit(
        lambda s, b: steps.train_step(s, b, cfg, opt_cfg))(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    assert float(metrics["grad_norm"]) > 0
    assert int(state2.step) == 1
    # a second step must reduce nothing to NaN
    state3, metrics3 = jax.jit(
        lambda s, b: steps.train_step(s, b, cfg, opt_cfg))(state2, batch)
    assert np.isfinite(float(metrics3["loss"]))
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state3.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_output_shapes(arch):
    cfg = reduced_config(arch)
    params = M.init_params(RNG, cfg)
    batch = make_batch(cfg, B=2, S=32)
    hidden, aux = M.forward(params, cfg, batch, remat=False)
    assert hidden.shape == (2, 32, cfg.d_model)
    logits = M.logits_from_hidden(params, cfg, hidden)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3-4b", "qwen2.5-32b", "granite-20b",
                                  "deepseek-moe-16b", "qwen3-moe-235b-a22b",
                                  "falcon-mamba-7b", "jamba-1.5-large-398b"])
def test_decode_matches_forward(arch):
    """Token-by-token paged decode == full teacher-forced forward."""
    cfg = reduced_config(arch)
    params = M.init_params(RNG, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    hidden, _ = M.forward(params, cfg, {"tokens": tokens}, remat=False)
    want = M.logits_from_hidden(params, cfg, hidden)
    cache = M.init_cache(cfg, B, S)
    got = []
    for t in range(S):
        lg, cache = M.decode_step(params, cfg, tokens[:, t], cache)
        got.append(lg)
    got = jnp.stack(got, axis=1)
    rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
    assert rel < 2e-3, (arch, rel)


def test_vlm_decode_with_vision_prefix():
    cfg = reduced_config("qwen2-vl-2b")
    params = M.init_params(RNG, cfg)
    B, S, nv = 2, 16, cfg.max_vision_tokens
    batch = make_batch(cfg, B=B, S=S)
    hidden, _ = M.forward(params, cfg, batch, remat=False)
    want = M.logits_from_hidden(params, cfg, hidden)
    cache = M.init_cache(cfg, B, S)
    got = []
    for t in range(S):
        ie = batch["vision_embeds"][:, t] if t < nv else None
        mp = batch["mrope_pos"][:, :, t : t + 1]
        lg, cache = M.decode_step(params, cfg, batch["tokens"][:, t], cache,
                                  mp, ie)
        got.append(lg)
    got = jnp.stack(got, axis=1)
    rel = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
    assert rel < 2e-3, rel


def test_remat_matches_no_remat():
    cfg = reduced_config("qwen3-4b")
    params = M.init_params(RNG, cfg)
    batch = make_batch(cfg)
    h1, _ = M.forward(params, cfg, batch, remat=True)
    h2, _ = M.forward(params, cfg, batch, remat=False)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


def test_param_count_matches_actual():
    for arch in ("qwen3-4b", "deepseek-moe-16b", "falcon-mamba-7b"):
        cfg = reduced_config(arch)
        params = M.init_params(RNG, cfg)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.01, \
            (arch, actual, predicted)


def test_encoder_only_has_no_decode_shapes():
    assert cell_skip_reason("hubert-xlarge", "decode_32k")
    assert cell_skip_reason("hubert-xlarge", "long_500k")
    assert cell_skip_reason("qwen3-4b", "long_500k")
    assert cell_skip_reason("falcon-mamba-7b", "long_500k") is None
    assert cell_skip_reason("jamba-1.5-large-398b", "long_500k") is None
