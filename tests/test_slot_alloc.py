"""Sharded slot magazines (ISSUE 8): exactly-once admission under
multi-thread contention, exact free-slot accounting through magazine
drains, legacy single-list parity, and chaos kill/recover of a fleet
node mid-fault."""
import random
import threading

from repro.core.config import (HotPathConfig, SwapConfig, small_test_config)
from repro.core.system import TaijiSystem
from repro.core.virt import PhysicalMemory
from repro.fleet.harness import build_fleet


def _phys(magazine_size=8, slot_shards=4, n_phys_ms=128):
    cfg = small_test_config(
        n_phys_ms=n_phys_ms, mpool_reserve_ms=2,
        swap=SwapConfig(hot_path=HotPathConfig(
            slot_shards=slot_shards, magazine_size=magazine_size)))
    return PhysicalMemory(cfg), cfg


# ------------------------------------------------------------ exactly-once
def test_threads_race_to_exhaustion_each_slot_served_once():
    phys, cfg = _phys()
    capacity = cfg.n_phys_ms - cfg.mpool_reserve_ms
    got = [[] for _ in range(6)]
    barrier = threading.Barrier(6)

    def worker(i):
        barrier.wait()
        while True:
            slot = phys.try_alloc_slot()
            if slot is None:
                # steal pass came up empty too: the pool is truly dry
                return
            got[i].append(slot)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    all_slots = [s for per in got for s in per]
    assert len(all_slots) == capacity                 # nothing lost
    assert len(set(all_slots)) == capacity            # nothing double-served
    assert set(all_slots) == set(range(cfg.mpool_reserve_ms, cfg.n_phys_ms))
    assert phys.free_count == 0
    assert phys.try_alloc_slot() is None
    assert phys.magazine_refills > 0


def test_seeded_alloc_free_chaos_accounting_is_exact():
    phys, cfg = _phys(n_phys_ms=64)
    capacity = cfg.n_phys_ms - cfg.mpool_reserve_ms
    held = [[] for _ in range(4)]
    barrier = threading.Barrier(4)

    def worker(i):
        rng = random.Random(1000 + i)
        mine = held[i]
        barrier.wait()
        for _ in range(4000):
            if mine and rng.random() < 0.5:
                phys.free_slot(mine.pop(rng.randrange(len(mine))))
            else:
                slot = phys.try_alloc_slot()
                if slot is not None:
                    mine.append(slot)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    survivors = [s for per in held for s in per]
    assert len(set(survivors)) == len(survivors)      # never double-held
    # quiescent accounting: free (shards + magazines) + held == capacity
    assert phys.free_count + len(survivors) == capacity
    phys.drain_magazines()
    assert phys.free_count + len(survivors) == capacity
    for s in survivors:
        phys.free_slot(s)
    assert phys.free_count == capacity


# ----------------------------------------------------------- magazine drain
def test_drain_magazines_returns_cached_slots_to_shards():
    phys, cfg = _phys(magazine_size=8)
    capacity = cfg.n_phys_ms - cfg.mpool_reserve_ms
    slot = phys.alloc_slot()              # refill caches magazine_size slots
    stats = phys.alloc_stats()
    assert stats["magazine_size"] == 8
    assert stats["magazine_cached"] == 8
    assert phys.free_count == capacity - 1            # cached slots counted
    drained = phys.drain_magazines()
    assert drained == 8
    assert phys.alloc_stats()["magazine_cached"] == 0
    assert phys.free_count == capacity - 1            # accounting unchanged
    phys.free_slot(slot)
    assert phys.free_count == capacity


def test_drain_collects_magazines_of_dead_threads():
    phys, cfg = _phys()
    capacity = cfg.n_phys_ms - cfg.mpool_reserve_ms
    out = []

    def worker():
        out.append(phys.alloc_slot())     # leaves a populated tls magazine

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    assert phys.alloc_stats()["magazine_cached"] > 0
    drained = phys.drain_magazines()
    assert drained > 0
    assert phys.alloc_stats()["magazine_cached"] == 0
    phys.free_slot(out[0])
    assert phys.free_count == capacity


# ------------------------------------------------------------- legacy mode
def test_legacy_single_list_mode_preserves_pop_order():
    phys, cfg = _phys(magazine_size=0, slot_shards=4)
    stats = phys.alloc_stats()
    assert stats["magazine_size"] == 0
    assert stats["slot_shards"] == 1      # forced single-shard
    # seed pop order: lowest managed pfn first, exactly as before
    assert phys.try_alloc_slot() == cfg.mpool_reserve_ms
    assert phys.try_alloc_slot() == cfg.mpool_reserve_ms + 1
    assert phys.drain_magazines() == 0
    assert phys.free_count == cfg.n_phys_ms - cfg.mpool_reserve_ms - 2


def test_magazine_and_legacy_reach_same_quiescent_state():
    results = []
    for hp in (HotPathConfig(), HotPathConfig.legacy_scalar()):
        s = TaijiSystem(small_test_config(swap=SwapConfig(hot_path=hp)))
        rng = random.Random(7)
        gfns = [s.guest_alloc_ms() for _ in range(6)]
        blobs = {}
        for g in gfns:
            blobs[g] = bytes(rng.randrange(256)
                             for _ in range(s.cfg.mp_bytes))
            s.guest.write(g, blobs[g])
        for g in gfns[:4]:
            s.engine.swap_out_ms(g)
        reads = {g: s.guest.read(g, s.cfg.mp_bytes) for g in gfns}
        s.engine.drain_deferred()
        results.append((reads, s.phys.free_count,
                        s.virt.free_ms, blobs))
        s.close()
    (r_mag, free_mag, vms_mag, b_mag), (r_leg, free_leg, vms_leg, b_leg) = \
        results
    assert r_mag == b_mag and r_leg == b_leg          # bytes survive faults
    assert free_mag == free_leg
    assert vms_mag == vms_leg


# ---------------------------------------------------- fleet chaos mid-fault
def test_chaos_kill_recover_mid_fault_keeps_accounting_exact():
    cfg = small_test_config()
    fleet = build_fleet(n_nodes=2, domains=2, cfg=cfg)
    n0, n1 = fleet.nodes
    payload = {}
    for node in (n0, n1):
        for _ in range(5):
            g = node.alloc_ms()
            payload[(node.node_id, g)] = bytes(
                [(g * 17 + node.node_id) & 0xFF]) * cfg.mp_bytes
            node.write_mp(g, 0, payload[(node.node_id, g)])
    for node in (n0, n1):
        for g in list(node.allocated):
            node.system.engine.swap_out_ms(g)
    # fault half of each node's set back in: the magazine path runs, and
    # n0 dies with slots still cached in its thread magazine
    for node in (n0, n1):
        for g in sorted(node.allocated)[:2]:
            assert node.read_mp(g, 0) == payload[(node.node_id, g)]
    assert n0.system.phys.alloc_stats()["magazine_cached"] > 0

    victims = len(n0.allocated)
    fleet.kill_node(0)                    # close() drains magazines + LRU
    fleet.tick()                          # controller re-places on n1
    assert fleet.ms_replaced == victims and fleet.ms_lost == 0

    fleet.recover_node(0)
    assert n0.alive and n0.serving
    # a recovered node boots empty with the full pool intact
    assert n0.system.phys.free_count == n0.managed_phys_ms
    assert n0.system.engine.drain_deferred() == 0
    assert n0.system.phys.free_count == n0.managed_phys_ms

    # survivor accounting is exact once deferred state is drained:
    # free slots + slots pinned under resident MSs == managed pool
    s1 = n1.system
    s1.engine.drain_deferred()
    held = sum(1 for g in range(cfg.mpool_reserve_ms, cfg.n_virt_ms)
               if int(s1.virt.table.pfn[g]) >= 0)
    assert s1.phys.free_count + held == n1.managed_phys_ms
    # surviving bytes still readable on their new home
    for g in sorted(n1.allocated):
        data = n1.read_mp(g, 0)
        assert len(data) == cfg.mp_bytes
    fleet.close()
