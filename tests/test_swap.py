"""Parallel swap engine (paper §4.2.2, Fig 8): correctness + concurrency."""
import threading
import zlib

import numpy as np
import pytest

from repro.core.config import small_test_config
from repro.core.errors import CorruptionError, PinnedError
from repro.core.ms import MS_PARTIAL, MS_RESIDENT, MS_SWAPPED
from repro.core.system import TaijiSystem


def fresh(**kw):
    return TaijiSystem(small_test_config(**kw))


def fill(s, g, seed):
    data = np.random.default_rng(seed).integers(
        0, 256, s.cfg.ms_bytes).astype(np.uint8).tobytes()
    s.guest.write(g, data)
    return data


# ------------------------------------------------------------ round trips
def test_full_swap_roundtrip_exact():
    s = fresh()
    g = s.guest_alloc_ms()
    data = fill(s, g, 1)
    assert s.engine.swap_out_ms(g) == s.cfg.mps_per_ms
    req = s.reqs.lookup(g)
    assert req.record.state == MS_SWAPPED
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    # reading every MP merged the MS back
    assert req.record.state == MS_RESIDENT
    assert s.metrics.ms_swapped_in == 1


def test_zero_pages_take_zero_backend():
    s = fresh()
    g = s.guest_alloc_ms()                 # zero-filled by alloc
    s.engine.swap_out_ms(g)
    assert s.metrics.backend_zero_mps == s.cfg.mps_per_ms
    assert s.guest.read(g, 32) == b"\x00" * 32


def test_partial_fault_leaves_consistent_split_state():
    s = fresh()
    g = s.guest_alloc_ms()
    data = fill(s, g, 2)
    s.engine.swap_out_ms(g)
    # fault only MP 3
    off = 3 * s.cfg.mp_bytes
    got = s.guest.read(g, s.cfg.mp_bytes, off=off)
    assert got == data[off : off + s.cfg.mp_bytes]
    rec = s.reqs.lookup(g).record
    assert rec.state == MS_PARTIAL
    assert rec.present_count == 1
    assert s.virt.table.is_split(g)
    # remaining MPs still load fine
    assert s.guest.read(g, s.cfg.ms_bytes) == data
    assert rec.state == MS_RESIDENT
    assert not s.virt.table.is_split(g)


def corrupt_one_stored_mp(backend):
    """Flip bits in one stored MP behind the engine's back, whichever
    representation (tagged standalone blob or batch extent) holds it."""
    for key, entry in backend._compressed.items():
        if entry[0] in ("z", "v"):
            blob = bytearray(entry[1])
            blob[0] ^= 0xFF
            backend._compressed[key] = (entry[0], bytes(blob))
            return
    # batched path: corrupt the decompressed payload of one extent (zlib
    # would reject a corrupted stream outright; corrupting the raw cache
    # exercises the CRC check itself)
    key = next(iter(backend._extents))
    ext = backend._extents[key]
    if not ext.is_raw:
        import zlib
        ext.payload = zlib.decompress(ext.payload)
        ext.is_raw = True
    raw = bytearray(ext.payload)
    raw[0] ^= 0xFF
    ext.payload = bytes(raw)


def test_crc_detects_backend_corruption():
    s = fresh()
    g = s.guest_alloc_ms()
    fill(s, g, 3)
    s.engine.swap_out_ms(g)
    corrupt_one_stored_mp(s.backend)
    with pytest.raises(CorruptionError):
        s.guest.read(g, s.cfg.ms_bytes)
    assert s.metrics.crc_failures >= 1


def test_pinned_ms_refuses_swap():
    s = fresh()
    g = s.guest_alloc_ms()
    s.virt.table.set_pinned(g, True)
    with pytest.raises(PinnedError):
        s.engine.swap_out_ms(g)


# ------------------------------------------------------------- watermarks
def test_overcommit_beyond_physical():
    """The headline claim: >50% more virtual memory than physical (O3)."""
    s = fresh()
    cfg = s.cfg
    n = cfg.n_virt_ms - cfg.mpool_reserve_ms
    payload = {}
    for i in range(n):
        g = s.guest_alloc_ms()
        payload[g] = fill(s, g, 100 + i)
    assert len(payload) > (cfg.n_phys_ms - cfg.mpool_reserve_ms) * 1.4
    for g, data in payload.items():
        assert s.guest.read(g, cfg.ms_bytes) == data
    assert s.metrics.ms_swapped_out > 0


def test_reclaim_round_respects_watermarks():
    s = fresh()
    managed = s.cfg.n_phys_ms - s.cfg.mpool_reserve_ms
    gfns = []
    while s.phys.free_count > s.watermark.low_ms - 1 and \
            len(gfns) < managed + 4:
        g = s.guest_alloc_ms()
        fill(s, g, len(gfns))
        gfns.append(g)
    # age everything to cold
    for _ in range(6):
        s.lru.scan_shard(0, 1)
    while s.engine.reclaim_round() > 0:
        pass
    assert s.phys.free_count >= s.watermark.high_ms


# ------------------------------------------------------------ concurrency
def test_concurrent_faults_same_ms_exactly_once():
    s = fresh()
    g = s.guest_alloc_ms()
    data = fill(s, g, 7)
    s.engine.swap_out_ms(g)
    errs = []

    def reader(mp):
        try:
            off = mp * s.cfg.mp_bytes
            got = s.guest.read(g, s.cfg.mp_bytes, off=off)
            assert got == data[off : off + s.cfg.mp_bytes]
        except Exception as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=reader, args=(mp % s.cfg.mps_per_ms,))
               for mp in range(4 * s.cfg.mps_per_ms)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    # exactly-once: each MP swapped in a single time
    assert s.metrics.mp_swapped_in == s.cfg.mps_per_ms
    assert s.reqs.lookup(g).record.state == MS_RESIDENT


def test_reader_cancels_writer():
    s = fresh()
    g = s.guest_alloc_ms()
    data = fill(s, g, 9)

    # slow the backend store so the writer holds the lock measurably
    orig_store = s.backend.store
    import time

    def slow_store(gfn, mp, d):
        time.sleep(0.002)
        return orig_store(gfn, mp, d)

    s.backend.store = slow_store
    done = threading.Event()

    def writer():
        s.engine.swap_out_ms(g)
        done.set()

    w = threading.Thread(target=writer)
    w.start()
    time.sleep(0.004)                   # let it swap a couple of MPs
    got = s.guest.read(g, s.cfg.mp_bytes)   # reader bumps the writer
    assert got == data[: s.cfg.mp_bytes]
    w.join(5)
    assert done.is_set()
    assert s.metrics.writer_cancels >= 1 or s.metrics.mp_swapped_out == s.cfg.mps_per_ms


def test_parallel_swaps_different_ms():
    s = fresh()
    gfns = []
    datas = {}
    for i in range(6):
        g = s.guest_alloc_ms()
        datas[g] = fill(s, g, 20 + i)
        gfns.append(g)
    for g in gfns:
        s.engine.swap_out_ms(g)
    errs = []

    def worker(g):
        try:
            assert s.guest.read(g, s.cfg.ms_bytes) == datas[g]
        except Exception as e:          # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(g,)) for g in gfns]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
