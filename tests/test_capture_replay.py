"""Captured serving workloads replay deterministically on a fleet (ISSUE 5).

Acceptance: a trace captured from the real elastic_kv serving loop (and
one from elastic_params expert churn) replays byte-identically on a
>= 2-node fleet via ``harness.assert_deterministic``, with zero
read-verify failures -- every ``rdata`` op checks the replayed bytes
against the content hash of what the application actually read at
capture time, and every ``wdata`` op rewrites the application's actual
bytes (not seed-derived pages).
"""
import pytest

from repro.fleet.capture import capture_expert_churn, capture_kv_serving
from repro.fleet.harness import assert_deterministic, replay
from repro.fleet.trace import OP_RDATA, OP_TICK, OP_WDATA, TraceHeader, parse_line


def _ops(cap):
    return [parse_line(ln) for ln in cap.lines[1:]]


@pytest.fixture(scope="module")
def kv_capture():
    return capture_kv_serving(smoke=True)


@pytest.fixture(scope="module")
def expert_capture():
    return capture_expert_churn(smoke=True)


def test_kv_capture_shape(kv_capture):
    """The captured trace is a well-formed workload: payload writes of
    real KV bytes, content-hash reads, background ticks, recycling."""
    hdr = TraceHeader.parse(kv_capture.lines[0])
    assert hdr.ms_bytes == kv_capture.cfg.ms_bytes
    ops = [op for _s, op, _a, _w, _p in _ops(kv_capture)]
    assert kv_capture.payload_writes > 50        # real fp16 KV appends
    assert kv_capture.payload_reads >= 1         # read_block verification
    assert OP_TICK in ops                        # aging travels in-trace
    assert ops.count("free") > 0                 # conversation recycling


def test_kv_capture_replays_deterministically(kv_capture):
    eq = assert_deterministic(kv_capture.lines, n_nodes=2, domains=2,
                              cfg=kv_capture.fleet_cfg)
    c = eq.runs[0].counters
    assert c["verify_failures"] == 0
    assert c["payload_writes"] == kv_capture.payload_writes
    assert c["payload_reads"] == kv_capture.payload_reads
    assert c["touch_unplaced"] == 0              # every token admitted
    # the replayed fleet actually exercised elasticity, not just writes
    det = eq.runs[0].deterministic
    assert det["admitted"] > 0
    assert det["reclaimed_mps"] > 0


def test_expert_capture_replays_deterministically(expert_capture):
    assert expert_capture.payload_writes > 10    # puts + optimizer updates
    assert expert_capture.payload_reads >= 1
    eq = assert_deterministic(expert_capture.lines, n_nodes=2, domains=2,
                              cfg=expert_capture.fleet_cfg)
    c = eq.runs[0].counters
    assert c["verify_failures"] == 0
    assert c["touch_unplaced"] == 0


def test_partial_capture_replays_with_zero_verify_failures():
    """Capture attached mid-run: pre-capture MS content is re-established
    by recorder-synthesized wdata ops, so the replay still verifies every
    read byte-for-byte."""
    import numpy as np
    from repro.core.system import TaijiSystem
    from repro.fleet.trace import TraceRecorder

    cap = capture_kv_serving(smoke=True)         # just for a sized cfg
    system = TaijiSystem(cap.cfg)
    space = system.guest
    rng = np.random.default_rng(5)
    pre = [space.alloc_ms() for _ in range(4)]   # pre-capture population
    blobs = {g: rng.integers(0, 256, cap.cfg.ms_bytes, dtype=np.int64)
             .astype(np.uint8).tobytes() for g in pre}
    for g, blob in blobs.items():
        space.write(g, blob)
    rec = space.attach(TraceRecorder.for_space(space))
    for g in pre:                                # reads of unseen content
        space.read(g)
    space.step_background()
    for g in pre:
        space.read(g, 128, off=256)
    lines = rec.lines()
    system.close()
    eq = assert_deterministic(lines, n_nodes=2, domains=2,
                              cfg=cap.fleet_cfg)
    c = eq.runs[0].counters
    assert c["payload_reads"] == 8
    assert c["verify_failures"] == 0


def test_chaos_data_loss_does_not_fake_verify_failures():
    """A hard kill re-places a token's MS as a fresh zeroed MS; a later
    captured-content read of that token must be counted as skipped (the
    content is correctly gone), not as a data-integrity failure."""
    from repro.fleet.trace import (TraceHeader, encode_payload,
                                   encode_read_check, format_line)

    cap = capture_kv_serving(smoke=True)         # just for a sized cfg
    cfg = cap.fleet_cfg
    hdr = TraceHeader(0, cfg.ms_bytes, cfg.mps_per_ms, 0.0, 0.0)
    data = b"\x42" * 64
    ops = [
        ("alloc", 0, 0, ""),                     # token 0 -> node 0
        ("wdata", 64, 1, encode_payload(data)),
        ("kill", 0, 0, ""),                      # hard crash of node 0
        ("tick", 2, 0, ""),                      # re-place token 0 fresh
        ("rdata", 64, 0, encode_read_check(data)),
    ]
    lines = [hdr.line()] + [format_line(i, *op) for i, op in enumerate(ops)]
    eq = assert_deterministic(lines, n_nodes=2, domains=2, cfg=cfg)
    c = eq.runs[0].counters
    assert c["ms_replaced"] == 1                 # re-placed fresh (zeroed)
    assert c["payload_reads"] == 1               # the read still executed
    assert c["payload_verify_skipped"] == 1      # ...but is not "corrupt"
    assert c["verify_failures"] == 0


def test_capture_is_seed_stable():
    """Same seed -> byte-identical captured trace (the capture loop is
    fully deterministic, so traces are reproducible artifacts)."""
    a = capture_kv_serving(seed=23, smoke=True)
    b = capture_kv_serving(seed=23, smoke=True)
    assert a.lines == b.lines


def test_corrupted_payload_fails_read_verify(kv_capture):
    """Flipping one captured write's payload must be caught by the
    content-hash verification of a later read of the same region --
    the replay-side proof that rdata actually checks bytes."""
    lines = list(kv_capture.lines)
    # find a wdata whose exact (addr) is later rdata-verified
    wdata_at = {}
    verified = None
    for i, ln in enumerate(lines[1:], start=1):
        _s, op, arg, _w, _p = parse_line(ln)
        if op == OP_WDATA:
            wdata_at[arg] = i
        elif op == OP_RDATA:
            # rdata reads span whole blocks; any wdata inside the span
            # that wrote non-zero bytes works -- use exact-addr match
            if arg in wdata_at:
                verified = wdata_at[arg]
    if verified is None:
        pytest.skip("capture produced no exact write->read pair")
    from repro.fleet.trace import encode_payload, decode_payload, format_line
    seq, op, arg, w, payload = parse_line(lines[verified])
    data = bytearray(decode_payload(payload))
    data[0] ^= 0xFF
    lines[verified] = format_line(seq, op, arg, w, encode_payload(bytes(data)))
    run = replay(lines, n_nodes=2, domains=2, cfg=kv_capture.fleet_cfg)
    assert run.counters["verify_failures"] > 0
