"""GuestSpace -- the unified guest-memory surface (ISSUE 5).

Covers the API itself (alloc/free, bounds-checked I/O, typed views,
batched touch, pin), the observer protocol, the TraceRecorder capture
observer, the TaijiSystem deprecation shims (byte-equivalence + the
warning contract), the production rollout profile, and the
prefetch-exception satellite.
"""
import numpy as np
import pytest

from repro.core.config import small_test_config
from repro.core.elastic_kv import ElasticKVCache, KVGeometry, make_kv_taiji_config
from repro.core.guest import GuestObserver
from repro.core.system import TaijiSystem
from repro.core.virt import NO_PFN
from repro.fleet.controller import FleetConfig
from repro.fleet.trace import (OP_ALLOC, OP_FREE, OP_RDATA, OP_TICK,
                               OP_TOUCH, OP_WDATA, TraceRecorder,
                               decode_payload, parse_line)


@pytest.fixture
def system():
    s = TaijiSystem(small_test_config())
    yield s
    s.close()


class _Log(GuestObserver):
    def __init__(self):
        self.events = []

    def on_alloc(self, gfn):
        self.events.append(("alloc", gfn))

    def on_free(self, gfn):
        self.events.append(("free", gfn))

    def on_access(self, gfn, off, nbytes, is_write, data=None):
        self.events.append(("access", gfn, off, nbytes, is_write, data))

    def on_tick(self, rounds):
        self.events.append(("tick", rounds))


# ------------------------------------------------------------ core surface
def test_rw_roundtrip_and_bounds(system):
    space = system.guest
    g = space.alloc_ms()
    data = bytes(range(256)) * (system.cfg.mp_bytes // 256)
    space.write(g, data, off=system.cfg.mp_bytes)      # second MP
    assert space.read(g, len(data), off=system.cfg.mp_bytes) == data
    assert space.read(g, 5, off=system.cfg.mp_bytes) == data[:5]
    with pytest.raises(ValueError):
        space.write(g, b"x" * 8, off=system.cfg.ms_bytes - 4)
    with pytest.raises(ValueError):
        space.read(g, system.cfg.ms_bytes + 1)
    with pytest.raises(ValueError):
        space.read(g, 4, off=-1)
    # zero-length access at off == ms_bytes must NOT resolve (and fault)
    # the *next* MS -- the offset itself has to be inside this MS
    with pytest.raises(ValueError):
        space.write(g, b"", off=system.cfg.ms_bytes)
    with pytest.raises(ValueError):
        space.read(g, off=system.cfg.ms_bytes)
    space.free_ms(g)


def test_read_defaults_to_ms_end(system):
    space = system.guest
    g = space.alloc_ms()
    assert len(space.read(g)) == system.cfg.ms_bytes
    assert len(space.read(g, off=100)) == system.cfg.ms_bytes - 100


def test_typed_view_roundtrip(system):
    space = system.guest
    g = space.alloc_ms()
    view = space.view(g, np.float16, (4, 8), off=16)
    arr = np.arange(32, dtype=np.float16).reshape(4, 8)
    view.store(arr)
    np.testing.assert_array_equal(view.load(), arr)
    with pytest.raises(ValueError):
        view.store(np.zeros((3, 8), np.float16))
    with pytest.raises(ValueError):                     # view beyond the MS
        space.view(g, np.float64, (system.cfg.ms_bytes,))


def test_touch_faults_swapped_ms_back_in(system):
    space = system.guest
    g = space.alloc_ms()
    data = bytes([7]) * system.cfg.ms_bytes
    space.write(g, data)
    system.engine.swap_out_ms(g)
    assert int(system.virt.table.pfn[g]) == NO_PFN
    assert space.touch([g]) == 1
    assert int(system.virt.table.pfn[g]) != NO_PFN
    assert space.touch([g]) == 0                        # already resident
    assert space.read(g) == data


def test_pin_context(system):
    space = system.guest
    g = space.alloc_ms()
    system.engine.swap_out_ms(g)
    with space.pin([g]):
        assert system.virt.table.is_pinned(g)
        assert int(system.virt.table.pfn[g]) != NO_PFN
    assert not system.virt.table.is_pinned(g)


def test_residency_counts(system):
    space = system.guest
    gfns = [space.alloc_ms() for _ in range(3)]
    space.write(gfns[0], b"\x01" * system.cfg.ms_bytes)
    system.engine.swap_out_ms(gfns[0])
    res = space.residency(gfns)
    assert res == {"resident": 2, "swapped": 1, "total": 3}


# -------------------------------------------------------------- observers
def test_observer_sees_alloc_access_free_tick(system):
    space = system.guest
    log = space.attach(_Log())
    g = space.alloc_ms()
    space.write(g, b"abcd", off=8)
    space.read(g, 4, off=8)
    space.hint_accessed([g])
    space.step_background()
    space.free_ms(g)
    space.detach(log)
    space.alloc_ms()                                    # not observed
    assert log.events == [
        ("alloc", g),
        ("access", g, 8, 4, True, b"abcd"),
        ("access", g, 8, 4, False, b"abcd"),
        ("access", g, 0, 0, False, None),
        ("tick", 1),
        ("free", g),
    ]


def test_system_guest_is_canonical(system):
    assert system.guest is system.guest
    cache = ElasticKVCache(
        KVGeometry(n_layers=1, kv_heads=1, head_dim=16, block_tokens=4),
        system)
    assert cache.space is system.guest


# ------------------------------------------------------ deprecation shims
def test_shims_warn_and_stay_byte_equivalent(system):
    space = system.guest
    g = space.alloc_ms()
    data = b"taiji-shim" * 3
    with pytest.warns(DeprecationWarning):
        addr = system.ms_addr(g, mp=1, off=4)
    assert addr == space.addr_of(g, mp=1, off=4)
    with pytest.warns(DeprecationWarning):
        system.write(addr, data)
    assert space.read(g, len(data), off=system.cfg.mp_bytes + 4) == data
    with pytest.warns(DeprecationWarning):
        got = system.read(addr, len(data))
    assert got == data


def test_shims_flow_through_guest_observers(system):
    """Shimmed accesses are visible to GuestSpace observers -- the shim
    delegates through the canonical space, not around it."""
    space = system.guest
    g = space.alloc_ms()
    log = space.attach(_Log())
    with pytest.warns(DeprecationWarning):
        system.write(space.addr_of(g, off=32), b"zz")
    assert log.events == [("access", g, 32, 2, True, b"zz")]


# ---------------------------------------------------------- TraceRecorder
def test_trace_recorder_emits_replayable_ops(system):
    space = system.guest
    pre = space.alloc_ms()                  # allocated before capture
    rec = space.attach(TraceRecorder.for_space(space))
    g = space.alloc_ms()
    space.write(g, b"\x05" * 64, off=128)
    space.read(g, 64, off=128)
    space.touch([g])
    space.step_background(2)
    space.write(pre, b"\x06" * 8)           # lazily registers `pre`
    space.free_ms(g)
    lines = rec.lines()
    parsed = [parse_line(ln) for ln in lines[1:]]
    ops = [(op, arg, w) for _seq, op, arg, w, _p in parsed]
    ms = system.cfg.ms_bytes
    assert ops == [
        (OP_ALLOC, 0, 0),
        (OP_WDATA, 0 * ms + 128, 1),
        (OP_RDATA, 0 * ms + 128, 0),
        (OP_TOUCH, 0 * ms, 0),
        (OP_TICK, 2, 0),
        (OP_ALLOC, 1, 0),                   # pre-capture MS, lazy token
        (OP_WDATA, 1 * ms, 1),
        (OP_FREE, 0, 0),
    ]
    assert decode_payload(parsed[1][4]) == b"\x05" * 64
    import zlib
    crc = zlib.crc32(b"\x05" * 64) & 0xFFFFFFFF
    assert parsed[2][4] == f"64:{crc:08x}"


def test_trace_recorder_reestablishes_precapture_content(system):
    """A read of pre-capture content (an MS that existed before the
    recorder attached) must first emit a wdata carrying the observed
    bytes -- a replay starts from a zeroed MS and cannot know them --
    so the rdata content check passes at replay."""
    space = system.guest
    pre = space.alloc_ms()
    payload = bytes(range(200, 256)) * 4                 # 224 bytes
    space.write(pre, payload, off=64)
    rec = space.attach(TraceRecorder.for_space(space))
    space.read(pre, len(payload), off=64)                # pre-capture bytes
    space.write(pre, b"\x11" * 16, off=64)               # now covered
    space.read(pre, 16, off=64)                          # no re-establish
    ops = [parse_line(ln) for ln in rec.lines()[1:]]
    kinds = [op for _s, op, _a, _w, _p in ops]
    assert kinds == [OP_ALLOC, OP_WDATA, OP_RDATA, OP_WDATA, OP_RDATA]
    # the first wdata is the synthesized re-establishment of what the
    # read observed, at the read's own address
    assert ops[1][2] == ops[2][2] == 0 * system.cfg.ms_bytes + 64
    assert decode_payload(ops[1][4]) == payload
    # gaps are per-range: the second read's range was written, so no
    # extra wdata precedes the second rdata


def test_trace_recorder_coverage_gap_splitting(system):
    """Partial coverage: only the unwritten subranges of a read are
    re-established, in order, with the observed bytes."""
    space = system.guest
    pre = space.alloc_ms()
    space.write(pre, b"\xAA" * 256)                      # [0, 256) content
    rec = space.attach(TraceRecorder.for_space(space))
    space.write(pre, b"\xBB" * 32, off=64)               # covers [64, 96)
    space.read(pre, 192)                                 # [0, 192)
    ops = [parse_line(ln) for ln in rec.lines()[1:]]
    kinds = [op for _s, op, _a, _w, _p in ops]
    assert kinds == [OP_ALLOC, OP_WDATA, OP_WDATA, OP_WDATA, OP_RDATA]
    # gap wdatas: [0, 64) then [96, 192), around the recorded write
    assert ops[2][2] % system.cfg.ms_bytes == 0
    assert decode_payload(ops[2][4]) == b"\xAA" * 64
    assert ops[3][2] % system.cfg.ms_bytes == 96
    assert decode_payload(ops[3][4]) == b"\xAA" * 96


# ------------------------------------------------------ rollout profile
def test_production_profile_wires_latency_guard():
    prof = FleetConfig.production_profile()
    assert prof.latency_guard_factor is not None
    assert prof.latency_guard_factor > 1.0
    assert prof.latency_guard_min_samples >= FleetConfig().latency_guard_min_samples
    assert prof.reclaim_stagger_groups >= 2
    # the profile is a plain FleetConfig: a fleet built from it runs the
    # guard path on every upgrade batch (exercised in test_fleet.py's
    # latency-guard tests); here we pin the wiring contract
    assert prof.overcommit_cap == pytest.approx(1.25)


# ------------------------------------------------- prefetch exceptions
def test_prefetch_async_surfaces_worker_exception():
    geom = KVGeometry(n_layers=1, kv_heads=1, head_dim=16, block_tokens=4)
    cfg = make_kv_taiji_config(geom, 8, overcommit=1.0)
    s = TaijiSystem(cfg)
    try:
        cache = ElasticKVCache(geom, s)
        cache.create_sequence(0)
        for _ in range(4):
            cache.append_kv(0, np.zeros((1, 2, 1, 16), np.float16))
        s.engine.swap_out_ms(cache.blocks_of(0)[0])

        boom = RuntimeError("prefetch exploded")

        def bad_swap_in(gfn, **kw):
            raise boom

        s.engine.swap_in_ms = bad_swap_in
        th = cache.prefetch_async([0])
        with pytest.raises(RuntimeError, match="prefetch exploded"):
            th.join(timeout=5)
        assert th.exc is boom
    finally:
        s.close()


def test_prefetch_async_clean_join():
    geom = KVGeometry(n_layers=1, kv_heads=1, head_dim=16, block_tokens=4)
    cfg = make_kv_taiji_config(geom, 8, overcommit=1.0)
    s = TaijiSystem(cfg)
    try:
        cache = ElasticKVCache(geom, s)
        cache.create_sequence(0)
        for _ in range(4):
            cache.append_kv(0, np.zeros((1, 2, 1, 16), np.float16))
        s.engine.swap_out_ms(cache.blocks_of(0)[0])
        th = cache.prefetch_async([0])
        th.join(timeout=5)                  # no exception to surface
        assert th.exc is None
    finally:
        s.close()
