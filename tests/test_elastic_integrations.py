"""Elastic KV cache + elastic expert cache over the Taiji core."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # excluded from the default CI lane

from repro.core.config import LRUConfig
from repro.core.elastic_kv import ElasticKVCache, KVGeometry, make_kv_taiji_config
from repro.core.elastic_params import ElasticExpertCache, make_expert_taiji_config
from repro.core.system import TaijiSystem

GEOM = KVGeometry(n_layers=2, kv_heads=2, head_dim=16, block_tokens=4,
                  dtype_bytes=2)


def make_cache(phys_blocks=8, overcommit=2.0):
    cfg = make_kv_taiji_config(GEOM, phys_blocks, overcommit=overcommit,
                               lru=LRUConfig(scan_interval_s=0.001,
                                             stabilize_scans=1, workers=1))
    system = TaijiSystem(cfg)
    return ElasticKVCache(GEOM, system), system


def test_kv_roundtrip_exact_under_pressure():
    cache, system = make_cache(phys_blocks=6)
    rng = np.random.default_rng(0)
    mirror = {}
    n_seqs, toks = 6, 12                  # 6 seqs x 3 blocks = 18 > 6 phys
    for sid in range(n_seqs):
        cache.create_sequence(sid)
        mirror[sid] = []
        for _ in range(toks):
            kv = rng.standard_normal((2, 2, 2, 16)).astype(np.float16)
            cache.append_kv(sid, kv)
            mirror[sid].append(kv)
    res = cache.residency()
    assert res["total_blocks"] == n_seqs * (toks // GEOM.block_tokens)
    assert res["swapped_blocks"] > 0      # pressure forced swaps
    for sid in range(n_seqs):
        for b in range(toks // GEOM.block_tokens):
            got = cache.read_block(sid, b)
            want = np.stack(mirror[sid][b * 4 : (b + 1) * 4])
            np.testing.assert_array_equal(got, want.astype(np.float16))
    system.close()


def test_prepare_step_pins_and_faults_in():
    cache, system = make_cache(phys_blocks=6)
    rng = np.random.default_rng(1)
    for sid in range(6):
        cache.create_sequence(sid)
        for _ in range(8):
            cache.append_kv(sid, rng.standard_normal((2, 2, 2, 16)).astype(np.float16))
    # force seq 0 out
    for g in cache.blocks_of(0):
        system.engine.swap_out_ms(g)
    with cache.prepare_step([0]):
        for g in cache.blocks_of(0):
            assert system.virt.table.is_pinned(g)
            assert int(system.virt.table.pfn[g]) != -1
    for g in cache.blocks_of(0):
        assert not system.virt.table.is_pinned(g)
    system.close()


def test_drop_sequence_frees_memory():
    cache, system = make_cache(phys_blocks=6)
    rng = np.random.default_rng(2)
    cache.create_sequence(0)
    for _ in range(8):
        cache.append_kv(0, rng.standard_normal((2, 2, 2, 16)).astype(np.float16))
    free_before = system.phys.free_count
    cache.drop_sequence(0)
    assert system.phys.free_count > free_before
    system.close()


def test_expert_cache_residency_follows_routing():
    n_experts, hot = 8, 3
    shape = (64, 32)
    cfg = make_expert_taiji_config(
        int(np.prod(shape)) * 4, hot, n_experts,
        lru=LRUConfig(scan_interval_s=0.001, stabilize_scans=1, workers=1))
    system = TaijiSystem(cfg)
    cache = ElasticExpertCache(system, n_experts, shape, dtype=np.float32)
    rng = np.random.default_rng(3)
    weights = {e: rng.standard_normal(shape).astype(np.float32)
               for e in range(n_experts)}
    for e, w in weights.items():
        cache.put_expert(e, w)

    # router loves experts 0..2
    for _ in range(10):
        cache.note_routing([0, 1, 2])
        for _ in range(3):
            system.lru.scan_shard(0, 1)
        system.engine.reclaim_round()

    # all experts still readable and exact (swapped ones fault back in)
    for e, w in weights.items():
        np.testing.assert_array_equal(cache.get_expert(e), w)

    # dispatch pinning works for a cold expert
    with cache.prepare_dispatch([5]):
        gfn = cache._view[5].gfn
        assert system.virt.table.is_pinned(gfn)
    system.close()
