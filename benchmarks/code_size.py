"""Module code size -- paper Table 2.

Paper LOC: MOD 2567 / Mpool 2492 / MS 3273 / VMX 9557 / Attr 3158 /
LRU 4202 / Sched 2755 / Swap 4101 / API 3063 (vs KVM 77k, Linux mm 151k).
"""
from __future__ import annotations

from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"

MODULES = {
    "Mpool": ["core/mpool.py"],
    "MS": ["core/ms.py", "core/req.py", "core/rbtree.py"],
    "VMX": ["core/virt.py", "core/hotswitch.py"],
    "LRU": ["core/lru.py"],
    "Sched": ["core/scheduler.py"],
    "Swap": ["core/swap.py", "core/backend.py", "core/watermark.py"],
    "Upgrade": ["core/hotupgrade.py"],
    "API": ["core/system.py", "core/dma.py", "core/elastic_kv.py",
            "core/elastic_params.py", "core/metrics.py", "core/config.py"],
    "Kernels": ["kernels/zero_detect.py", "kernels/compress.py",
                "kernels/crc32c.py", "kernels/swap_copy.py",
                "kernels/paged_attention.py"],
}


def loc(path: Path) -> int:
    return sum(1 for line in path.read_text().splitlines()
               if line.strip() and not line.strip().startswith("#"))


def run(verbose: bool = True) -> dict:
    out = {}
    for mod, files in MODULES.items():
        out[mod] = sum(loc(SRC / f) for f in files)
    total = sum(out.values())
    if verbose:
        print("module LOC (paper Table 2 analogue):")
        for mod, n in out.items():
            print(f"  {mod:8s} {n}")
        print(f"  total    {total}")
    out["total"] = total
    return out


def rows() -> list:
    r = run(verbose=False)
    return [("code_size_total_loc", r["total"],
             ",".join(f"{k}={v}" for k, v in r.items() if k != "total"))]


if __name__ == "__main__":
    run()
