"""Virtualization / elasticity overhead -- paper Fig 11 / 12 + §5.2.2.

Paper: CPU+memory benchmarks within 3% of native; cloud workloads within
~3-5%; metadata overhead 0.38% live / 1.2% reserved.

Our data plane is a jitted decode step whose tensors Taiji does not touch
(block tables are native inputs), so the analogue of the paper's
"benchmark under virtualization" is: (a) decode step time with the
elastic manager active vs. absent, and (b) the translated-access penalty
on the host control path (direct numpy vs. block-table translated).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduce import reduced_config
from repro.core.config import ObsConfig, small_test_config
from repro.core.system import TaijiSystem
from repro.models import model as M


def _time_decode(step, params, tok, cache, iters=30):
    logits, c = step(params, tok, cache)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, c = step(params, tok, c)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / iters


def run(verbose: bool = True) -> dict:
    # (a) data-plane step: native vs with an active elastic manager
    cfg = reduced_config("qwen3-4b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 4, 64)
    tok = jnp.zeros((4,), jnp.int32)
    step = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c))

    t_native = _time_decode(step, params, tok, cache)

    # live-manager decode, untraced and with stage tracing on
    # (repro.obs). Alternate the two configs and keep the min of each: a
    # single 30-iter pair is hostage to background spikes on shared
    # runners, and the tracer comparison (gated at 5%) needs both sides
    # measured under the same machine weather
    t_elastic = float("inf")
    t_elastic_traced = float("inf")
    for _ in range(5):
        for traced in (False, True):
            system = TaijiSystem(
                small_test_config(obs=ObsConfig(enabled=traced)))
            system.start_background()  # manager live: BACK tasks running
            t = _time_decode(step, params, tok, cache, iters=10)
            system.stop_background()
            system.close()
            if traced:
                t_elastic_traced = min(t_elastic_traced, t)
            else:
                t_elastic = min(t_elastic, t)

    # (b) host access path: direct numpy vs block-table translation
    s = TaijiSystem(small_test_config())
    space = s.guest
    g = space.alloc_ms()
    n = 20000
    buf = s.phys.ms_view(int(s.virt.table.pfn[g]))
    t0 = time.perf_counter()
    for _ in range(n):
        bytes(buf[:64])
    t_direct = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    for _ in range(n):
        space.read(g, 64)
    t_translated = (time.perf_counter() - t0) / n
    # batched access path: the same 64B reads issued through read_many in
    # vectors of 64 -- bounds/residency/observer dispatch amortized over
    # the batch (the per-access cost upper layers actually pay when they
    # use the batch API)
    batch = [(g, 0, 64)] * 64
    n_batches = max(1, n // 64)
    t0 = time.perf_counter()
    for _ in range(n_batches):
        space.read_many(batch)
    t_batched = (time.perf_counter() - t0) / (n_batches * 64)
    s.close()

    # translated access with the span tracer recording (one guest_access
    # span per read, flushed every ring_capacity pushes)
    s = TaijiSystem(small_test_config(obs=ObsConfig(enabled=True)))
    space = s.guest
    g = space.alloc_ms()
    t0 = time.perf_counter()
    for _ in range(n):
        space.read(g, 64)
    t_translated_traced = (time.perf_counter() - t0) / n
    s.close()

    result = {
        "decode_native_ms": t_native * 1e3,
        "decode_elastic_ms": t_elastic * 1e3,
        "decode_overhead": t_elastic / t_native - 1.0,
        "tracer_overhead": t_elastic_traced / max(t_elastic, 1e-12) - 1.0,
        "decode_traced_ms": t_elastic_traced * 1e3,
        "host_direct_us": t_direct * 1e6,
        "host_translated_us": t_translated * 1e6,
        "host_translated_traced_us": t_translated_traced * 1e6,
        "host_batched_us": t_batched * 1e6,
        "host_overhead_x": t_translated / max(t_direct, 1e-12),
    }
    if verbose:
        print(f"decode step: native {result['decode_native_ms']:.2f} ms, "
              f"with manager {result['decode_elastic_ms']:.2f} ms "
              f"(overhead {result['decode_overhead']*100:+.1f}%; paper <5%), "
              f"traced {result['decode_traced_ms']:.2f} ms "
              f"(tracer {result['tracer_overhead']*100:+.1f}%)")
        print(f"host access: direct {result['host_direct_us']:.2f} us, "
              f"translated {result['host_translated_us']:.2f} us "
              f"(traced {result['host_translated_traced_us']:.2f} us), "
              f"batched {result['host_batched_us']:.2f} us/access")
    return result


def rows() -> list:
    r = run(verbose=False)
    return [
        ("decode_overhead_frac", r["decode_overhead"], "paper<0.05"),
        # span-tracer cost on the decode workload (manager live, tracing
        # on vs off). The measured difference can come out negative on a
        # noisy box (both sides are min-of-5 of a 10-iter mean); clamp
        # the reported row at 0.0 so the CI gate compares against a
        # monotone value, and keep the raw signed measurement in derived
        ("tracer_overhead_frac", max(0.0, r["tracer_overhead"]),
         f"raw={r['tracer_overhead']:+.5f}_"
         f"host_traced={r['host_translated_traced_us']:.2f}us_target<0.05"),
        ("host_translated_access_us", r["host_translated_us"],
         f"direct={r['host_direct_us']:.2f}us"),
        ("host_batched_access_us", r["host_batched_us"],
         "read_many_64x64B"),
    ]


if __name__ == "__main__":
    run()
