"""Virtualization / elasticity overhead -- paper Fig 11 / 12 + §5.2.2.

Paper: CPU+memory benchmarks within 3% of native; cloud workloads within
~3-5%; metadata overhead 0.38% live / 1.2% reserved.

Our data plane is a jitted decode step whose tensors Taiji does not touch
(block tables are native inputs), so the analogue of the paper's
"benchmark under virtualization" is: (a) decode step time with the
elastic manager active vs. absent, and (b) the translated-access penalty
on the host control path (direct numpy vs. block-table translated).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.reduce import reduced_config
from repro.core.config import ObsConfig, small_test_config
from repro.core.system import TaijiSystem
from repro.models import model as M


def _time_decode(step, params, tok, cache, iters=30):
    logits, c = step(params, tok, cache)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, c = step(params, tok, c)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / iters


def run(verbose: bool = True) -> dict:
    # (a) data-plane step: native vs with an active elastic manager
    cfg = reduced_config("qwen3-4b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 4, 64)
    tok = jnp.zeros((4,), jnp.int32)
    step = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c))

    # native vs live-manager decode, untraced and with stage tracing on
    # (repro.obs), as TRIMMED MEANS OF PAIRED ADJACENT LONG-WINDOW
    # RATIOS (ISSUE 9). Three things poisoned the old min-of-short-
    # windows comparison on this class of shared 1-vCPU runner, and the
    # design below answers each:
    #   1. The first TaijiSystem constructed in a process runs its
    #      manager-live decode 30-80% slow for that system's lifetime
    #      (warm-up pathology that a fresh system clears). The old code
    #      measured native BEFORE any system existed and elastic INSIDE
    #      the first one -- manufacturing most of the reported overhead.
    #      -> a sacrificial warm-up system + decode burst runs first,
    #      and every measured window uses a fresh short-lived system.
    #   2. Machine weather (co-tenant CPU steal) shifts the whole floor
    #      +-10-40% on a 1-3 s timescale, so any comparison whose two
    #      sides sit seconds apart is hostage to it. -> each ratio pairs
    #      two ADJACENT ~110 ms windows (mean-of-150-iters, which
    #      averages spike outliers instead of gambling a min on them),
    #      the in-pair order alternates and the settle jitters so a
    #      periodic co-tenant cannot phase-lock onto one side, and a
    #      25%-trimmed mean over the pairs absorbs the pairs a weather
    #      edge still split.
    #   3. The tracer tax is a second-order effect; dividing two noisy
    #      native-relative ratios doubled its noise. -> it gets its own
    #      directly-paired loop (traced vs untraced manager, adjacent).
    # The settle before each elastic window lets the scheduler's idle
    # backoff engage (cycle_ms=2, ramp to 16x over ~5 idle cycles):
    # production managers are long-lived, so steady-state is the honest
    # comparison -- without it the window overlaps post-start active
    # cycles and measures boot transient, not overhead. GC is parked
    # during the timed region so collection pauses land between
    # windows, not inside one.
    import gc
    import random
    rng = random.Random(0)

    def _trimmed(xs, k):
        xs = sorted(xs)[k:len(xs) - k]
        return sum(xs) / len(xs)

    def _elastic_window(traced, settle):
        system = TaijiSystem(
            small_test_config(obs=ObsConfig(enabled=traced)))
        system.start_background()   # manager live: BACK tasks running
        time.sleep(settle)
        t = _time_decode(step, params, tok, cache, iters=150)
        system.stop_background()
        system.close()
        return t

    gc.collect()
    gc.disable()
    try:
        warm = TaijiSystem(small_test_config())
        warm.start_background()
        time.sleep(0.5)
        for _ in range(4):
            _time_decode(step, params, tok, cache, iters=100)
        warm.stop_background()
        warm.close()

        ratios, traced_ratios = [], []
        t_native = t_elastic = t_elastic_traced = float("inf")
        for i in range(16):
            settle = rng.uniform(0.2, 0.35)
            if i % 2 == 0:
                t_e = _elastic_window(False, settle)
                t_n = _time_decode(step, params, tok, cache, iters=150)
            else:
                t_n = _time_decode(step, params, tok, cache, iters=150)
                t_e = _elastic_window(False, settle)
            ratios.append(t_e / t_n)
            t_native = min(t_native, t_n)
            t_elastic = min(t_elastic, t_e)
        for i in range(10):
            settle = rng.uniform(0.2, 0.35)
            if i % 2 == 0:
                t_t = _elastic_window(True, settle)
                t_e = _elastic_window(False, settle)
            else:
                t_e = _elastic_window(False, settle)
                t_t = _elastic_window(True, settle)
            traced_ratios.append(t_t / t_e)
            t_elastic_traced = min(t_elastic_traced, t_t)
    finally:
        gc.enable()
    # The warm-up pathology of item 1 recurs at random on a minority of
    # fresh systems (+25-80% for that system's whole manager-live
    # phase), far outside both the true steady-state cost (~3%) and
    # weather splits of an adjacent pair (+-8%). Pairs beyond the 1.15
    # cutoff are excluded as pathological -- but ONLY while they are a
    # minority: a real regression that slowed the steady state >15%
    # would push most pairs over the cutoff and be kept wholesale.
    def _screen(xs, lo, hi):
        kept = [r for r in xs if lo < r < hi]
        return kept if len(kept) >= (len(xs) + 1) // 2 else xs

    ratios = _screen(ratios, 0.0, 1.15)
    traced_ratios = _screen(traced_ratios, 0.85, 1.15)
    # trim ~20% per side of whatever survived the screen
    decode_overhead = _trimmed(ratios, min(len(ratios) // 5,
                                           (len(ratios) - 1) // 2)) - 1.0
    tracer_overhead = _trimmed(
        traced_ratios, min(len(traced_ratios) // 5,
                           (len(traced_ratios) - 1) // 2)) - 1.0

    # (b) host access path: direct numpy vs block-table translation
    s = TaijiSystem(small_test_config())
    space = s.guest
    g = space.alloc_ms()
    n = 20000
    buf = s.phys.ms_view(int(s.virt.table.pfn[g]))
    t0 = time.perf_counter()
    for _ in range(n):
        bytes(buf[:64])
    t_direct = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    for _ in range(n):
        space.read(g, 64)
    t_translated = (time.perf_counter() - t0) / n
    # batched access path: the same 64B reads issued through read_many in
    # vectors of 64 -- bounds/residency/observer dispatch amortized over
    # the batch (the per-access cost upper layers actually pay when they
    # use the batch API)
    batch = [(g, 0, 64)] * 64
    n_batches = max(1, n // 64)
    t0 = time.perf_counter()
    for _ in range(n_batches):
        space.read_many(batch)
    t_batched = (time.perf_counter() - t0) / (n_batches * 64)
    s.close()

    # translated access with the span tracer recording (one guest_access
    # span per read, flushed every ring_capacity pushes)
    s = TaijiSystem(small_test_config(obs=ObsConfig(enabled=True)))
    space = s.guest
    g = space.alloc_ms()
    t0 = time.perf_counter()
    for _ in range(n):
        space.read(g, 64)
    t_translated_traced = (time.perf_counter() - t0) / n
    s.close()

    result = {
        "decode_native_ms": t_native * 1e3,
        "decode_elastic_ms": t_elastic * 1e3,
        "decode_overhead": decode_overhead,
        "tracer_overhead": tracer_overhead,
        "decode_traced_ms": t_elastic_traced * 1e3,
        "host_direct_us": t_direct * 1e6,
        "host_translated_us": t_translated * 1e6,
        "host_translated_traced_us": t_translated_traced * 1e6,
        "host_batched_us": t_batched * 1e6,
        "host_overhead_x": t_translated / max(t_direct, 1e-12),
    }
    if verbose:
        print(f"decode step: native {result['decode_native_ms']:.2f} ms, "
              f"with manager {result['decode_elastic_ms']:.2f} ms "
              f"(overhead {result['decode_overhead']*100:+.1f}%; paper <5%), "
              f"traced {result['decode_traced_ms']:.2f} ms "
              f"(tracer {result['tracer_overhead']*100:+.1f}%)")
        print(f"host access: direct {result['host_direct_us']:.2f} us, "
              f"translated {result['host_translated_us']:.2f} us "
              f"(traced {result['host_translated_traced_us']:.2f} us), "
              f"batched {result['host_batched_us']:.2f} us/access")
    return result


def rows() -> list:
    r = run(verbose=False)
    return [
        ("decode_overhead_frac", r["decode_overhead"], "paper<0.05"),
        # span-tracer cost on the decode workload (manager live, tracing
        # on vs off, directly paired). The trimmed-mean estimate can come
        # out slightly negative on a noisy box; clamp
        # the reported row at 0.0 so the CI gate compares against a
        # monotone value, and keep the raw signed measurement in derived
        ("tracer_overhead_frac", max(0.0, r["tracer_overhead"]),
         f"raw={r['tracer_overhead']:+.5f}_"
         f"host_traced={r['host_translated_traced_us']:.2f}us_target<0.05"),
        ("host_translated_access_us", r["host_translated_us"],
         f"direct={r['host_direct_us']:.2f}us"),
        ("host_batched_access_us", r["host_batched_us"],
         "read_many_64x64B"),
    ]


if __name__ == "__main__":
    run()
