"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,value,derived`` CSV rows per the harness contract, plus the
full roofline table, and records every row in a ``BENCH_*.json`` artifact
(``BENCH_smoke.json`` for the CI perf canary, ``BENCH_full.json``
otherwise). ``python -m benchmarks.run [--quick] [--smoke]``.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
import traceback


def _module_rows(mod, smoke: bool, trace_out=None):
    """Call ``mod.rows()``, passing ``smoke=`` / ``trace_out=`` only
    where supported."""
    params = inspect.signature(mod.rows).parameters
    kw = {}
    if smoke and "smoke" in params:
        kw["smoke"] = True
    if trace_out and "trace_out" in params:
        kw["trace_out"] = trace_out
    return mod.rows(**kw)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slower latency benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs, seconds not minutes (CI perf canary); "
                         "writes BENCH_smoke.json")
    ap.add_argument("--out", default=None,
                    help="JSON artifact path (default BENCH_smoke.json / "
                         "BENCH_full.json)")
    ap.add_argument("--trace-out", default=None,
                    help="write the fleet replay's stage spans as "
                         "Chrome-trace-event JSON (open in Perfetto / "
                         "chrome://tracing)")
    args = ap.parse_args()

    from . import (backend_ratio, code_size, fault_latency, fleet,
                   lru_accuracy, metadata, overcommit, overhead, roofline)

    modules = [
        ("overhead (Fig 11/12)", overhead),
        ("metadata (Fig 13a)", metadata),
        ("overcommit (Fig 13b, §5.3.3)", overcommit),
        ("lru_accuracy (Fig 15b)", lru_accuracy),
        ("backend_ratio (Fig 15c)", backend_ratio),
        ("code_size (Table 2)", code_size),
        ("fleet (ISSUE 2/4: multi-node replay + chaos)", fleet),
    ]
    if not args.quick:
        # smoke mode keeps fault_latency (it carries the batched-vs-scalar
        # swap throughput rows the CI canary gates on) with a tiny config
        modules.insert(0, ("fault_latency (Fig 14f/15d)", fault_latency))

    print("name,value,derived")
    failures = 0
    recorded = {}
    for title, mod in modules:
        t0 = time.time()
        try:
            for name, value, derived in _module_rows(mod, args.smoke,
                                                     args.trace_out):
                print(f"{name},{value:.6g},{derived}")
                recorded[name] = {"value": float(value), "derived": str(derived)}
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {title} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if not args.smoke:
        print("\n# === roofline table (from dry-run artifacts) ===")
        try:
            roofline.run(verbose=True)
        except Exception:
            failures += 1
            traceback.print_exc()

    out_path = args.out or ("BENCH_smoke.json" if args.smoke else "BENCH_full.json")
    payload = {
        "mode": "smoke" if args.smoke else ("quick" if args.quick else "full"),
        "failures": failures,
        "rows": recorded,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.abspath(out_path)}", file=sys.stderr)

    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
