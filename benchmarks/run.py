"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,value,derived`` CSV rows per the harness contract, plus the
full roofline table. ``python -m benchmarks.run [--quick]``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slower latency benchmark")
    args = ap.parse_args()

    from . import (backend_ratio, code_size, fault_latency, lru_accuracy,
                   metadata, overcommit, overhead, roofline)

    modules = [
        ("overhead (Fig 11/12)", overhead),
        ("metadata (Fig 13a)", metadata),
        ("overcommit (Fig 13b, §5.3.3)", overcommit),
        ("lru_accuracy (Fig 15b)", lru_accuracy),
        ("backend_ratio (Fig 15c)", backend_ratio),
        ("code_size (Table 2)", code_size),
    ]
    if not args.quick:
        modules.insert(0, ("fault_latency (Fig 14f/15d)", fault_latency))

    print("name,value,derived")
    failures = 0
    for title, mod in modules:
        t0 = time.time()
        try:
            for name, value, derived in mod.rows():
                print(f"{name},{value:.6g},{derived}")
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {title} done in {time.time()-t0:.1f}s", file=sys.stderr)

    print("\n# === roofline table (from dry-run artifacts) ===")
    try:
        roofline.run(verbose=True)
    except Exception:
        failures += 1
        traceback.print_exc()

    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
