"""Fault (passive swap-in) latency distribution -- paper Fig 14f / 15d.

Paper targets: P90 < 10 us; measured in production 92.51-95.50% under
10 us during high-load hot upgrades and 93.57% cluster-wide.

Methodology: fill an overcommitted system with the paper's page mix
(76.79% zero / 23.21% ~48%-compressible), let background reclaim swap the
cold set out, then touch swapped MPs one at a time through the guest read
path so each access takes exactly one EPT fault.
"""
from __future__ import annotations

import sys

import numpy as np

# cap GIL-wait for the latency-critical fault path (the BACK reclaim
# thread releases the GIL inside zlib, but Python-level sections would
# otherwise hold it for the default 5 ms switch interval)
sys.setswitchinterval(0.0005)

from repro.core.config import (BackendConfig, LRUConfig, SchedulerConfig,
                               SwapConfig, TaijiConfig, WatermarkConfig,
                               small_test_config)
from repro.core.metrics import FK_NAMES, LatencyHistogram
from repro.core.system import TaijiSystem

# a per-kind percentile from fewer samples than this is noise, not a
# distribution: the row is still emitted (trend visibility) but tagged
# UNSTABLE so CI gates and humans know not to regress-test against it
MIN_KIND_SAMPLES = 16

from .workload import fill_system, paper_mix_ms


def run(n_faults: int = 3000, verbose: bool = True, smoke: bool = False,
        fast_path: bool = True, readahead: bool = True) -> dict:
    """Measure the passive fault-path latency distribution.

    ``fast_path=False, readahead=False`` runs the locked scalar reference
    path (the A/B semantic baseline the descriptor-table fast path is
    benchmarked against).
    """
    if smoke:
        n_faults = min(n_faults, 400)
    cfg = TaijiConfig(
        ms_bytes=(64 * 1024 if smoke else 256 * 1024),  # production: 4 KiB MPs
        mps_per_ms=64,
        n_phys_ms=32 if smoke else 48,
        overcommit_ratio=0.5,
        mpool_reserve_ms=4,
        # stabilize_scans=2: recently-faulted MSs survive a few scan
        # rounds before drifting cold again, as in production (§4.2.1
        # time-based stabilization). With instant aging the reclaimer
        # re-swaps half-consumed hot MSs, which re-fragments their
        # compressed rows into fresh extents and over-weights expensive
        # first-into-extent faults in the recorded distribution.
        lru=LRUConfig(scan_interval_s=0.001, workers=2, stabilize_scans=2),
        watermark=WatermarkConfig(high=0.25, low=0.15, min=0.04,
                                  reclaim_batch=8),
        scheduler=SchedulerConfig(cycle_ms=2.0, shards=2),
        swap=SwapConfig(fast_fault_enabled=fast_path,
                        readahead_enabled=readahead),
    )
    system = TaijiSystem(cfg)
    space = system.guest
    rng = np.random.default_rng(7)

    payload = fill_system(system, cfg.n_virt_ms - cfg.mpool_reserve_ms, seed=7)
    gfns = list(payload)

    # age + reclaim until the watermark is satisfied (background path);
    # enough scan rounds for the whole fill to drift cold through the
    # stabilized level ladder
    for _ in range(4 * cfg.lru.stabilize_scans * 3):
        for w in range(cfg.lru.workers):
            system.lru.scan_shard(w, cfg.lru.workers)
    while system.engine.reclaim_round() > 0:
        pass

    # Fault swapped MPs with production-like locality: MS popularity is
    # Zipf-distributed and MP touches within an MS are sequential, so most
    # faults land on already-partial MSs (no slot allocation on the path).
    # On this single-core container, FRONT (faults) and BACK (lru scans +
    # reclaim) are time-multiplexed exactly as hv_sched does on a
    # saturated DPU: a burst of faults (timed), then a BACK slice
    # (untimed) that keeps free memory above the watermarks.
    import gc as _gc

    ranks = np.arange(1, len(gfns) + 1, dtype=np.float64)
    pop = 1.0 / ranks ** 1.2
    pop /= pop.sum()
    cursor = {g: 0 for g in gfns}
    burst = 0
    low_ms = system.watermark.low_ms

    def back_slice():
        """Untimed BACK work: scans + reclaim drained to the high
        watermark, exactly what hv_sched's background tasks keep up with
        on a real DPU. Letting free memory reach the critical zone would
        time synchronous reclaim (zlib compress) inside the fault burst,
        which the paper's watermark design exists to prevent."""
        for w in range(cfg.lru.workers):
            system.lru.scan_shard(w, cfg.lru.workers)
        while system.engine.reclaim_round() > 0:
            pass
        _gc.collect(0)                  # collector runs in BACK, not FRONT

    def drive(n: int) -> None:
        nonlocal burst
        faulted = 0
        tries = 0
        # pre-draw the Zipf pick sequence: per-fault rng.choice costs more
        # than the fault under test and thrashes the cache between samples
        picks = rng.choice(len(gfns), size=n * 50, p=pop)
        while faulted < n and tries < n * 50:
            tries += 1
            g = gfns[int(picks[tries - 1])]
            req = system.reqs.lookup(g)
            if req is None:
                continue
            rec = req.record
            # next swapped MP at/after the cursor (wrapping) via one int
            # scan of the bm_out words -- a per-MP is_swapped_out() loop
            # costs more than the fault under test and pollutes the cache
            v = int.from_bytes(rec.bm_out.tobytes(), "little")
            if v == 0:
                continue
            start = cursor[g] % cfg.mps_per_ms
            x = v >> start
            if x:
                mp = start + (x & -x).bit_length() - 1
            else:
                mp = (v & -v).bit_length() - 1
            cursor[g] = mp + 1
            before = system.metrics.faults
            space.read(g, 64, off=mp * cfg.mp_bytes)
            faulted += system.metrics.faults - before
            burst += 1
            if burst >= 16 or system.phys.free_count < low_ms:
                burst = 0
                back_slice()

    _COUNTERS = ("fault_zero_pages", "fault_compressed_pages",
                 "fault_fast_path", "readahead_extents",
                 "fault_readahead_mps")
    windows = []
    _gc.disable()                       # GC pauses move to the BACK slice
    try:
        # steady-state measurement: a warmup pass touches every code path
        # (imports, numpy dispatch, branch caches, page-in of the buffer)
        # first, then three measured windows; the median window (by P90)
        # is reported so one burst of machine noise cannot masquerade as
        # a fault-path regression
        drive(max(120, n_faults // 8))
        for _win in range(3):
            system.metrics.sync()
            system.metrics.reset_fault_latency()
            base = {k: getattr(system.metrics, k) for k in _COUNTERS}
            drive(n_faults)
            system.metrics.sync()    # settle deferred fast-path counters
            h = system.metrics.fault_latency
            snap = h.snapshot()
            # keep the live per-kind histogram objects: the next window's
            # reset_fault_latency() rebuilds fresh ones, so these retain
            # exactly this window's samples for the cross-window merge
            kinds = dict(system.metrics.fault_latency_by_kind)
            windows.append({
                "faults": h.count,
                "p50_us": snap["p50_us"],
                "p90_us": snap["p90_us"],
                "p99_us": snap["p99_us"],
                "mean_us": snap["mean_us"],
                "frac_under_10us": h.fraction_below(10_000),
                "frac_under_15us": h.fraction_below(15_000),
                "by_kind": {name: hist.snapshot()
                            for name, hist in kinds.items()},
                "_kind_hists": kinds,
                "_delta": {k: getattr(system.metrics, k) - base[k]
                           for k in _COUNTERS},
            })
    finally:
        _gc.enable()
    # De-starve the compressed kind: a compressed fault needs a cold
    # non-zero MP that readahead did not already materialize, and the
    # smoke windows can land only a handful. Seed a dedicated batch --
    # write a compressible non-zero pattern, swap that MP out through the
    # scalar store (a standalone zlib blob, not an extent, so the fault
    # records as plain FK_COMPRESSED), fault it back -- and merge ONLY
    # its compressed-kind samples below. Runs after the measured windows
    # so the headline distribution never sees the synthetic faults.
    n_seed = 2 * MIN_KIND_SAMPLES
    pat = bytes(range(1, 129)) * (cfg.mp_bytes // 128)
    seed_gfns = gfns[:n_seed]
    for g in seed_gfns:                 # writes may fault: all before reset
        space.write(g, pat, off=0)
    for g in seed_gfns:
        system.engine.swap_out_mps(g, [0], batched=False)
    system.metrics.sync()
    system.metrics.reset_fault_latency()
    for g in seed_gfns:
        space.read(g, 64, off=0)
    system.metrics.sync()
    seeded_comp = system.metrics.fault_latency_by_kind["compressed"]
    # Per-kind distributions merge across ALL windows: rare kinds may
    # land only a couple of samples per window, and a p90 from n=2 is
    # sample starvation, not a latency figure.
    # The headline p50/p90/p99 still comes from the median window alone
    # so one burst of machine noise cannot masquerade as a regression.
    merged_by_kind = {}
    for name in FK_NAMES:
        agg = LatencyHistogram()
        for win in windows:
            agg.merge(win["_kind_hists"][name])
        if name == "compressed":
            agg.merge(seeded_comp)
        merged_by_kind[name] = agg.snapshot()
    for win in windows:
        del win["_kind_hists"]
    windows.sort(key=lambda win: win["p90_us"])
    result = windows[len(windows) // 2]
    result["by_kind_merged"] = merged_by_kind
    result["compressed_seeded"] = seeded_comp.count
    delta = result.pop("_delta")
    result.update({
        "zero_page_faults": delta["fault_zero_pages"],
        "compressed_faults": delta["fault_compressed_pages"],
        "fast_path_faults": delta["fault_fast_path"],
        "readahead_extents": delta["readahead_extents"],
        "readahead_mps": delta["fault_readahead_mps"],
    })
    if verbose:
        print(f"faults={result['faults']}  P50={result['p50_us']:.1f}us  "
              f"P90={result['p90_us']:.1f}us  P99={result['p99_us']:.1f}us")
        print(f"under 10us: {result['frac_under_10us']*100:.2f}%  "
              f"(paper: 93.57% cluster / >90% target)")
        for name, ks in merged_by_kind.items():
            if ks["count"]:
                tag = ("" if ks["count"] >= MIN_KIND_SAMPLES
                       else "  [UNSTABLE: small sample]")
                print(f"  {name:<11} n={ks['count']:<5} "
                      f"P50={ks['p50_us']:.1f}us  "
                      f"P90={ks['p90_us']:.1f}us (3-window merged){tag}")
        if result["readahead_extents"]:
            print(f"  readahead: {result['readahead_extents']} extents, "
                  f"{result['readahead_mps']} sibling MPs materialized")
    system.close()
    return result


def swap_throughput(smoke: bool = False, verbose: bool = True) -> dict:
    """Batched-vs-scalar swap pipeline throughput on 64-MP MSs.

    The tentpole A/B: the same paper-mix working set is pushed through
    ``swap_out_ms``/``swap_in_ms`` with the scalar per-MP path and with
    the batched index-vector path (bulk ``store_batch``/``load_batch``,
    extent compression). Best-of-``reps`` wall clock per direction;
    throughput in MPs/s.
    """
    import time as _time

    import gc as _gc

    mp_bytes = 1024                    # per-call overhead dominated geometry
    n_ms = 12 if smoke else 16
    reps = 7
    best = {False: None, True: None}
    # interleave scalar/batched reps so machine-load drift hits both paths
    # equally; best-of-reps per direction filters the residual noise
    for _rep in range(reps):
        for batched in (False, True):
            s = TaijiSystem(small_test_config(
                ms_bytes=64 * mp_bytes, mps_per_ms=64,
                n_phys_ms=n_ms + 8, mpool_reserve_ms=4))
            rng = np.random.default_rng(9)
            gfns = []
            for _i in range(n_ms):
                g = s.guest.alloc_ms()
                s.guest.write(g, paper_mix_ms(rng, s.cfg.ms_bytes,
                                              s.cfg.mps_per_ms))
                gfns.append(g)
            _gc.disable()              # keep collector pauses out of best-of
            try:
                t0 = _time.perf_counter()
                for g in gfns:
                    s.engine.swap_out_ms(g, batched=batched)
                t1 = _time.perf_counter()
                for g in gfns:
                    s.engine.swap_in_ms(g, batched=batched)
                t2 = _time.perf_counter()
            finally:
                _gc.enable()
            cur = (t1 - t0, t2 - t1)
            b = best[batched]
            best[batched] = cur if b is None else (min(b[0], cur[0]),
                                                   min(b[1], cur[1]))
            s.close()
    out = {}
    mps = n_ms * 64
    for batched in (False, True):
        key = "batched" if batched else "scalar"
        b = best[batched]
        out[f"{key}_out_mps_per_s"] = mps / b[0]
        out[f"{key}_in_mps_per_s"] = mps / b[1]
        out[f"{key}_pipeline_mps_per_s"] = 2 * mps / (b[0] + b[1])
    out["swap_out_speedup"] = (out["batched_out_mps_per_s"]
                               / out["scalar_out_mps_per_s"])
    out["swap_in_speedup"] = (out["batched_in_mps_per_s"]
                              / out["scalar_in_mps_per_s"])
    out["swap_pipeline_speedup"] = (out["batched_pipeline_mps_per_s"]
                                    / out["scalar_pipeline_mps_per_s"])
    if verbose:
        print(f"swap-out  {out['swap_out_speedup']:.2f}x  "
              f"({out['batched_out_mps_per_s']:.0f} vs "
              f"{out['scalar_out_mps_per_s']:.0f} MPs/s)")
        print(f"swap-in   {out['swap_in_speedup']:.2f}x  "
              f"({out['batched_in_mps_per_s']:.0f} vs "
              f"{out['scalar_in_mps_per_s']:.0f} MPs/s)")
        print(f"pipeline  {out['swap_pipeline_speedup']:.2f}x  (target >= 3x)")
    return out


def extent_sweep(smoke: bool = False, verbose: bool = True) -> list:
    """``BackendConfig.extent_max_rows`` sweep (ROADMAP follow-on).

    The extent cap trades worst-case fault latency (a fault into a wide
    extent decompresses more sibling rows) against compression ratio
    (wider extents share one zlib stream).  Same paper-mix workload per
    cap: fill, age + reclaim everything, then fault the whole set back
    sequentially so every extent is paid for exactly once.
    """
    out = []
    for cap in (4, 16, 64):
        cfg = small_test_config(
            ms_bytes=32 * 1024, mps_per_ms=32,
            n_phys_ms=12 if smoke else 20, mpool_reserve_ms=2,
            backend=BackendConfig(extent_max_rows=cap))
        s = TaijiSystem(cfg)
        space = s.guest
        fill_system(s, cfg.n_virt_ms - cfg.mpool_reserve_ms, seed=3)
        for _ in range(6 * cfg.lru.stabilize_scans):
            for w in range(cfg.lru.workers):
                s.lru.scan_shard(w, cfg.lru.workers)
        while s.engine.reclaim_round() > 0:
            pass
        s.metrics.sync()
        s.metrics.reset_fault_latency()
        for g in range(cfg.mpool_reserve_ms, cfg.n_virt_ms):
            req = s.reqs.lookup(g)
            if req is None:
                continue
            for mp in range(cfg.mps_per_ms):
                if req.record.is_swapped_out(mp):
                    space.read(g, 64, off=mp * cfg.mp_bytes)
        s.metrics.sync()
        snap = s.metrics.fault_latency.snapshot()
        ratio = s.metrics.compression_ratio()
        out.append({"extent_max_rows": cap, "faults": snap["count"],
                    "p50_us": snap["p50_us"], "p90_us": snap["p90_us"],
                    "compression_ratio": ratio,
                    "readahead_extents": s.metrics.readahead_extents})
        if verbose:
            print(f"extent_max_rows={cap:<3} p50={snap['p50_us']:.1f}us "
                  f"p90={snap['p90_us']:.1f}us comp_ratio={ratio:.3f}")
        s.close()
    return out


def slot_alloc_bench(verbose: bool = True, n: int = 20000) -> dict:
    """Slot-allocator microbenchmark (ISSUE 8): allocation cost on the
    sharded magazine allocator vs the legacy single-list path.

    Only the *alloc* side rides the fault budget (first-in allocation
    happens under the per-MS ``mp_mutex``; frees happen on the reclaim /
    teardown paths), so the headline number times alloc-until-empty
    phases only: the magazine path pays one shard lock per
    ``magazine_size`` allocations and pops lock-free in between, the
    legacy path pays the one global lock every time. The free side is
    reported separately in the result dict. Best of 3.
    """
    import time as _time
    from repro.core.config import HotPathConfig
    from repro.core.virt import PhysicalMemory

    out = {}
    for name, hp in (("magazine", HotPathConfig()),
                     ("legacy", HotPathConfig.legacy_scalar())):
        cfg = small_test_config(n_phys_ms=128, mpool_reserve_ms=2,
                                swap=SwapConfig(hot_path=hp))
        phys = PhysicalMemory(cfg)
        cap = phys.n_managed
        phases = max(1, n // cap)
        best_alloc = best_free = float("inf")
        for _ in range(3):
            alloc_ns = free_ns = 0
            ops = 0
            for _ in range(phases):
                got = []
                t0 = _time.perf_counter_ns()
                while True:
                    s = phys.try_alloc_slot()
                    if s is None:
                        break
                    got.append(s)
                alloc_ns += _time.perf_counter_ns() - t0
                ops += len(got)
                t0 = _time.perf_counter_ns()
                for s in got:
                    phys.free_slot(s)
                free_ns += _time.perf_counter_ns() - t0
            best_alloc = min(best_alloc, alloc_ns / ops / 1e3)
            best_free = min(best_free, free_ns / ops / 1e3)
        out[name + "_us"] = best_alloc
        out[name + "_free_us"] = best_free
    out["speedup"] = out["legacy_us"] / max(out["magazine_us"], 1e-12)
    if verbose:
        print(f"slot alloc: magazine {out['magazine_us']*1e3:.0f} ns/alloc "
              f"(free {out['magazine_free_us']*1e3:.0f} ns), "
              f"legacy {out['legacy_us']*1e3:.0f} ns/alloc "
              f"(free {out['legacy_free_us']*1e3:.0f} ns) "
              f"-> {out['speedup']:.2f}x")
    return out


def rows(smoke: bool = False) -> list:
    r = run(verbose=False, smoke=smoke)
    # A/B: the locked scalar reference path (no descriptor fast path, no
    # extent readahead) on a smaller fault budget
    ref = run(n_faults=200 if smoke else 1000, verbose=False, smoke=smoke,
              fast_path=False, readahead=False)
    t = swap_throughput(smoke=smoke, verbose=False)
    sweep = extent_sweep(smoke=smoke, verbose=False)
    sa = slot_alloc_bench(verbose=False, n=5000 if smoke else 20000)
    # per-kind rows come from the 3-window merged histograms (median-window
    # slices starve rare kinds down to n=2); rows under MIN_KIND_SAMPLES
    # are tagged UNSTABLE so nothing regress-tests against noise
    zero = r["by_kind_merged"]["zero"]
    comp = r["by_kind_merged"]["compressed"]
    ra = r["by_kind_merged"]["readahead"]

    def _n(ks):
        return (f"n={ks['count']}" if ks["count"] >= MIN_KIND_SAMPLES
                else f"UNSTABLE_n={ks['count']}")

    p90_speedup = ref["p90_us"] / r["p90_us"] if r["p90_us"] else 0.0
    return [
        ("fault_latency_p50", r["p50_us"], "paper_target<10us_p90"),
        ("fault_latency_p90", r["p90_us"], f"under10us={r['frac_under_10us']:.4f}"),
        ("fault_latency_p99", r["p99_us"], f"under15us={r['frac_under_15us']:.4f}"),
        ("fault_under_10us_frac", r["frac_under_10us"],
         "paper=0.9357_cluster"),
        ("fault_zero_p90_us", zero["p90_us"], _n(zero)),
        ("fault_compressed_p90_us", comp["p90_us"],
         f"{_n(comp)}_seeded={r['compressed_seeded']}"),
        # p50 in derived differentiates this order statistic from the
        # headline p99: both can select the same underlying sample on
        # small windows (e.g. both reporting 221.517us is two quantiles
        # of ~400 samples landing on one point, not object aliasing --
        # pinned by tests/test_obs.py)
        ("fault_readahead_p90_us", ra["p90_us"],
         f"{_n(ra)}_p50={ra['p50_us']:.1f}us_extents={r['readahead_extents']}"),
        ("fault_readahead_mps", r["readahead_mps"],
         f"faults_avoided_per_extent"),
        ("fault_scalar_ref_p90_us", ref["p90_us"],
         f"p50={ref['p50_us']:.1f}us_locked_path"),
        ("fault_p90_speedup", p90_speedup, "fast_vs_scalar_ref"),
        # sharded-magazine allocator vs the legacy single-lock free list
        # (us per alloc/free op, single-thread steady state)
        ("slot_alloc_us", sa["magazine_us"],
         f"legacy={sa['legacy_us']:.4f}us_speedup={sa['speedup']:.2f}x"),
        ("swap_out_batched_mps_per_s", t["batched_out_mps_per_s"],
         f"scalar={t['scalar_out_mps_per_s']:.0f}"),
        ("swap_in_batched_mps_per_s", t["batched_in_mps_per_s"],
         f"scalar={t['scalar_in_mps_per_s']:.0f}"),
        ("swap_out_speedup", t["swap_out_speedup"], "target>=3x"),
        ("swap_in_speedup", t["swap_in_speedup"], "zlib-bound_leg"),
        ("swap_pipeline_speedup", t["swap_pipeline_speedup"], "target>=3x"),
    ] + [
        (f"extent_rows{sw['extent_max_rows']}_fault_p90_us", sw["p90_us"],
         f"comp_ratio={sw['compression_ratio']:.4f}"
         f"_faults={sw['faults']}")
        for sw in sweep
    ]


if __name__ == "__main__":
    run()
    swap_throughput()
