"""Fault (passive swap-in) latency distribution -- paper Fig 14f / 15d.

Paper targets: P90 < 10 us; measured in production 92.51-95.50% under
10 us during high-load hot upgrades and 93.57% cluster-wide.

Methodology: fill an overcommitted system with the paper's page mix
(76.79% zero / 23.21% ~48%-compressible), let background reclaim swap the
cold set out, then touch swapped MPs one at a time through the guest read
path so each access takes exactly one EPT fault.
"""
from __future__ import annotations

import sys

import numpy as np

# cap GIL-wait for the latency-critical fault path (the BACK reclaim
# thread releases the GIL inside zlib, but Python-level sections would
# otherwise hold it for the default 5 ms switch interval)
sys.setswitchinterval(0.0005)

from repro.core.config import (LRUConfig, SchedulerConfig, TaijiConfig,
                               WatermarkConfig, small_test_config)
from repro.core.system import TaijiSystem

from .workload import fill_system, paper_mix_ms


def run(n_faults: int = 3000, verbose: bool = True, smoke: bool = False) -> dict:
    if smoke:
        n_faults = min(n_faults, 400)
    cfg = TaijiConfig(
        ms_bytes=(64 * 1024 if smoke else 256 * 1024),  # production: 4 KiB MPs
        mps_per_ms=64,
        n_phys_ms=24 if smoke else 48,
        overcommit_ratio=0.5,
        mpool_reserve_ms=4,
        lru=LRUConfig(scan_interval_s=0.001, workers=2, stabilize_scans=1),
        watermark=WatermarkConfig(high=0.25, low=0.15, min=0.04,
                                  reclaim_batch=8),
        scheduler=SchedulerConfig(cycle_ms=2.0, shards=2),
    )
    system = TaijiSystem(cfg)
    rng = np.random.default_rng(7)

    payload = fill_system(system, cfg.n_virt_ms - cfg.mpool_reserve_ms, seed=7)
    gfns = list(payload)

    # age + reclaim until the watermark is satisfied (background path)
    for _ in range(4):
        for w in range(cfg.lru.workers):
            system.lru.scan_shard(w, cfg.lru.workers)
    while system.engine.reclaim_round() > 0:
        pass

    # Fault swapped MPs with production-like locality: MS popularity is
    # Zipf-distributed and MP touches within an MS are sequential, so most
    # faults land on already-partial MSs (no slot allocation on the path).
    # On this single-core container, FRONT (faults) and BACK (lru scans +
    # reclaim) are time-multiplexed exactly as hv_sched does on a
    # saturated DPU: a burst of faults (timed), then a BACK slice
    # (untimed) that keeps free memory above the watermarks.
    ranks = np.arange(1, len(gfns) + 1, dtype=np.float64)
    pop = 1.0 / ranks ** 1.2
    pop /= pop.sum()
    cursor = {g: 0 for g in gfns}
    faulted = 0
    attempts = 0
    burst = 0
    while faulted < n_faults and attempts < n_faults * 50:
        attempts += 1
        g = gfns[int(rng.choice(len(gfns), p=pop))]
        req = system.reqs.lookup(g)
        if req is None:
            continue
        rec = req.record
        start = cursor[g]
        mp = next((m % cfg.mps_per_ms for m in range(start, start + cfg.mps_per_ms)
                   if rec.is_swapped_out(m % cfg.mps_per_ms)), None)
        if mp is None:
            continue
        cursor[g] = mp + 1
        before = system.metrics.faults
        system.read(system.ms_addr(g, mp=mp), 64)
        faulted += system.metrics.faults - before
        burst += 1
        if burst >= 32:                 # BACK slice: scans + reclaim
            burst = 0
            for w in range(cfg.lru.workers):
                system.lru.scan_shard(w, cfg.lru.workers)
            system.engine.reclaim_round()

    h = system.metrics.fault_latency
    snap = h.snapshot()
    result = {
        "faults": h.count,
        "p50_us": snap["p50_us"],
        "p90_us": snap["p90_us"],
        "p99_us": snap["p99_us"],
        "mean_us": snap["mean_us"],
        "frac_under_10us": h.fraction_below(10_000),
        "frac_under_15us": h.fraction_below(15_000),
        "zero_page_faults": system.metrics.fault_zero_pages,
        "compressed_faults": system.metrics.fault_compressed_pages,
    }
    if verbose:
        print(f"faults={result['faults']}  P50={result['p50_us']:.1f}us  "
              f"P90={result['p90_us']:.1f}us  P99={result['p99_us']:.1f}us")
        print(f"under 10us: {result['frac_under_10us']*100:.2f}%  "
              f"(paper: 93.57% cluster / >90% target)")
    system.close()
    return result


def swap_throughput(smoke: bool = False, verbose: bool = True) -> dict:
    """Batched-vs-scalar swap pipeline throughput on 64-MP MSs.

    The tentpole A/B: the same paper-mix working set is pushed through
    ``swap_out_ms``/``swap_in_ms`` with the scalar per-MP path and with
    the batched index-vector path (bulk ``store_batch``/``load_batch``,
    extent compression). Best-of-``reps`` wall clock per direction;
    throughput in MPs/s.
    """
    import time as _time

    import gc as _gc

    mp_bytes = 1024                    # per-call overhead dominated geometry
    n_ms = 12 if smoke else 16
    reps = 7
    best = {False: None, True: None}
    # interleave scalar/batched reps so machine-load drift hits both paths
    # equally; best-of-reps per direction filters the residual noise
    for _rep in range(reps):
        for batched in (False, True):
            s = TaijiSystem(small_test_config(
                ms_bytes=64 * mp_bytes, mps_per_ms=64,
                n_phys_ms=n_ms + 8, mpool_reserve_ms=4))
            rng = np.random.default_rng(9)
            gfns = []
            for _i in range(n_ms):
                g = s.guest_alloc_ms()
                s.write(s.ms_addr(g),
                        paper_mix_ms(rng, s.cfg.ms_bytes, s.cfg.mps_per_ms))
                gfns.append(g)
            _gc.disable()              # keep collector pauses out of best-of
            try:
                t0 = _time.perf_counter()
                for g in gfns:
                    s.engine.swap_out_ms(g, batched=batched)
                t1 = _time.perf_counter()
                for g in gfns:
                    s.engine.swap_in_ms(g, batched=batched)
                t2 = _time.perf_counter()
            finally:
                _gc.enable()
            cur = (t1 - t0, t2 - t1)
            b = best[batched]
            best[batched] = cur if b is None else (min(b[0], cur[0]),
                                                   min(b[1], cur[1]))
            s.close()
    out = {}
    mps = n_ms * 64
    for batched in (False, True):
        key = "batched" if batched else "scalar"
        b = best[batched]
        out[f"{key}_out_mps_per_s"] = mps / b[0]
        out[f"{key}_in_mps_per_s"] = mps / b[1]
        out[f"{key}_pipeline_mps_per_s"] = 2 * mps / (b[0] + b[1])
    out["swap_out_speedup"] = (out["batched_out_mps_per_s"]
                               / out["scalar_out_mps_per_s"])
    out["swap_in_speedup"] = (out["batched_in_mps_per_s"]
                              / out["scalar_in_mps_per_s"])
    out["swap_pipeline_speedup"] = (out["batched_pipeline_mps_per_s"]
                                    / out["scalar_pipeline_mps_per_s"])
    if verbose:
        print(f"swap-out  {out['swap_out_speedup']:.2f}x  "
              f"({out['batched_out_mps_per_s']:.0f} vs "
              f"{out['scalar_out_mps_per_s']:.0f} MPs/s)")
        print(f"swap-in   {out['swap_in_speedup']:.2f}x  "
              f"({out['batched_in_mps_per_s']:.0f} vs "
              f"{out['scalar_in_mps_per_s']:.0f} MPs/s)")
        print(f"pipeline  {out['swap_pipeline_speedup']:.2f}x  (target >= 3x)")
    return out


def rows(smoke: bool = False) -> list:
    r = run(verbose=False, smoke=smoke)
    t = swap_throughput(smoke=smoke, verbose=False)
    return [
        ("fault_latency_p50", r["p50_us"], "paper_target<10us_p90"),
        ("fault_latency_p90", r["p90_us"], f"under10us={r['frac_under_10us']:.4f}"),
        ("fault_latency_p99", r["p99_us"], f"under15us={r['frac_under_15us']:.4f}"),
        ("swap_out_batched_mps_per_s", t["batched_out_mps_per_s"],
         f"scalar={t['scalar_out_mps_per_s']:.0f}"),
        ("swap_in_batched_mps_per_s", t["batched_in_mps_per_s"],
         f"scalar={t['scalar_in_mps_per_s']:.0f}"),
        ("swap_out_speedup", t["swap_out_speedup"], "target>=3x"),
        ("swap_in_speedup", t["swap_in_speedup"], "zlib-bound_leg"),
        ("swap_pipeline_speedup", t["swap_pipeline_speedup"], "target>=3x"),
    ]


if __name__ == "__main__":
    run()
    swap_throughput()
