"""Fault (passive swap-in) latency distribution -- paper Fig 14f / 15d.

Paper targets: P90 < 10 us; measured in production 92.51-95.50% under
10 us during high-load hot upgrades and 93.57% cluster-wide.

Methodology: fill an overcommitted system with the paper's page mix
(76.79% zero / 23.21% ~48%-compressible), let background reclaim swap the
cold set out, then touch swapped MPs one at a time through the guest read
path so each access takes exactly one EPT fault.
"""
from __future__ import annotations

import sys

import numpy as np

# cap GIL-wait for the latency-critical fault path (the BACK reclaim
# thread releases the GIL inside zlib, but Python-level sections would
# otherwise hold it for the default 5 ms switch interval)
sys.setswitchinterval(0.0005)

from repro.core.config import LRUConfig, SchedulerConfig, TaijiConfig, WatermarkConfig
from repro.core.system import TaijiSystem

from .workload import fill_system


def run(n_faults: int = 3000, verbose: bool = True) -> dict:
    cfg = TaijiConfig(
        ms_bytes=256 * 1024,          # production-shaped: 4 KiB MPs
        mps_per_ms=64,
        n_phys_ms=48,
        overcommit_ratio=0.5,
        mpool_reserve_ms=4,
        lru=LRUConfig(scan_interval_s=0.001, workers=2, stabilize_scans=1),
        watermark=WatermarkConfig(high=0.25, low=0.15, min=0.04,
                                  reclaim_batch=8),
        scheduler=SchedulerConfig(cycle_ms=2.0, shards=2),
    )
    system = TaijiSystem(cfg)
    rng = np.random.default_rng(7)

    payload = fill_system(system, cfg.n_virt_ms - cfg.mpool_reserve_ms, seed=7)
    gfns = list(payload)

    # age + reclaim until the watermark is satisfied (background path)
    for _ in range(4):
        for w in range(cfg.lru.workers):
            system.lru.scan_shard(w, cfg.lru.workers)
    while system.engine.reclaim_round() > 0:
        pass

    # Fault swapped MPs with production-like locality: MS popularity is
    # Zipf-distributed and MP touches within an MS are sequential, so most
    # faults land on already-partial MSs (no slot allocation on the path).
    # On this single-core container, FRONT (faults) and BACK (lru scans +
    # reclaim) are time-multiplexed exactly as hv_sched does on a
    # saturated DPU: a burst of faults (timed), then a BACK slice
    # (untimed) that keeps free memory above the watermarks.
    ranks = np.arange(1, len(gfns) + 1, dtype=np.float64)
    pop = 1.0 / ranks ** 1.2
    pop /= pop.sum()
    cursor = {g: 0 for g in gfns}
    faulted = 0
    attempts = 0
    burst = 0
    while faulted < n_faults and attempts < n_faults * 50:
        attempts += 1
        g = gfns[int(rng.choice(len(gfns), p=pop))]
        req = system.reqs.lookup(g)
        if req is None:
            continue
        rec = req.record
        start = cursor[g]
        mp = next((m % cfg.mps_per_ms for m in range(start, start + cfg.mps_per_ms)
                   if rec.is_swapped_out(m % cfg.mps_per_ms)), None)
        if mp is None:
            continue
        cursor[g] = mp + 1
        before = system.metrics.faults
        system.read(system.ms_addr(g, mp=mp), 64)
        faulted += system.metrics.faults - before
        burst += 1
        if burst >= 32:                 # BACK slice: scans + reclaim
            burst = 0
            for w in range(cfg.lru.workers):
                system.lru.scan_shard(w, cfg.lru.workers)
            system.engine.reclaim_round()

    h = system.metrics.fault_latency
    snap = h.snapshot()
    result = {
        "faults": h.count,
        "p50_us": snap["p50_us"],
        "p90_us": snap["p90_us"],
        "p99_us": snap["p99_us"],
        "mean_us": snap["mean_us"],
        "frac_under_10us": h.fraction_below(10_000),
        "frac_under_15us": h.fraction_below(15_000),
        "zero_page_faults": system.metrics.fault_zero_pages,
        "compressed_faults": system.metrics.fault_compressed_pages,
    }
    if verbose:
        print(f"faults={result['faults']}  P50={result['p50_us']:.1f}us  "
              f"P90={result['p90_us']:.1f}us  P99={result['p99_us']:.1f}us")
        print(f"under 10us: {result['frac_under_10us']*100:.2f}%  "
              f"(paper: 93.57% cluster / >90% target)")
    system.close()
    return result


def rows() -> list:
    r = run(verbose=False)
    return [
        ("fault_latency_p50", r["p50_us"], "paper_target<10us_p90"),
        ("fault_latency_p90", r["p90_us"], f"under10us={r['frac_under_10us']:.4f}"),
        ("fault_latency_p99", r["p99_us"], f"under15us={r['frac_under_15us']:.4f}"),
    ]


if __name__ == "__main__":
    run()
