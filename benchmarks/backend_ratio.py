"""Backend composition + compression ratio -- paper Fig 15c.

Paper: 76.79% zero pages / 23.21% compressed, 47.63% compression ratio,
swapped pages stored in 1.73 GB for 15.6 GB freed.
"""
from __future__ import annotations

from repro.core.config import LRUConfig, TaijiConfig
from repro.core.system import TaijiSystem

from .workload import fill_system


def run(verbose: bool = True) -> dict:
    cfg = TaijiConfig(ms_bytes=128 * 1024, mps_per_ms=32, n_phys_ms=40,
                      overcommit_ratio=0.5, mpool_reserve_ms=4,
                      lru=LRUConfig(stabilize_scans=1, workers=1))
    system = TaijiSystem(cfg)
    fill_system(system, cfg.n_virt_ms - cfg.mpool_reserve_ms, seed=11)
    # swap everything out to measure the full backend composition
    for _ in range(4):
        system.lru.scan_shard(0, 1)
    for gfn in list(system.lru.pick_coldest_any(10_000)):
        try:
            system.engine.swap_out_ms(gfn)
        except Exception:
            pass
    m = system.metrics
    total = m.backend_zero_mps + m.backend_compressed_mps
    result = {
        "zero_fraction": m.backend_zero_mps / max(1, total),
        "compressed_fraction": m.backend_compressed_mps / max(1, total),
        "compression_ratio": m.compression_ratio(),
        "raw_bytes": m.backend_raw_bytes,
        "stored_bytes": m.backend_stored_bytes,
    }
    if verbose:
        print(f"zero={result['zero_fraction']*100:.2f}% (paper 76.79%)  "
              f"compressed={result['compressed_fraction']*100:.2f}% (paper 23.21%)")
        print(f"compression ratio={result['compression_ratio']*100:.2f}% "
              f"(paper 47.63%)")
    system.close()
    return result


def rows() -> list:
    r = run(verbose=False)
    return [
        ("backend_zero_fraction", r["zero_fraction"], "paper=0.7679"),
        ("backend_compression_ratio", r["compression_ratio"], "paper=0.4763"),
    ]


if __name__ == "__main__":
    run()
