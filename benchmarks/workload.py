"""Shared synthetic workload matching the paper's production page mix.

Paper Fig 15c: of all swapped MPs, 76.79% are zero pages and 23.21%
compressed with an average compression ratio of 47.63%. The generator
reproduces that mix so backend/latency benchmarks measure the same
distribution the paper reports.
"""
from __future__ import annotations

import numpy as np

ZERO_FRACTION = 0.7679
COMPRESS_TARGET = 0.4763


def paper_mix_ms(rng: np.random.Generator, ms_bytes: int,
                 mps_per_ms: int) -> bytes:
    """One MS worth of data with the paper's per-MP mix."""
    mp = ms_bytes // mps_per_ms
    out = bytearray()
    for _ in range(mps_per_ms):
        if rng.random() < ZERO_FRACTION:
            out += bytes(mp)
        else:
            # ~50%-compressible page: half structured, half random
            structured = np.full(mp // 2, rng.integers(0, 256), np.uint8)
            noise = rng.integers(0, 256, mp - mp // 2).astype(np.uint8)
            page = np.concatenate([structured, noise])
            rng.shuffle(page.reshape(-1, 16))        # mix at 16B granularity
            out += page.tobytes()
    return bytes(out)


def fill_system(system, n_ms: int, seed: int = 0):
    """Allocate + fill ``n_ms`` sections with paper-mix data.

    Returns {gfn: data} for later verification."""
    rng = np.random.default_rng(seed)
    space = system.guest
    payload = {}
    for _ in range(n_ms):
        g = space.alloc_ms()
        data = paper_mix_ms(rng, system.cfg.ms_bytes, system.cfg.mps_per_ms)
        space.write(g, data)
        payload[g] = data
    return payload
