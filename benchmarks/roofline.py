"""Roofline table from the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh): the three terms in seconds, the dominant term,
MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) vs loop-aware HLO FLOPs,
and the per-cell bottleneck note. Reads artifacts/dryrun/*.json.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from repro.configs import SHAPES, all_cells

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

_NOTES = {
    "compute_s": "raise arithmetic intensity / remove replicated compute",
    "memory_s": "fuse elementwise chains; cut activation traffic (kernels)",
    "collective_s": "re-shard to localize gathers; batch/overlap collectives",
}


def model_flops(rec: dict, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    if sh.kind == "decode":
        tokens = sh.global_batch                 # one token per sequence
    else:
        tokens = sh.global_batch * sh.seq_len
    n = rec["active_params"]
    mult = 6.0 if sh.kind == "train" else 2.0
    return mult * n * tokens


def rows_for_mesh(mesh: str):
    out = []
    for f in sorted(glob.glob(str(ART / f"*__{mesh}.json"))):
        d = json.load(open(f))
        la, r = d["loop_aware"], d["roofline"]
        mf = model_flops(d, d["shape"])
        hlo_total = la["flops_per_device"] * d["n_devices"]
        out.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": mesh,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "roofline_fraction": r["roofline_fraction"],
            "model_flops": mf,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "note": _NOTES[r["dominant"]],
        })
    return out


_HILLCLIMB = [
    ("qwen3-4b", "decode_32k", "perseq"),
    ("qwen3-moe-235b-a22b", "train_4k", "groupedmoe"),
    ("qwen2.5-32b", "train_4k", "mesh32x8"),
]


def hillclimb_rows():
    """Before/after for the three §Perf cells (EXPERIMENTS.md)."""
    out = []
    for arch, shape, variant in _HILLCLIMB:
        base = ART / f"{arch}__{shape}__pod16x16.json"
        opt = ART / f"{arch}__{shape}__pod16x16__{variant}.json"
        if not (base.exists() and opt.exists()):
            continue
        b = json.load(open(base))["roofline"]
        o = json.load(open(opt))["roofline"]
        out.append((arch, shape, variant, b, o))
    return out


def run(verbose: bool = True):
    table = rows_for_mesh("pod16x16")
    if verbose:
        hdr = (f"{'arch':22s} {'shape':12s} {'comp_s':>8s} {'mem_s':>8s} "
               f"{'coll_s':>8s} {'dominant':>12s} {'frac':>6s} {'useful':>7s}")
        print(hdr)
        print("-" * len(hdr))
        for r in table:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:8.3f} "
                  f"{r['memory_s']:8.3f} {r['collective_s']:8.3f} "
                  f"{r['dominant']:>12s} {r['roofline_fraction']:6.3f} "
                  f"{r['useful_ratio']:7.3f}")
        skipped = [(a, s, why) for a, s, why in all_cells() if why]
        print(f"\nskipped cells ({len(skipped)}):")
        for a, s, why in skipped:
            print(f"  {a} x {s}: {why}")
        hc = hillclimb_rows()
        if hc:
            print("\n§Perf hillclimb cells (baseline -> optimized, seconds):")
            for arch, shape, variant, b, o in hc:
                print(f"  {arch} x {shape} [{variant}]")
                for term in ("compute_s", "memory_s", "collective_s"):
                    print(f"    {term:13s} {b[term]:9.3f} -> {o[term]:9.3f}")
                print(f"    fraction      {b['roofline_fraction']:9.3f} -> "
                      f"{o['roofline_fraction']:9.3f}")
    return table


def rows() -> list:
    table = rows_for_mesh("pod16x16")
    return [(f"roofline_{r['arch']}_{r['shape']}", r["roofline_fraction"],
             f"dom={r['dominant']},useful={r['useful_ratio']:.3f}")
            for r in table]


if __name__ == "__main__":
    run()
