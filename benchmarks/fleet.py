"""Fleet control plane: trace-driven multi-node replay + chaos (ISSUE 2/4).

Two scenarios, both through the replay-equivalence harness
(``repro.fleet.harness``: run-twice-compare with a first-divergence
report):

  * **replay** -- a seeded >=2k-op trace through a 4-node fleet (two
    failure domains): FRONT fill past the fleet admission cap, BACK aging
    with staggered reclaim windows, a Zipf fault burst, churn, and one
    full rolling hot-upgrade. Reports fleet-wide swap-in (fault-path)
    latency percentiles against the paper's 10 us P90 claim.
  * **chaos** -- a seeded failure schedule layered on the same workload:
    live MS migrations under load, node kills (drained and hard),
    controller failure recovery, and node recoveries. The determinism
    contract must hold across chaos too; the CI canary gates on both
    ``fleet_replay_deterministic`` and ``fleet_chaos_deterministic``.
  * **capture** (ISSUE 5) -- traces captured from the *real*
    elastic_kv serving loop and elastic_params expert churn through the
    instrumented GuestSpace surface, replayed twice on a 2-node fleet:
    the application's actual bytes are rewritten (``wdata``) and every
    read content-verified (``rdata``). CI gates on
    ``fleet_capture_deterministic == 1.0`` and
    ``capture_verify_failures == 0``.
"""
from __future__ import annotations

from repro.core.backend import modeled_policy_ns
from repro.core.config import ObsConfig, small_test_config
from repro.fleet import (REJECT_OVERCOMMIT, capture_expert_churn,
                         capture_kv_serving, chaos_trace, paper_trace)
from repro.fleet.harness import build_fleet, replay_twice
from repro.obs import export_chrome, stage_tree

# self-time attribution of the fleet fault path (fleet_swapin_stage_*
# rows): (row suffix, stage name). The eight stages partition fault_total
# exactly (fault_total's own self-time is the "other" bucket; fault_alloc
# is the first-in slot-allocation child carved out of fault_desc), so a
# naive sum over the rows reproduces the fleet's mean fault latency.
_FAULT_STAGES = (
    ("mutex", "fault_mutex"),
    ("desc", "fault_desc"),
    ("alloc", "fault_alloc"),
    ("copy", "fault_copy"),
    ("backend", "fault_backend"),
    ("readahead", "fault_readahead"),
    ("decode", "readahead_decode"),
    ("other", "fault_total"),
)


def run(smoke: bool = False, verbose: bool = True,
        trace_out: str = None) -> dict:
    n_nodes = 4
    obs = ObsConfig(enabled=True)
    cfg = small_test_config(obs=obs) if smoke else small_test_config(
        ms_bytes=64 * 1024, mps_per_ms=16, n_phys_ms=32, obs=obs)
    gen = paper_trace(7, cfg.ms_bytes, cfg.mps_per_ms,
                      fill_ms=int(n_nodes * (cfg.n_phys_ms
                                             - cfg.mpool_reserve_ms) * 1.35),
                      burst=600 if smoke else 2000,
                      churn_frees=20)

    # capture the fleets the harness builds: tracer aggregates are plain
    # numpy arrays, so they survive the harness's fleet.close()
    fleets = []

    def make_fleet():
        fleet = build_fleet(n_nodes, 2, cfg)
        fleets.append(fleet)
        return fleet

    eq = replay_twice(gen.lines(), make_fleet=make_fleet)
    det = eq.runs[0].deterministic
    lat = eq.runs[0].result["latency"]

    # stage attribution from the FIRST replay's tracers (the same run the
    # latency snapshot above describes)
    tracers = [tr for n in fleets[0].nodes
               if (tr := n.system.metrics.tracer) is not None]
    if fleets[0].tracer is not None:
        tracers.append(fleets[0].tracer)
    tree = stage_tree(tracers)
    n_faults = max(1, int(lat["fault"]["count"]))
    stage_us = {}
    for suffix, stage in _FAULT_STAGES:
        node = tree.get(stage)
        stage_us[suffix] = (node["self_ns"] / 1e3 / n_faults
                            if node is not None else 0.0)
    fault_total_ns = (tree["fault_total"]["total_ns"]
                      if "fault_total" in tree else 0)
    trace_events = 0
    if trace_out:
        trace_events = export_chrome(trace_out, tracers)

    out = {
        "n_nodes": n_nodes,
        "trace_ops": gen.n_ops,
        "deterministic": 1.0 if eq.identical else 0.0,
        "divergence": eq.divergence or "",
        "admitted": det["admitted"],
        "rejected_overcommit": det["rejections"][REJECT_OVERCOMMIT],
        "reclaimed_mps": det["reclaimed_mps"],
        "upgrade_batches_done": det["upgrade_batches_done"],
        "upgrade_aborted": det["upgrade_aborted"],
        "verify_failures": det["replay"]["verify_failures"],
        "faults": lat["fault"]["count"],
        "swap_in_p50_us": lat["fault"]["p50_us"],
        "swap_in_p90_us": lat["fault"]["p90_us"],
        "swap_in_p99_us": lat["fault"]["p99_us"],
        "frac_under_10us": lat["frac_fault_under_10us"],
        "stage_us": stage_us,
        "fault_mean_us": fault_total_ns / 1e3 / n_faults,
        "trace_events": trace_events,
    }
    if verbose:
        print(f"{n_nodes} nodes, {out['trace_ops']} trace ops: "
              f"admitted={out['admitted']} "
              f"rejected={out['rejected_overcommit']} "
              f"reclaimed={out['reclaimed_mps']} MPs, "
              f"upgrade batches={out['upgrade_batches_done']}")
        print(f"fleet swap-in P50={out['swap_in_p50_us']:.1f}us "
              f"P90={out['swap_in_p90_us']:.1f}us "
              f"(paper target: P90 < 10us on DPU hardware)  "
              f"deterministic={bool(out['deterministic'])}")
        budget = " ".join(f"{k}={v:.2f}us" for k, v in stage_us.items())
        print(f"fault-path budget (self-time/fault, "
              f"mean={out['fault_mean_us']:.2f}us): {budget}")
        if trace_out:
            print(f"wrote {trace_events} Chrome trace events to {trace_out}")
        if eq.divergence:
            print(f"DIVERGENCE: {eq.divergence}")
    return out


def run_chaos(smoke: bool = False, verbose: bool = True) -> dict:
    """Seeded chaos scenario: the determinism bit must survive kills,
    recoveries and live migrations (the failure schedule is part of the
    trace, so two replays see identical failures)."""
    n_nodes = 4
    cfg = small_test_config()
    managed = n_nodes * (cfg.n_phys_ms - cfg.mpool_reserve_ms)
    gen = chaos_trace(13, cfg.ms_bytes, cfg.mps_per_ms, n_nodes,
                      fill_ms=int(managed * 1.1),
                      burst=400 if smoke else 1500,
                      kills=2, migrations=3)

    eq = replay_twice(gen.lines(), n_nodes=n_nodes, domains=2, cfg=cfg)
    det = eq.runs[0].deterministic
    c = det["replay"]

    out = {
        "trace_ops": gen.n_ops,
        "deterministic": 1.0 if eq.identical else 0.0,
        "divergence": eq.divergence or "",
        "kills": c["kills"],
        "recovers": c["recovers"],
        "migrations": det["migrations"],
        "migration_mps": det["migration_mps"],
        "ms_replaced": det["ms_replaced"],
        "ms_lost": det["ms_lost"],
        "verify_failures": c["verify_failures"],
        # remote-peer tier (ISSUE 9): lease lifecycle counters from the
        # controller snapshot -- these are inside the deterministic dict,
        # so the replay-twice equality above already pins them
        "remote_puts": det["remote_puts"],
        "remote_recovered": det["remote_recovered"],
        "remote_rereplicated": det["remote_rereplicated"],
        "remote_dropped": det["remote_dropped"],
        "remote_evicted": det["remote_evicted"],
        "remote_held": det["remote_held"],
        "remote_modeled_ns": det["remote_modeled_ns"],
    }
    if verbose:
        print(f"chaos: {out['trace_ops']} ops, kills={out['kills']} "
              f"recovers={out['recovers']} migrations={out['migrations']} "
              f"replaced={out['ms_replaced']} lost={out['ms_lost']} "
              f"deterministic={bool(out['deterministic'])}")
        print(f"remote tier: puts={out['remote_puts']} "
              f"recovered={out['remote_recovered']} "
              f"rereplicated={out['remote_rereplicated']} "
              f"dropped={out['remote_dropped']} "
              f"evicted={out['remote_evicted']} held={out['remote_held']}")
        if eq.divergence:
            print(f"DIVERGENCE: {eq.divergence}")
    return out


def run_capture(smoke: bool = False, verbose: bool = True) -> dict:
    """Capture real integration workloads, replay each twice on a 2-node
    fleet: both must be byte-identical with zero content-verify misses."""
    out = {"deterministic": 1.0, "verify_failures": 0,
           "payload_writes": 0, "payload_reads": 0, "trace_ops": 0}
    for cap in (capture_kv_serving(smoke=smoke),
                capture_expert_churn(smoke=smoke)):
        # pressure-matched replay nodes (see CapturedTrace.fleet_cfg): the
        # 2-node fleet is as overcommitted as the capture node was
        eq = replay_twice(cap.lines, n_nodes=2, domains=2, cfg=cap.fleet_cfg)
        c = eq.runs[0].counters
        out[f"{cap.name}_ops"] = cap.n_ops
        out[f"{cap.name}_deterministic"] = 1.0 if eq.identical else 0.0
        out["trace_ops"] += cap.n_ops
        out["payload_writes"] += c["payload_writes"]
        out["payload_reads"] += c["payload_reads"]
        out["verify_failures"] += c["verify_failures"]
        if not eq.identical:
            out["deterministic"] = 0.0
            out["divergence"] = f"{cap.name}: {eq.divergence}"
        if verbose:
            print(f"capture {cap.name}: {cap.n_ops} ops "
                  f"(w={c['payload_writes']} r={c['payload_reads']}) "
                  f"deterministic={eq.identical} "
                  f"verify_failures={c['verify_failures']}")
    return out


def _policy_rows(ch: dict) -> list:
    """Fast/Slow/Smart placement rows over the chaos run's replicated
    population (``remote_puts`` MS images). Pure data-not-measurement
    (``modeled_policy_ns``): Fast pretends every image stayed in local
    compressed DRAM (cheap, zero durability), Slow pushes every load
    over the peer fabric, Smart is the deployed split -- only the
    ``remote_recovered`` images (dead-owner rebuilds) actually paid the
    peer-fetch RTT."""
    total = ch["remote_puts"]
    n_remote = ch["remote_recovered"]
    n_local = max(0, total - n_remote)
    return [
        (f"fleet_remote_policy_{policy}_us",
         modeled_policy_ns(*split, policy) / 1e3,
         f"images={total}_remote_reads={n_remote}")
        for policy, split in (("fast", (total, 0)),
                              ("slow", (0, total)),
                              ("smart", (n_local, n_remote)))]


def rows(smoke: bool = False, trace_out: str = None) -> list:
    r = run(smoke=smoke, verbose=False, trace_out=trace_out)
    ch = run_chaos(smoke=smoke, verbose=False)
    cap = run_capture(smoke=smoke, verbose=False)
    total_us = sum(r["stage_us"].values())
    stage_rows = [
        (f"fleet_swapin_stage_{suffix}_us", r["stage_us"][suffix],
         f"share={r['stage_us'][suffix] / max(1e-12, total_us):.3f}_"
         f"of_mean={r['fault_mean_us']:.2f}us")
        for suffix, _ in _FAULT_STAGES]
    return [
        ("fleet_trace_ops", r["trace_ops"], f"nodes={r['n_nodes']}"),
        ("fleet_replay_deterministic", r["deterministic"],
         "byte-identical_snapshots"),
        ("fleet_admission_rejects", r["rejected_overcommit"],
         f"admitted={r['admitted']}"),
        ("fleet_reclaimed_mps", r["reclaimed_mps"], "staggered_windows"),
        ("fleet_upgrade_batches", r["upgrade_batches_done"],
         f"aborted={r['upgrade_aborted']}"),
        ("fleet_swap_in_p50_us", r["swap_in_p50_us"],
         f"faults={r['faults']}"),
        ("fleet_swap_in_p90_us", r["swap_in_p90_us"],
         f"under10us={r['frac_under_10us']:.4f}"),
        # stage-attributed fault-path budget (repro.obs): per-fault
        # self-time of each stage; the seven rows partition the fleet's
        # mean fault latency exactly, so their naive sum == the mean
        *stage_rows,
        ("fleet_fault_mean_us", r["fault_mean_us"],
         f"stage_sum={total_us:.2f}us"),
        ("fleet_verify_failures", r["verify_failures"], "target=0"),
        ("fleet_chaos_deterministic", ch["deterministic"],
         f"kills={ch['kills']}_migrations={ch['migrations']}"),
        ("fleet_chaos_kills", ch["kills"],
         f"recovers={ch['recovers']}"),
        ("fleet_chaos_migrations", ch["migrations"],
         f"replaced={ch['ms_replaced']}_lost={ch['ms_lost']}"),
        # lost MSs leave the read-verify written-set (a lost token has
        # nothing left to verify), so verify_failures alone cannot see
        # data loss: the loss count is its own gated row
        ("fleet_chaos_ms_lost", ch["ms_lost"],
         f"replaced={ch['ms_replaced']}"),
        ("fleet_chaos_verify_failures", ch["verify_failures"], "target=0"),
        # remote-peer swap tier (ISSUE 9): lease-brokered replication of
        # fully-swapped MSs onto peers. `recovered` is the payoff row --
        # dead-owner MSs rebuilt byte-identical from peer replicas
        # instead of being counted lost
        ("fleet_remote_puts", ch["remote_puts"],
         f"dropped={ch['remote_dropped']}_evicted={ch['remote_evicted']}"),
        ("fleet_remote_recovered", ch["remote_recovered"],
         f"rereplicated={ch['remote_rereplicated']}_"
         f"held={ch['remote_held']}"),
        ("fleet_remote_modeled_ms", ch["remote_modeled_ns"] / 1e6,
         "declared_tier_latency_accrual"),
        # modeled placement-policy comparison (flatmem's Fast/Slow/Smart
        # trio over declared tier latencies): one sweep of the chaos
        # run's replicated population under each policy. Smart charges
        # remote RTT only to the MSs that actually needed a peer fetch.
        *_policy_rows(ch),
        # captured serving workloads (ISSUE 5): real elastic_kv /
        # elastic_params traffic recorded at the GuestSpace layer and
        # replayed on a 2-node fleet with content verification
        ("fleet_capture_trace_ops", cap["trace_ops"],
         f"kv={cap['kv_serving_ops']}_expert={cap['expert_churn_ops']}"),
        ("fleet_capture_deterministic", cap["deterministic"],
         "kv+expert_byte-identical"),
        ("fleet_capture_payload_ops",
         cap["payload_writes"] + cap["payload_reads"],
         f"writes={cap['payload_writes']}_reads={cap['payload_reads']}"),
        ("capture_verify_failures", cap["verify_failures"], "target=0"),
    ]


if __name__ == "__main__":
    run()
    run_chaos()
    run_capture()
