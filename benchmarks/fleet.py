"""Fleet control plane: trace-driven multi-node replay (ISSUE 2).

Drives a seeded >=2k-op trace through a 4-node fleet (two failure
domains): FRONT fill past the fleet admission cap, BACK aging with
staggered reclaim windows, a Zipf fault burst, churn, and one full
rolling hot-upgrade. Reports fleet-wide swap-in (fault-path) latency
percentiles against the paper's 10 us P90 claim, plus a determinism bit:
the same trace replayed twice must produce byte-identical deterministic
snapshots (the CI canary gates on it).
"""
from __future__ import annotations

import json

from repro.core.config import small_test_config
from repro.fleet import (REJECT_OVERCOMMIT, FleetConfig, FleetController,
                         NodeAgent, TraceReplayer, paper_trace)


def _build_fleet(n_nodes: int, cfg) -> FleetController:
    nodes = [NodeAgent(i, cfg, failure_domain=i % 2) for i in range(n_nodes)]
    return FleetController(nodes, FleetConfig())


def run(smoke: bool = False, verbose: bool = True) -> dict:
    n_nodes = 4
    cfg = small_test_config() if smoke else small_test_config(
        ms_bytes=64 * 1024, mps_per_ms=16, n_phys_ms=32)
    gen = paper_trace(7, cfg.ms_bytes, cfg.mps_per_ms,
                      fill_ms=int(n_nodes * (cfg.n_phys_ms
                                             - cfg.mpool_reserve_ms) * 1.35),
                      burst=600 if smoke else 2000,
                      churn_frees=20)
    lines = gen.lines()

    results = []
    for _rep in range(2):                    # two runs: the determinism bit
        fleet = _build_fleet(n_nodes, cfg)
        rep = TraceReplayer(fleet, lines)
        res = rep.run()
        results.append((rep.deterministic_bytes(), res))
        fleet.close()
    (b1, res), (b2, _) = results
    det = json.loads(b1.decode())
    lat = res["latency"]

    out = {
        "n_nodes": n_nodes,
        "trace_ops": gen.n_ops,
        "deterministic": 1.0 if b1 == b2 else 0.0,
        "admitted": det["admitted"],
        "rejected_overcommit": det["rejections"][REJECT_OVERCOMMIT],
        "reclaimed_mps": det["reclaimed_mps"],
        "upgrade_batches_done": det["upgrade_batches_done"],
        "upgrade_aborted": det["upgrade_aborted"],
        "verify_failures": det["replay"]["verify_failures"],
        "faults": lat["fault"]["count"],
        "swap_in_p50_us": lat["fault"]["p50_us"],
        "swap_in_p90_us": lat["fault"]["p90_us"],
        "swap_in_p99_us": lat["fault"]["p99_us"],
        "frac_under_10us": lat["frac_fault_under_10us"],
    }
    if verbose:
        print(f"{n_nodes} nodes, {out['trace_ops']} trace ops: "
              f"admitted={out['admitted']} "
              f"rejected={out['rejected_overcommit']} "
              f"reclaimed={out['reclaimed_mps']} MPs, "
              f"upgrade batches={out['upgrade_batches_done']}")
        print(f"fleet swap-in P50={out['swap_in_p50_us']:.1f}us "
              f"P90={out['swap_in_p90_us']:.1f}us "
              f"(paper target: P90 < 10us on DPU hardware)  "
              f"deterministic={bool(out['deterministic'])}")
    return out


def rows(smoke: bool = False) -> list:
    r = run(smoke=smoke, verbose=False)
    return [
        ("fleet_trace_ops", r["trace_ops"], f"nodes={r['n_nodes']}"),
        ("fleet_replay_deterministic", r["deterministic"],
         "byte-identical_snapshots"),
        ("fleet_admission_rejects", r["rejected_overcommit"],
         f"admitted={r['admitted']}"),
        ("fleet_reclaimed_mps", r["reclaimed_mps"], "staggered_windows"),
        ("fleet_upgrade_batches", r["upgrade_batches_done"],
         f"aborted={r['upgrade_aborted']}"),
        ("fleet_swap_in_p50_us", r["swap_in_p50_us"],
         f"faults={r['faults']}"),
        ("fleet_swap_in_p90_us", r["swap_in_p90_us"],
         f"under10us={r['frac_under_10us']:.4f}"),
        ("fleet_verify_failures", r["verify_failures"], "target=0"),
    ]


if __name__ == "__main__":
    run()
